"""Solve-cluster benchmark: replay the same seeded **skewed** trace
(one hot graph dominating, Zipf-like choice) through a fresh
:class:`repro.serve.SolveCluster` per routing policy — ``affinity``,
``p2c``, ``rr`` — and record the affinity-hit rate, routing counters and
end-to-end latency percentiles per policy.

The CI ``bench-cluster`` job runs

    PYTHONPATH=src python -m benchmarks.bench_cluster --json \
        BENCH_cluster.json

uploads the JSON as an artifact, and gates merges with
``benchmarks.check_cluster_regression``: request conservation across
replicas (every routed request lands on exactly one replica and
resolves), ``factor_affinity`` achieving a **strictly higher**
affinity-hit rate than ``round_robin`` on the skewed trace, and —
when ``--replicate-above`` is active, as it is in CI — the hot graph
actually being promoted onto a second replica (``replications >= 1``
for the affinity run).

The trace is closed-loop (all requests arrive at t=0) by default, and
the replication rate window is minutes wide (``--rate-window-s``, vs a
serving-scale window in production) so the whole burst lands inside one
window whatever the machine speed — the hot graph's ~24 arrivals clear
the ``0.02 req/s x 600 s = 12``-arrival bar with 2x margin, making the
replication gate deterministic rather than wall-clock-paced.
``--arrival-rate`` switches to open-loop seeded-Poisson arrivals.
"""
from __future__ import annotations

import argparse
import json

from repro.launch.cluster import run_cluster, run_factor_storm

from .common import emit

POLICIES = ("affinity", "p2c", "rr")


def run_storm(*, replicas=2, storm_graphs=4, warm_dt_s=0.25, seed=0,
              metrics=None, flight=None):
    """Factor-storm comparison: the same cold-burst-over-warm-stream
    workload, colocated (``factor_replicas=0``) vs disaggregated
    (``factor_replicas=1``).  The gate
    (``check_cluster_regression``) requires the disaggregated run to
    strictly beat colocated on warm-request e2e p95 **and** on
    solve-driver ``control_s`` — construction seconds off the serving
    drivers, not merely moved around.  Each mode's overload-detector
    snapshot rides along in its ``overload`` key."""
    out = {}
    for mode, k in (("colocated", 0), ("disaggregated", 1)):
        m = run_factor_storm(replicas=replicas, factor_replicas=k,
                             storm_graphs=storm_graphs,
                             warm_dt_s=warm_dt_s, seed=seed,
                             metrics=metrics, flight=flight)
        out[mode] = m
        ov = m.get("overload") or {}
        emit(f"cluster/storm/{mode}/warm_p95_us", m["warm_p95_s"] * 1e6,
             f"p50_us={m['warm_p50_s']*1e6:.0f};"
             f"warm={m['warm_requests']};storm_s={m['storm_s']:.1f};"
             f"control_s={m['solve_control_s']:.1f};"
             f"overload_transitions={ov.get('transitions', 0)}")
    emit("cluster/storm/p95_speedup",
         out["colocated"]["warm_p95_s"]
         / max(out["disaggregated"]["warm_p95_s"], 1e-9),
         f"colocated={out['colocated']['warm_p95_s']*1e3:.0f}ms;"
         f"disagg={out['disaggregated']['warm_p95_s']*1e3:.0f}ms")
    return out


def run(*, suite="micro", requests=48, replicas=2, slots=8,
        iters_per_tick=8, seed=0, skew=1.2, arrival_rate=None,
        replicate_above=0.02, rate_window_s=600.0, policies=POLICIES,
        storm=True, storm_graphs=4, prom=None, postmortem_dir=None):
    from repro.obs import FlightRecorder, MetricsRegistry, render
    registry = MetricsRegistry() if prom else None
    flight = (FlightRecorder(postmortem_dir=postmortem_dir)
              if postmortem_dir else None)
    if flight is not None:
        flight.attach(registry=registry)
    out = {"suite": suite, "requests": requests, "replicas": replicas,
           "skew": skew, "arrival_rate": arrival_rate,
           "replicate_above": replicate_above,
           "rate_window_s": rate_window_s, "seed": seed,
           "policies": {}}
    for routing in policies:
        metrics, _ = run_cluster(
            suite=suite, requests=requests, replicas=replicas,
            routing=routing, slots=slots, iters_per_tick=iters_per_tick,
            seed=seed, skew=skew, arrival_rate=arrival_rate,
            replicate_above=replicate_above, rate_window_s=rate_window_s,
            metrics=registry, flight=flight)
        metrics["replicate_above"] = replicate_above
        out["policies"][routing] = metrics
        c = metrics["cluster"]
        emit(f"cluster/{routing}/hit_rate", c["hit_rate"],
             f"hits={c['affinity_hits']};misses={c['affinity_misses']};"
             f"replications={c['replications']};shed={c['shed']}")
        emit(f"cluster/{routing}/latency_p95_us",
             metrics["latency_p95_s"] * 1e6,
             f"p50_us={metrics['latency_p50_s']*1e6:.0f};"
             f"completed={metrics['completed']}")
    if {"affinity", "rr"} <= set(out["policies"]):
        a = out["policies"]["affinity"]["cluster"]["hit_rate"]
        r = out["policies"]["rr"]["cluster"]["hit_rate"]
        out["affinity_vs_rr_hit_rate"] = {"affinity": a, "rr": r}
        emit("cluster/affinity_vs_rr_hit_rate", a - r,
             f"affinity={a:.3f};rr={r:.3f}")
    if storm:
        out["factor_storm"] = run_storm(replicas=replicas,
                                        storm_graphs=storm_graphs,
                                        seed=seed, metrics=registry,
                                        flight=flight)
    if registry is not None:
        with open(prom, "w") as fh:
            fh.write(render(registry))
        print(f"wrote {prom}")
    if flight is not None:
        path = flight.dump("bench_cluster_final")
        out["flight"] = flight.stats()
        print(f"wrote {path}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="micro",
                    choices=["micro", "tiny", "small"])
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--iters-per-tick", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0,
                    help="trace RNG seed (graph choice, rhs content, "
                         "arrival gaps) — fixed default keeps artifacts "
                         "reproducible")
    ap.add_argument("--skew", type=float, default=1.2,
                    help="Zipf-like graph-choice skew of the trace")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="open-loop Poisson rate (req/s); default "
                         "closed-loop so replication triggers on any "
                         "machine speed")
    ap.add_argument("--replicate-above", type=float, default=0.02,
                    help="hot-factor replication threshold (req/s over "
                         "the rate window)")
    ap.add_argument("--rate-window-s", type=float, default=600.0,
                    help="arrival-rate window; minutes-wide default "
                         "makes the replication gate count the whole "
                         "closed-loop burst, machine-independently")
    ap.add_argument("--skip-storm", action="store_true",
                    help="skip the factor-storm colocated-vs-"
                         "disaggregated comparison (it factors "
                         "storm-graphs cold graphs twice)")
    ap.add_argument("--storm-graphs", type=int, default=4,
                    help="cold graphs in the factor-storm burst")
    ap.add_argument("--prom", default=None,
                    help="write the shared registry's final Prometheus "
                         "scrape to this file (uploaded as a CI "
                         "artifact)")
    ap.add_argument("--json", default=None,
                    help="write per-policy metrics to this JSON file "
                         "(uploaded as a CI artifact)")
    ap.add_argument("--postmortem-dir", default=None,
                    help="mount a flight recorder across every run and "
                         "dump its lifecycle-event ring here at the end "
                         "(uploaded as a CI artifact when gates fail)")
    args = ap.parse_args()
    metrics = run(suite=args.suite, requests=args.requests,
                  replicas=args.replicas, slots=args.slots,
                  iters_per_tick=args.iters_per_tick, seed=args.seed,
                  skew=args.skew, arrival_rate=args.arrival_rate,
                  replicate_above=args.replicate_above,
                  rate_window_s=args.rate_window_s,
                  storm=not args.skip_storm,
                  storm_graphs=args.storm_graphs,
                  prom=args.prom, postmortem_dir=args.postmortem_dir)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(metrics, fh, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
