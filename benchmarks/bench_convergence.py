"""Paper Table 2 analogue: PCG convergence with ParAC vs baselines.

Columns: factor time, solve time, iterations, relative residual for
  parac      — randomized Cholesky (wavefront engine), AMD-like ordering
  ichol0     — zero-fill incomplete Cholesky (cuSPARSE csric02 analogue)
  icholt     — threshold IC (MATLAB ichol 'ict' analogue; fill ~ parac)
  jacobi     — diagonal preconditioner
  none       — plain CG
  amg        — smoothed-aggregation V-cycle (HyPre/AmgX stand-in)
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.data import graphs
from repro.core.parac import factorize_wavefront
from repro.core.trisolve import precond_apply_np, build_schedules, solve_levels_np
from repro.core.pcg import laplacian_pcg_np
from repro.core.ichol import ichol, jacobi_preconditioner
from repro.core.ordering import ORDERINGS
from repro.core.amg import smoothed_aggregation_preconditioner

from .common import emit


def _parac_precond(g, key, ordering="nnz-sort"):
    perm = ORDERINGS[ordering](g, seed=0) \
        if ordering in ("random", "nnz-sort") else ORDERINGS[ordering](g)
    gp = g.permute(perm).coalesce()
    t0 = time.perf_counter()
    f = factorize_wavefront(gp, key, chunk=256, fill_slack=32, strict=False)
    factor_t = time.perf_counter() - t0
    fwd, bwd = build_schedules(f)
    dinv = np.where(f.D > 0, 1.0 / np.maximum(f.D, 1e-30), 0.0)

    def apply(r):
        rp = r[_inv(perm)]
        y = solve_levels_np(fwd, rp)
        x = solve_levels_np(bwd, y * dinv, flip=True)
        return x[perm]

    return apply, factor_t, f


def _inv(perm):
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size)
    return inv


def run(suite=None, tol=1e-6, maxiter=1000):
    suite = suite or graphs.SUITE
    key = jax.random.key(0)
    rng = np.random.default_rng(0)
    rows = []
    for name, make in suite.items():
        g = make()
        b = rng.normal(size=g.n)
        b -= b.mean()

        # --- parac ---------------------------------------------------------
        apply_p, t_factor, f = _parac_precond(g, key)
        t0 = time.perf_counter()
        res = laplacian_pcg_np(g, apply_p, b, tol=tol, maxiter=maxiter)
        t_solve = time.perf_counter() - t0
        emit(f"table2/{name}/parac/factor_s", t_factor * 1e6,
             f"nnz_ratio={f.fill_ratio(g):.2f}")
        emit(f"table2/{name}/parac/solve_s", t_solve * 1e6,
             f"iters={int(res.iters)};relres={float(res.relres):.2e}")
        rows.append((name, "parac", t_factor, t_solve, int(res.iters),
                     float(res.relres)))

        # --- ichol(0) -------------------------------------------------------
        try:
            t0 = time.perf_counter()
            ic = ichol(g, droptol=0.0)
            t_factor = time.perf_counter() - t0
            t0 = time.perf_counter()
            res = laplacian_pcg_np(g, ic.apply, b, tol=tol, maxiter=maxiter)
            t_solve = time.perf_counter() - t0
            emit(f"table2/{name}/ichol0/solve_s", t_solve * 1e6,
                 f"iters={int(res.iters)};relres={float(res.relres):.2e}")
            rows.append((name, "ichol0", t_factor, t_solve, int(res.iters),
                         float(res.relres)))
        except RuntimeError as e:
            emit(f"table2/{name}/ichol0/solve_s", -1, f"breakdown:{e}")

        # --- threshold ichol (fill matched to parac) ------------------------
        try:
            t0 = time.perf_counter()
            ict = ichol(g, droptol=0.02)
            t_factor = time.perf_counter() - t0
            t0 = time.perf_counter()
            res = laplacian_pcg_np(g, ict.apply, b, tol=tol, maxiter=maxiter)
            t_solve = time.perf_counter() - t0
            emit(f"table2/{name}/icholt/solve_s", t_solve * 1e6,
                 f"iters={int(res.iters)};relres={float(res.relres):.2e}")
            rows.append((name, "icholt", t_factor, t_solve, int(res.iters),
                         float(res.relres)))
        except RuntimeError as e:
            emit(f"table2/{name}/icholt/solve_s", -1, f"breakdown:{e}")

        # --- jacobi / none ---------------------------------------------------
        jac = jacobi_preconditioner(g)
        t0 = time.perf_counter()
        res = laplacian_pcg_np(g, jac, b, tol=tol, maxiter=maxiter)
        emit(f"table2/{name}/jacobi/solve_s",
             (time.perf_counter() - t0) * 1e6,
             f"iters={int(res.iters)};relres={float(res.relres):.2e}")
        t0 = time.perf_counter()
        res = laplacian_pcg_np(g, lambda r: r, b, tol=tol, maxiter=maxiter)
        emit(f"table2/{name}/none/solve_s", (time.perf_counter() - t0) * 1e6,
             f"iters={int(res.iters)};relres={float(res.relres):.2e}")

        # --- AMG-lite ---------------------------------------------------------
        try:
            t0 = time.perf_counter()
            amg = smoothed_aggregation_preconditioner(g)
            t_setup = time.perf_counter() - t0
            t0 = time.perf_counter()
            res = laplacian_pcg_np(g, amg, b, tol=tol, maxiter=maxiter)
            t_solve = time.perf_counter() - t0
            emit(f"table2/{name}/amg/setup_s", t_setup * 1e6, "")
            emit(f"table2/{name}/amg/solve_s", t_solve * 1e6,
                 f"iters={int(res.iters)};relres={float(res.relres):.2e}")
        except Exception as e:  # noqa: BLE001
            emit(f"table2/{name}/amg/solve_s", -1, f"error:{type(e).__name__}")
    return rows


if __name__ == "__main__":
    run()
