"""Paper Table 2 analogue: PCG convergence with ParAC vs baselines.

Columns: factor time, solve time, iterations, relative residual for
  parac      — randomized Cholesky (wavefront engine), AMD-like ordering
  ichol0     — zero-fill incomplete Cholesky (cuSPARSE csric02 analogue)
  icholt     — threshold IC (MATLAB ichol 'ict' analogue; fill ~ parac)
  jacobi     — diagonal preconditioner
  none       — plain CG
  amg        — smoothed-aggregation V-cycle (HyPre/AmgX stand-in)

Run bare (``python -m benchmarks.bench_convergence``) for the legacy
host-side Table-2 sweep over the full suite.  With ``--json PATH`` it
instead produces the **serving-zoo artifact** the ``bench-precond`` CI
job gates on (``benchmarks.check_precond_regression``):

* ``families`` — the family matrix: every registered preconditioner
  family (:data:`repro.core.solver.PRECOND_FAMILIES`) constructed and
  served through the *device fleet* path (``FactorCache.factor`` →
  ``PreconditionerHandle.solve``) on every suite graph, reporting
  construction seconds, solve seconds, iterations, relative residual
  and device bytes — the table ``docs/preconditioners.md`` renders;
* ``replay`` — always-AC vs ``--precond auto`` on the same skewed
  open-loop deadline trace (``repro.launch.serve.run_service``), the
  deadline-hit-rate comparison the adaptive selector is gated on.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax

from repro.data import graphs
from repro.core.parac import factorize_wavefront
from repro.core.trisolve import precond_apply_np, build_schedules, solve_levels_np
from repro.core.pcg import laplacian_pcg_np
from repro.core.ichol import ichol, jacobi_preconditioner
from repro.core.ordering import ORDERINGS
from repro.core.amg import smoothed_aggregation_preconditioner

from .common import emit


def _parac_precond(g, key, ordering="nnz-sort"):
    perm = ORDERINGS[ordering](g, seed=0) \
        if ordering in ("random", "nnz-sort") else ORDERINGS[ordering](g)
    gp = g.permute(perm).coalesce()
    t0 = time.perf_counter()
    f = factorize_wavefront(gp, key, chunk=256, fill_slack=32, strict=False)
    factor_t = time.perf_counter() - t0
    fwd, bwd = build_schedules(f)
    dinv = np.where(f.D > 0, 1.0 / np.maximum(f.D, 1e-30), 0.0)

    def apply(r):
        rp = r[_inv(perm)]
        y = solve_levels_np(fwd, rp)
        x = solve_levels_np(bwd, y * dinv, flip=True)
        return x[perm]

    return apply, factor_t, f


def _inv(perm):
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size)
    return inv


def run(suite=None, tol=1e-6, maxiter=1000):
    suite = suite or graphs.SUITE
    key = jax.random.key(0)
    rng = np.random.default_rng(0)
    rows = []
    for name, make in suite.items():
        g = make()
        b = rng.normal(size=g.n)
        b -= b.mean()

        # --- parac ---------------------------------------------------------
        apply_p, t_factor, f = _parac_precond(g, key)
        t0 = time.perf_counter()
        res = laplacian_pcg_np(g, apply_p, b, tol=tol, maxiter=maxiter)
        t_solve = time.perf_counter() - t0
        emit(f"table2/{name}/parac/factor_s", t_factor * 1e6,
             f"nnz_ratio={f.fill_ratio(g):.2f}")
        emit(f"table2/{name}/parac/solve_s", t_solve * 1e6,
             f"iters={int(res.iters)};relres={float(res.relres):.2e}")
        rows.append((name, "parac", t_factor, t_solve, int(res.iters),
                     float(res.relres)))

        # --- ichol(0) -------------------------------------------------------
        try:
            t0 = time.perf_counter()
            ic = ichol(g, droptol=0.0)
            t_factor = time.perf_counter() - t0
            t0 = time.perf_counter()
            res = laplacian_pcg_np(g, ic.apply, b, tol=tol, maxiter=maxiter)
            t_solve = time.perf_counter() - t0
            emit(f"table2/{name}/ichol0/solve_s", t_solve * 1e6,
                 f"iters={int(res.iters)};relres={float(res.relres):.2e}")
            rows.append((name, "ichol0", t_factor, t_solve, int(res.iters),
                         float(res.relres)))
        except RuntimeError as e:
            emit(f"table2/{name}/ichol0/solve_s", -1, f"breakdown:{e}")

        # --- threshold ichol (fill matched to parac) ------------------------
        try:
            t0 = time.perf_counter()
            ict = ichol(g, droptol=0.02)
            t_factor = time.perf_counter() - t0
            t0 = time.perf_counter()
            res = laplacian_pcg_np(g, ict.apply, b, tol=tol, maxiter=maxiter)
            t_solve = time.perf_counter() - t0
            emit(f"table2/{name}/icholt/solve_s", t_solve * 1e6,
                 f"iters={int(res.iters)};relres={float(res.relres):.2e}")
            rows.append((name, "icholt", t_factor, t_solve, int(res.iters),
                         float(res.relres)))
        except RuntimeError as e:
            emit(f"table2/{name}/icholt/solve_s", -1, f"breakdown:{e}")

        # --- jacobi / none ---------------------------------------------------
        jac = jacobi_preconditioner(g)
        t0 = time.perf_counter()
        res = laplacian_pcg_np(g, jac, b, tol=tol, maxiter=maxiter)
        emit(f"table2/{name}/jacobi/solve_s",
             (time.perf_counter() - t0) * 1e6,
             f"iters={int(res.iters)};relres={float(res.relres):.2e}")
        t0 = time.perf_counter()
        res = laplacian_pcg_np(g, lambda r: r, b, tol=tol, maxiter=maxiter)
        emit(f"table2/{name}/none/solve_s", (time.perf_counter() - t0) * 1e6,
             f"iters={int(res.iters)};relres={float(res.relres):.2e}")

        # --- AMG-lite ---------------------------------------------------------
        try:
            t0 = time.perf_counter()
            amg = smoothed_aggregation_preconditioner(g)
            t_setup = time.perf_counter() - t0
            t0 = time.perf_counter()
            res = laplacian_pcg_np(g, amg, b, tol=tol, maxiter=maxiter)
            t_solve = time.perf_counter() - t0
            emit(f"table2/{name}/amg/setup_s", t_setup * 1e6, "")
            emit(f"table2/{name}/amg/solve_s", t_solve * 1e6,
                 f"iters={int(res.iters)};relres={float(res.relres):.2e}")
        except Exception as e:  # noqa: BLE001
            emit(f"table2/{name}/amg/solve_s", -1, f"error:{type(e).__name__}")
    return rows


def run_family_matrix(suite=None, *, tol=1e-6, maxiter=500, seed=0):
    """Serve every registered preconditioner family on every suite
    graph through the device-fleet path and tabulate cost/quality.
    Returns ``{graph: {family: row}}`` with construction seconds, solve
    seconds, block iterations, relative residual, convergence and
    device footprint per row."""
    from repro.core.solver import FactorCache, PRECOND_FAMILIES
    suite = suite or graphs.SUITE_TINY
    key = jax.random.key(0)
    rng = np.random.default_rng(seed)
    matrix = {}
    for name, make in suite.items():
        g = make()
        b = rng.normal(size=g.n).astype(np.float32)
        b -= b.mean()
        row = {}
        for fam in sorted(PRECOND_FAMILIES):
            cache = FactorCache(strict=False)
            h = cache.factor(g, key, graph_id=name, family=fam)
            t0 = time.perf_counter()
            res = h.solve(b, tol=tol, maxiter=maxiter)
            t_solve = time.perf_counter() - t0
            iters = int(np.max(res.iters))
            relres = float(np.max(res.relres))
            row[fam] = dict(construct_s=h.construct_s, solve_s=t_solve,
                            iters=iters, relres=relres,
                            converged=bool(relres <= 10 * tol),
                            kind=h.kind, device_bytes=h.device_bytes)
            emit(f"precond/{name}/{fam}/iters", iters,
                 f"relres={relres:.2e};construct_s={h.construct_s:.2f}")
        matrix[name] = row
    return matrix


def run_auto_replay(*, suite="tiny", requests=24, warmup=16, slots=4,
                    iters_per_tick=8, deadline_ms=1500.0, skew=1.5,
                    arrival_rate=20.0, seed=0, select_epsilon=0.25,
                    flight=None):
    """Replay one skewed open-loop deadline trace twice — always-AC vs
    adaptive family selection — and report the deadline outcome per
    mode.  Both replays share the trace seed (identical requests and
    arrivals) and warm up through the same engine first, so the
    comparison isolates the selector's family choices.

    Deadlines are accounted **post hoc** (a request missed its SLO when
    its end-to-end latency exceeded ``deadline_ms``) under the plain
    FIFO scheduler rather than via the deadline policy's hopeless-lane
    eviction: eviction retires a request the moment its budget is
    blown, which truncates the very latencies the two modes are being
    compared on (and its first eviction per bucket pays a jit compile
    that would punish whichever mode evicts first)."""
    from repro.launch.serve import run_service
    out = {}
    for mode in ("ac", "auto"):
        m, done = run_service(
            suite=suite, requests=requests, slots=slots,
            iters_per_tick=iters_per_tick, seed=seed,
            warmup_requests=warmup, arrival_rate=arrival_rate,
            policy="fifo", deadline_ms=deadline_ms, precond=mode,
            select_epsilon=select_epsilon, skew=skew, flight=flight)
        slo_missed = sum(1 for r in done
                         if r.deadline_s is not None
                         and r.latency_s > r.deadline_s)
        out[mode] = dict(
            requests=m["requests"], completed=m["completed"],
            converged=m["converged"], slo_missed=slo_missed,
            deadline_missed=m["deadline_missed"],
            latency_p95_s=m["latency_p95_s"],
            service_p95_s=m["service_p95_s"],
            selector=m["selector"])
        emit(f"precond/replay/{mode}/slo_missed", slo_missed,
             f"completed={m['completed']};requests={m['requests']}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="write the serving-zoo artifact (family matrix "
                         "+ auto-vs-AC deadline replay) to this file; "
                         "omit for the legacy host Table-2 sweep")
    ap.add_argument("--suite", default="tiny",
                    choices=["micro", "tiny", "full"])
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--maxiter", type=int, default=500)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--warmup", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--deadline-ms", type=float, default=1500.0)
    ap.add_argument("--skew", type=float, default=1.5)
    ap.add_argument("--arrival-rate", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--postmortem-dir", default=None,
                    help="mount a flight recorder on the deadline "
                         "replay and dump its event ring here at the "
                         "end (uploaded as a CI artifact when the zoo "
                         "gate fails)")
    args = ap.parse_args()

    if args.json is None:
        run()
        return
    flight = None
    if args.postmortem_dir:
        from repro.obs import FlightRecorder
        flight = FlightRecorder(postmortem_dir=args.postmortem_dir)
    spec = {"micro": graphs.SUITE_MICRO, "tiny": graphs.SUITE_TINY,
            "full": graphs.SUITE}[args.suite]
    matrix = run_family_matrix(spec, tol=args.tol, maxiter=args.maxiter,
                               seed=args.seed)
    replay = run_auto_replay(
        suite=args.suite if args.suite != "full" else "tiny",
        requests=args.requests, warmup=args.warmup, slots=args.slots,
        deadline_ms=args.deadline_ms, skew=args.skew,
        arrival_rate=args.arrival_rate, seed=args.seed, flight=flight)
    artifact = dict(suite=args.suite, tol=args.tol, maxiter=args.maxiter,
                    seed=args.seed, deadline_ms=args.deadline_ms,
                    skew=args.skew, families=matrix, replay=replay)
    if flight is not None:
        print(f"wrote {flight.dump('bench_precond_final')}")
        artifact["flight"] = flight.stats()
    with open(args.json, "w") as fh:
        json.dump(artifact, fh, indent=2)
    print(f"wrote {args.json}")
    for name, row in matrix.items():
        cells = "  ".join(f"{fam}:{r['iters']}it"
                          f"{'' if r['converged'] else '(!)'}"
                          for fam, r in row.items())
        print(f"{name:16s} {cells}")
    print(f"replay: ac missed={replay['ac']['slo_missed']} "
          f"auto missed={replay['auto']['slo_missed']} "
          f"(of {replay['ac']['requests']})")


if __name__ == "__main__":
    main()
