"""Paper Fig. 4 analogue: classical vs actual e-tree height, triangular-
solve critical path, and fill ratio — per ordering (random / nnz-sort /
AMD-like).  The central structural claim: randomized clique sampling
slashes the dependency depth, and locality-favouring orderings (AMD)
benefit least — exactly why they lose on massively-parallel hardware.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.data import graphs
from repro.core.parac import factorize_wavefront
from repro.core import etree
from repro.core.trisolve import build_schedules
from repro.core.ordering import ORDERINGS

from .common import emit

ORDERS = ("random", "nnz-sort", "amd-like")


def run(suite=None):
    suite = suite or graphs.SUITE
    key = jax.random.key(0)
    rows = []
    for name, make in suite.items():
        g = make()
        for oname in ORDERS:
            perm = ORDERINGS[oname](g, seed=1) \
                if oname in ("random", "nnz-sort") else ORDERINGS[oname](g)
            gp = g.permute(perm).coalesce()
            h_classical = etree.classical_etree_height(g, perm)
            f = factorize_wavefront(gp, key, chunk=256, fill_slack=32,
                                    strict=False)
            h_actual = etree.actual_etree_height(f)
            h_parent = etree.actual_parent_etree_height(f)
            fwd, _ = build_schedules(f)
            crit = fwd.n_levels
            fill = f.fill_ratio(g)
            emit(f"fig4/{name}/{oname}/heights", h_actual,
                 f"classical={h_classical};etree={h_parent};"
                 f"critical_path={crit};fill_ratio={fill:.2f};"
                 f"rounds={f.stats['rounds']}")
            rows.append((name, oname, h_classical, h_actual, crit, fill))
    return rows


if __name__ == "__main__":
    run()
