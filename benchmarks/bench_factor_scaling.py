"""Paper Fig. 3 analogue: factorization time scaling.

The paper scales CPU threads; on one CPU core we scale the engine's
*chunk width* (vertices eliminated per bulk-synchronous round) — the
quantity that maps to occupied cores/SMs — and report wall time, rounds
and available parallelism (mean wavefront size) per ordering.
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.data import graphs
from repro.core.parac import factorize_wavefront
from repro.core import etree
from repro.core.ordering import ORDERINGS

from .common import emit

CHUNKS = (16, 64, 256, 1024)


def run(suite=None, orderings=("random", "nnz-sort")):
    suite = suite or {k: graphs.SUITE[k] for k in
                      ("grid2d_64", "grid3d_uniform_16", "powerlaw_4k",
                       "road_64")}
    key = jax.random.key(0)
    for name, make in suite.items():
        g = make()
        for oname in orderings:
            perm = ORDERINGS[oname](g, seed=1)
            gp = g.permute(perm).coalesce()
            for chunk in CHUNKS:
                t0 = time.perf_counter()
                f = factorize_wavefront(gp, key, chunk=chunk, fill_slack=32,
                                        strict=False)
                dt = time.perf_counter() - t0
                prof = etree.wavefront_profile(f)
                emit(f"fig3/{name}/{oname}/chunk{chunk}", dt * 1e6,
                     f"rounds={f.stats['rounds']};"
                     f"mean_wavefront={prof.mean():.0f};"
                     f"max_wavefront={prof.max()}")


if __name__ == "__main__":
    run()
