"""Fleet-kernel microbench: achieved vs theoretical bytes/s for the
serving hot loop's Pallas kernels.

    PYTHONPATH=src python -m benchmarks.bench_fleet_kernels \
        --json BENCH_fleet_kernels.json

Times the four kernels the engine tick is built from — ``ell_spmv``,
``ell_spmv_multi``, ``ell_spmv_fleet``, and ``trisolve_fleet`` — on
synthetic ELL panels at serving-representative shapes, against a simple
bytes-moved model (cols + vals + gathered operand reads + result
write).  The "theoretical" reference is not a datasheet number but a
measured **device-copy proxy**: a jitted f32 copy of a large array on
the same backend, so ``frac_of_copy`` reads as "fraction of the
bandwidth this machine demonstrably sustains" and is comparable across
interpret (CPU) and native (GPU/TPU) lowering.  All four kernels are
memory-bound at serving K (a handful of fused multiply-adds per 12
bytes of panel), so the copy fraction *is* the roofline fraction.

The CI ``bench-serve`` job uploads the JSON artifact;
``benchmarks.roofline_report --kernels`` renders it as a markdown
table next to the model-level roofline.  Values move with the runner,
so nothing here is gated — the artifact exists to make a lowering
regression (e.g. interpret mode silently re-enabled on an accelerator)
visible as an order-of-magnitude bandwidth dip.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from .common import emit, time_call


def _panels(rng, *shape):
    """Zero-valued ELL panels: memory traffic identical to real factor
    panels (same reads, same gather, same write) while keeping repeated
    trisolve sweeps numerically inert — no overflow across sweeps."""
    cols = rng.integers(0, shape[-2], size=shape, dtype=np.int32)
    vals = np.zeros(shape, np.float32)
    return cols, vals


def bench_kernels(*, n=4096, k=8, lanes=4, nrhs=4, levels=8, repeats=5,
                  copy_mb=64):
    """Run the microbench; returns the JSON-able record dict."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import (ell_spmv, ell_spmv_fleet,
                                   ell_spmv_multi, trisolve_fleet)
    from repro.kernels.runtime import default_interpret

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=n).astype(np.float32))
    xm = jnp.asarray(rng.normal(size=(n, nrhs)).astype(np.float32))
    xf = jnp.asarray(rng.normal(size=(lanes, n)).astype(np.float32))

    # bandwidth proxy: a jitted device copy (read + write) of a big f32
    # array — the sustained-bandwidth ceiling the kernels are judged by
    copy_n = max(copy_mb, 1) * (1 << 20) // 4
    big = jnp.asarray(rng.normal(size=copy_n).astype(np.float32))
    copy_fn = jax.jit(lambda a: a + 0.0)
    t_copy, _ = time_call(lambda: jax.block_until_ready(copy_fn(big)),
                          repeats=repeats)
    peak_bs = 2 * copy_n * 4 / t_copy if t_copy > 0 else 0.0

    records = []

    def record(name, fn, bytes_moved, shape):
        t, _ = time_call(lambda: jax.block_until_ready(fn()),
                         repeats=repeats)
        bs = bytes_moved / t if t > 0 else 0.0
        rec = dict(kernel=name, shape=shape, time_us=t * 1e6,
                   bytes=bytes_moved, achieved_gbs=bs / 1e9,
                   frac_of_copy=bs / peak_bs if peak_bs > 0 else 0.0)
        records.append(rec)
        emit(f"kernels/{name}/us", rec["time_us"],
             f"GB/s={rec['achieved_gbs']:.2f};"
             f"frac={rec['frac_of_copy']:.3f}")

    # per-call bytes: cols + vals reads (4B each), the gathered operand
    # read (4B per ELL slot per rhs), and the result write
    c1, v1 = _panels(rng, n, k)
    c1, v1 = jnp.asarray(c1), jnp.asarray(v1)
    record("ell_spmv", lambda: ell_spmv(c1, v1, x),
           n * k * 12 + n * 4, dict(n=n, k=k))

    record("ell_spmv_multi", lambda: ell_spmv_multi(c1, v1, xm),
           n * k * 8 + n * k * nrhs * 4 + n * nrhs * 4,
           dict(n=n, k=k, nrhs=nrhs))

    cf, vf = _panels(rng, lanes, n, k)
    cf, vf = jnp.asarray(cf), jnp.asarray(vf)
    fleet_bytes = lanes * (n * k * 12 + n * 4)
    record("ell_spmv_fleet", lambda: ell_spmv_fleet(cf, vf, xf),
           fleet_bytes, dict(lanes=lanes, n=n, k=k))

    # trisolve: (levels-1) masked sweeps, each one fleet SpMV plus the
    # level_of read and the committed y write; jitted whole like the
    # engine's PCG step (an eager lax loop would time dispatch, not the
    # kernel)
    lof = jnp.asarray(rng.integers(0, levels, size=(lanes, n),
                                   dtype=np.int32))
    tri_fn = jax.jit(lambda c, v, lo, y:
                     trisolve_fleet(c, v, lo, y, n_levels=levels))
    tri_bytes = (levels - 1) * (fleet_bytes + lanes * n * 8)
    record("trisolve_fleet", lambda: tri_fn(cf, vf, lof, xf),
           tri_bytes, dict(lanes=lanes, n=n, k=k, levels=levels))

    return dict(backend=jax.default_backend(),
                interpret=default_interpret(),
                copy_mb=copy_mb, copy_gbs=peak_bs / 1e9,
                repeats=repeats, records=records)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096,
                    help="padded rows per lane")
    ap.add_argument("--k", type=int, default=8, help="ELL panel width")
    ap.add_argument("--lanes", type=int, default=4,
                    help="fleet lanes (L) for the batched kernels")
    ap.add_argument("--nrhs", type=int, default=4,
                    help="columns for ell_spmv_multi")
    ap.add_argument("--levels", type=int, default=8,
                    help="trisolve level sweeps")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--copy-mb", type=int, default=64,
                    help="size of the bandwidth-proxy device copy")
    ap.add_argument("--json", default=None,
                    help="write records to this JSON file (CI artifact)")
    args = ap.parse_args()
    out = bench_kernels(n=args.n, k=args.k, lanes=args.lanes,
                        nrhs=args.nrhs, levels=args.levels,
                        repeats=args.repeats, copy_mb=args.copy_mb)
    print(f"backend={out['backend']} interpret={out['interpret']} "
          f"copy={out['copy_gbs']:.2f} GB/s")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(out, fh, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
