"""Solve-service benchmark: replay a mixed request trace through the
device-resident continuous-batching :class:`SolveEngine` and report
service-level numbers — requests/sec, rhs/sec, ticks/sec, p50/p95
latency, and (open-loop) queueing delay.

The CI ``bench-serve`` job runs

    PYTHONPATH=src python -m benchmarks.bench_serve \
        --suite tiny --json BENCH_serve.json

uploads the JSON as an artifact, and gates merges by comparing
``ticks_per_s`` against the committed baseline in
``benchmarks/baselines/`` (``benchmarks.check_serve_regression``), so a
>2x serving-throughput regression fails the build instead of showing up
as a silent time-series dip.  The trace RNG is explicitly seeded
(``--seed``, default 0) — rhs content *and* Poisson arrival gaps — so
artifacts are reproducible across runs.

The artifact also carries a **wide-head admission-policy sweep**
(``policy_sweep``; disable with ``--no-sweep``): the same seeded
Poisson trace — a hard narrow blocker, a full-width request stuck
behind it, then a stream of easy narrow arrivals at
``--sweep-arrival-rate`` — replayed under ``fifo`` and ``priority``
(backfill) admission, recording queueing vs service vs end-to-end
latency per policy.  ``check_serve_regression`` gates that backfill
strictly improves p95 end-to-end latency over FIFO and that every
engine's scheduler counters conserve requests and respect the
starvation bound.

Two padding-tax blocks ride in the same artifact:

* ``tier_sweep`` (disable with ``--no-tier-sweep``): one seeded
  hub-heavy trace replayed through a K-tiered cache and an untiered
  one; the gate is that the tiered engine's ``sweep_elements`` (padded
  ``n_pad x K x sweeps`` work) is strictly lower — the K-tiering win;
* ``fleet_memory`` (disable with ``--no-fleet-memory``): eviction
  churn followed by stack compaction; the gate is
  ``fleet_device_bytes <= 1.5 x fleet_live_bytes`` with at least one
  compaction, and the post-compaction replay must still converge.

An **observability-overhead** block (``obs_overhead``; disable with
``--no-obs-overhead``) replays the same closed-loop trace through a
plain engine and a fully instrumented one (metrics registry + tracer),
interleaved best-of-N, and records the tick-throughput ratio;
``check_serve_regression`` gates ``ratio >= 0.98`` so instrumentation
can never quietly tax the serve hot path.  ``--prom`` dumps the final
Prometheus scrape of the instrumented run's registry to a file (the CI
jobs upload it next to the JSON artifact).
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.launch.serve import replay_trace, run_service

from .common import emit


def make_wide_head_trace(gid, n, *, width, narrow=10, seed=0,
                         arrival_rate=100.0, blocker_iters=200):
    """Seeded wide-head Poisson trace — the workload where backfill
    admission pays:

    * rid 0: a *blocker* — narrow, unreachable tolerance, so it runs its
      full ``blocker_iters`` budget holding one lane;
    * rid 1: a *wide* request (``width`` lanes — the whole engine) that
      cannot admit until the blocker retires; under FIFO it blocks the
      head of the queue the entire time;
    * rid 2..: a Poisson stream of easy narrow requests that FIFO parks
      behind the wide head while ``width - 1`` lanes idle, and backfill
      slots straight into the free lanes (until the wide head's
      starvation bound seals the queue).
    """
    from repro.serve import SolveRequest
    rng = np.random.default_rng(seed)

    def rhs(nrhs):
        b = rng.normal(size=(nrhs, n) if nrhs > 1 else n)
        b = b - b.mean(axis=-1, keepdims=True)
        return b.astype(np.float32)

    reqs = [SolveRequest(rid=0, graph_id=gid, b=rhs(1), tol=1e-30,
                         maxiter=blocker_iters, arrival_s=0.0),
            SolveRequest(rid=1, graph_id=gid, b=rhs(width), tol=1e-4,
                         maxiter=300, arrival_s=0.0)]
    arrival = 0.0
    for rid in range(2, 2 + narrow):
        arrival += float(rng.exponential(1.0 / arrival_rate))
        reqs.append(SolveRequest(rid=rid, graph_id=gid, b=rhs(1),
                                 tol=1e-3, maxiter=300,
                                 arrival_s=arrival))
    return reqs


def run_policy_sweep(cache, gid, n, *, slots=4, iters_per_tick=8, seed=0,
                     arrival_rate=100.0, narrow=30, max_skips=64,
                     policies=("fifo", "priority")):
    """Replay the same seeded wide-head Poisson trace under each
    admission policy (fresh engine per policy over the shared factor
    cache; one warmup replay per engine pays the jit compiles) and
    record queueing vs service latency per policy.  The headline
    comparison: backfill (``priority``) must beat ``fifo`` on p95
    end-to-end latency, because FIFO parks every narrow request behind
    the blocked wide head while ``slots - 1`` lanes idle.

    The trace is deliberately narrow-dominated (``narrow`` ≫ 2): the
    p95 of the trace must land inside the narrow-request mass, which is
    the population backfill helps — the wide request's own latency is
    blocker-bound under *every* policy, so a tail thin enough to reach
    it (few narrows) would measure the blocker, not the scheduler.
    ``max_skips`` is likewise generous here: the sweep measures the
    backfill win, while the starvation *bound* has its own tests and CI
    counter gate."""
    from repro.serve import SolveEngine, make_policy
    out = {"arrival_rate": arrival_rate, "slots": slots,
           "narrow": narrow, "max_skips": max_skips, "policies": {}}
    for name in policies:
        eng = SolveEngine(cache, slots=slots,
                          iters_per_tick=iters_per_tick,
                          admission=make_policy(name,
                                                max_skips=max_skips))
        # warmup: same shapes as the measured trace (narrow + wide
        # admits, the bucket step, gathers) so compiles are excluded
        warm = make_wide_head_trace(gid, n, width=slots, narrow=2,
                                    seed=seed + 1, arrival_rate=1e6,
                                    blocker_iters=8)
        replay_trace(eng, warm)
        trace = make_wide_head_trace(gid, n, width=slots, narrow=narrow,
                                     seed=seed, arrival_rate=arrival_rate)
        metrics, done = replay_trace(eng, trace)
        metrics["engine"] = eng.stats().as_dict()
        out["policies"][name] = metrics
        emit(f"serve/wide_head/{name}/latency_p95_us",
             metrics["latency_p95_s"] * 1e6,
             f"queue_p95_us={metrics['queue_wait_p95_s']*1e6:.0f};"
             f"service_p95_us={metrics['service_p95_s']*1e6:.0f};"
             f"backfill_skips={metrics['engine']['backfill_skips']}")
    if {"fifo", "priority"} <= set(out["policies"]):
        f95 = out["policies"]["fifo"]["latency_p95_s"]
        b95 = out["policies"]["priority"]["latency_p95_s"]
        out["backfill_p95_speedup"] = f95 / b95 if b95 > 0 else 0.0
        emit("serve/wide_head/backfill_p95_speedup",
             out["backfill_p95_speedup"], "fifo_p95/priority_p95")
    return out


def run_tier_sweep(*, seed=0, requests=24, slots=8, iters_per_tick=8):
    """Replay one seeded hub-heavy trace twice — through a K-tiered
    cache and an untiered one — and compare the engines' padded sweep
    work.  The workload is the padding tax's worst case: one hub-heavy
    powerlaw graph (fat ELL panels) sharing a shape bucket with
    low-degree mesh graphs, so the untiered fleet drags every
    low-degree lane through the hub's panel width while tiering keeps
    them in narrow-K fleets.  ELL padding is zero-valued, so the modes
    converge identically and ``sweep_elements`` — padded
    ``n_pad x K x live-sweeps`` elements per occupied lane per tick —
    isolates pure padding; ``check_serve_regression`` gates that the
    tiered count is strictly lower with the same convergence counts."""
    import jax

    from repro.core.solver import FactorCache
    from repro.data import graphs
    from repro.launch.serve import make_trace
    from repro.serve import SolveEngine

    built = {
        "hub": graphs.powerlaw(220, 12, seed=5),   # hub-heavy, fat K
        "mesh": graphs.grid2d(15, 15, seed=3),     # low-degree ...
        "road": graphs.road_like(15, seed=4),      # ... same shape bucket
    }
    keys = {name: jax.random.key(i) for i, name in enumerate(built)}
    sizes = {name: g.n for name, g in built.items()}
    out = {"graphs": sizes, "requests": requests, "modes": {}}
    for mode, tiering in (("tiered", True), ("untiered", False)):
        cache = FactorCache(strict=False, k_tiering=tiering)
        cache.factor_batched(list(built.values()),
                             [keys[name] for name in built],
                             graph_ids=list(built.keys()))
        eng = SolveEngine(cache, slots=slots,
                          iters_per_tick=iters_per_tick)
        trace = make_trace(list(built), sizes, requests, seed=seed,
                           max_nrhs=min(4, slots))
        metrics, _ = replay_trace(eng, trace)
        st = eng.stats()
        cs = cache.stats()
        out["modes"][mode] = dict(
            k_tiers=sorted({kt for _, _, kt in cache.fleets}),
            buckets=st.buckets, step_compiles=st.step_compiles,
            sweep_elements=st.sweep_elements,
            sweeps_skipped=st.sweeps_skipped,
            fleet_device_bytes=cs["fleet_device_bytes"],
            completed=metrics["completed"],
            converged=metrics["converged"], ticks=st.ticks)
    t, u = out["modes"]["tiered"], out["modes"]["untiered"]
    out["sweep_elements_ratio"] = (u["sweep_elements"] / t["sweep_elements"]
                                   if t["sweep_elements"] else 0.0)
    emit("serve/tier_sweep/sweep_elements_ratio",
         out["sweep_elements_ratio"],
         f"tiered={t['sweep_elements']};untiered={u['sweep_elements']};"
         f"tiers={t['k_tiers']}")
    return out


def run_fleet_memory(*, seed=0, slots=8, iters_per_tick=8, n_graphs=6,
                     keep=2):
    """Churn workload for the stack-compaction memory gate: factor
    ``n_graphs`` same-bucket graphs, serve a seeded trace, evict all
    but ``keep``, force a compaction pass, and report the fleet-stack
    footprint against the live floor.  ``check_serve_regression`` gates
    ``fleet_device_bytes <= 1.5 x fleet_live_bytes`` (and that at least
    one compaction actually ran) so eviction churn can never strand the
    fleet stacks at their high-water capacity.  A post-compaction
    replay over the survivors closes the loop: the engine re-syncs its
    resident row indices against the rebuilt stacks and the solves
    still converge."""
    import jax

    from repro.core.solver import FactorCache
    from repro.data import graphs
    from repro.launch.serve import make_trace
    from repro.serve import SolveEngine

    built = {f"g{i}": graphs.grid2d(12, 12, seed=i)
             for i in range(n_graphs)}
    keys = {name: jax.random.key(i) for i, name in enumerate(built)}
    sizes = {name: g.n for name, g in built.items()}
    cache = FactorCache(strict=False)
    cache.factor_batched(list(built.values()),
                         [keys[name] for name in built],
                         graph_ids=list(built.keys()))
    eng = SolveEngine(cache, slots=slots, iters_per_tick=iters_per_tick)
    gids = list(built)
    trace = make_trace(gids, sizes, 2 * n_graphs, seed=seed,
                       max_nrhs=min(4, slots))
    replay_trace(eng, trace)
    peak = cache.stats()["fleet_device_bytes"]
    for gid in gids[keep:]:
        cache.evict(gid)
    cache.compact()        # deterministic: don't ride on GC timing
    cs = cache.stats()
    survivors = gids[:keep]
    post = make_trace(survivors, sizes, 2 * keep, seed=seed + 1,
                      max_nrhs=min(4, slots))
    post_metrics, _ = replay_trace(eng, post)
    live = cs["fleet_live_bytes"]
    out = dict(graphs=n_graphs, evicted=n_graphs - keep,
               peak_device_bytes=peak,
               fleet_device_bytes=cs["fleet_device_bytes"],
               fleet_live_bytes=live,
               ratio=(cs["fleet_device_bytes"] / live if live else 0.0),
               compactions=cs["compactions"],
               fleet_resyncs=eng.stats().fleet_resyncs,
               post_compact_completed=post_metrics["completed"],
               post_compact_converged=post_metrics["converged"])
    emit("serve/fleet_memory/device_over_live", out["ratio"],
         f"device={out['fleet_device_bytes']};live={live};"
         f"compactions={out['compactions']};"
         f"resyncs={out['fleet_resyncs']}")
    return out


def run_obs_overhead(*, seed=0, slots=8, iters_per_tick=8, requests=24,
                     rounds=3):
    """Measure what instrumentation costs the serve hot path: the same
    seeded closed-loop trace replayed through a plain engine and a
    fully instrumented one (metrics registry + tracer + Prometheus
    render at the end), over one shared warm factor cache.  Rounds are
    **interleaved** (plain, instrumented, plain, ...) and the headline
    ratio is best-of-N over best-of-N, so machine noise hits both arms
    alike; compiles are paid by a warmup replay per engine before any
    timing.  ``check_serve_regression`` gates
    ``instrumented >= 0.98 x plain`` ticks/s — the off-hot-path
    contract (pre-bound counter children, per-tick gauges, no device
    syncs) turned into a number CI can refuse."""
    import time

    import jax

    from repro.core.solver import FactorCache
    from repro.data import graphs
    from repro.launch.serve import make_trace
    from repro.obs import (FlightRecorder, HealthMonitor, MetricsRegistry,
                           Tracer, render)
    from repro.serve import SolveEngine

    built = {"mesh": graphs.grid2d(12, 12, seed=1),
             "road": graphs.road_like(12, seed=2)}
    keys = {name: jax.random.key(i) for i, name in enumerate(built)}
    sizes = {name: g.n for name, g in built.items()}
    cache = FactorCache(strict=False)
    cache.factor_batched(list(built.values()),
                         [keys[name] for name in built],
                         graph_ids=list(built.keys()))
    registry = MetricsRegistry()
    tracer = Tracer()
    # the instrumented arm carries the *whole* observability stack the
    # serving path can mount: metrics + tracer (PR 9) and the flight
    # recorder + numerical-health monitor (PR 10) — the 0.98 gate covers
    # all of it at once
    flight = FlightRecorder(capacity=4096)
    health = HealthMonitor(registry, flight=flight)
    flight.attach(registry=registry)
    engines = {
        "plain": SolveEngine(cache, slots=slots,
                             iters_per_tick=iters_per_tick),
        "instrumented": SolveEngine(cache, slots=slots,
                                    iters_per_tick=iters_per_tick,
                                    metrics=registry, tracer=tracer,
                                    flight=flight, health=health),
    }
    health.watch_engine(engines["instrumented"])
    health.watch_cache(cache)
    gids = list(built)
    # closed-loop (no arrival gaps): the measurement is pure tick
    # throughput, not open-loop waiting that would mask the overhead
    trace_for = lambda s: make_trace(gids, sizes, requests, seed=s,
                                     max_nrhs=min(4, slots))
    for eng in engines.values():           # compiles out of the timing
        replay_trace(eng, trace_for(seed + 1))
    best = {name: 0.0 for name in engines}
    for _ in range(rounds):
        for name, eng in engines.items():  # interleaved arms
            t0, k0 = time.perf_counter(), eng.ticks
            replay_trace(eng, trace_for(seed))
            dt = time.perf_counter() - t0
            tps = (eng.ticks - k0) / dt if dt > 0 else 0.0
            best[name] = max(best[name], tps)
    out = dict(
        rounds=rounds, requests=requests,
        plain_ticks_per_s=best["plain"],
        instrumented_ticks_per_s=best["instrumented"],
        ratio=(best["instrumented"] / best["plain"]
               if best["plain"] > 0 else 0.0),
        traces_recorded=tracer.stats()["recorded"],
        flight_events=flight.stats()["recorded"],
        health_observed=health.snapshot()["observed"],
        scrape_lines=len(render(registry).splitlines()))
    emit("serve/obs_overhead/ticks_per_s_ratio", out["ratio"],
         f"plain={best['plain']:.0f};"
         f"instrumented={best['instrumented']:.0f};"
         f"rounds={rounds};traces={out['traces_recorded']};"
         f"flight={out['flight_events']}")
    return out


def run(*, suite="tiny", requests=16, slots=8, iters_per_tick=8, seed=0,
        warm=True, arrival_rate=None, policy="fifo", sweep=True,
        sweep_arrival_rate=100.0, tier_sweep=True, fleet_memory=True,
        obs_overhead=True, prom=None, postmortem_dir=None):
    """One warmup replay through the same engine (pays jit compiles),
    then the measured replay; with ``sweep`` the wide-head policy
    comparison reuses the already-factored cache.  With ``prom`` the
    main run serves under a metrics registry whose final scrape is
    written to that path.  With ``postmortem_dir`` a flight recorder
    rides the main run and unconditionally dumps its event ring there
    at the end — the artifact a failing CI gate uploads, so a
    regression report comes with the lifecycle events behind it."""
    from repro.obs import FlightRecorder, MetricsRegistry, render
    registry = MetricsRegistry() if prom else None
    flight = (FlightRecorder(postmortem_dir=postmortem_dir)
              if postmortem_dir else None)
    if flight is not None:
        flight.attach(registry=registry)
    metrics, _, eng = run_service(
        suite=suite, requests=requests, slots=slots,
        iters_per_tick=iters_per_tick, seed=seed,
        warmup_requests=requests if warm else 0,
        arrival_rate=arrival_rate, policy=policy, return_engine=True,
        metrics=registry, flight=flight)
    emit(f"serve/{suite}/requests_per_s", metrics["requests_per_s"],
         f"completed={metrics['completed']};rhs={metrics['rhs_total']}")
    emit(f"serve/{suite}/ticks_per_s", metrics["ticks_per_s"],
         f"ticks={metrics['ticks']};slots={metrics['slots']}")
    emit(f"serve/{suite}/latency_p50_us", metrics["latency_p50_s"] * 1e6,
         f"p95_us={metrics['latency_p95_s']*1e6:.0f}")
    emit(f"serve/{suite}/queue_wait_p50_us",
         metrics["queue_wait_p50_s"] * 1e6,
         f"p95_us={metrics['queue_wait_p95_s']*1e6:.0f};"
         f"arrival_rate={arrival_rate}")
    emit(f"serve/{suite}/factor_batched_us", metrics["factor_s"] * 1e6,
         f"graphs={metrics['graphs']}")
    if sweep:
        # smallest suite graph → one shape bucket, cheapest compiles
        cache = eng.cache
        gid = min(cache.graph_ids, key=lambda g: cache.peek(g).n)
        metrics["policy_sweep"] = run_policy_sweep(
            cache, gid, cache.peek(gid).n, seed=seed,
            arrival_rate=sweep_arrival_rate,
            iters_per_tick=iters_per_tick)
    if tier_sweep:
        metrics["tier_sweep"] = run_tier_sweep(
            seed=seed, slots=slots, iters_per_tick=iters_per_tick)
    if fleet_memory:
        metrics["fleet_memory"] = run_fleet_memory(
            seed=seed, slots=slots, iters_per_tick=iters_per_tick)
    if obs_overhead:
        metrics["obs_overhead"] = run_obs_overhead(
            seed=seed, slots=slots, iters_per_tick=iters_per_tick)
    if registry is not None:
        with open(prom, "w") as fh:
            fh.write(render(registry))
        print(f"wrote {prom}")
    if flight is not None:
        path = flight.dump("bench_serve_final")
        metrics["flight"] = flight.stats()
        print(f"wrote {path}")
    return metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="tiny", choices=["tiny", "small"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--iters-per-tick", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0,
                    help="trace RNG seed (rhs content + arrival gaps); "
                         "fixed default keeps JSON artifacts reproducible")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="open-loop Poisson arrival rate (req/s) passed "
                         "through to the trace, so the artifact records "
                         "queueing metrics")
    ap.add_argument("--no-warm", action="store_true",
                    help="skip the warmup replay (include compiles)")
    ap.add_argument("--policy", default="fifo",
                    choices=["fifo", "priority", "deadline"],
                    help="admission policy for the main mixed-trace run")
    ap.add_argument("--no-sweep", action="store_true",
                    help="skip the wide-head admission-policy sweep")
    ap.add_argument("--sweep-arrival-rate", type=float, default=100.0,
                    help="Poisson rate for the wide-head policy sweep "
                         "(queueing vs service latency per policy)")
    ap.add_argument("--no-tier-sweep", action="store_true",
                    help="skip the K-tiered vs untiered padded-sweep-"
                         "work comparison (hub-heavy trace)")
    ap.add_argument("--no-fleet-memory", action="store_true",
                    help="skip the eviction-churn + compaction "
                         "fleet-memory measurement")
    ap.add_argument("--no-obs-overhead", action="store_true",
                    help="skip the instrumented-vs-plain tick-"
                         "throughput comparison")
    ap.add_argument("--prom", default=None,
                    help="write the main run's final Prometheus scrape "
                         "to this file (uploaded as a CI artifact)")
    ap.add_argument("--postmortem-dir", default=None,
                    help="mount a flight recorder on the main run and "
                         "dump its lifecycle-event ring here at the end "
                         "(uploaded as a CI artifact when gates fail)")
    ap.add_argument("--json", default=None,
                    help="write service metrics to this JSON file "
                         "(uploaded as a CI artifact)")
    args = ap.parse_args()
    metrics = run(suite=args.suite, requests=args.requests,
                  slots=args.slots, iters_per_tick=args.iters_per_tick,
                  seed=args.seed, warm=not args.no_warm,
                  arrival_rate=args.arrival_rate, policy=args.policy,
                  sweep=not args.no_sweep,
                  sweep_arrival_rate=args.sweep_arrival_rate,
                  tier_sweep=not args.no_tier_sweep,
                  fleet_memory=not args.no_fleet_memory,
                  obs_overhead=not args.no_obs_overhead,
                  prom=args.prom, postmortem_dir=args.postmortem_dir)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(metrics, fh, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
