"""Solve-service benchmark: replay a mixed request trace through the
device-resident continuous-batching :class:`SolveEngine` and report
service-level numbers — requests/sec, rhs/sec, ticks/sec, p50/p95
latency, and (open-loop) queueing delay.

The CI ``bench-serve`` job runs

    PYTHONPATH=src python -m benchmarks.bench_serve \
        --suite tiny --json BENCH_serve.json

uploads the JSON as an artifact, and gates merges by comparing
``ticks_per_s`` against the committed baseline in
``benchmarks/baselines/`` (``benchmarks.check_serve_regression``), so a
>2x serving-throughput regression fails the build instead of showing up
as a silent time-series dip.  The trace RNG is explicitly seeded
(``--seed``, default 0) — rhs content *and* Poisson arrival gaps — so
artifacts are reproducible across runs.
"""
from __future__ import annotations

import argparse
import json

from repro.launch.serve import run_service

from .common import emit


def run(*, suite="tiny", requests=16, slots=8, iters_per_tick=8, seed=0,
        warm=True, arrival_rate=None):
    """One warmup replay through the same engine (pays jit compiles),
    then the measured replay."""
    metrics, _ = run_service(
        suite=suite, requests=requests, slots=slots,
        iters_per_tick=iters_per_tick, seed=seed,
        warmup_requests=requests if warm else 0,
        arrival_rate=arrival_rate)
    emit(f"serve/{suite}/requests_per_s", metrics["requests_per_s"],
         f"completed={metrics['completed']};rhs={metrics['rhs_total']}")
    emit(f"serve/{suite}/ticks_per_s", metrics["ticks_per_s"],
         f"ticks={metrics['ticks']};slots={metrics['slots']}")
    emit(f"serve/{suite}/latency_p50_us", metrics["latency_p50_s"] * 1e6,
         f"p95_us={metrics['latency_p95_s']*1e6:.0f}")
    emit(f"serve/{suite}/queue_wait_p50_us",
         metrics["queue_wait_p50_s"] * 1e6,
         f"p95_us={metrics['queue_wait_p95_s']*1e6:.0f};"
         f"arrival_rate={arrival_rate}")
    emit(f"serve/{suite}/factor_batched_us", metrics["factor_s"] * 1e6,
         f"graphs={metrics['graphs']}")
    return metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="tiny", choices=["tiny", "small"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--iters-per-tick", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0,
                    help="trace RNG seed (rhs content + arrival gaps); "
                         "fixed default keeps JSON artifacts reproducible")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="open-loop Poisson arrival rate (req/s) passed "
                         "through to the trace, so the artifact records "
                         "queueing metrics")
    ap.add_argument("--no-warm", action="store_true",
                    help="skip the warmup replay (include compiles)")
    ap.add_argument("--json", default=None,
                    help="write service metrics to this JSON file "
                         "(uploaded as a CI artifact)")
    args = ap.parse_args()
    metrics = run(suite=args.suite, requests=args.requests,
                  slots=args.slots, iters_per_tick=args.iters_per_tick,
                  seed=args.seed, warm=not args.no_warm,
                  arrival_rate=args.arrival_rate)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(metrics, fh, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
