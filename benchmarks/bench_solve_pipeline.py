"""Paper Table 3 analogue: end-to-end jitted pipeline timings —
factor (wavefront engine, jit) + level-scheduled triangular-solve apply
+ PCG iterations, on the JAX production path (CPU backend here; the
same program lowers to TPU).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.data import graphs
from repro.core.parac import factorize_wavefront
from repro.core.trisolve import make_preconditioner
from repro.core.pcg import laplacian_pcg_jax
from repro.core.ordering import ORDERINGS

from .common import emit


def run(suite=None, tol=1e-6, maxiter=500):
    suite = suite or {k: graphs.SUITE[k] for k in
                      ("grid2d_64", "grid3d_contrast_16", "powerlaw_4k",
                       "delaunay_4k")}
    key = jax.random.key(0)
    rng = np.random.default_rng(0)
    for name, make in suite.items():
        g = make()
        perm = ORDERINGS["nnz-sort"](g, seed=1)
        gp = g.permute(perm).coalesce()

        t0 = time.perf_counter()
        f = factorize_wavefront(gp, key, chunk=256, fill_slack=32,
                                strict=False)
        t_factor = time.perf_counter() - t0

        t0 = time.perf_counter()
        precond = make_preconditioner(f)
        b = rng.normal(size=g.n).astype(np.float32)
        b -= b.mean()
        bp = jnp.asarray(b[np.argsort(perm)])  # permuted rhs
        solve = jax.jit(lambda bb: laplacian_pcg_jax(
            gp, precond, bb, tol=tol, maxiter=maxiter))
        res = solve(bp)   # includes trisolve-schedule compile
        jax.block_until_ready(res.x)
        t_first = time.perf_counter() - t0

        t0 = time.perf_counter()
        res = solve(bp)
        jax.block_until_ready(res.x)
        t_solve = time.perf_counter() - t0

        emit(f"table3/{name}/factor_s", t_factor * 1e6,
             f"rounds={f.stats['rounds']}")
        emit(f"table3/{name}/solve_s", t_solve * 1e6,
             f"iters={int(res.iters)};relres={float(res.relres):.2e};"
             f"first_call_s={t_first:.2f}")


if __name__ == "__main__":
    run()
