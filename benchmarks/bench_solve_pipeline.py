"""Paper Table 3 analogue: end-to-end jitted pipeline timings —
factor (wavefront engine + device compaction) + device schedule build +
PCG solves through the ``Solver`` API, single-rhs and batched multi-rhs
(the factor-once / serve-many shape).  CPU backend here; the same
program lowers to TPU.

CLI (used by the CI smoke job):

    PYTHONPATH=src python -m benchmarks.bench_solve_pipeline \
        --suite tiny --json bench_solve_pipeline.json
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.data import graphs
from repro.core.solver import Solver
from repro.core.ordering import ORDERINGS

from .common import emit


DEFAULT_SUITE = ("grid2d_64", "grid3d_contrast_16", "powerlaw_4k",
                 "delaunay_4k")


def tiny_suite():
    """Sub-second graphs for the CI smoke job (canonical registry)."""
    return {k: graphs.SUITE_TINY[k]
            for k in ("grid2d_tiny", "powerlaw_tiny")}


def run(suite=None, tol=1e-6, maxiter=500, nrhs=8, records=None):
    suite = suite or {k: graphs.SUITE[k] for k in DEFAULT_SUITE}
    key = jax.random.key(0)
    rng = np.random.default_rng(0)
    records = records if records is not None else []
    for name, make in suite.items():
        g = make()
        perm = ORDERINGS["nnz-sort"](g, seed=1)
        gp = g.permute(perm).coalesce()
        solver = Solver(chunk=256, fill_slack=32, strict=False)

        t0 = time.perf_counter()
        handle = solver.factor(gp, key)
        jax.block_until_ready(handle.factor.device.vals)
        t_factor = time.perf_counter() - t0

        b = rng.normal(size=g.n).astype(np.float32)
        b -= b.mean()
        bp = jnp.asarray(b[np.argsort(perm)])

        t0 = time.perf_counter()
        res = solver.solve(bp, tol=tol, maxiter=maxiter)  # + compile
        jax.block_until_ready(res.x)
        t_first = time.perf_counter() - t0

        t0 = time.perf_counter()
        res = solver.solve(bp, tol=tol, maxiter=maxiter)
        jax.block_until_ready(res.x)
        t_solve = time.perf_counter() - t0

        B = rng.normal(size=(nrhs, g.n)).astype(np.float32)
        B -= B.mean(axis=1, keepdims=True)
        Bp = jnp.asarray(B[:, np.argsort(perm)])
        resB = solver.solve(Bp, tol=tol, maxiter=maxiter)  # compile
        jax.block_until_ready(resB.x)
        t0 = time.perf_counter()
        resB = solver.solve(Bp, tol=tol, maxiter=maxiter)
        jax.block_until_ready(resB.x)
        t_batch = time.perf_counter() - t0

        emit(f"table3/{name}/factor_s", t_factor * 1e6,
             f"rounds={handle.factor.stats['rounds']};"
             f"levels={handle.n_levels}")
        emit(f"table3/{name}/solve_s", t_solve * 1e6,
             f"iters={int(res.iters)};relres={float(res.relres):.2e};"
             f"first_call_s={t_first:.2f}")
        emit(f"table3/{name}/batch{nrhs}_solve_s", t_batch * 1e6,
             f"iters_max={int(np.asarray(resB.iters).max())};"
             f"per_rhs_s={t_batch / nrhs:.4f}")
        records.append(dict(
            graph=name, n=g.n, m=g.m, nrhs=nrhs,
            factor_s=t_factor, solve_s=t_solve, first_call_s=t_first,
            batch_solve_s=t_batch, per_rhs_s=t_batch / nrhs,
            iters=int(res.iters), relres=float(res.relres),
            converged=bool(res.converged),
            batch_converged=bool(np.all(np.asarray(resB.converged))),
            rounds=int(handle.factor.stats["rounds"]),
            n_levels=int(handle.n_levels)))
    return records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="default",
                    choices=["default", "tiny"],
                    help="'tiny' = sub-second graphs for CI smoke")
    ap.add_argument("--nrhs", type=int, default=8)
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--maxiter", type=int, default=500)
    ap.add_argument("--json", default=None,
                    help="write timing records to this JSON file "
                         "(uploaded as a CI artifact)")
    args = ap.parse_args()
    suite = tiny_suite() if args.suite == "tiny" else None
    records = run(suite=suite, tol=args.tol, maxiter=args.maxiter,
                  nrhs=args.nrhs)
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(records, fh, indent=2)
        print(f"wrote {args.json} ({len(records)} records)")


if __name__ == "__main__":
    main()
