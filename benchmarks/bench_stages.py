"""Paper §5 stage breakdown: time the engine's three per-round stages
(gather+eliminate / factor write-back / scatter+dependency update) by
benchmarking the isolated batched column-elimination (jnp path and the
Pallas sample_clique kernel) against the full engine round rate.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.data import graphs
from repro.core.parac import factorize_wavefront, _build_pool
from repro.core.column_math import eliminate_column, column_uniforms
from repro.kernels import ops as kops

from .common import emit, time_call


def run():
    key = jax.random.key(0)
    g = graphs.grid3d_like() if hasattr(graphs, "grid3d_like") else \
        graphs.grid3d(16, 16, 16, "uniform", seed=2)

    # full engine rate
    t0 = time.perf_counter()
    f = factorize_wavefront(g, key, chunk=256, fill_slack=32, strict=False)
    t_engine = time.perf_counter() - t0
    emit("stages/engine_total_s", t_engine * 1e6,
         f"rounds={f.stats['rounds']};n={g.n}")

    # isolated stage-2 (sort+sample): batched eliminate_column, jnp path
    R, W = 256, 32
    rng = np.random.default_rng(0)
    ids = rng.integers(0, 4096, (R, W)).astype(np.int32)
    ws = rng.uniform(0.1, 10.0, (R, W)).astype(np.float32)
    fill = np.full(R, W, np.int32)
    u = np.asarray(jax.vmap(lambda v: column_uniforms(key, v, W))(
        jnp.arange(R, dtype=jnp.int32)))
    valid = np.ones((R, W), bool)

    jnp_fn = jax.jit(jax.vmap(eliminate_column))
    dt, _ = time_call(
        lambda: jax.block_until_ready(jnp_fn(
            jnp.asarray(ids), jnp.asarray(ws), jnp.asarray(valid),
            jnp.asarray(u))))
    emit("stages/eliminate_jnp_s", dt * 1e6, f"rows={R};width={W}")

    dt, _ = time_call(
        lambda: jax.block_until_ready(kops.sample_clique(
            jnp.asarray(ids), jnp.asarray(ws), jnp.asarray(fill),
            jnp.asarray(u))))
    emit("stages/eliminate_pallas_interp_s", dt * 1e6,
         "interpret-mode (CPU); TPU target lowers natively")


if __name__ == "__main__":
    run()
