"""CI gate over the ``BENCH_cluster.json`` artifact: machine-independent
cluster invariants, no committed baseline needed.

    python -m benchmarks.check_cluster_regression BENCH_cluster.json

Per routing-policy block:

* **request conservation across replicas** — every submitted request is
  either routed to exactly one replica or shed
  (``submitted == routed + shed``); every routed request reached a
  replica frontend (``routed == Σ frontend.submitted``) and resolved
  there (``Σ frontend.submitted == Σ (completed + failed)`` after the
  replay's drain — nothing blackholed);
* **per-replica engine invariants** — the same scheduler gates
  ``check_serve_regression`` applies to single engines (request
  conservation, starvation bound, no sealed backfill under
  ``max_skips == 0``), applied to every replica's engine counters;
* **routing counters** — ``affinity_hits + affinity_misses == routed``.

Across policies:

* ``factor_affinity`` must achieve a **strictly higher** affinity-hit
  rate than ``round_robin`` on the skewed trace — the economics the
  cluster exists for;
* when the artifact was produced with hot-factor replication enabled
  (``replicate_above`` set), the affinity run must show the replication
  path exercised (``replications >= 1``).

Factor-storm block (``factor_storm`` in the artifact, colocated vs
``factor_replicas=1``):

* disaggregated warm-request e2e **p95 strictly below** colocated —
  the cold burst must not stall the warm stream once construction
  leaves the serving drivers;
* colocated solve-driver ``control_s`` **strictly above** disaggregated
  — the stall is measured off the drivers, not inferred from latency;
* the disaggregated run actually used the tier (``adoptions >= storm
  size``, tier ``factored >= storm size``) and every storm request
  converged in both runs.
"""
from __future__ import annotations

import argparse
import json
import sys

from .check_serve_regression import _engine_failures


def _cluster_failures(name: str, metrics: dict) -> list:
    failures = []
    c = metrics.get("cluster")
    if not c:
        return [f"[{name}] no cluster counters in artifact"]
    if c["submitted"] != c["routed"] + c["shed"]:
        failures.append(
            f"[{name}] submitted={c['submitted']} != routed={c['routed']}"
            f" + shed={c['shed']} (cluster request conservation broken)")
    if c["affinity_hits"] + c["affinity_misses"] != c["routed"]:
        failures.append(
            f"[{name}] hits={c['affinity_hits']} + "
            f"misses={c['affinity_misses']} != routed={c['routed']} "
            f"(every route is a hit or a miss)")
    fe_submitted = fe_completed = fe_failed = 0
    for r in c["per_replica"]:
        fe = r["frontend"]
        fe_submitted += fe["submitted"]
        fe_completed += fe["completed"]
        fe_failed += fe["failed"]
        failures += _engine_failures(
            fe["engine"], label=f"{name}/replica{r['index']}",
            require_bucket_compiles=False)
    if fe_submitted != c["routed"]:
        failures.append(
            f"[{name}] sum of replica frontend.submitted={fe_submitted} "
            f"!= routed={c['routed']} (a routed request never reached "
            f"its replica)")
    if fe_completed + fe_failed != fe_submitted:
        failures.append(
            f"[{name}] replica completed+failed="
            f"{fe_completed}+{fe_failed} != submitted={fe_submitted} "
            f"(requests blackholed after drain)")
    return failures


def _storm_failures(storm: dict) -> list:
    failures = []
    col = storm.get("colocated")
    dis = storm.get("disaggregated")
    if not col or not dis:
        return ["[storm] factor_storm block incomplete (needs "
                "'colocated' and 'disaggregated' runs)"]
    for name, m in (("colocated", col), ("disaggregated", dis)):
        if m["storm_converged"] != m["storm_graphs"]:
            failures.append(
                f"[storm/{name}] only {m['storm_converged']} of "
                f"{m['storm_graphs']} cold storm requests converged")
    if not dis["warm_p95_s"] < col["warm_p95_s"]:
        failures.append(
            f"[storm] disaggregated warm p95 {dis['warm_p95_s']*1e3:.0f}"
            f"ms is not strictly below colocated "
            f"{col['warm_p95_s']*1e3:.0f}ms — the factor tier did not "
            f"unstall the warm stream")
    else:
        print(f"storm p95 OK: disaggregated "
              f"{dis['warm_p95_s']*1e3:.0f}ms < colocated "
              f"{col['warm_p95_s']*1e3:.0f}ms")
    if not col["solve_control_s"] > dis["solve_control_s"]:
        failures.append(
            f"[storm] colocated solve-driver control_s "
            f"{col['solve_control_s']:.1f}s is not strictly above "
            f"disaggregated {dis['solve_control_s']:.1f}s — "
            f"construction work did not leave the serving drivers")
    else:
        print(f"storm control_s OK: colocated "
              f"{col['solve_control_s']:.1f}s > disaggregated "
              f"{dis['solve_control_s']:.1f}s")
    tier = (dis.get("cluster") or {}).get("factor_tier") or {}
    factored = sum(w.get("factored", 0)
                   for w in tier.get("per_replica", []))
    if factored < dis["storm_graphs"]:
        failures.append(
            f"[storm] factor tier constructed {factored} factors for a "
            f"{dis['storm_graphs']}-graph storm (cold work leaked back "
            f"to the serving drivers)")
    if dis["adoptions"] < dis["storm_graphs"]:
        failures.append(
            f"[storm] solve replicas adopted {dis['adoptions']} < "
            f"{dis['storm_graphs']} payloads in the disaggregated run")
    return failures


def check(path: str) -> int:
    with open(path) as fh:
        art = json.load(fh)
    failures = []
    pols = art.get("policies") or {}
    for name, metrics in pols.items():
        failures += _cluster_failures(name, metrics)
    if {"affinity", "rr"} <= set(pols):
        a = float(pols["affinity"]["cluster"]["hit_rate"])
        r = float(pols["rr"]["cluster"]["hit_rate"])
        if not a > r:
            failures.append(
                f"[hit-rate] factor_affinity hit rate {a:.3f} is not "
                f"strictly higher than round_robin {r:.3f} on the "
                f"skewed trace")
        else:
            print(f"affinity hit rate OK: {a:.3f} > rr {r:.3f}")
    if "affinity" in pols and \
            pols["affinity"].get("replicate_above") is not None:
        reps = int(pols["affinity"]["cluster"]["replications"])
        if reps < 1:
            failures.append(
                "[replication] replicate_above was set but the affinity "
                "run promoted no hot factor to a second replica")
        else:
            print(f"replication path exercised: {reps} promotion(s)")
    if "factor_storm" in art:
        failures += _storm_failures(art["factor_storm"])
    for msg in failures:
        print(f"INVARIANT VIOLATED: {msg}")
    if not failures:
        print(f"cluster invariants OK over {len(pols)} policies: "
              f"request conservation across replicas, hit/miss "
              f"accounting, per-replica scheduler gates"
              + (", factor-storm disaggregation gates"
                 if "factor_storm" in art else ""))
    return 1 if failures else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh BENCH_cluster.json")
    args = ap.parse_args()
    sys.exit(check(args.current))


if __name__ == "__main__":
    main()
