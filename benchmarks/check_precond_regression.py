"""CI gate: validate a fresh ``BENCH_precond.json`` (the serving-zoo
artifact ``bench_convergence --json`` writes) against invariants and
the committed baseline.

    python -m benchmarks.check_precond_regression BENCH_precond.json \
        benchmarks/baselines/BENCH_precond.json

Three kinds of gate:

* **zoo health** (machine-independent): every registered family must
  have converged on every suite graph through the device-fleet serving
  path — a family that stops converging is broken, not slow;
* **AC iteration count** vs the committed baseline, per graph: the
  paper's preconditioner must stay within ``--max-iter-ratio`` of its
  recorded iterations (iterations are deterministic given the trace
  seed, so the default bar of 1.5 only absorbs intentional numeric
  changes — refresh with ``--write-baseline`` when construction
  changes on purpose);
* **adaptive selection**: on the recorded skewed deadline replay,
  ``--precond auto`` must never miss more SLOs than always-AC
  (``auto.slo_missed <= ac.slo_missed``) and both modes must complete
  the full trace.  The bound is relative *within one artifact*, so CI
  runner speed cancels: a machine where both modes miss everything
  still passes, a selector that picks pathological families does not.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys


def check(artifact: dict, baseline: dict, *,
          max_iter_ratio: float) -> list:
    failures = []

    fams = artifact.get("families", {})
    if not fams:
        failures.append("artifact has no family matrix "
                        "(families == {})")
    for graph, row in fams.items():
        for fam, r in row.items():
            if not r.get("converged", False):
                failures.append(
                    f"[{graph}/{fam}] did not converge "
                    f"(iters={r.get('iters')}, relres={r.get('relres')})")

    base_fams = baseline.get("families", {})
    for graph, row in fams.items():
        base_ac = base_fams.get(graph, {}).get("ac")
        ac = row.get("ac")
        if base_ac is None or ac is None:
            continue
        bound = max_iter_ratio * base_ac["iters"]
        if ac["iters"] > bound:
            failures.append(
                f"[{graph}/ac] iterations regressed: {ac['iters']} > "
                f"{max_iter_ratio} * baseline {base_ac['iters']}")

    replay = artifact.get("replay", {})
    ac_r, auto_r = replay.get("ac"), replay.get("auto")
    if ac_r is None or auto_r is None:
        failures.append("artifact replay section missing ac/auto modes")
    else:
        for mode, r in (("ac", ac_r), ("auto", auto_r)):
            if r["completed"] != r["requests"]:
                failures.append(
                    f"[replay/{mode}] completed={r['completed']} != "
                    f"requests={r['requests']} (trace not fully served)")
        if auto_r["slo_missed"] > ac_r["slo_missed"]:
            failures.append(
                f"[replay] adaptive selection missed more SLOs than "
                f"always-AC: auto={auto_r['slo_missed']} > "
                f"ac={ac_r['slo_missed']} "
                f"(of {ac_r['requests']} requests)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifact", help="fresh BENCH_precond.json")
    ap.add_argument("baseline",
                    help="committed benchmarks/baselines/BENCH_precond.json")
    ap.add_argument("--max-iter-ratio", type=float, default=1.5,
                    help="AC iterations may grow to at most this ratio "
                         "of the baseline per graph")
    ap.add_argument("--write-baseline", action="store_true",
                    help="copy the fresh artifact over the baseline "
                         "instead of gating (intentional refresh)")
    args = ap.parse_args()

    with open(args.artifact) as fh:
        artifact = json.load(fh)
    if args.write_baseline:
        shutil.copyfile(args.artifact, args.baseline)
        print(f"baseline refreshed: {args.baseline}")
        return 0
    with open(args.baseline) as fh:
        baseline = json.load(fh)

    failures = check(artifact, baseline,
                     max_iter_ratio=args.max_iter_ratio)
    if failures:
        print(f"PRECOND GATE FAILED ({len(failures)}):")
        for f in failures:
            print(f"  - {f}")
        return 1
    rep = artifact["replay"]
    print(f"precond gate OK: {len(artifact['families'])} graphs x "
          f"{len(next(iter(artifact['families'].values())))} families "
          f"converged; replay auto={rep['auto']['slo_missed']} <= "
          f"ac={rep['ac']['slo_missed']} SLO misses")
    return 0


if __name__ == "__main__":
    sys.exit(main())
