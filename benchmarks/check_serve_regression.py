"""CI gate: compare a fresh ``BENCH_serve.json`` against the committed
baseline and fail on a serving-throughput regression.

    python -m benchmarks.check_serve_regression BENCH_serve.json \
        benchmarks/baselines/BENCH_serve.json --max-ratio 2.0

Two kinds of gate:

* **deterministic invariants** (machine-independent, checked first):
  the artifact's engine counters must show one compiled step program
  per shape bucket (``step_compiles == buckets``) and conserved column
  traffic (``cols_in == cols_out`` — every admitted column retired).
  The compile equality is an invariant of *this benchmark's phase
  structure* (``bench_serve`` admits the whole fleet before serving, so
  fleet shapes never grow mid-run), not of the engine in general — a
  live service admitting a new factor to a grown bucket legitimately
  retraces.  Within the benchmark it is exactly the mega-batching
  contract: compiles scale with buckets, never with factors;
* **throughput ratio**: ``ticks_per_s`` vs the committed baseline
  (insensitive to request mix, sensitive to per-tick host glue).  The
  bar is deliberately loose (default: fail only when the baseline is
  more than ``--max-ratio`` times faster) because CI runners vary in
  speed; refresh the baseline with ``--write-baseline`` when the
  benchmark or reference hardware changes intentionally.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys


def check_invariants(current: dict) -> int:
    """Machine-independent engine-counter gates (no baseline needed)."""
    eng = current.get("engine")
    if not eng:
        print("no engine counters in artifact; invariant gate skipped")
        return 0
    failures = []
    if eng["step_compiles"] != eng["buckets"]:
        failures.append(
            f"step_compiles={eng['step_compiles']} != "
            f"buckets={eng['buckets']} (upfront-admission benchmark "
            f"should compile once per bucket, never per factor)")
    if eng["cols_in"] != eng["cols_out"]:
        failures.append(
            f"cols_in={eng['cols_in']} != cols_out={eng['cols_out']} "
            f"(column traffic not conserved)")
    for msg in failures:
        print(f"INVARIANT VIOLATED: {msg}")
    if not failures:
        print(f"engine invariants OK: step_compiles==buckets=="
              f"{eng['buckets']}, cols_in==cols_out=={eng['cols_in']}")
    return 1 if failures else 0


def check(current_path: str, baseline_path: str, *,
          metric: str = "ticks_per_s", max_ratio: float = 2.0) -> int:
    with open(current_path) as fh:
        current = json.load(fh)
    if check_invariants(current):
        return 1
    try:
        with open(baseline_path) as fh:
            baseline = json.load(fh)
    except FileNotFoundError:
        print(f"no baseline at {baseline_path} — nothing to gate "
              f"(commit one with --write-baseline)")
        return 0
    cur = float(current.get(metric, 0.0))
    base = float(baseline.get(metric, 0.0))
    if base <= 0:
        print(f"baseline {metric} is {base}; gate skipped")
        return 0
    ratio = base / cur if cur > 0 else float("inf")
    verdict = "OK" if ratio <= max_ratio else "REGRESSION"
    print(f"{metric}: current={cur:.2f} baseline={base:.2f} "
          f"slowdown={ratio:.2f}x (max {max_ratio:.2f}x) -> {verdict}")
    return 0 if verdict == "OK" else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh benchmark JSON")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--metric", default="ticks_per_s")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when baseline/current exceeds this")
    ap.add_argument("--write-baseline", action="store_true",
                    help="copy current over the baseline instead of "
                         "checking (baseline refresh)")
    args = ap.parse_args()
    if args.write_baseline:
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline refreshed: {args.baseline}")
        return
    sys.exit(check(args.current, args.baseline, metric=args.metric,
                   max_ratio=args.max_ratio))


if __name__ == "__main__":
    main()
