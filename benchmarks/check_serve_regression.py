"""CI gate: compare a fresh ``BENCH_serve.json`` against the committed
baseline and fail on a serving-throughput regression.

    python -m benchmarks.check_serve_regression BENCH_serve.json \
        benchmarks/baselines/BENCH_serve.json --max-ratio 2.0

Two kinds of gate:

* **deterministic invariants** (machine-independent, checked first):
  the artifact's engine counters must show one compiled step program
  per shape bucket (``step_compiles == buckets``) and conserved column
  traffic (``cols_in == cols_out`` — every admitted column retired).
  The compile equality is an invariant of *this benchmark's phase
  structure* (``bench_serve`` admits the whole fleet before serving, so
  fleet shapes never grow mid-run), not of the engine in general — a
  live service admitting a new factor to a grown bucket legitimately
  retraces.  Within the benchmark it is exactly the mega-batching
  contract: compiles scale with buckets, never with factors.

  Scheduler invariants (every engine-counter block in the artifact,
  including each policy-sweep entry): request conservation
  (``admitted_reqs == completed + in_flight_reqs``) and the backfill
  starvation bound (``backfill_skips <= max_skips * skipped_reqs``,
  degenerating to ``backfill_skips == 0`` for FIFO where
  ``max_skips == 0``).  Work-conserving admissions past a starvation
  seal are counted separately (``sealed_backfills``) and must never
  appear under a policy that cannot seal (``max_skips == 0``), so the
  starvation bound holds with seal backfill enabled.  When the
  artifact carries a wide-head
  ``policy_sweep``, the backfill policy must strictly beat FIFO on p95
  end-to-end latency — the scheduling contract the subsystem exists
  for.

  Padding-tax invariants (when the artifact carries the blocks): the
  ``tier_sweep`` replay's K-tiered engine must report strictly less
  padded sweep work (``sweep_elements``) than the untiered engine on
  the same hub-heavy trace — the K-tiering contract — with identical
  convergence counts (padding width never changes what converges);
  and the ``fleet_memory`` churn block must show
  ``fleet_device_bytes <= 1.5 x fleet_live_bytes`` after eviction +
  compaction, with at least one compaction run and every
  post-compaction solve converged (stacks really shrank, and shrinking
  them kept the engine's resident row indices coherent).

  The ``obs_overhead`` block (when present) gates the observability
  tax: the instrumented engine's best ticks/s must be at least 0.98x
  the plain engine's on the same interleaved closed-loop replay;
* **throughput ratio**: ``ticks_per_s`` vs the committed baseline
  (insensitive to request mix, sensitive to per-tick host glue).  The
  bar is deliberately loose (default: fail only when the baseline is
  more than ``--max-ratio`` times faster) because CI runners vary in
  speed; refresh the baseline with ``--write-baseline`` when the
  benchmark or reference hardware changes intentionally.
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys


def _engine_failures(eng: dict, *, label: str,
                     require_bucket_compiles: bool) -> list:
    failures = []
    if require_bucket_compiles and eng["step_compiles"] != eng["buckets"]:
        failures.append(
            f"[{label}] step_compiles={eng['step_compiles']} != "
            f"buckets={eng['buckets']} (upfront-admission benchmark "
            f"should compile once per bucket, never per factor)")
    if eng["cols_in"] != eng["cols_out"]:
        failures.append(
            f"[{label}] cols_in={eng['cols_in']} != "
            f"cols_out={eng['cols_out']} (column traffic not conserved)")
    # scheduler counters (absent in pre-scheduler artifacts)
    if "admitted_reqs" in eng:
        if eng["admitted_reqs"] != eng["completed"] + eng["in_flight_reqs"]:
            failures.append(
                f"[{label}] admitted_reqs={eng['admitted_reqs']} != "
                f"completed={eng['completed']} + "
                f"in_flight={eng['in_flight_reqs']} "
                f"(request conservation broken)")
        bound = eng["max_skips"] * eng["skipped_reqs"]
        if eng["backfill_skips"] > bound:
            failures.append(
                f"[{label}] backfill_skips={eng['backfill_skips']} > "
                f"max_skips*skipped_reqs={bound} "
                f"(starvation bound violated)")
        # work-conserving seal admissions are counted separately and must
        # never leak into the skip counters; a policy that cannot seal
        # (max_skips == 0, i.e. FIFO) must report none at all
        if eng["max_skips"] == 0 and eng.get("sealed_backfills", 0) != 0:
            failures.append(
                f"[{label}] sealed_backfills="
                f"{eng['sealed_backfills']} under max_skips=0 "
                f"(a policy that never seals cannot seal-backfill)")
    return failures


def _padding_failures(current: dict) -> list:
    """Gates on the tier_sweep / fleet_memory artifact blocks (absent
    in pre-tiering artifacts: both checks are then skipped)."""
    failures = []
    ts = current.get("tier_sweep") or {}
    modes = ts.get("modes") or {}
    if {"tiered", "untiered"} <= set(modes):
        t, u = modes["tiered"], modes["untiered"]
        if not t["sweep_elements"] < u["sweep_elements"]:
            failures.append(
                f"[tier_sweep] tiered sweep_elements="
                f"{t['sweep_elements']} not strictly below untiered="
                f"{u['sweep_elements']} (K-tiering is not cutting "
                f"padded sweep work on the hub-heavy trace)")
        if (t["completed"], t["converged"]) != \
                (u["completed"], u["converged"]):
            failures.append(
                f"[tier_sweep] convergence drift across tiering modes: "
                f"tiered {t['converged']}/{t['completed']} vs untiered "
                f"{u['converged']}/{u['completed']} (panel padding must "
                f"not change what converges)")
        if not failures:
            print(f"tier_sweep OK: sweep_elements "
                  f"{t['sweep_elements']} < {u['sweep_elements']} "
                  f"({ts.get('sweep_elements_ratio', 0.0):.2f}x "
                  f"untiered/tiered)")
    fm = current.get("fleet_memory")
    if fm:
        if fm["compactions"] < 1:
            failures.append(
                "[fleet_memory] no compaction ran under eviction churn "
                "(free-row threshold never triggered and the forced "
                "pass was a no-op)")
        live = fm["fleet_live_bytes"]
        if live and fm["fleet_device_bytes"] > 1.5 * live:
            failures.append(
                f"[fleet_memory] fleet_device_bytes="
                f"{fm['fleet_device_bytes']} > 1.5x live bytes={live} "
                f"(compaction left the stacks stranded at high-water "
                f"capacity)")
        if fm["post_compact_converged"] != fm["post_compact_completed"] \
                or fm["post_compact_completed"] == 0:
            failures.append(
                f"[fleet_memory] post-compaction replay converged "
                f"{fm['post_compact_converged']}/"
                f"{fm['post_compact_completed']} (rebuilt stacks or "
                f"engine row re-sync broke serving)")
        if not any(f.startswith("[fleet_memory]") for f in failures):
            print(f"fleet_memory OK: device={fm['fleet_device_bytes']} "
                  f"<= 1.5x live={live} after "
                  f"{fm['compactions']} compaction(s), "
                  f"post-compaction {fm['post_compact_converged']}/"
                  f"{fm['post_compact_completed']} converged")
    return failures


# instrumentation may cost at most this fraction of tick throughput —
# the off-hot-path contract of repro.obs, measured interleaved
# best-of-N so runner noise hits both arms alike
OBS_OVERHEAD_MIN_RATIO = 0.98


def _obs_overhead_failures(current: dict) -> list:
    """Gate on the ``obs_overhead`` block (absent in pre-observability
    artifacts: check skipped): the instrumented engine must hold at
    least ``OBS_OVERHEAD_MIN_RATIO`` of the plain engine's best
    ticks/s on the same trace."""
    ob = current.get("obs_overhead")
    if not ob:
        return []
    failures = []
    ratio = float(ob.get("ratio", 0.0))
    if ratio < OBS_OVERHEAD_MIN_RATIO:
        failures.append(
            f"[obs_overhead] instrumented/plain ticks_per_s ratio="
            f"{ratio:.3f} < {OBS_OVERHEAD_MIN_RATIO} "
            f"(instrumented={ob['instrumented_ticks_per_s']:.0f}/s vs "
            f"plain={ob['plain_ticks_per_s']:.0f}/s — metrics/tracing/"
            f"flight/health are taxing the serve hot path)")
    # newer artifacts carry the flight-recorder arm: the instrumented
    # engine must actually have recorded lifecycle events, else the
    # ratio gate is vacuously passing a disconnected recorder
    if "flight_events" in ob and int(ob["flight_events"]) <= 0:
        failures.append(
            "[obs_overhead] flight_events=0 — the instrumented arm's "
            "flight recorder saw no admit/retire events (hook wiring "
            "broken), so the overhead ratio no longer measures the "
            "full observability stack")
    if failures:
        return failures
    print(f"obs_overhead OK: instrumented/plain ratio={ratio:.3f} "
          f">= {OBS_OVERHEAD_MIN_RATIO} "
          f"({ob['traces_recorded']} traces recorded, "
          f"{ob.get('flight_events', 'n/a')} flight events)")
    return []


def check_invariants(current: dict) -> int:
    """Machine-independent engine-counter gates (no baseline needed)."""
    eng = current.get("engine")
    if not eng:
        print("no engine counters in artifact; invariant gate skipped")
        return 0
    failures = _engine_failures(eng, label="main",
                                require_bucket_compiles=True)
    sweep = current.get("policy_sweep") or {}
    for name, m in (sweep.get("policies") or {}).items():
        if "engine" in m:
            # sweep engines serve one graph: still one bucket/compile
            failures += _engine_failures(m["engine"], label=name,
                                         require_bucket_compiles=True)
    failures += _padding_failures(current)
    failures += _obs_overhead_failures(current)
    if {"fifo", "priority"} <= set(sweep.get("policies") or {}):
        f95 = float(sweep["policies"]["fifo"]["latency_p95_s"])
        b95 = float(sweep["policies"]["priority"]["latency_p95_s"])
        if not b95 < f95:
            failures.append(
                f"[sweep] backfill did not improve p95 e2e latency on "
                f"the wide-head trace: priority={b95:.4f}s vs "
                f"fifo={f95:.4f}s")
        else:
            print(f"backfill p95 OK: priority={b95:.4f}s < "
                  f"fifo={f95:.4f}s "
                  f"({f95/b95:.1f}x)")
    for msg in failures:
        print(f"INVARIANT VIOLATED: {msg}")
    if not failures:
        print(f"engine invariants OK: step_compiles==buckets=="
              f"{eng['buckets']}, cols_in==cols_out=={eng['cols_in']}, "
              f"admitted=={eng.get('admitted_reqs', 'n/a')}==retired+"
              f"in_flight, backfill_skips<=max_skips*skipped_reqs")
    return 1 if failures else 0


def check(current_path: str, baseline_path: str, *,
          metric: str = "ticks_per_s", max_ratio: float = 2.0) -> int:
    with open(current_path) as fh:
        current = json.load(fh)
    if check_invariants(current):
        return 1
    try:
        with open(baseline_path) as fh:
            baseline = json.load(fh)
    except FileNotFoundError:
        print(f"no baseline at {baseline_path} — nothing to gate "
              f"(commit one with --write-baseline)")
        return 0
    cur = float(current.get(metric, 0.0))
    base = float(baseline.get(metric, 0.0))
    if base <= 0:
        print(f"baseline {metric} is {base}; gate skipped")
        return 0
    ratio = base / cur if cur > 0 else float("inf")
    verdict = "OK" if ratio <= max_ratio else "REGRESSION"
    print(f"{metric}: current={cur:.2f} baseline={base:.2f} "
          f"slowdown={ratio:.2f}x (max {max_ratio:.2f}x) -> {verdict}")
    return 0 if verdict == "OK" else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh benchmark JSON")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--metric", default="ticks_per_s")
    ap.add_argument("--max-ratio", type=float, default=2.0,
                    help="fail when baseline/current exceeds this")
    ap.add_argument("--write-baseline", action="store_true",
                    help="copy current over the baseline instead of "
                         "checking (baseline refresh)")
    args = ap.parse_args()
    if args.write_baseline:
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline refreshed: {args.baseline}")
        return
    sys.exit(check(args.current, args.baseline, metric=args.metric,
                   max_ratio=args.max_ratio))


if __name__ == "__main__":
    main()
