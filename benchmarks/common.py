"""Shared benchmark helpers: timing, CSV emission, graph suite."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np


def time_call(fn: Callable, *args, repeats: int = 3, warmup: int = 1):
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def block(x):
    import jax
    return jax.block_until_ready(x)
