"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
results/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir results/dryrun]

With ``--kernels BENCH_fleet_kernels.json`` also renders the serving
kernel microbench (``benchmarks.bench_fleet_kernels`` artifact) as a
measured-bandwidth table: achieved bytes/s per kernel against the
device-copy proxy recorded in the same artifact, so the kernel-level
roofline fraction sits next to the model-level one.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def load(dirpath):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def roofline_table(recs, mesh="16x16"):
    rows = []
    for r in recs:
        if r["mesh"] != mesh:
            continue
        key = f'{r["arch"]} × {r["shape"]}'
        if r["status"] == "skipped":
            rows.append(f"| {key} | — | — | — | — | skipped: {r['note']} |")
            continue
        if r["status"] != "ok" or "roofline" not in r:
            rows.append(f"| {key} | — | — | — | — | "
                        f"FAILED: {r.get('error','?')[:60]} |")
            continue
        t = r["roofline"]
        dom = t["dominant"]
        rows.append(
            f"| {key} | {t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} "
            f"| {t['collective_s']*1e3:.2f} | **{dom}** "
            f"| rf={t['roofline_fraction']:.2f} "
            f"useful={t['useful_fraction']:.2f} |")
    hdr = ("| arch × shape | compute (ms) | memory (ms) | collective (ms) "
           "| bound | fractions |\n|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def dryrun_table(recs):
    rows = []
    for r in recs:
        key = f'{r["arch"]} × {r["shape"]} × {r["mesh"]}'
        if r["status"] == "skipped":
            rows.append(f"| {key} | skipped | {r['note']} |")
        elif r["status"] == "ok":
            mem = r["mem"]
            cf = r.get("cost", r.get("cost_full_scanbody_once", {}))
            coll = cf.get("coll_by_op", {})
            coll_s = ", ".join(f"{k}:{fmt_bytes(v)}G"
                               for k, v in sorted(coll.items()) if v) or "none"
            rows.append(
                f"| {key} | ok ({r['compile_s']}s) | "
                f"args {fmt_bytes(mem['argument_bytes'])}G + "
                f"temp {fmt_bytes(mem['temp_bytes'])}G; {coll_s} |")
        else:
            rows.append(f"| {key} | FAILED | {r.get('error','')[:80]} |")
    hdr = ("| cell | compile | bytes/device + collective schedule |\n"
           "|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def kernels_table(rec):
    """Markdown table for a ``bench_fleet_kernels`` artifact: achieved
    bytes/s per serving kernel vs the artifact's own device-copy
    bandwidth proxy (the sustained ceiling on that machine)."""
    rows = []
    for r in rec.get("records", []):
        shape = ",".join(f"{k}={v}" for k, v in r["shape"].items())
        rows.append(
            f"| {r['kernel']} | {shape} | {r['time_us']:.1f} "
            f"| {r['bytes']/1e6:.2f} | {r['achieved_gbs']:.2f} "
            f"| {r['frac_of_copy']:.3f} |")
    hdr = (f"backend={rec.get('backend','?')} "
           f"interpret={rec.get('interpret','?')} "
           f"copy-proxy={rec.get('copy_gbs', 0.0):.2f} GB/s\n\n"
           "| kernel | shape | time (us) | MB moved | GB/s "
           "| frac of copy |\n|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--section", default="both",
                    choices=("roofline", "dryrun", "both"))
    ap.add_argument("--kernels", default=None,
                    help="bench_fleet_kernels JSON artifact to render "
                         "as a measured kernel-bandwidth table")
    args = ap.parse_args()
    if args.kernels:
        with open(args.kernels) as fh:
            print("\n### Serving kernels (measured)\n")
            print(kernels_table(json.load(fh)))
        if not glob.glob(os.path.join(args.dir, "*.json")):
            return           # kernels-only invocation: no dryrun cells
    recs = load(args.dir)
    ok = sum(r["status"] == "ok" for r in recs)
    sk = sum(r["status"] == "skipped" for r in recs)
    fl = len(recs) - ok - sk
    print(f"<!-- {len(recs)} cells: {ok} ok, {sk} skipped, {fl} failed -->")
    if args.section in ("roofline", "both"):
        print("\n### Roofline (single-pod 16×16, per-device terms)\n")
        print(roofline_table(recs, "16x16"))
        print("\n### Roofline (multi-pod 2×16×16)\n")
        print(roofline_table(recs, "2x16x16"))
    if args.section in ("dryrun", "both"):
        print("\n### Dry-run detail\n")
        print(dryrun_table(recs))


if __name__ == "__main__":
    main()
