"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small graph suite only")
    ap.add_argument("--only", default=None,
                    help="comma list: convergence,etree,scaling,pipeline,"
                         "stages")
    args = ap.parse_args()

    from repro.data import graphs
    suite = graphs.SUITE if not args.quick else {
        "grid2d_64": graphs.SUITE["grid2d_64"],
        "powerlaw_4k": graphs.SUITE["powerlaw_4k"],
    }
    which = set((args.only or "convergence,etree,scaling,pipeline,stages")
                .split(","))

    t0 = time.time()
    print("name,us_per_call,derived")
    if "convergence" in which:
        from . import bench_convergence
        bench_convergence.run(suite)
    if "etree" in which:
        from . import bench_etree
        bench_etree.run(suite)
    if "scaling" in which:
        from . import bench_factor_scaling
        bench_factor_scaling.run()
    if "pipeline" in which:
        from . import bench_solve_pipeline
        bench_solve_pipeline.run()
    if "stages" in which:
        from . import bench_stages
        bench_stages.run()
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
