"""Quickstart: build a Laplacian, construct the ParAC preconditioner in
parallel, and solve with PCG — the paper's core loop in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

from repro.data import graphs
from repro.core.parac import factorize_wavefront
from repro.core.trisolve import make_preconditioner
from repro.core.pcg import laplacian_pcg_jax
from repro.core.ordering import ORDERINGS
from repro.core import etree

# a high-contrast 3D Poisson problem (paper Table 1 family)
g = graphs.grid3d(12, 12, 12, kind="contrast", seed=0)
print(f"graph: {g.n} vertices, {g.m} edges")

# nnz-sort elimination ordering (the paper's best GPU ordering)
perm = ORDERINGS["nnz-sort"](g, seed=0)
gp = g.permute(perm).coalesce()

# parallel randomized Cholesky (bulk-synchronous wavefronts)
f = factorize_wavefront(gp, jax.random.key(0), chunk=256)
print(f"factor: nnz={f.nnz}, fill_ratio={f.fill_ratio(g):.2f}, "
      f"wavefront rounds={f.stats['rounds']}, "
      f"actual e-tree height={etree.actual_etree_height(f)} "
      f"(vs classical {etree.classical_etree_height(g, perm)})")

# PCG with the G D Gᵀ preconditioner
rng = np.random.default_rng(0)
b = rng.normal(size=g.n)
b -= b.mean()
bp = jax.numpy.asarray(b[np.argsort(perm)], dtype=jax.numpy.float32)
res = jax.jit(lambda bb: laplacian_pcg_jax(
    gp, make_preconditioner(f), bb, tol=1e-6, maxiter=500))(bp)
print(f"PCG: {int(res.iters)} iterations, relres={float(res.relres):.2e}")
assert bool(res.converged)
