"""Serve a small model with batched requests through the slot-based
continuous-batching engine (decode path = the same serve_step the
dry-run lowers at scale).

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import transformer as tf
from repro.models.common import init_params
from repro.serve import ServeEngine, Request

cfg = get_smoke_config("qwen3-14b")
params = init_params(tf.pdefs(cfg), jax.random.key(0), jnp.float32)
engine = ServeEngine(cfg, params, slots=4, max_len=64)

rng = np.random.default_rng(0)
reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab, 8,
                                           dtype=np.int32),
                max_new_tokens=12, temperature=0.0 if i % 2 else 0.8)
        for i in range(6)]
for r in reqs:
    engine.submit(r)

ticks = 0
while (not engine.queue.empty()) or any(a is not None for a in engine.active):
    out = engine.tick()
    ticks += 1
    if out:
        print(f"tick {ticks:3d}: emitted {out}")
    if ticks > 200:
        break

for r in reqs:
    assert r.out_tokens and len(r.out_tokens) == r.max_new_tokens, r.rid
    print(f"request {r.rid}: {len(r.out_tokens)} tokens -> "
          f"{r.out_tokens[:8]}...")
print(f"served {len(reqs)} requests in {ticks} engine ticks "
      f"(continuous batching over 4 slots)")
