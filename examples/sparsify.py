"""Incremental spectral sparsification (paper §1: "situations where the
input changes every round, such as incremental sparsification") — the
regime where ParAC's near-zero preprocessing wins over nested-dissection
pipelines.

Each round: construct the randomized factor of the current graph (no
symbolic pre-processing!), estimate effective resistances from the
factor via Johnson-Lindenstrauss sketching of G⁻¹ edge indicators, and
resample edges proportional to leverage scores.

    PYTHONPATH=src python examples/sparsify.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.data import graphs
from repro.core.laplacian import Graph, laplacian_dense
from repro.core.parac import factorize_wavefront
from repro.core.trisolve import make_preconditioner
from repro.core.pcg import laplacian_pcg_jax
from repro.core.ordering import ORDERINGS

rng = np.random.default_rng(0)
g = graphs.random_regular(512, 8, seed=2)
print(f"start: n={g.n} m={g.m}")

Q = 12                                     # JL sketch dimension
for rnd in range(3):
    perm = ORDERINGS["nnz-sort"](g, seed=rnd)
    gp = g.permute(perm).coalesce()
    iperm = np.argsort(perm)
    f = factorize_wavefront(gp, jax.random.key(rnd), chunk=256,
                            strict=False)
    precond = make_preconditioner(f)
    solve = jax.jit(lambda bb: laplacian_pcg_jax(
        gp, precond, bb, tol=1e-4, maxiter=200).x)
    # effective resistance sketch: R_e ≈ ||Z (e_u - e_v)||², Z = Q^{-1/2} L⁺ B W^{1/2}
    zs = []
    for q in range(Q):
        s = rng.choice([-1.0, 1.0], g.m) * np.sqrt(g.w)
        b = np.zeros(g.n)
        np.add.at(b, g.src, s)
        np.add.at(b, g.dst, -s)
        b -= b.mean()
        zs.append(np.asarray(solve(jnp.asarray(b[iperm],
                                               jnp.float32)))[perm])
    Z = np.stack(zs) / np.sqrt(Q)
    reff = np.sum((Z[:, g.src] - Z[:, g.dst]) ** 2, axis=0)
    lev = np.clip(g.w * reff, 1e-6, 1.0)    # leverage ≈ w·R_eff
    keep_p = np.clip(lev * 4.0, 0.05, 1.0)
    keep = rng.random(g.m) < keep_p
    g = Graph(g.n, g.src[keep], g.dst[keep],
              (g.w[keep] / keep_p[keep]).astype(np.float32)).coalesce()
    print(f"round {rnd}: kept {keep.sum()}/{keep.size} edges -> m={g.m}")

# sanity: sparsifier preserves quadratic forms of the original roughly
print("done: final sparsifier", g.m, "edges")
