"""Spectral graph embedding via ParAC-preconditioned inverse power
iteration — the graph-learning use case from the paper's introduction
(spectral partitioning / Laplacian learning).

Computes the first k nontrivial Laplacian eigenvectors by orthogonal
inverse iteration, where every linear solve L x = b uses PCG with the
randomized Cholesky preconditioner, then bi-partitions the graph by the
Fiedler vector's sign.

    PYTHONPATH=src python examples/spectral_embedding.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.data import graphs
from repro.core.parac import factorize_wavefront
from repro.core.trisolve import make_preconditioner
from repro.core.pcg import laplacian_pcg_jax
from repro.core.laplacian import laplacian_matvec_np
from repro.core.ordering import ORDERINGS

k = 4
g = graphs.road_like(24, seed=3)          # two-ish communities road grid
perm = ORDERINGS["nnz-sort"](g, seed=0)
gp = g.permute(perm).coalesce()
f = factorize_wavefront(gp, jax.random.key(0), chunk=256)
precond = make_preconditioner(f)
solve = jax.jit(lambda bb: laplacian_pcg_jax(gp, precond, bb,
                                             tol=1e-7, maxiter=400).x)

rng = np.random.default_rng(0)
V = rng.normal(size=(g.n, k)).astype(np.float32)
iperm = np.argsort(perm)
for it in range(12):
    # inverse power step: V <- L⁺ V (per column), then orthonormalize
    cols = []
    for j in range(k):
        b = V[:, j] - V[:, j].mean()
        x = np.asarray(solve(jnp.asarray(b[iperm])))[perm]
        cols.append(x - x.mean())
    V = np.stack(cols, axis=1)
    V, _ = np.linalg.qr(V)

# Rayleigh quotients ≈ smallest nontrivial eigenvalues
lams = []
for j in range(k):
    Lv = laplacian_matvec_np(g, V[:, j].astype(np.float64))
    lams.append(float(V[:, j] @ Lv))
order = np.argsort(lams)
lams = np.array(lams)[order]
fiedler = V[:, order[0]]
cut = fiedler >= 0
cut_edges = np.sum(cut[g.src] != cut[g.dst])
print(f"approx eigenvalues: {np.round(lams, 5)}")
print(f"Fiedler bipartition: {cut.sum()} vs {(~cut).sum()} vertices, "
      f"{cut_edges}/{g.m} edges cut ({100 * cut_edges / g.m:.1f}%)")
assert cut_edges / g.m < 0.5
