"""End-to-end driver: train a ~100M-parameter qwen3-family model for a
few hundred steps on the synthetic pipeline, with checkpoints and an
(optional) simulated mid-run crash + resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--crash-at 60]
"""
import argparse
import dataclasses
import shutil

import jax

from repro.configs import get_smoke_config
from repro.configs.shapes import ShapeCell
from repro.launch.mesh import make_host_mesh
from repro.train import Trainer, TrainConfig


def build_cfg():
    # ~100M params: 12L, d=512, ff=2048, vocab 32k
    base = get_smoke_config("qwen3-14b")
    return dataclasses.replace(
        base, n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab=32_000, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--crash-at", type=int, default=0,
                    help="simulate a failure at this step, then resume")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = build_cfg()
    n = cfg.param_count()
    print(f"model: {cfg.name}-derived, {n/1e6:.0f}M params")
    mesh = make_host_mesh(1, 1)
    cell = ShapeCell("example", "train", args.seq, args.batch)
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    tcfg = TrainConfig(steps=args.steps, ckpt_every=50,
                       ckpt_dir=args.ckpt_dir, lr=3e-4, log_every=20)
    trainer = Trainer(cfg, mesh, cell, tcfg)
    trainer.init_or_restore()

    if args.crash_at:
        # run until the crash point, drop everything, then resume
        tcfg_short = dataclasses.replace(tcfg, steps=args.crash_at)
        trainer.tcfg = tcfg_short
        trainer.run(on_step=lambda s, m: print("  ", m))
        print(f"-- simulated crash at step {trainer.step}; restarting --")
        trainer = Trainer(cfg, mesh, cell, tcfg)
        resumed = trainer.init_or_restore()
        print(f"resumed={resumed} at step {trainer.step}")

    hist = trainer.run(on_step=lambda s, m: print("  ", m))
    first, last = hist[0]["ce"], hist[-1]["ce"]
    print(f"CE {first:.3f} -> {last:.3f} over {trainer.step} steps")
    assert last < first, "loss should decrease"


if __name__ == "__main__":
    main()
