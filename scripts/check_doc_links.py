"""Check that every intra-repo link in the markdown docs resolves.

    python scripts/check_doc_links.py [README.md docs/*.md ...]

With no arguments, checks ``README.md``, ``ROADMAP.md`` and every
``.md`` under ``docs/``.  External links (``http(s)://``, ``mailto:``)
are ignored; relative links are resolved against the linking file's
directory and must point at an existing file (anchors are stripped —
``foo.md#section`` checks ``foo.md``).  Exit code 1 lists every broken
link.
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
EXTERNAL = ("http://", "https://", "mailto:")


def broken_links(path: pathlib.Path, root: pathlib.Path) -> list:
    bad = []
    text = path.read_text(encoding="utf-8")
    for m in LINK.finditer(text):
        target = m.group(1)
        if target.startswith(EXTERNAL) or target.startswith("#"):
            continue
        rel = target.split("#", 1)[0]
        resolved = (path.parent / rel).resolve()
        if not resolved.exists():
            line = text[:m.start()].count("\n") + 1
            bad.append((f"{path.relative_to(root)}:{line}", target))
    return bad


def main(argv: list) -> int:
    root = pathlib.Path(__file__).resolve().parent.parent
    if argv:
        files = [pathlib.Path(a).resolve() for a in argv]
    else:
        files = [root / "README.md", root / "ROADMAP.md"]
        files += sorted((root / "docs").glob("**/*.md"))
    files = [f for f in files if f.exists()]
    bad = []
    for f in files:
        bad.extend(broken_links(f, root))
    if bad:
        print(f"BROKEN DOC LINKS ({len(bad)}):")
        for where, target in bad:
            print(f"  {where}: {target}")
        return 1
    print(f"doc links OK: {len(files)} files checked")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
