"""Insert the generated dry-run/roofline tables into EXPERIMENTS.md and
print baseline -> optimized deltas."""
import io
import json
import glob
import sys
sys.path.insert(0, "src")
from benchmarks.roofline_report import load, roofline_table, dryrun_table

new = load("results/dryrun")
old = load("results/dryrun_baseline")

# deltas on the dominant term for cells whose bound moved >5%
omap = {(r["arch"], r["shape"], r["mesh"]): r for r in old}
deltas = []
for r in new:
    k = (r["arch"], r["shape"], r["mesh"])
    if k in omap and r.get("roofline") and omap[k].get("roofline"):
        b0 = omap[k]["roofline"]["bound_s"]
        b1 = r["roofline"]["bound_s"]
        if b0 > 0 and abs(b1 - b0) / b0 > 0.05 and r["mesh"] == "16x16":
            deltas.append((k[0], k[1], b0, b1, b0 / b1))
deltas.sort(key=lambda d: -d[4])
dl = ["| cell | paper-faithful baseline bound | optimized bound | speedup |",
      "|---|---|---|---|"]
for a, s, b0, b1, sp in deltas:
    dl.append(f"| {a} × {s} | {b0*1e3:.2f} ms | {b1*1e3:.2f} ms | "
              f"**{sp:.2f}×** |")
delta_tbl = "\n".join(dl)

ok = sum(r["status"] == "ok" for r in new)
sk = sum(r["status"] == "skipped" for r in new)
summary = (f"{len(new)} cells: **{ok} compiled ok, {sk} documented skips, "
           f"{len(new)-ok-sk} failed** (single-pod 16×16 and multi-pod "
           f"2×16×16).")

text = open("EXPERIMENTS.md").read()
text = text.replace("<!-- DRYRUN_TABLE -->",
                    summary + "\n\n" + dryrun_table(new))
text = text.replace(
    "<!-- ROOFLINE_TABLE -->",
    "### Single-pod 16×16 (per-device terms)\n\n"
    + roofline_table(new, "16x16")
    + "\n\n### Multi-pod 2×16×16\n\n" + roofline_table(new, "2x16x16")
    + "\n\n### Baseline → optimized deltas (dominant term, cells that "
      "moved >5%)\n\nThe paper-faithful baseline sweep is preserved in "
      "`results/dryrun_baseline/`; the table above reflects the adopted "
      "beyond-baseline optimizations (§Perf).\n\n" + delta_tbl)
open("EXPERIMENTS.md", "w").write(text)
print(delta_tbl)
print("\nwrote EXPERIMENTS.md")
