"""Perf hillclimb driver: run a cell under variants, print roofline terms.

    PYTHONPATH=src python scripts/hillclimb.py <arch> <shape> \
        '{"feature_shard": true}' [--cfg '{"remat": false}']
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import argparse, json, sys
sys.path.insert(0, "src")

ap = argparse.ArgumentParser()
ap.add_argument("arch")
ap.add_argument("shape")
ap.add_argument("variant", nargs="?", default="{}")
ap.add_argument("--cfg", default="{}")
ap.add_argument("--multi-pod", action="store_true")
args = ap.parse_args()

from repro.launch.dryrun import run_cell
r = run_cell(args.arch, args.shape, args.multi_pod,
             variant=json.loads(args.variant),
             cfg_override=json.loads(args.cfg) or None)
out = {k: r.get(k) for k in ("status", "error")}
if "roofline" in r:
    t = r["roofline"]
    out.update({k: round(v, 6) if isinstance(v, float) else v
                for k, v in t.items()})
    out["coll_by_op_GB"] = {k: round(v / 1e9, 2)
                            for k, v in r["cost"]["coll_by_op"].items()}
if "mem" in r:
    out["peak_GB"] = round(r["mem"]["peak_bytes"] / 1e9, 2)
print(json.dumps(out, indent=1))
