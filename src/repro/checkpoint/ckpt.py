"""Preemption-safe pytree checkpointing.

Layout: ``<dir>/step_<N>/`` with one ``.npy`` per flattened leaf plus a
msgpack manifest (tree structure, dtypes, step).  Writes go to a
``.tmp`` directory that is atomically renamed — a killed writer never
corrupts the latest checkpoint, which is what checkpoint/restart fault
tolerance needs.  ``keep`` bounds disk use; restore validates the
manifest hash against the tree structure it is asked to fill.

On a real multi-host cluster each host writes its own addressable shards
(jax.experimental.multihost_utils); on this single-process container the
full arrays are written.  The API (save/restore/latest_step) is what the
trainer codes against either way.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
from typing import Any, Optional, Tuple

import numpy as np
import jax


def _tree_signature(treedef) -> str:
    return hashlib.sha1(str(treedef).encode()).hexdigest()[:16]


def save_checkpoint(directory: str, step: int, tree: Any,
                    keep: int = 3) -> str:
    d = pathlib.Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    tmp = d / f".tmp_step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    manifest = {"step": step, "n_leaves": len(leaves),
                "sig": _tree_signature(treedef)}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"leaf_{i}.npy", arr)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    final = d / f"step_{step}"
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic publish
    # GC old checkpoints
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*"))
    for s in steps[:-keep]:
        shutil.rmtree(d / f"step_{s}", ignore_errors=True)
    return str(final)


def latest_step(directory: str) -> Optional[int]:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like: Any,
                       step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``tree_like`` (values ignored)."""
    d = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    src = d / f"step_{step}"
    manifest = json.loads((src / "manifest.json").read_text())
    leaves, treedef = jax.tree.flatten(tree_like)
    if manifest["sig"] != _tree_signature(treedef):
        raise ValueError("checkpoint tree structure mismatch")
    if manifest["n_leaves"] != len(leaves):
        raise ValueError("checkpoint leaf count mismatch")
    out = [np.load(src / f"leaf_{i}.npy") for i in range(len(leaves))]
    restored = jax.tree.unflatten(treedef, out)
    return restored, step
