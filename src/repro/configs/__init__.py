"""Architecture registry: ``get_config(name)`` / ``list_archs()``.

Each module defines ``CONFIG`` (the exact assigned architecture),
``smoke_config()`` (a reduced same-family config for CPU tests) and
shares the shape cells in :mod:`repro.configs.shapes`.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCHS: List[str] = [
    "mamba2_1p3b",
    "qwen1p5_4b",
    "qwen3_14b",
    "phi3_medium_14b",
    "gemma3_27b",
    "moonshot_v1_16b_a3b",
    "llama4_scout_17b_16e",
    "recurrentgemma_2b",
    "chameleon_34b",
    "whisper_tiny",
]

# canonical ids from the assignment -> module names
ALIASES: Dict[str, str] = {
    "mamba2-1.3b": "mamba2_1p3b",
    "qwen1.5-4b": "qwen1p5_4b",
    "qwen3-14b": "qwen3_14b",
    "phi3-medium-14b": "phi3_medium_14b",
    "gemma3-27b": "gemma3_27b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_16e",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "chameleon-34b": "chameleon_34b",
    "whisper-tiny": "whisper_tiny",
}


def _module(name: str):
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str, production: bool = False) -> ModelConfig:
    """``production=True`` applies mesh-driven padding (heads/vocab)."""
    cfg = _module(name).CONFIG
    if production:
        import dataclasses
        cfg = dataclasses.replace(cfg, pad_heads_multiple=16,
                                  pad_vocab_multiple=256)
    return cfg


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def list_archs() -> List[str]:
    return list(ALIASES)
