"""chameleon-34b — early-fusion VLM backbone, VQ image tokens in the
vocab [arXiv:2405.09818].  48L d_model=8192 64H (kv=8) d_ff=22016
vocab=65536, qk-norm.  The VQ tokenizer frontend is a stub per the
assignment: inputs are token ids over the joint text+image vocabulary."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab=65536,
    pattern=("attn",), qk_norm=True, rope_theta=1e4, mlp_act="silu",
)


def smoke_config() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512)
