"""gemma3-27b — 5:1 local:global hybrid, 128k context
[hf:google/gemma-3-1b-pt].  62L d_model=5376 32H (kv=16) d_ff=21504
vocab=262144, local window 1024, qk-norm, sqrt(d) embedding scale."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="hybrid",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab=262144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    qk_norm=True, local_window=1024, rope_theta=1e6,
    mlp_act="gelu", emb_scale=True, use_post_norm=True, tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=7, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, local_window=16)
