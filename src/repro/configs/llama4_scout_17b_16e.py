"""llama4-scout-17b-a16e — MoE 16e top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].  48L d_model=5120 40H (kv=8)
d_ff=8192 vocab=202048."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048,
    pattern=("attn",), mlp_act="silu", rope_theta=5e5,
    n_experts=16, top_k=1, moe_d_ff=8192, n_shared_experts=1,
)


def smoke_config() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512, n_experts=4, top_k=1, moe_d_ff=128,
        n_shared_experts=1, capacity_factor=4.0)
