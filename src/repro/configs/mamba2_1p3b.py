"""mamba2-1.3b — SSD (state-space duality), attention-free
[arXiv:2405.21060].  48L d_model=2048 d_ff=0 vocab=50280 ssm_state=128."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab=50280,
    pattern=("ssm",),
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128, ssm_conv=4,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, vocab=512, ssm_state=16,
        ssm_head_dim=32, ssm_chunk=32)
