"""moonshot-v1-16b-a3b — MoE 64e top-6 (kimi/moonlight)
[hf:moonshotai/Moonlight-16B-A3B].  48L d_model=2048 16H (kv=16)
expert d_ff=1408 vocab=163840.  (The assignment's 48-layer config yields
~28B total params; the released Moonlight checkpoint is shallower —
we follow the assignment numbers.)"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=163840,
    pattern=("attn",), mlp_act="silu", rope_theta=5e4,
    n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
)


def smoke_config() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=64, vocab=512, n_experts=8, top_k=2, moe_d_ff=64,
        n_shared_experts=1, capacity_factor=4.0)
