"""phi3-medium-14b — dense, RoPE SwiGLU GQA [arXiv:2404.14219].
40L d_model=5120 40H (kv=10) d_ff=17920 vocab=100352."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
    d_ff=17920, vocab=100352,
    pattern=("attn",), rope_theta=1e4, mlp_act="silu",
)


def smoke_config() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512)
