"""qwen3-14b — dense, qk-norm GQA [hf:Qwen/Qwen3-8B].
40L d_model=5120 40H (kv=8) d_ff=17408 vocab=151936."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab=151936,
    pattern=("attn",), qk_norm=True, rope_theta=1e6, mlp_act="silu",
)


def smoke_config() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512)
