"""recurrentgemma-2b — RG-LRU + local attention 1:2 hybrid
[arXiv:2402.19427].  26L d_model=2560 10H (kv=1) d_ff=7680 vocab=256000,
window 2048, GeGLU."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256000,
    pattern=("rglru", "rglru", "local"),
    local_window=2048, mlp_act="gelu", emb_scale=True, tie_embeddings=True,
    rglru_width=2560, rglru_conv=4,
)


def smoke_config() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=512, local_window=16, rglru_width=64)
