"""Assigned input-shape cells and ShapeDtypeStruct input specs.

  train_4k     seq 4096  × gb 256   -> train_step
  prefill_32k  seq 32768 × gb 32    -> prefill forward
  decode_32k   1 token, 32768-cache × gb 128 -> serve_step
  long_500k    1 token, 524288-cache × gb 1  -> serve_step (sub-quadratic
               state families only; full-attention archs skip, DESIGN.md §4)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

# families that can run 1-token decode against a 500k context with
# sub-quadratic state (SSM / RG-LRU hybrid / local:global hybrid)
LONG_OK_FAMILIES = ("ssm", "hybrid")


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    if cell.name == "long_500k":
        if cfg.name == "gemma3-27b":
            return True, "5:1 local:global — global KV seq-sharded"
        if cfg.family not in LONG_OK_FAMILIES:
            return False, "pure full-attention arch (quadratic) — skipped"
    if cell.kind == "decode" and cfg.is_encoder_decoder:
        # whisper has a decoder; decode cells lower mechanically with the
        # caveat that the real model caps decoder length at 448.
        return True, "enc-dec: decoder-side cache (mechanical beyond 448)"
    return True, ""


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    if cell.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "targets": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.is_encoder_decoder:
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
        return specs
    if cell.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.is_encoder_decoder:
            specs["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
        return specs
    # decode: one new token against a seq_len cache
    specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
             "cache_pos": jax.ShapeDtypeStruct((), i32)}
    if cfg.is_encoder_decoder:
        specs["enc_out"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
    return specs
