"""whisper-tiny — encoder-decoder ASR backbone [arXiv:2212.04356].
4+4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.  The conv frontend is
a stub per the assignment: ``input_specs`` feeds precomputed frame
embeddings (B, 1500, d).  Both sides use sinusoidal positions
(simplification of whisper's learned decoder positions)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab=51865,
    pattern=("attn",), qkv_bias=True, mlp_act="gelu",
    use_layer_norm_bias=True, norm_eps=1e-5,
    is_encoder_decoder=True, n_encoder_layers=4, encoder_len=1500,
    rope_theta=1e4,
)


def smoke_config() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=512, encoder_len=32)
