# The paper's primary contribution: randomized approximate Cholesky
# (AC) factorization of graph Laplacians with bulk-synchronous parallel
# construction (ParAC), plus the solver stack built on it.
from .laplacian import Graph, laplacian_matvec, laplacian_matvec_np  # noqa: F401
from .ref_ac import ACFactor, DeviceFactor, factorize_sequential     # noqa: F401
from .parac import factorize_wavefront                               # noqa: F401
from .trisolve import (make_preconditioner, precond_apply_np,        # noqa: F401
                       build_schedules_device)
from .pcg import (pcg_jax, pcg_jax_batched, pcg_np,                  # noqa: F401
                  laplacian_pcg_jax, laplacian_pcg_jax_batched,
                  laplacian_pcg_np)
from .solver import (Solver, FactorCache, FactorHandle,              # noqa: F401
                     PreconditionerHandle, FactorFleet,
                     PrecondFamily, PRECOND_FAMILIES,
                     register_family, get_family)
from .ordering import ORDERINGS                                      # noqa: F401
