"""Smoothed-aggregation AMG V-cycle — HyPre/AmgX stand-in baseline.

Greedy strength-based aggregation, piecewise-constant tentative
prolongator smoothed by one weighted-Jacobi step, Galerkin coarse
operators, V(1,1)-cycle with weighted-Jacobi smoothing.  scipy.sparse
host implementation — it is a *quality baseline* (iteration counts for
Table 2), not a performance target.
"""
from __future__ import annotations

from typing import Callable, List

import numpy as np
import scipy.sparse as sp

from .laplacian import Graph, grounded_laplacian_coo
from .spai import EllPrecond, dense_to_ell


def _laplacian_csr(g: Graph) -> sp.csr_matrix:
    # grounding shared with ichol (an absolute 1e-12 diagonal epsilon):
    # the previous amg-local variant scaled the epsilon by
    # ``wd.max() or 1.0``, an ``or`` over a numpy float whose truthiness
    # silently rewrote a 0.0 maximum — and meant the two baselines
    # factored *different* operators.  Both now ground identically.
    i, j, v = grounded_laplacian_coo(g)
    return sp.coo_matrix((v, (i, j)), shape=(g.n, g.n)).tocsr()


def _aggregate(A: sp.csr_matrix, theta: float = 0.08) -> np.ndarray:
    """Greedy aggregation on the strength graph."""
    n = A.shape[0]
    D = np.asarray(A.diagonal())
    agg = np.full(n, -1, np.int64)
    next_agg = 0
    indptr, indices, data = A.indptr, A.indices, A.data
    # pass 1: seed aggregates around unaggregated vertices
    for v in range(n):
        if agg[v] >= 0:
            continue
        nbrs = indices[indptr[v]:indptr[v + 1]]
        vals = data[indptr[v]:indptr[v + 1]]
        strong = nbrs[(nbrs != v) & (-vals >= theta * np.sqrt(
            np.abs(D[v] * D[nbrs]) + 1e-30))]
        if np.all(agg[strong] < 0):
            agg[v] = next_agg
            agg[strong] = next_agg
            next_agg += 1
    # pass 2: attach leftovers to a strong neighbour's aggregate
    for v in range(n):
        if agg[v] >= 0:
            continue
        nbrs = indices[indptr[v]:indptr[v + 1]]
        cand = nbrs[agg[nbrs] >= 0]
        if cand.size:
            vals = data[indptr[v]:indptr[v + 1]][agg[nbrs] >= 0]
            agg[v] = agg[cand[np.argmin(vals)]]
        else:
            agg[v] = next_agg
            next_agg += 1
    return agg


def _build_hierarchy(A: sp.csr_matrix, max_levels: int = 10,
                     min_coarse: int = 64):
    levels = [{"A": A}]
    while len(levels) < max_levels and levels[-1]["A"].shape[0] > min_coarse:
        Al = levels[-1]["A"]
        agg = _aggregate(Al)
        nc = int(agg.max()) + 1
        if nc >= Al.shape[0]:
            break
        T = sp.coo_matrix((np.ones(Al.shape[0]),
                           (np.arange(Al.shape[0]), agg)),
                          shape=(Al.shape[0], nc)).tocsr()
        Dinv = sp.diags(1.0 / np.maximum(Al.diagonal(), 1e-30))
        P = (sp.identity(Al.shape[0]) - (2.0 / 3.0) * (Dinv @ Al)) @ T
        Ac = (P.T @ Al @ P).tocsr()
        levels[-1].update(P=P)
        levels.append({"A": Ac})
    return levels


def _jacobi(A, Dinv, x, b, omega=2.0 / 3.0, iters=1):
    for _ in range(iters):
        x = x + omega * Dinv * (b - A @ x)
    return x


def smoothed_aggregation_preconditioner(g: Graph) -> Callable:
    A = _laplacian_csr(g)
    levels = _build_hierarchy(A)
    for lv in levels:
        lv["Dinv"] = 1.0 / np.maximum(lv["A"].diagonal(), 1e-30)
    coarse = levels[-1]["A"].toarray()
    coarse_pinv = np.linalg.pinv(coarse)

    def cycle(lv: int, b: np.ndarray) -> np.ndarray:
        if lv == len(levels) - 1:
            return coarse_pinv @ b
        L = levels[lv]
        x = _jacobi(L["A"], L["Dinv"], np.zeros_like(b), b)
        r = b - L["A"] @ x
        xc = cycle(lv + 1, L["P"].T @ r)
        x = x + L["P"] @ xc
        return _jacobi(L["A"], L["Dinv"], x, b)

    return lambda r: cycle(0, np.asarray(r, np.float64))


def amg_ell_precond(g: Graph, *, droptol: float = 1e-3,
                    dtype=np.float32) -> EllPrecond:
    """Flatten the V(1,1)-cycle into a materialized ELL operator.

    The smoothed-aggregation V-cycle is a fixed **linear** operator
    ``M ≈ L⁺`` (Jacobi smoothing, Galerkin coarse operators and the
    coarse pseudo-inverse are all linear, and the hierarchy is frozen at
    construction), so applying it to the ``n`` basis vectors
    materializes it exactly.  The dense result is symmetrized (the
    V(1,1) cycle with matched pre/post smoothing is symmetric up to
    roundoff) and packed into ELL rows, turning every serving-side apply
    into a single lane-batched SpMV — the same fleet kernel the SPAI
    family rides — instead of a host V-cycle per iteration.

    Materialization costs ``n`` cycle applies and densifies rows, so
    this is for serving-scale graphs (the suites this repo benches);
    ``docs/preconditioners.md`` documents the restriction.

    Args:
        g: graph to precondition.
        droptol: relative drop threshold on the flattened operator
            (``1e-3`` trims roundoff-level fill; ``0.0`` keeps the
            cycle exactly).
        dtype: value dtype of the packed rows.

    Returns:
        The packed :class:`~repro.core.spai.EllPrecond` with
        ``meta["levels"]`` recording the hierarchy depth.
    """
    cycle = smoothed_aggregation_preconditioner(g)
    n = g.n
    M = np.empty((n, n), np.float64)
    e = np.zeros(n, np.float64)
    for j in range(n):
        e[j] = 1.0
        M[:, j] = cycle(e)
        e[j] = 0.0
    M = 0.5 * (M + M.T)
    # Deflate the constant mode: the cycle approximates the inverse of
    # the *grounded* Laplacian, whose 1e-12 epsilon makes it amplify
    # span(1) by ~1e12 — harmless to the float64 host PCG (projection
    # kills it to roundoff) but catastrophic in the float32 fleet apply,
    # and it would dominate the relative droptol.  Serving PCG iterates
    # mean-zero, so ``P M P`` (P = I - 11ᵀ/n) is the operator that
    # actually acts — SPD on the mean-zero subspace.
    M = M - M.mean(axis=1, keepdims=True) - M.mean(axis=0, keepdims=True) \
        + M.mean()
    out = dense_to_ell(M, droptol=droptol, dtype=dtype)
    out.meta.update(family="amg")
    return out
