"""Smoothed-aggregation AMG V-cycle — HyPre/AmgX stand-in baseline.

Greedy strength-based aggregation, piecewise-constant tentative
prolongator smoothed by one weighted-Jacobi step, Galerkin coarse
operators, V(1,1)-cycle with weighted-Jacobi smoothing.  scipy.sparse
host implementation — it is a *quality baseline* (iteration counts for
Table 2), not a performance target.
"""
from __future__ import annotations

from typing import Callable, List

import numpy as np
import scipy.sparse as sp

from .laplacian import Graph


def _laplacian_csr(g: Graph) -> sp.csr_matrix:
    i = np.concatenate([g.src, g.dst, np.arange(g.n)])
    j = np.concatenate([g.dst, g.src, np.arange(g.n)])
    wd = g.weighted_degrees()
    v = np.concatenate([-g.w, -g.w, wd + 1e-12 * (wd.max() or 1.0)])
    return sp.coo_matrix((v, (i, j)), shape=(g.n, g.n)).tocsr()


def _aggregate(A: sp.csr_matrix, theta: float = 0.08) -> np.ndarray:
    """Greedy aggregation on the strength graph."""
    n = A.shape[0]
    D = np.asarray(A.diagonal())
    agg = np.full(n, -1, np.int64)
    next_agg = 0
    indptr, indices, data = A.indptr, A.indices, A.data
    # pass 1: seed aggregates around unaggregated vertices
    for v in range(n):
        if agg[v] >= 0:
            continue
        nbrs = indices[indptr[v]:indptr[v + 1]]
        vals = data[indptr[v]:indptr[v + 1]]
        strong = nbrs[(nbrs != v) & (-vals >= theta * np.sqrt(
            np.abs(D[v] * D[nbrs]) + 1e-30))]
        if np.all(agg[strong] < 0):
            agg[v] = next_agg
            agg[strong] = next_agg
            next_agg += 1
    # pass 2: attach leftovers to a strong neighbour's aggregate
    for v in range(n):
        if agg[v] >= 0:
            continue
        nbrs = indices[indptr[v]:indptr[v + 1]]
        cand = nbrs[agg[nbrs] >= 0]
        if cand.size:
            vals = data[indptr[v]:indptr[v + 1]][agg[nbrs] >= 0]
            agg[v] = agg[cand[np.argmin(vals)]]
        else:
            agg[v] = next_agg
            next_agg += 1
    return agg


def _build_hierarchy(A: sp.csr_matrix, max_levels: int = 10,
                     min_coarse: int = 64):
    levels = [{"A": A}]
    while len(levels) < max_levels and levels[-1]["A"].shape[0] > min_coarse:
        Al = levels[-1]["A"]
        agg = _aggregate(Al)
        nc = int(agg.max()) + 1
        if nc >= Al.shape[0]:
            break
        T = sp.coo_matrix((np.ones(Al.shape[0]),
                           (np.arange(Al.shape[0]), agg)),
                          shape=(Al.shape[0], nc)).tocsr()
        Dinv = sp.diags(1.0 / np.maximum(Al.diagonal(), 1e-30))
        P = (sp.identity(Al.shape[0]) - (2.0 / 3.0) * (Dinv @ Al)) @ T
        Ac = (P.T @ Al @ P).tocsr()
        levels[-1].update(P=P)
        levels.append({"A": Ac})
    return levels


def _jacobi(A, Dinv, x, b, omega=2.0 / 3.0, iters=1):
    for _ in range(iters):
        x = x + omega * Dinv * (b - A @ x)
    return x


def smoothed_aggregation_preconditioner(g: Graph) -> Callable:
    A = _laplacian_csr(g)
    levels = _build_hierarchy(A)
    for lv in levels:
        lv["Dinv"] = 1.0 / np.maximum(lv["A"].diagonal(), 1e-30)
    coarse = levels[-1]["A"].toarray()
    coarse_pinv = np.linalg.pinv(coarse)

    def cycle(lv: int, b: np.ndarray) -> np.ndarray:
        if lv == len(levels) - 1:
            return coarse_pinv @ b
        L = levels[lv]
        x = _jacobi(L["A"], L["Dinv"], np.zeros_like(b), b)
        r = b - L["A"] @ x
        xc = cycle(lv + 1, L["P"].T @ r)
        x = x + L["P"] @ xc
        return _jacobi(L["A"], L["Dinv"], x, b)

    return lambda r: cycle(0, np.asarray(r, np.float64))
