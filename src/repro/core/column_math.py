"""Per-column elimination math shared by the sequential oracle and the
parallel wavefront engine (paper Algorithm 2 / Algorithm 3 lines 13-21,
Algorithm 4 lines 14-23).

Everything operates on a fixed-width padded column so it vmaps/tiles:

  * merge parallel (multi-)edges with the same neighbour id,
  * ℓ_kk = Σ merged weights (Laplacian diagonal is implicit),
  * sort neighbours ascending by (|ℓ_ki|, id)   [paper: sort improves quality],
  * suffix sums S[i] = Σ_{g≥i} w_g,
  * for each position i < m-1: inverse-CDF sample a partner j > i with
    probability w_j / S[i+1] and emit the spanning-tree edge
    (id_i, id_j) with weight  S[i+1] · w_i / ℓ_kk.

Randomness is supplied per *logical slot* so the sampled factor is
*schedule independent*: the oracle and the engine feed identical uniforms
(``fold_in(key, vertex)`` then ``fold_in(·, slot)``) and must produce
bit-identical factors — the correctness claim of the bulk-synchronous
wavefront adaptation (DESIGN.md §2), tested in tests/test_core_ac.py.

Bit-exactness across different padding widths requires *width-independent
reduction bracketing*.  ``jnp.cumsum`` lowers to a tree scan whose shape
depends on the array length, so we use a Hillis–Steele scan instead: the
value at position i combines only positions ≤ i with a bracketing that
depends on i alone (shifted-in zeros are exact no-ops).  Prefix scans run
on left-aligned data; suffix scans on right-aligned data (the sampling
sort pushes invalid lanes to the *front* with a −inf key) so both are
padding-invariant.  The same scan vectorises on TPU VPU lanes inside the
Pallas ``sample_clique`` kernel.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

INVALID_ID = jnp.iinfo(jnp.int32).max
_NEG_INF = float("-inf")


class ColumnElim(NamedTuple):
    """Result of eliminating one vertex (fixed width ``width``).

    ``g_rows/g_vals`` are left-aligned (positions < m valid); the sampled
    edges live at right-aligned positions — use ``e_valid`` to select.
    """

    g_rows: jnp.ndarray   # int32[width]  merged neighbour ids, ascending
    g_vals: jnp.ndarray   # f32[width]    factor values  -w/ℓkk
    m: jnp.ndarray        # int32         number of merged neighbours
    ell_kk: jnp.ndarray   # f32           diagonal D[k]
    e_lo: jnp.ndarray     # int32[width]  sampled edge endpoints, lo < hi
    e_hi: jnp.ndarray     # int32[width]
    e_w: jnp.ndarray      # f32[width]    sampled edge weights (> 0 where valid)
    e_valid: jnp.ndarray  # bool[width]


def _next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


def hs_cumsum(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive prefix sum with index-only bracketing (Hillis–Steele).

    prefix[i] is a fixed binary-tree combination of x[0..i]; appending
    padding on the right never changes earlier prefixes (shifted-in zeros
    add exactly).  This is what makes oracle (pow2-of-d padding) and
    engine (global dmax padding) factors bit-identical.
    """
    w = x.shape[0]
    n2 = _next_pow2(w)
    x = jnp.pad(x, (0, n2 - w))
    k = 1
    while k < n2:
        x = x + jnp.pad(x[:-k], (k, 0))
        k *= 2
    return x[:w]


def hs_suffix_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Suffix sums with index-from-the-right bracketing.  Width-independent
    provided the *valid data is right-aligned* (padding on the left)."""
    return hs_cumsum(x[::-1])[::-1]


def column_uniforms(key: jax.Array, vertex: jnp.ndarray, width: int) -> jnp.ndarray:
    """Schedule-independent uniforms: slot i of vertex k depends only on
    (key, k, i) — never on padding width or wavefront composition."""
    kk = jax.random.fold_in(key, vertex)
    slots = jnp.arange(width, dtype=jnp.int32)
    return jax.vmap(lambda i: jax.random.uniform(jax.random.fold_in(kk, i)))(slots)


def eliminate_column(ids: jnp.ndarray, ws: jnp.ndarray, valid: jnp.ndarray,
                     u: jnp.ndarray) -> ColumnElim:
    """Eliminate one vertex given its (padded) incident multi-edge list.

    ids/ws/valid/u: int32[width], f32[width], bool[width], f32[width].
    ``u[i]`` is the uniform for the i-th *logical* sampling slot.
    """
    width = ids.shape[0]
    pos = jnp.arange(width, dtype=jnp.int32)
    ids = jnp.where(valid, ids, INVALID_ID).astype(jnp.int32)
    ws = jnp.where(valid, ws, jnp.zeros((), ws.dtype))

    # ---- stage 1: merge multi-edges with equal neighbour id -------------
    # sort by (id, w): valid ids ascending, INVALID_ID sentinels trailing
    ids_s, ws_s = jax.lax.sort((ids, ws), num_keys=2)
    is_start = jnp.concatenate([jnp.ones((1,), bool),
                                ids_s[1:] != ids_s[:-1]])
    is_start = is_start & (ids_s != INVALID_ID)
    cs = hs_cumsum(ws_s)                       # width-independent prefixes
    nvalid = jnp.sum(ids_s != INVALID_ID).astype(jnp.int32)
    start_pos = jnp.where(is_start, pos, width)
    rev_min = jax.lax.associative_scan(jnp.minimum, start_pos[::-1])[::-1]
    nxt = jnp.concatenate([rev_min[1:], jnp.array([width])])
    # clamp the last run's end to the last *valid* lane: prefix values at
    # padding positions have width-dependent bracketing.
    run_end = jnp.clip(nxt - 1, 0, jnp.maximum(nvalid - 1, 0))
    prev_cs = jnp.where(pos > 0, cs[jnp.maximum(pos - 1, 0)], 0.0)
    run_sum = cs[run_end] - prev_cs            # Σ of each id-run

    merged_id = jnp.where(is_start, ids_s, INVALID_ID)
    merged_w = jnp.where(is_start, run_sum, 0.0)
    m = jnp.sum(is_start).astype(jnp.int32)
    ell_kk = jnp.where(nvalid > 0, cs[jnp.maximum(nvalid - 1, 0)], 0.0)

    # compact merged entries to the front (ids ascending already)
    g_rows, g_vals_w = jax.lax.sort((merged_id, merged_w), num_keys=1)
    safe_ell = jnp.where(ell_kk > 0, ell_kk, 1.0)
    g_vals = jnp.where(g_rows != INVALID_ID, -g_vals_w / safe_ell, 0.0)

    # ---- stage 2: sort by (w, id) ascending, RIGHT-aligned ---------------
    # invalid lanes get a −inf key so they sort to the *front*; the valid
    # ascending-by-weight run is right-aligned, making the suffix scan
    # padding-invariant.
    sort_w = jnp.where(g_rows != INVALID_ID, g_vals_w,
                       jnp.asarray(_NEG_INF, g_vals_w.dtype))
    sw, sid, sval = jax.lax.sort((sort_w, g_rows, g_vals_w), num_keys=2)
    sval = jnp.where(sid != INVALID_ID, sval, 0.0)
    S = hs_suffix_sum(sval)                     # S[p] = Σ_{q≥p} sval[q]
    S1 = jnp.concatenate([S[1:], jnp.zeros((1,), S.dtype)])   # S1[p] = S[p+1]

    # ---- stage 3: inverse-CDF spanning-tree sampling ---------------------
    # valid sampling positions: p ∈ [width−m, width−1); logical slot
    # i = p − (width − m) indexes the uniforms.
    first = width - m
    i_log = jnp.clip(pos - first, 0, width - 1)
    up = u[i_log]
    # thresh_p = S[p+1] − u·S[p+1]; partner j = smallest j > p with
    # S[j+1] ≤ thresh (S1 non-increasing; leading lanes hold the full sum).
    thresh = S1 - up * S1
    rev = S1[::-1]
    c = jnp.searchsorted(rev, thresh, side="right")
    j_idx = jnp.minimum(jnp.maximum(pos + 1, width - c), width - 1)

    e_valid = (pos >= first) & (pos < width - 1) & (m >= 2)
    a = sid
    b = sid[j_idx]
    e_lo = jnp.where(e_valid, jnp.minimum(a, b), INVALID_ID).astype(jnp.int32)
    e_hi = jnp.where(e_valid, jnp.maximum(a, b), INVALID_ID).astype(jnp.int32)
    e_w = jnp.where(e_valid, S1 * sval / safe_ell, 0.0)

    return ColumnElim(g_rows=g_rows.astype(jnp.int32), g_vals=g_vals,
                      m=m, ell_kk=ell_kk,
                      e_lo=e_lo, e_hi=e_hi, e_w=e_w, e_valid=e_valid)
