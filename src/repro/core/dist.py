"""Distributed solver paths (DESIGN.md §2: how an O(1)-arithmetic-
intensity algorithm uses a mesh).

Two production modes:

* ``sharded_pcg`` — ONE huge system: edges sharded across the mesh,
  SpMV = local partial products + ``psum`` (vector replicated; the
  standard fat-node layout for bandwidth-bound SpMV).  The
  preconditioner (level-scheduled trisolve) stays replicated — the
  paper's observation that fine-grained factor communication is not
  worth it at O(1) intensity.
* ``batched_factorize`` — MANY independent systems (incremental
  sparsification): whole graphs sharded across devices via
  ``shard_map``; zero cross-graph communication; factors are
  bit-identical to the single-device engine per (graph, key).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .laplacian import Graph
from .pcg import PCGResult


def _pad_edges(g: Graph, multiple: int):
    m = g.m
    pad = (-m) % multiple
    src = np.concatenate([g.src, np.zeros(pad, np.int32)])
    dst = np.concatenate([g.dst, np.zeros(pad, np.int32)])
    w = np.concatenate([g.w, np.zeros(pad, np.float32)])
    return src, dst, w


def make_sharded_matvec(g: Graph, mesh, axis: str = "data") -> Callable:
    """Edge-sharded Laplacian matvec: y = Σ_shards scatter(w·(x_u−x_v))."""
    n_sh = mesh.shape[axis]
    src, dst, w = _pad_edges(g, n_sh)
    espec = NamedSharding(mesh, P(axis))
    srcs = jax.device_put(jnp.asarray(src), espec)
    dsts = jax.device_put(jnp.asarray(dst), espec)
    ws = jax.device_put(jnp.asarray(w), espec)
    n = g.n

    def local_mv(s, d, ww, x):
        diff = ww * (x[s] - x[d])
        y = jnp.zeros(n, x.dtype).at[s].add(diff).at[d].add(-diff)
        return jax.lax.psum(y, axis)

    smapped = shard_map(
        local_mv, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P()),
        out_specs=P())

    def mv(x):
        return smapped(srcs, dsts, ws, x)

    return mv


def sharded_pcg(g: Graph, mesh, precond: Callable, b: jnp.ndarray, *,
                axis: str = "data", tol: float = 1e-6,
                maxiter: int = 500) -> PCGResult:
    from .pcg import pcg_jax
    mv = make_sharded_matvec(g, mesh, axis)
    return pcg_jax(mv, precond, b, tol=tol, maxiter=maxiter)


def batched_factorize(g: Graph, keys, mesh, *, chunk: int = 256,
                      fill_slack: int = 32, axis: str = "data"):
    """Factorize the same graph under B different sampling keys, graphs
    sharded over ``axis`` (the sparsification ensemble).  Returns the
    stacked EngineState (host-side extraction as needed)."""
    from .parac import _run_engine, _build_pool
    chunk = min(chunk, max(g.n, 1))
    (pool_row, pool_val, fill, dep, col_base, cap, Ptot, dmax) = \
        _build_pool(g, fill_slack, np.float32)
    args = (jnp.asarray(pool_row), jnp.asarray(pool_val), jnp.asarray(fill),
            jnp.asarray(dep), jnp.asarray(col_base), jnp.asarray(cap))

    def one(key_slice):
        return jax.vmap(lambda k: _run_engine.__wrapped__(
            *args, k, dmax=dmax, chunk=chunk))(key_slice)

    smapped = shard_map(one, mesh=mesh, in_specs=P(axis), out_specs=P(axis),
                        check_rep=False)
    return jax.jit(smapped)(keys)
