"""Elimination-tree and critical-path analysis (paper Fig. 4).

Three structural quantities per (graph, ordering):

  * **classical e-tree height** — height of the elimination tree of the
    *exact* (clique fill) factorization, computed with Liu's
    path-compression algorithm directly from the matrix pattern;
  * **actual e-tree height** — dependency-DAG longest path of the
    *randomized* factor: level(k) = 1 + max level over columns j whose
    sampled column contains k.  This equals the number of bulk-synchronous
    wavefronts the ParAC engine needs (DESIGN.md §2);
  * **triangular-solve critical path** — longest path through all nonzeros
    of G (equals ``LevelSchedule.n_levels`` of the forward solve).
"""
from __future__ import annotations

import numpy as np

from .laplacian import Graph
from .ref_ac import ACFactor


def classical_etree(g: Graph, perm: np.ndarray) -> np.ndarray:
    """Liu's algorithm: e-tree of the filled pattern from A's pattern only.

    Returns parent array over elimination positions (-1 = root).
    """
    n = g.n
    lo = np.minimum(perm[g.src], perm[g.dst])
    hi = np.maximum(perm[g.src], perm[g.dst])
    order = np.argsort(hi, kind="stable")
    lo, hi = lo[order], hi[order]
    parent = np.full(n, -1, np.int64)
    ancestor = np.full(n, -1, np.int64)
    ptr = 0
    for i in range(n):
        while ptr < hi.shape[0] and hi[ptr] == i:
            k = lo[ptr]
            ptr += 1
            # walk from k to the root of its current subtree, compressing
            while True:
                a = ancestor[k]
                ancestor[k] = i
                if a == -1:
                    if k != i and parent[k] == -1:
                        parent[k] = i
                    break
                if a == i:
                    break
                k = a
    return parent


def tree_height(parent: np.ndarray) -> int:
    """Longest root-to-leaf path (#nodes) of a forest given parent[]."""
    n = parent.shape[0]
    depth = np.zeros(n, np.int64)
    # parents always have larger position index ⇒ process descending
    for i in range(n - 1, -1, -1):
        p = parent[i]
        if p >= 0:
            depth[i] = depth[p] + 1
    return int(depth.max()) + 1 if n else 0


def classical_etree_height(g: Graph, perm: np.ndarray) -> int:
    return tree_height(classical_etree(g, perm))


def factor_levels(f: ACFactor) -> np.ndarray:
    """Wavefront level of every column of the randomized factor."""
    n = f.n
    cols = np.repeat(np.arange(n, dtype=np.int64),
                     np.diff(f.col_ptr).astype(np.int64))
    rows = f.rows.astype(np.int64)
    level = np.zeros(n, np.int64)
    while True:  # level-synchronous longest-path relaxation
        cand = np.zeros(n, np.int64)
        np.maximum.at(cand, rows, level[cols] + 1)
        new = np.maximum(level, cand)
        if np.array_equal(new, level):
            return level
        level = new


def actual_etree_height(f: ACFactor) -> int:
    """Actual dependency height = #wavefronts (paper Fig. 4 'actual')."""
    lv = factor_levels(f)
    return int(lv.max()) + 1 if f.n else 0


def actual_parent_etree_height(f: ACFactor) -> int:
    """Height of the e-tree defined as parent = first nonzero per column
    (the paper's strict e-tree definition, Def. 3.1)."""
    n = f.n
    parent = np.full(n, -1, np.int64)
    for c in range(n):
        lo, hi = f.col_ptr[c], f.col_ptr[c + 1]
        if hi > lo:
            parent[c] = int(f.rows[lo:hi].min())
    return tree_height(parent)


def wavefront_profile(f: ACFactor) -> np.ndarray:
    """Histogram: #columns eliminable at each wavefront (parallelism profile)."""
    lv = factor_levels(f)
    return np.bincount(lv)
