"""Incomplete-Cholesky baselines (paper Tables 2/3 comparisons).

* ``ichol0`` — zero-fill IC on the matrix pattern (cuSPARSE csric02
  analogue): fast construction, weaker preconditioner.
* ``icholt`` — threshold-dropping IC (MATLAB ``ichol(...,'ict')``
  analogue): drop |v| < τ·norm(col), like the paper's tuned-fill runs.

Both operate on the (possibly grounded) Laplacian with a Manteuffel-style
diagonal shift retry on breakdown — IC on a singular Laplacian needs it.
Host-side sequential numpy: these are *quality baselines*, their
construction cost is reported but not optimized (the paper's point is
precisely that their parallel construction is the hard part).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import numpy as np
import scipy.sparse as sp

from .laplacian import Graph, grounded_laplacian_coo


def _laplacian_csc(g: Graph, shift: float) -> sp.csc_matrix:
    i, j, v = grounded_laplacian_coo(g, shift)
    return sp.coo_matrix((v, (i, j)), shape=(g.n, g.n)).tocsc()


@dataclasses.dataclass
class ICholFactor:
    """L_ic lower-triangular CSC with explicit diagonal (A ≈ L Lᵀ)."""

    L: sp.csc_matrix
    shift: float

    def apply(self, r: np.ndarray) -> np.ndarray:
        y = sp.linalg.spsolve_triangular(self.L.tocsr(), r, lower=True)
        return sp.linalg.spsolve_triangular(self.L.T.tocsr(), y, lower=False)

    @property
    def nnz(self) -> int:
        return self.L.nnz


def _ic_factor(A: sp.csc_matrix, droptol: float) -> sp.csc_matrix:
    """Left-looking IC with threshold dropping (droptol=0 ⇒ IC(0) pattern)."""
    n = A.shape[0]
    A = A.tocsc()
    cols_i: list = []
    cols_v: list = []
    # row-wise access to already-computed columns: store per-row lists
    row_entries: list = [[] for _ in range(n)]  # (col, val)
    pattern = [set(A.indices[A.indptr[k]:A.indptr[k + 1]]) for k in range(n)] \
        if droptol == 0.0 else None
    for k in range(n):
        lo, hi = A.indptr[k], A.indptr[k + 1]
        col = dict(zip(A.indices[lo:hi], A.data[lo:hi]))
        # subtract L(k:,j) * L(k,j) for all j < k with L(k,j) != 0
        for (j, lkj) in row_entries[k]:
            for (i2, lij) in zip(cols_i[j], cols_v[j]):
                if i2 >= k:
                    col[i2] = col.get(i2, 0.0) - lij * lkj
        dkk = col.pop(k, 0.0)
        if dkk <= 0:
            raise FloatingPointError(f"IC breakdown at column {k}")
        lkk = np.sqrt(dkk)
        ids, vals = [], []
        if col:
            items = [(i2, v / lkk) for i2, v in col.items() if i2 > k]
            if droptol > 0.0:
                nrm = np.sqrt(sum(v * v for _, v in items)) or 1.0
                items = [(i2, v) for i2, v in items
                         if abs(v) >= droptol * nrm]
            else:
                items = [(i2, v) for i2, v in items
                         if i2 in pattern[k]]
            items.sort()
            ids = [i2 for i2, _ in items]
            vals = [v for _, v in items]
            for i2, v in zip(ids, vals):
                row_entries[i2].append((k, v))
        cols_i.append(np.array([k] + ids, np.int64))
        cols_v.append(np.array([lkk] + vals, np.float64))
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum([c.size for c in cols_i], out=indptr[1:])
    indices = np.concatenate(cols_i)
    data = np.concatenate(cols_v)
    return sp.csc_matrix((data, indices, indptr), shape=(n, n))


def ichol(g: Graph, droptol: float = 0.0, max_shift_tries: int = 8) -> ICholFactor:
    shift = 0.0
    for _ in range(max_shift_tries):
        try:
            L = _ic_factor(_laplacian_csc(g, shift), droptol)
            return ICholFactor(L=L, shift=shift)
        except FloatingPointError:
            shift = max(2 * shift, 1e-3)
    raise RuntimeError("ichol breakdown even with diagonal shift")


def ichol_device_factor(g: Graph, droptol: float = 0.0,
                        max_shift_tries: int = 8, dtype=np.float32):
    """Incomplete Cholesky re-expressed as the fleet's ``(G, D)`` form.

    ``L_ic L_icᵀ = G D Gᵀ`` with ``G = L_ic · diag(1/ℓ_kk)`` unit lower
    triangular and ``D = diag(ℓ_kk²)`` — exactly the shape the
    randomized AC factor ships in, so an ichol preconditioner rides the
    same ``DeviceFactor → PackedSchedule → FactorFleet`` admission path
    and the same masked fleet trisolves as AC, with zero new kernels.

    Args:
        g: graph whose grounded Laplacian to factor.
        droptol: threshold-drop tolerance (``0.0`` = IC(0) pattern).
        max_shift_tries: Manteuffel shift retries on IC breakdown.
        dtype: device value dtype.

    Returns:
        A :class:`~repro.core.ref_ac.DeviceFactor` (strict-lower ``G``
        in CSC plus ``D``) whose implied preconditioner equals
        ``ichol(g, droptol).apply`` up to dtype rounding.

    Raises:
        RuntimeError: IC broke down even with the maximum shift.
    """
    from .ref_ac import DeviceFactor
    import jax
    import jax.numpy as jnp

    ic = ichol(g, droptol=droptol, max_shift_tries=max_shift_tries)
    L = ic.L.tocsc()
    n = g.n
    col_ptr = np.zeros(n + 1, np.int64)
    rows_l: list = []
    vals_l: list = []
    D = np.zeros(n, np.float64)
    for k in range(n):
        lo, hi = L.indptr[k], L.indptr[k + 1]
        idx = L.indices[lo:hi]
        val = L.data[lo:hi]
        dpos = np.nonzero(idx == k)[0]
        lkk = float(val[dpos[0]])
        D[k] = lkk * lkk
        off = idx != k
        rows_l.append(idx[off].astype(np.int32))
        vals_l.append(val[off] / lkk)
        col_ptr[k + 1] = col_ptr[k] + int(off.sum())
    rows = np.concatenate(rows_l) if rows_l else np.zeros(0, np.int32)
    vals = np.concatenate(vals_l) if vals_l else np.zeros(0, np.float64)
    with jax.ensure_compile_time_eval():
        return DeviceFactor(col_ptr=jnp.asarray(col_ptr, jnp.int32),
                            rows=jnp.asarray(rows, jnp.int32),
                            vals=jnp.asarray(vals.astype(dtype)),
                            D=jnp.asarray(D.astype(dtype)))


def jacobi_preconditioner(g: Graph) -> Callable:
    wd = g.weighted_degrees()
    dinv = np.where(wd > 0, 1.0 / np.maximum(wd, 1e-30), 0.0)

    def apply(r: np.ndarray) -> np.ndarray:
        return dinv * r

    return apply
