"""Graph / Laplacian containers and basic linear-algebra helpers.

The whole library works on *weighted undirected graphs* stored as an edge
list with ``src < dst`` (one record per undirected edge).  The graph
Laplacian is never materialised densely except in tests; all operators are
edge-list (COO) based so they vectorise on TPU and shard trivially
(edges are the natural data-parallel axis; see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Graph:
    """Weighted undirected graph, one record per edge, ``src < dst``."""

    n: int
    src: np.ndarray  # int32[m]
    dst: np.ndarray  # int32[m]
    w: np.ndarray    # float[m], strictly positive

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    def validate(self) -> None:
        assert self.src.shape == self.dst.shape == self.w.shape
        assert np.all(self.src < self.dst), "edges must satisfy src < dst"
        assert np.all(self.src >= 0) and np.all(self.dst < self.n)
        assert np.all(self.w > 0), "edge weights must be positive"

    def degrees(self) -> np.ndarray:
        """Number of incident edges per vertex."""
        deg = np.zeros(self.n, dtype=np.int64)
        np.add.at(deg, self.src, 1)
        np.add.at(deg, self.dst, 1)
        return deg

    def weighted_degrees(self) -> np.ndarray:
        wd = np.zeros(self.n, dtype=np.float64)
        np.add.at(wd, self.src, self.w)
        np.add.at(wd, self.dst, self.w)
        return wd

    def coalesce(self) -> "Graph":
        """Merge parallel edges (sum weights) and drop self loops."""
        keep = self.src != self.dst
        src, dst, w = self.src[keep], self.dst[keep], self.w[keep]
        key = src.astype(np.int64) * self.n + dst
        order = np.argsort(key, kind="stable")
        key, src, dst, w = key[order], src[order], dst[order], w[order]
        uniq, inv = np.unique(key, return_inverse=True)
        wm = np.zeros(uniq.shape[0], dtype=w.dtype)
        np.add.at(wm, inv, w)
        first = np.searchsorted(uniq, key[np.searchsorted(key, uniq)])
        del first
        # representative src/dst per unique key
        s = (uniq // self.n).astype(np.int32)
        d = (uniq % self.n).astype(np.int32)
        return Graph(self.n, s, d, wm)

    def permute(self, perm: np.ndarray) -> "Graph":
        """Relabel vertices: new label of old vertex v is ``perm[v]``.

        The factorization eliminates vertices in new-label order, so
        ``perm`` IS the elimination priority (position of each vertex).
        """
        ns = perm[self.src].astype(np.int32)
        nd = perm[self.dst].astype(np.int32)
        lo = np.minimum(ns, nd)
        hi = np.maximum(ns, nd)
        return Graph(self.n, lo, hi, self.w.copy())


def grounded_laplacian_coo(g: Graph, shift: float = 0.0):
    """COO triples ``(i, j, v)`` of the grounded (SPD) Laplacian
    ``L + shift·diag(L) + 1e-12·I`` — the operator every host baseline
    factors.

    The grounding term is an **absolute** ``1e-12`` on the diagonal
    (plus the optional Manteuffel-style relative ``shift`` used by the
    incomplete-Cholesky breakdown retry): one definition shared by
    ``ichol`` and ``amg`` so both baselines precondition exactly the
    same matrix.  An earlier ``amg``-local variant scaled the epsilon by
    ``wd.max() or 1.0``, whose truthiness guard silently misfired on a
    numpy float equal to 0.0; keeping the guard-free absolute form here
    removes that class of bug.

    Args:
        g: graph whose Laplacian to ground.
        shift: relative diagonal shift (``0.0`` = plain grounding).

    Returns:
        ``(i, j, v)`` int/float numpy arrays suitable for
        ``scipy.sparse.coo_matrix((v, (i, j)), shape=(g.n, g.n))``.
    """
    i = np.concatenate([g.src, g.dst, np.arange(g.n)])
    j = np.concatenate([g.dst, g.src, np.arange(g.n)])
    wd = g.weighted_degrees()
    v = np.concatenate([-g.w, -g.w, wd * (1.0 + shift) + 1e-12])
    return i, j, v


def laplacian_dense(g: Graph, dtype=np.float64) -> np.ndarray:
    """Dense Laplacian — tests/small benchmarks only."""
    L = np.zeros((g.n, g.n), dtype=dtype)
    for s, d, w in zip(g.src, g.dst, g.w):
        L[s, s] += w
        L[d, d] += w
        L[s, d] -= w
        L[d, s] -= w
    return L


def laplacian_matvec_np(g: Graph, x: np.ndarray) -> np.ndarray:
    """y = L x on host (numpy), edge-list formulation."""
    diff = x[g.src] - x[g.dst]
    y = np.zeros_like(x)
    np.add.at(y, g.src, g.w * diff)
    np.add.at(y, g.dst, -g.w * diff)
    return y


def laplacian_matvec(src: jnp.ndarray, dst: jnp.ndarray, w: jnp.ndarray,
                     n: int, x: jnp.ndarray) -> jnp.ndarray:
    """y = L x in JAX. ``L = Σ w_e (e_s - e_d)(e_s - e_d)ᵀ``.

    Edge-parallel: gathers two endpoints, scatter-adds two contributions.
    This is the SpMV that dominates PCG; the Pallas ELL kernel in
    ``repro.kernels.spmv`` is the tiled version of the same contraction.
    """
    diff = w * (x[src] - x[dst])
    y = jnp.zeros(n, dtype=x.dtype)
    y = y.at[src].add(diff)
    y = y.at[dst].add(-diff)
    return y


def project_mean_zero(x: jnp.ndarray) -> jnp.ndarray:
    """Project onto 1⊥ — Laplacians are singular with nullspace = span(1)."""
    return x - jnp.mean(x)


# ---------------------------------------------------------------------------
# SDD → Laplacian reduction (paper §1: "generalizes to SDD")
# ---------------------------------------------------------------------------

def sdd_to_grounded_laplacian(A_diag: np.ndarray, g: Graph) -> Graph:
    """Reduce an SDD system ``A = L(g) + diag(surplus)`` to a Laplacian.

    ``A_diag`` is the full diagonal of A; the surplus
    ``s_v = A_vv - Σ_incident w`` must be ≥ 0 (diagonally dominant).
    Standard grounding construction: add vertex ``n`` ("ground") with an
    edge (v, n, s_v) for every v with s_v > 0.  Solving the grounded
    Laplacian with rhs ``[b; -Σb]`` and grounding x_n = 0 solves A x = b.
    """
    wd = g.weighted_degrees()
    surplus = np.asarray(A_diag, dtype=np.float64) - wd
    if np.any(surplus < -1e-9 * np.abs(A_diag)):
        raise ValueError("matrix is not diagonally dominant")
    surplus = np.maximum(surplus, 0.0)
    keep = surplus > 0
    vs = np.nonzero(keep)[0].astype(np.int32)
    gsrc = np.concatenate([g.src, vs])
    gdst = np.concatenate([g.dst, np.full(vs.shape, g.n, dtype=np.int32)])
    gw = np.concatenate([g.w, surplus[keep].astype(g.w.dtype)])
    return Graph(g.n + 1, gsrc, gdst, gw)
