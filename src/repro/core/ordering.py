"""Elimination orderings studied by the paper (§6): random, nnz-sort and an
AMD-like minimum-degree ordering.

``nnz-sort`` sorts vertices ascending by initial degree with randomized
tie-break — the paper's best GPU ordering.  The AMD stand-in is exact
greedy minimum-degree (with clique fill tracking) for small graphs and
reverse Cuthill–McKee (the locality-favouring classical ordering) beyond
that — AMD's supernodal tricks are orthogonal to the paper's contribution
(DESIGN.md §7.3).

A *permutation* here maps original vertex id -> elimination position.
"""
from __future__ import annotations

import numpy as np

from .laplacian import Graph


def natural_order(g: Graph) -> np.ndarray:
    return np.arange(g.n, dtype=np.int32)


def random_order(g: Graph, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.permutation(g.n).astype(np.int32)


def nnz_sort_order(g: Graph, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    deg = g.degrees().astype(np.float64)
    jitter = rng.uniform(0, 1, g.n)
    order = np.lexsort((jitter, deg))  # ascending degree, random tie-break
    perm = np.empty(g.n, np.int32)
    perm[order] = np.arange(g.n, dtype=np.int32)
    return perm


def rcm_order(g: Graph) -> np.ndarray:
    """Reverse Cuthill–McKee (locality-favouring)."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import reverse_cuthill_mckee
    A = sp.coo_matrix(
        (np.ones(2 * g.m), (np.concatenate([g.src, g.dst]),
                            np.concatenate([g.dst, g.src]))),
        shape=(g.n, g.n)).tocsr()
    order = reverse_cuthill_mckee(A, symmetric_mode=True)
    perm = np.empty(g.n, np.int32)
    perm[order] = np.arange(g.n, dtype=np.int32)
    return perm


def min_degree_order(g: Graph, max_exact: int = 4000) -> np.ndarray:
    """Greedy minimum degree with clique fill (exact, small n); RCM beyond."""
    if g.n > max_exact:
        return rcm_order(g)
    import heapq
    adj = [set() for _ in range(g.n)]
    for s, d in zip(g.src, g.dst):
        adj[int(s)].add(int(d))
        adj[int(d)].add(int(s))
    heap = [(len(adj[v]), v) for v in range(g.n)]
    heapq.heapify(heap)
    eliminated = np.zeros(g.n, bool)
    perm = np.empty(g.n, np.int32)
    pos = 0
    while heap:
        d, v = heapq.heappop(heap)
        if eliminated[v] or d != len(adj[v]):
            continue  # stale entry
        eliminated[v] = True
        perm[v] = pos
        pos += 1
        nbrs = [u for u in adj[v] if not eliminated[u]]
        for i, a in enumerate(nbrs):  # clique fill
            adj[a].discard(v)
            for b in nbrs[i + 1:]:
                if b not in adj[a]:
                    adj[a].add(b)
                    adj[b].add(a)
        for a in nbrs:
            heapq.heappush(heap, (len(adj[a]), a))
        adj[v] = set()
    return perm


ORDERINGS = {
    "natural": lambda g, seed=0: natural_order(g),
    "random": random_order,
    "nnz-sort": nnz_sort_order,
    "amd-like": lambda g, seed=0: min_degree_order(g),
    "rcm": lambda g, seed=0: rcm_order(g),
}
