"""ParAC — bulk-synchronous wavefront randomized Cholesky (JAX).

TPU-native adaptation of the paper's GPU persistent-kernel algorithm
(Algorithm 4).  Each round:

  1. the *ready set* (dep == 0, not eliminated) is an independent set of
     the current multi-graph — take the ``chunk`` smallest labels;
  2. gather their column slabs from the static edge pool, eliminate them
     all at once (``vmap`` of the shared per-column math; the Pallas
     ``sample_clique`` kernel is the tiled version of the same math);
  3. write the normalized column back in place (the pool doubles as the
     output factor, like the paper's array O);
  4. bulk-scatter sampled spanning-tree edges to their owner column's
     slab at sort-derived offsets (the barrier-free analogue of the
     paper's ``hash(a) + fill_in_count(a)`` insertion);
  5. update dependency counters with segment adds (the atomic-free
     analogue of Algorithm 4 lines 21/24).

Rounds iterate under ``lax.while_loop`` until every vertex is eliminated.
The factor is bit-identical to the sequential oracle because per-vertex
randomness is schedule independent (``column_math.column_uniforms``).

Memory model (paper §5.1): one static pool sized ``m + n·fill_slack``;
column k owns slab ``[col_base[k], col_base[k] + cap[k])``.  Overflowing
sampled edges are dropped *and counted* — `strict=True` retries with a
doubled slack instead (dynamic malloc is as ill-advised in XLA as in
device code).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from .laplacian import Graph
from .column_math import eliminate_column, column_uniforms, INVALID_ID
from .ref_ac import ACFactor, DeviceFactor


class EngineState(NamedTuple):
    pool_row: jnp.ndarray   # int32[P] — max-label endpoint / factor row id
    pool_val: jnp.ndarray   # f32[P]   — alive: edge weight (>0); done: G value
    col_fill: jnp.ndarray   # int32[n] — #entries in each column slab
    dep: jnp.ndarray        # int32[n] — #alive multi-edges with max endpoint v
    elim: jnp.ndarray       # bool[n]
    D: jnp.ndarray          # f32[n]
    n_elim: jnp.ndarray     # int32
    n_rounds: jnp.ndarray   # int32
    overflow: jnp.ndarray   # int32 — dropped sampled edges (0 in strict runs)


@partial(jax.jit, static_argnames=("dmax", "chunk"))
def _run_engine(pool_row, pool_val, col_fill, dep, col_base, cap, key,
                *, dmax: int, chunk: int) -> EngineState:
    n = col_fill.shape[0]
    P = pool_row.shape[0]
    labels = jnp.arange(n, dtype=jnp.int32)
    offs = jnp.arange(dmax, dtype=jnp.int32)

    state = EngineState(
        pool_row=pool_row, pool_val=pool_val, col_fill=col_fill, dep=dep,
        elim=jnp.zeros(n, bool), D=jnp.zeros(n, pool_val.dtype),
        n_elim=jnp.int32(0), n_rounds=jnp.int32(0), overflow=jnp.int32(0))

    def cond(s: EngineState):
        return (s.n_elim < n) & (s.n_rounds <= n)

    def body(s: EngineState) -> EngineState:
        # -- 1. ready set: chunk smallest ready labels ---------------------
        prio = jnp.where((~s.elim) & (s.dep == 0), labels, n)
        _, cand = jax.lax.top_k(-prio, chunk)
        cand = cand.astype(jnp.int32)
        cand_ok = prio[cand] < n

        # -- 2. gather column slabs + eliminate ----------------------------
        base = col_base[cand]
        fill = s.col_fill[cand]
        slots = base[:, None] + offs[None, :]
        sv = (offs[None, :] < fill[:, None]) & cand_ok[:, None]
        slots_c = jnp.where(sv, slots, P)
        ids = jnp.take(s.pool_row, slots_c, mode="fill",
                       fill_value=INVALID_ID)
        ws = jnp.take(s.pool_val, slots_c, mode="fill", fill_value=0.0)
        u = jax.vmap(lambda v: column_uniforms(key, v, dmax))(cand)
        res = jax.vmap(eliminate_column)(ids, ws, sv, u)

        # -- 3. write factor columns in place ------------------------------
        wmask = (offs[None, :] < res.m[:, None]) & cand_ok[:, None]
        tgt = jnp.where(wmask, slots, P).ravel()
        pool_row = s.pool_row.at[tgt].set(res.g_rows.ravel(), mode="drop")
        pool_val = s.pool_val.at[tgt].set(res.g_vals.ravel(), mode="drop")
        col_fill = s.col_fill.at[cand].set(
            jnp.where(cand_ok, res.m, s.col_fill[cand]))
        D = s.D.at[cand].set(jnp.where(cand_ok, res.ell_kk, s.D[cand]))
        elim = s.elim.at[cand].set(cand_ok | s.elim[cand])

        # -- 4. dep decrements for consumed multi-edges --------------------
        dep = s.dep.at[jnp.where(sv, ids, n).ravel()].add(-1, mode="drop")

        # -- 5. scatter sampled edges to owner slabs -----------------------
        e_valid = (res.e_valid & cand_ok[:, None]).ravel()
        e_lo = jnp.where(e_valid, res.e_lo.ravel(), n)
        e_hi = res.e_hi.ravel()
        e_w = res.e_w.ravel()
        order = jnp.argsort(e_lo, stable=True)
        so, sh, sw2 = e_lo[order], e_hi[order], e_w[order]
        E = so.shape[0]
        eidx = jnp.arange(E, dtype=jnp.int32)
        is_start = jnp.concatenate([jnp.ones((1,), bool), so[1:] != so[:-1]])
        run_start = jax.lax.associative_scan(
            jnp.maximum, jnp.where(is_start, eidx, 0))
        rank = eidx - run_start
        valid_e = so < n
        dst_fill = jnp.take(col_fill, jnp.minimum(so, n - 1))
        slot = jnp.take(col_base, jnp.minimum(so, n - 1)) + dst_fill + rank
        fits = valid_e & (dst_fill + rank < jnp.take(cap, jnp.minimum(so, n - 1)))
        overflow = s.overflow + jnp.sum(valid_e & ~fits)
        tgt_e = jnp.where(fits, slot, P)
        pool_row = pool_row.at[tgt_e].set(sh, mode="drop")
        pool_val = pool_val.at[tgt_e].set(sw2, mode="drop")
        col_fill = col_fill.at[jnp.where(fits, so, n)].add(1, mode="drop")
        dep = dep.at[jnp.where(fits, sh, n)].add(1, mode="drop")

        return EngineState(
            pool_row=pool_row, pool_val=pool_val, col_fill=col_fill,
            dep=dep, elim=elim, D=D,
            n_elim=s.n_elim + jnp.sum(cand_ok).astype(jnp.int32),
            n_rounds=s.n_rounds + 1, overflow=overflow)

    return jax.lax.while_loop(cond, body, state)


@jax.jit
def _compact_pool(pool_row, pool_val, col_fill, col_base):
    """Device-side CSC compaction: squeeze each column's live slab prefix
    into contiguous CSC order.  One vectorized pass (ownership lookup via
    searchsorted over slab bases + masked scatter) — the jit replacement
    for the old ``for k in range(n)`` host loop.

    Returns pool-sized ``rows_c``/``vals_c`` whose first ``col_ptr[-1]``
    entries are the compact factor, plus ``col_ptr`` (int32[n+1]).
    """
    P = pool_row.shape[0]
    slot = jnp.arange(P, dtype=jnp.int32)
    # owner column of each pool slot (zero-cap slabs are skipped because
    # consecutive equal bases collapse under side="right")
    owner = (jnp.searchsorted(col_base, slot, side="right") - 1).astype(
        jnp.int32)
    off = slot - col_base[owner]
    keep = off < col_fill[owner]
    col_ptr = jnp.concatenate([
        jnp.zeros(1, jnp.int32), jnp.cumsum(col_fill, dtype=jnp.int32)])
    dest = jnp.where(keep, col_ptr[owner] + off, P)
    rows_c = jnp.zeros(P, pool_row.dtype).at[dest].set(pool_row, mode="drop")
    vals_c = jnp.zeros(P, pool_val.dtype).at[dest].set(pool_val, mode="drop")
    return rows_c, vals_c, col_ptr


def _build_pool(g: Graph, fill_slack: int, dtype):
    """Static slab layout: cap_k = owned-initial-degree + fill_slack."""
    n = g.n
    owned = np.zeros(n, np.int64)
    np.add.at(owned, g.src, 1)
    cap = owned + fill_slack
    col_base = np.zeros(n + 1, np.int64)
    np.cumsum(cap, out=col_base[1:])
    P = int(col_base[-1])
    pool_row = np.full(P, INVALID_ID, np.int32)
    pool_val = np.zeros(P, dtype)
    fill = np.zeros(n, np.int64)
    # place initial edges at the head of their owner slab
    idx = col_base[g.src] + _cumcount(g.src, n)
    pool_row[idx] = g.dst
    pool_val[idx] = g.w.astype(dtype)
    fill[: n] = owned
    dep = np.zeros(n, np.int64)
    np.add.at(dep, g.dst, 1)
    dmax = int(cap.max()) if n else 1
    return (pool_row, pool_val, fill.astype(np.int32), dep.astype(np.int32),
            col_base.astype(np.int32), cap.astype(np.int32), P, dmax)


def _cumcount(keys: np.ndarray, n: int) -> np.ndarray:
    """Occurrence rank of each element within its key group (keys arbitrary order)."""
    order = np.argsort(keys, kind="stable")
    sk = keys[order]
    start = np.concatenate([[True], sk[1:] != sk[:-1]])
    run_start = np.maximum.accumulate(np.where(start, np.arange(sk.size), 0))
    rank_sorted = np.arange(sk.size) - run_start
    rank = np.empty_like(rank_sorted)
    rank[order] = rank_sorted
    return rank


def factorize_wavefront(g: Graph, key: jax.Array, *, chunk: int = 64,
                        fill_slack: int = 32, strict: bool = True,
                        max_retries: int = 3,
                        dtype=np.float32) -> ACFactor:
    """Parallel ParAC factorization.  Returns the same ``ACFactor`` as the
    sequential oracle (bit-identical for the same key when no overflow)."""
    n = g.n
    slack = fill_slack
    for attempt in range(max_retries + 1):
        (pool_row, pool_val, fill, dep, col_base, cap, P, dmax) = \
            _build_pool(g, slack, dtype)
        final = _run_engine(
            jnp.asarray(pool_row), jnp.asarray(pool_val), jnp.asarray(fill),
            jnp.asarray(dep), jnp.asarray(col_base), jnp.asarray(cap), key,
            dmax=dmax, chunk=min(chunk, max(n, 1)))
        ovf = int(final.overflow)
        if ovf == 0 or not strict or attempt == max_retries:
            break
        slack *= 2
    if int(final.n_elim) != n:
        raise RuntimeError(
            f"engine stalled: {int(final.n_elim)}/{n} eliminated "
            f"(overflow={ovf})")

    # device-side compaction: no per-column host loop; the factor stays
    # resident on device (DeviceFactor) for the trisolve schedule builder.
    rows_c, vals_c, col_ptr_d = _compact_pool(
        final.pool_row, final.pool_val, final.col_fill,
        jnp.asarray(col_base))
    nnz = int(col_ptr_d[-1])
    rows_dev = jax.lax.slice(rows_c, (0,), (nnz,))
    vals_dev = jax.lax.slice(vals_c, (0,), (nnz,))
    dev = DeviceFactor(col_ptr=col_ptr_d, rows=rows_dev, vals=vals_dev,
                       D=final.D)
    stats = dict(rounds=int(final.n_rounds), overflow=ovf,
                 chunk=chunk, fill_slack=slack, pool_size=P, dmax=dmax)
    return ACFactor(n=n, col_ptr=np.asarray(col_ptr_d).astype(np.int64),
                    rows=np.asarray(rows_dev), vals=np.asarray(vals_dev),
                    D=np.asarray(final.D), stats=stats, device=dev)
