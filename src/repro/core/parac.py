"""ParAC — bulk-synchronous wavefront randomized Cholesky (JAX).

TPU-native adaptation of the paper's GPU persistent-kernel algorithm
(Algorithm 4).  Each round:

  1. the *ready set* (dep == 0, not eliminated) is an independent set of
     the current multi-graph — take the ``chunk`` smallest labels;
  2. gather their column slabs from the static edge pool, eliminate them
     all at once (``vmap`` of the shared per-column math; the Pallas
     ``sample_clique`` kernel is the tiled version of the same math);
  3. write the normalized column back in place (the pool doubles as the
     output factor, like the paper's array O);
  4. bulk-scatter sampled spanning-tree edges to their owner column's
     slab at sort-derived offsets (the barrier-free analogue of the
     paper's ``hash(a) + fill_in_count(a)`` insertion);
  5. update dependency counters with segment adds (the atomic-free
     analogue of Algorithm 4 lines 21/24).

Rounds iterate under ``lax.while_loop`` until every vertex is eliminated.
The factor is bit-identical to the sequential oracle because per-vertex
randomness is schedule independent (``column_math.column_uniforms``).

The round is decomposed into pure stage functions (`_round_ready`,
`_round_eliminate`, `_round_commit`, `_round_scatter`) composed by
``_engine_round``; ``_run_engine`` drives one graph and
``_run_engine_batched`` ``vmap``s the same round over a padded fleet —
``factorize_batched`` factors B Laplacians in one XLA program and is
bit-identical to per-graph ``factorize_wavefront`` because the factor is
schedule- and padding-width-independent (phantom vertices start
eliminated; phantom pool slots belong to zero-capacity columns).

Memory model (paper §5.1): one static pool sized ``m + n·fill_slack``;
column k owns slab ``[col_base[k], col_base[k] + cap[k])``.  Overflowing
sampled edges are dropped *and counted* — `strict=True` retries with a
doubled slack instead (dynamic malloc is as ill-advised in XLA as in
device code).  In the batched path only the overflowing graphs re-run
(masked re-runs at doubled slack); converged graphs keep their result.
"""
from __future__ import annotations

from functools import partial
from typing import List, NamedTuple, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from .laplacian import Graph
from .column_math import eliminate_column, column_uniforms, INVALID_ID
from .ref_ac import ACFactor, DeviceFactor


class EngineState(NamedTuple):
    pool_row: jnp.ndarray   # int32[P] — max-label endpoint / factor row id
    pool_val: jnp.ndarray   # f32[P]   — alive: edge weight (>0); done: G value
    col_fill: jnp.ndarray   # int32[n] — #entries in each column slab
    dep: jnp.ndarray        # int32[n] — #alive multi-edges with max endpoint v
    elim: jnp.ndarray       # bool[n]
    D: jnp.ndarray          # f32[n]
    n_elim: jnp.ndarray     # int32
    n_rounds: jnp.ndarray   # int32
    overflow: jnp.ndarray   # int32 — dropped sampled edges (0 in strict runs)


# ---------------------------------------------------------------------------
# Pure per-round stages (shared verbatim by the single-graph and batched
# engines — the batched path must not fork the math)
# ---------------------------------------------------------------------------

def _init_state(pool_row, pool_val, col_fill, dep,
                elim0: Optional[jnp.ndarray] = None) -> EngineState:
    """Fresh engine state.  ``elim0`` pre-eliminates vertices (the padded
    batched path marks phantom vertices eliminated so they never enter a
    ready set)."""
    n = col_fill.shape[0]
    elim = jnp.zeros(n, bool) if elim0 is None else elim0
    return EngineState(
        pool_row=pool_row, pool_val=pool_val, col_fill=col_fill, dep=dep,
        elim=elim, D=jnp.zeros(n, pool_val.dtype),
        n_elim=jnp.sum(elim).astype(jnp.int32), n_rounds=jnp.int32(0),
        overflow=jnp.int32(0))


def _round_ready(elim: jnp.ndarray, dep: jnp.ndarray, *, chunk: int):
    """Stage 1 — the ready set: ``chunk`` smallest ready labels.  Returns
    candidate labels and their validity mask (short rounds pad with
    invalid candidates)."""
    n = elim.shape[0]
    labels = jnp.arange(n, dtype=jnp.int32)
    prio = jnp.where((~elim) & (dep == 0), labels, n)
    _, cand = jax.lax.top_k(-prio, chunk)
    cand = cand.astype(jnp.int32)
    return cand, prio[cand] < n


def _round_eliminate(s: EngineState, cand, cand_ok, col_base, key, *,
                     dmax: int):
    """Stage 2 — gather candidate column slabs and eliminate them all at
    once.  Returns the per-column elimination results plus the gathered
    slab geometry the commit stage writes back through."""
    P = s.pool_row.shape[0]
    offs = jnp.arange(dmax, dtype=jnp.int32)
    base = col_base[cand]
    fill = s.col_fill[cand]
    slots = base[:, None] + offs[None, :]
    sv = (offs[None, :] < fill[:, None]) & cand_ok[:, None]
    slots_c = jnp.where(sv, slots, P)
    ids = jnp.take(s.pool_row, slots_c, mode="fill", fill_value=INVALID_ID)
    ws = jnp.take(s.pool_val, slots_c, mode="fill", fill_value=0.0)
    u = jax.vmap(lambda v: column_uniforms(key, v, dmax))(cand)
    res = jax.vmap(eliminate_column)(ids, ws, sv, u)
    return res, slots, sv, ids


def _round_commit(s: EngineState, cand, cand_ok, res, slots, sv, ids, *,
                  dmax: int):
    """Stages 3+4 — write normalized factor columns in place and decrement
    dependency counters for the consumed multi-edges."""
    n = s.col_fill.shape[0]
    P = s.pool_row.shape[0]
    offs = jnp.arange(dmax, dtype=jnp.int32)
    wmask = (offs[None, :] < res.m[:, None]) & cand_ok[:, None]
    tgt = jnp.where(wmask, slots, P).ravel()
    pool_row = s.pool_row.at[tgt].set(res.g_rows.ravel(), mode="drop")
    pool_val = s.pool_val.at[tgt].set(res.g_vals.ravel(), mode="drop")
    col_fill = s.col_fill.at[cand].set(
        jnp.where(cand_ok, res.m, s.col_fill[cand]))
    D = s.D.at[cand].set(jnp.where(cand_ok, res.ell_kk, s.D[cand]))
    elim = s.elim.at[cand].set(cand_ok | s.elim[cand])
    dep = s.dep.at[jnp.where(sv, ids, n).ravel()].add(-1, mode="drop")
    return pool_row, pool_val, col_fill, dep, elim, D


def _run_ranks(sorted_keys: jnp.ndarray) -> jnp.ndarray:
    """Rank of each element within its run of equal consecutive keys
    (keys must already be sorted/grouped; device-side analogue of
    ``_cumcount``).  The shared scatter-offset idiom of the engine's
    sampled-edge scatter and the trisolve schedule builders' ELL
    packers — one implementation so the run-boundary handling cannot
    drift between them."""
    E = sorted_keys.shape[0]
    eidx = jnp.arange(E, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_keys[1:] != sorted_keys[:-1]])
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, eidx, 0))
    return eidx - run_start


def _round_scatter(pool_row, pool_val, col_fill, dep, res, cand_ok,
                   col_base, cap, overflow):
    """Stage 5 — scatter sampled spanning-tree edges to their owner
    column's slab at sort-derived offsets; edges past a slab's capacity
    are dropped and counted in ``overflow``."""
    n = col_fill.shape[0]
    P = pool_row.shape[0]
    e_valid = (res.e_valid & cand_ok[:, None]).ravel()
    e_lo = jnp.where(e_valid, res.e_lo.ravel(), n)
    e_hi = res.e_hi.ravel()
    e_w = res.e_w.ravel()
    order = jnp.argsort(e_lo, stable=True)
    so, sh, sw2 = e_lo[order], e_hi[order], e_w[order]
    rank = _run_ranks(so)
    valid_e = so < n
    dst_fill = jnp.take(col_fill, jnp.minimum(so, n - 1))
    slot = jnp.take(col_base, jnp.minimum(so, n - 1)) + dst_fill + rank
    fits = valid_e & (dst_fill + rank < jnp.take(cap, jnp.minimum(so, n - 1)))
    overflow = overflow + jnp.sum(valid_e & ~fits)
    tgt_e = jnp.where(fits, slot, P)
    pool_row = pool_row.at[tgt_e].set(sh, mode="drop")
    pool_val = pool_val.at[tgt_e].set(sw2, mode="drop")
    col_fill = col_fill.at[jnp.where(fits, so, n)].add(1, mode="drop")
    dep = dep.at[jnp.where(fits, sh, n)].add(1, mode="drop")
    return pool_row, pool_val, col_fill, dep, overflow


def _engine_round(s: EngineState, col_base, cap, key, *, dmax: int,
                  chunk: int) -> EngineState:
    """One bulk-synchronous round — the composition of the pure stages."""
    cand, cand_ok = _round_ready(s.elim, s.dep, chunk=chunk)
    res, slots, sv, ids = _round_eliminate(s, cand, cand_ok, col_base, key,
                                           dmax=dmax)
    pool_row, pool_val, col_fill, dep, elim, D = _round_commit(
        s, cand, cand_ok, res, slots, sv, ids, dmax=dmax)
    pool_row, pool_val, col_fill, dep, overflow = _round_scatter(
        pool_row, pool_val, col_fill, dep, res, cand_ok, col_base, cap,
        s.overflow)
    return EngineState(
        pool_row=pool_row, pool_val=pool_val, col_fill=col_fill,
        dep=dep, elim=elim, D=D,
        n_elim=s.n_elim + jnp.sum(cand_ok).astype(jnp.int32),
        n_rounds=s.n_rounds + 1, overflow=overflow)


def _engine_cond(s: EngineState):
    n = s.elim.shape[0]
    return (s.n_elim < n) & (s.n_rounds <= n)


@partial(jax.jit, static_argnames=("dmax", "chunk"))
def _run_engine(pool_row, pool_val, col_fill, dep, col_base, cap, key,
                *, dmax: int, chunk: int) -> EngineState:
    state = _init_state(pool_row, pool_val, col_fill, dep)
    return jax.lax.while_loop(
        _engine_cond,
        lambda s: _engine_round(s, col_base, cap, key, dmax=dmax,
                                chunk=chunk),
        state)


@partial(jax.jit, static_argnames=("dmax", "chunk"))
def _run_engine_batched(pool_row, pool_val, col_fill, dep, col_base, cap,
                        elim0, keys, *, dmax: int, chunk: int) -> EngineState:
    """The wavefront ``while_loop`` under ``vmap``: one XLA program
    factors the whole padded fleet.  Graphs whose predicate goes false
    freeze (vmap-of-while masks their updates) while the rest keep
    iterating, so each graph takes exactly its own round sequence."""
    def one(pr, pv, cf, dp, cb, cp, e0, key):
        state = _init_state(pr, pv, cf, dp, e0)
        return jax.lax.while_loop(
            _engine_cond,
            lambda s: _engine_round(s, cb, cp, key, dmax=dmax, chunk=chunk),
            state)

    return jax.vmap(one)(pool_row, pool_val, col_fill, dep, col_base, cap,
                         elim0, keys)


@jax.jit
def _compact_pool(pool_row, pool_val, col_fill, col_base):
    """Device-side CSC compaction: squeeze each column's live slab prefix
    into contiguous CSC order.  One vectorized pass (ownership lookup via
    searchsorted over slab bases + masked scatter) — the jit replacement
    for the old ``for k in range(n)`` host loop.

    Returns pool-sized ``rows_c``/``vals_c`` whose first ``col_ptr[-1]``
    entries are the compact factor, plus ``col_ptr`` (int32[n+1]).
    """
    P = pool_row.shape[0]
    slot = jnp.arange(P, dtype=jnp.int32)
    # owner column of each pool slot (zero-cap slabs are skipped because
    # consecutive equal bases collapse under side="right")
    owner = (jnp.searchsorted(col_base, slot, side="right") - 1).astype(
        jnp.int32)
    off = slot - col_base[owner]
    keep = off < col_fill[owner]
    col_ptr = jnp.concatenate([
        jnp.zeros(1, jnp.int32), jnp.cumsum(col_fill, dtype=jnp.int32)])
    dest = jnp.where(keep, col_ptr[owner] + off, P)
    rows_c = jnp.zeros(P, pool_row.dtype).at[dest].set(pool_row, mode="drop")
    vals_c = jnp.zeros(P, pool_val.dtype).at[dest].set(pool_val, mode="drop")
    return rows_c, vals_c, col_ptr


def _build_pool(g: Graph, fill_slack: int, dtype):
    """Static slab layout: cap_k = owned-initial-degree + fill_slack."""
    n = g.n
    owned = np.zeros(n, np.int64)
    np.add.at(owned, g.src, 1)
    cap = owned + fill_slack
    col_base = np.zeros(n + 1, np.int64)
    np.cumsum(cap, out=col_base[1:])
    P = int(col_base[-1])
    pool_row = np.full(P, INVALID_ID, np.int32)
    pool_val = np.zeros(P, dtype)
    fill = np.zeros(n, np.int64)
    # place initial edges at the head of their owner slab
    idx = col_base[g.src] + _cumcount(g.src, n)
    pool_row[idx] = g.dst
    pool_val[idx] = g.w.astype(dtype)
    fill[: n] = owned
    dep = np.zeros(n, np.int64)
    np.add.at(dep, g.dst, 1)
    dmax = int(cap.max()) if n else 1
    return (pool_row, pool_val, fill.astype(np.int32), dep.astype(np.int32),
            col_base.astype(np.int32), cap.astype(np.int32), P, dmax)


def _cumcount(keys: np.ndarray, n: int) -> np.ndarray:
    """Occurrence rank of each element within its key group (keys arbitrary order)."""
    order = np.argsort(keys, kind="stable")
    sk = keys[order]
    start = np.concatenate([[True], sk[1:] != sk[:-1]])
    run_start = np.maximum.accumulate(np.where(start, np.arange(sk.size), 0))
    rank_sorted = np.arange(sk.size) - run_start
    rank = np.empty_like(rank_sorted)
    rank[order] = rank_sorted
    return rank


def _finalize_factor(g: Graph, final: EngineState, col_base: jnp.ndarray,
                     *, n_phantom: int = 0, stats: dict) -> ACFactor:
    """Compact the engine pool on device and wrap it as an ``ACFactor``.

    Shared by the single-graph and batched paths; in the padded batched
    case ``final`` carries ``n_phantom`` pre-eliminated phantom vertices
    whose columns are empty — everything past position ``g.n`` is sliced
    away (phantom writes land at pool offsets ≥ nnz, never below).
    """
    n = g.n
    eliminated = int(final.n_elim) - n_phantom
    if eliminated != n:
        raise RuntimeError(
            f"engine stalled: {eliminated}/{n} eliminated "
            f"(overflow={int(final.overflow)})")
    rows_c, vals_c, col_ptr_d = _compact_pool(
        final.pool_row, final.pool_val, final.col_fill, col_base)
    nnz = int(col_ptr_d[n])
    col_ptr_g = jax.lax.slice(col_ptr_d, (0,), (n + 1,))
    rows_dev = jax.lax.slice(rows_c, (0,), (nnz,))
    vals_dev = jax.lax.slice(vals_c, (0,), (nnz,))
    D_dev = jax.lax.slice(final.D, (0,), (n,))
    dev = DeviceFactor(col_ptr=col_ptr_g, rows=rows_dev, vals=vals_dev,
                       D=D_dev)
    return ACFactor(n=n, col_ptr=np.asarray(col_ptr_g).astype(np.int64),
                    rows=np.asarray(rows_dev), vals=np.asarray(vals_dev),
                    D=np.asarray(D_dev), stats=stats, device=dev)


def factorize_wavefront(g: Graph, key: jax.Array, *, chunk: int = 64,
                        fill_slack: int = 32, strict: bool = True,
                        max_retries: int = 3,
                        dtype=np.float32) -> ACFactor:
    """Parallel ParAC factorization.  Returns the same ``ACFactor`` as the
    sequential oracle (bit-identical for the same key when no overflow)."""
    n = g.n
    slack = fill_slack
    for attempt in range(max_retries + 1):
        (pool_row, pool_val, fill, dep, col_base, cap, P, dmax) = \
            _build_pool(g, slack, dtype)
        final = _run_engine(
            jnp.asarray(pool_row), jnp.asarray(pool_val), jnp.asarray(fill),
            jnp.asarray(dep), jnp.asarray(col_base), jnp.asarray(cap), key,
            dmax=dmax, chunk=min(chunk, max(n, 1)))
        ovf = int(final.overflow)
        if ovf == 0 or not strict or attempt == max_retries:
            break
        slack *= 2
    stats = dict(rounds=int(final.n_rounds), overflow=ovf,
                 chunk=chunk, fill_slack=slack, pool_size=P, dmax=dmax)
    return _finalize_factor(g, final, jnp.asarray(col_base), stats=stats)


# ---------------------------------------------------------------------------
# Batched fleet factorization
# ---------------------------------------------------------------------------

def _next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


def _pad_np(x: np.ndarray, size: int, fill) -> np.ndarray:
    if x.shape[0] == size:
        return x
    return np.concatenate([x, np.full(size - x.shape[0], fill, x.dtype)])


def factorize_batched(gs: Sequence[Graph], keys, *, chunk: int = 64,
                      fill_slack: int = 32, strict: bool = True,
                      max_retries: int = 3, dtype=np.float32,
                      bucket: bool = True, with_schedules: bool = False,
                      device: Optional[jax.Device] = None):
    """Factor a fleet of Laplacians concurrently in one XLA program.

    Pools are padded to a common shape bucket (powers of two when
    ``bucket`` — bounds jit recompiles across fleets) and the wavefront
    ``while_loop`` runs under ``vmap``.  Padding never changes a factor:
    phantom vertices start eliminated, phantom pool slots belong to
    zero-capacity columns, and the per-column math is padding-width
    independent (``column_math``), so each returned ``ACFactor`` is
    bit-identical to ``factorize_wavefront(g, key, ...)``.

    Overflow is handled per graph: converged graphs keep their factor
    while the overflowing subset re-runs at doubled slack (masked
    re-runs), mirroring the single-graph strict retry loop.

    With ``with_schedules`` the fleet's triangular level schedules are
    also derived in one vmapped pass (``trisolve.build_schedules_batched``
    over the padded device factors) and the call returns
    ``(factors, schedules)`` — the complete factor→solve admission
    payload in two batched XLA programs total.

    ``device`` targets the whole construction (wavefront engine,
    compaction and schedule derivation) at a specific accelerator —
    a dedicated factor replica runs here while serving replicas' solve
    programs run undisturbed on theirs.  Outputs stay uncommitted, so
    adopting them onto a serving device is one transfer at admission.
    """
    if device is not None:
        with jax.default_device(device):
            return factorize_batched(
                gs, keys, chunk=chunk, fill_slack=fill_slack,
                strict=strict, max_retries=max_retries, dtype=dtype,
                bucket=bucket, with_schedules=with_schedules)
    gs = list(gs)
    B = len(gs)
    if not isinstance(keys, jax.Array):
        keys = jnp.stack(list(keys))
    if keys.shape[0] != B:
        raise ValueError(f"got {B} graphs but {keys.shape[0]} keys")
    if B == 0:
        return ([], []) if with_schedules else []

    slacks = [fill_slack] * B
    results: List[Optional[ACFactor]] = [None] * B
    pending = list(range(B))
    for attempt in range(max_retries + 1):
        built = {i: _build_pool(gs[i], slacks[i], dtype) for i in pending}
        n_pad = max(max(gs[i].n for i in pending), 1)
        P_pad = max(max(built[i][6] for i in pending), 1)
        dmax_pad = max(built[i][7] for i in pending)
        if bucket:
            n_pad = _next_pow2(n_pad)
            P_pad = _next_pow2(P_pad)
            dmax_pad = _next_pow2(dmax_pad)
        chunk_eff = min(chunk, n_pad)

        PR, PV, CF, DP, CB, CP, E0 = [], [], [], [], [], [], []
        for i in pending:
            pool_row, pool_val, fill, dep, col_base, cap, P, _ = built[i]
            n = gs[i].n
            PR.append(_pad_np(pool_row, P_pad, INVALID_ID))
            PV.append(_pad_np(pool_val, P_pad, 0))
            CF.append(_pad_np(fill, n_pad, 0))
            DP.append(_pad_np(dep, n_pad, 0))
            CB.append(_pad_np(col_base, n_pad + 1, col_base[-1]))
            CP.append(_pad_np(cap, n_pad, 0))
            elim0 = np.zeros(n_pad, bool)
            elim0[n:] = True
            E0.append(elim0)
        out = _run_engine_batched(
            jnp.asarray(np.stack(PR)), jnp.asarray(np.stack(PV)),
            jnp.asarray(np.stack(CF)), jnp.asarray(np.stack(DP)),
            jnp.asarray(np.stack(CB)), jnp.asarray(np.stack(CP)),
            jnp.asarray(np.stack(E0)),
            jnp.stack([keys[i] for i in pending]),
            dmax=dmax_pad, chunk=chunk_eff)

        retry = []
        for bi, i in enumerate(pending):
            final_i = jax.tree_util.tree_map(lambda x, bi=bi: x[bi], out)
            ovf = int(final_i.overflow)
            if ovf == 0 or not strict or attempt == max_retries:
                stats = dict(rounds=int(final_i.n_rounds), overflow=ovf,
                             chunk=chunk, fill_slack=slacks[i],
                             pool_size=int(built[i][6]),
                             dmax=int(built[i][7]), batched=True,
                             batch_size=len(pending), n_pad=n_pad,
                             P_pad=P_pad, dmax_pad=dmax_pad)
                results[i] = _finalize_factor(
                    gs[i], final_i, jnp.asarray(CB[bi]),
                    n_phantom=n_pad - gs[i].n, stats=stats)
            else:
                slacks[i] *= 2
                retry.append(i)
        pending = retry
        if not pending:
            break
    if not with_schedules:
        return results
    from .trisolve import build_schedules_batched
    return results, build_schedules_batched([f.device for f in results])
