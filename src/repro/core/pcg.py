"""Preconditioned conjugate gradient — JAX (jit, production) and numpy
(host, baseline comparisons).

Laplacian systems are singular with nullspace span(1); both solvers keep
iterates mean-zero (standard projection, same as the paper's experimental
setup which reports relative residuals on Laplacian systems).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from .laplacian import Graph, laplacian_matvec, laplacian_matvec_np


class PCGResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray
    relres: jnp.ndarray
    converged: jnp.ndarray


class PCGBatchState(NamedTuple):
    """Carry of the batched PCG loop — exposed so a serving engine can
    drive solves incrementally (``pcg_batched_init`` → repeated
    ``pcg_batched_step``) instead of one closed ``while_loop``.  Lanes
    are independent (frozen-column masking), so a lane's trajectory does
    not depend on which other lanes share the batch or on how the
    iterations are sliced into steps."""

    X: jnp.ndarray        # (nrhs, n) iterate
    R: jnp.ndarray        # (nrhs, n) residual
    Z: jnp.ndarray        # (nrhs, n) preconditioned residual
    P: jnp.ndarray        # (nrhs, n) search direction
    rz: jnp.ndarray       # (nrhs,)
    it: jnp.ndarray       # int32 (nrhs,)
    active: jnp.ndarray   # bool  (nrhs,)
    bnorm: jnp.ndarray    # (nrhs,) — rhs norms (1.0 for zero rhs)


def pcg_jax(matvec: Callable, precond: Callable, b: jnp.ndarray, *,
            tol: float = 1e-6, maxiter: int = 1000,
            project: bool = True) -> PCGResult:
    """Standard PCG; runs under jit (while_loop)."""
    if project:
        b = b - jnp.mean(b)
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm > 0, bnorm, 1.0)

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = precond(r0)
    if project:
        z0 = z0 - jnp.mean(z0)
    p0 = z0
    rz0 = jnp.vdot(r0, z0)

    def cond(c):
        x, r, z, p, rz, it = c
        return (jnp.linalg.norm(r) / bnorm > tol) & (it < maxiter)

    def body(c):
        x, r, z, p, rz, it = c
        Ap = matvec(p)
        alpha = rz / jnp.vdot(p, Ap)
        x = x + alpha * p
        r = r - alpha * Ap
        z = precond(r)
        if project:
            z = z - jnp.mean(z)
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        return (x, r, z, p, rz_new, it + 1)

    x, r, z, p, rz, it = jax.lax.while_loop(
        cond, body, (x0, r0, z0, p0, rz0, jnp.int32(0)))
    relres = jnp.linalg.norm(r) / bnorm
    return PCGResult(x=x, iters=it, relres=relres, converged=relres <= tol)


def pcg_batched_init(matvec: Callable, precond: Callable, B: jnp.ndarray, *,
                     tol=1e-6, project: bool = True) -> PCGBatchState:
    """Set up the batched PCG carry for ``B`` of shape ``(nrhs, n)``.
    ``tol`` may be a scalar or a per-lane ``(nrhs,)`` array (mixed-tol
    continuous batching)."""
    if project:
        B = B - jnp.mean(B, axis=1, keepdims=True)
    bnorm = jnp.linalg.norm(B, axis=1)
    bnorm = jnp.where(bnorm > 0, bnorm, 1.0)
    nrhs = B.shape[0]

    R0 = B
    Z0 = precond(R0)
    if project:
        Z0 = Z0 - jnp.mean(Z0, axis=1, keepdims=True)
    rz0 = jnp.sum(R0 * Z0, axis=1)
    act0 = (jnp.linalg.norm(B, axis=1) / bnorm) > tol
    return PCGBatchState(X=jnp.zeros_like(B), R=R0, Z=Z0, P=Z0, rz=rz0,
                         it=jnp.zeros(nrhs, jnp.int32), active=act0,
                         bnorm=bnorm)


def _pcg_batched_body(matvec: Callable, precond: Callable, *, tol, maxiter,
                      project: bool):
    """One frozen-column batched PCG iteration as a pure
    ``PCGBatchState -> PCGBatchState`` closure — shared by the one-shot
    ``pcg_jax_batched`` loop and the serving engine's incremental
    ``pcg_batched_step``.  ``tol``/``maxiter`` may be scalars or per-lane
    arrays."""
    def _proj(Z):
        return Z - jnp.mean(Z, axis=1, keepdims=True) if project else Z

    def body(s: PCGBatchState) -> PCGBatchState:
        X, R, Z, P, rz, it, active = (s.X, s.R, s.Z, s.P, s.rz, s.it,
                                      s.active)
        AP = matvec(P)
        pAp = jnp.sum(P * AP, axis=1)
        alpha = jnp.where(active, rz / jnp.where(pAp != 0, pAp, 1.0), 0.0)
        Xn = X + alpha[:, None] * P
        Rn = R - alpha[:, None] * AP
        Zn = _proj(precond(Rn))
        rz_new = jnp.sum(Rn * Zn, axis=1)
        beta = jnp.where(active, rz_new / jnp.where(rz != 0, rz, 1.0), 0.0)
        Pn = Zn + beta[:, None] * P
        m = active[:, None]
        X = jnp.where(m, Xn, X)
        R = jnp.where(m, Rn, R)
        Z = jnp.where(m, Zn, Z)
        P = jnp.where(m, Pn, P)
        rz = jnp.where(active, rz_new, rz)
        it = it + active.astype(jnp.int32)
        relres = jnp.linalg.norm(R, axis=1) / s.bnorm
        active = active & (relres > tol) & (it < maxiter)
        return PCGBatchState(X=X, R=R, Z=Z, P=P, rz=rz, it=it,
                             active=active, bnorm=s.bnorm)

    return body


def pcg_batched_step(matvec: Callable, precond: Callable,
                     state: PCGBatchState, *, k: int, tol, maxiter,
                     project: bool = True) -> PCGBatchState:
    """Advance every active lane by up to ``k`` PCG iterations (early
    exit when all lanes freeze).  Slicing a solve into steps is exact:
    step-k-then-continue takes the same per-lane iterates as one closed
    loop."""
    body = _pcg_batched_body(matvec, precond, tol=tol, maxiter=maxiter,
                             project=project)

    def cond(c):
        s, j = c
        return jnp.any(s.active) & (j < k)

    def stepped(c):
        s, j = c
        return body(s), j + 1

    state, _ = jax.lax.while_loop(cond, stepped, (state, jnp.int32(0)))
    return state


def pcg_batched_result(state: PCGBatchState, tol) -> PCGResult:
    """Read a ``PCGResult`` off the current carry."""
    relres = jnp.linalg.norm(state.R, axis=1) / state.bnorm
    return PCGResult(x=state.X, iters=state.it, relres=relres,
                     converged=relres <= tol)


def pcg_jax_batched(matvec: Callable, precond: Callable, B: jnp.ndarray, *,
                    tol: float = 1e-6, maxiter: int = 1000,
                    project: bool = True) -> PCGResult:
    """Batched multi-RHS PCG: one ``while_loop`` drives every column of
    ``B`` (shape ``(nrhs, n)``) against the same operator/preconditioner.

    ``matvec``/``precond`` take and return ``(nrhs, n)`` blocks (vmap a
    single-vector closure, or pass a block closure that fuses the rhs
    axis, e.g. the multi-rhs ELL trisolve).  Converged columns are frozen
    by an active mask, so each column takes exactly the iterates of its
    independent single-rhs solve — results match ``pcg_jax`` per column
    instead of drifting while slow columns finish.
    """
    state = pcg_batched_init(matvec, precond, B, tol=tol, project=project)
    body = _pcg_batched_body(matvec, precond, tol=tol, maxiter=maxiter,
                             project=project)
    state = jax.lax.while_loop(lambda s: jnp.any(s.active), body, state)
    return pcg_batched_result(state, tol)


# ---------------------------------------------------------------------------
# Fleet PCG — factor data as traced arguments (shape-bucket mega-batching)
# ---------------------------------------------------------------------------

class FleetArrays(NamedTuple):
    """Stacked, bucket-padded device factors — the **traced** factor
    argument of the fleet PCG programs.  Row ``f`` holds one factor's
    padded Laplacian edge lists, row-indexed forward/backward trisolve
    panels, inverse diagonal and true size; a lane gathers its factor by
    index, so every factor whose padded shapes match shares one compiled
    step program (the factor is data, not a closure constant)."""

    src: jnp.ndarray      # int32[F, m_pad] — Laplacian edges (0-padded)
    dst: jnp.ndarray      # int32[F, m_pad]
    w: jnp.ndarray        # f32[F, m_pad]   (0 on padding)
    fcols: jnp.ndarray    # int32[F, n_pad, Kf] — fwd panels, row-indexed
    fvals: jnp.ndarray    # f32[F, n_pad, Kf]
    flevel: jnp.ndarray   # int32[F, n_pad]
    bcols: jnp.ndarray    # int32[F, n_pad, Kb] — bwd panels (unflipped)
    bvals: jnp.ndarray    # f32[F, n_pad, Kb]
    blevel: jnp.ndarray   # int32[F, n_pad]
    dinv: jnp.ndarray     # f32[F, n_pad]  — 1/D (0 where D <= 0 / phantom)
    nvalid: jnp.ndarray   # int32[F]       — true vertex count per factor
    fnlv: jnp.ndarray     # int32[F]       — true fwd level count per factor
    bnlv: jnp.ndarray     # int32[F]       — true bwd level count per factor


class FleetPCGState(NamedTuple):
    """Carry of the fleet PCG loop: per-lane iterate block plus the
    per-lane routing/termination scalars.  Everything a serving engine
    needs between ticks lives here, device-resident — admission scatters
    new columns in, retirement gathers finished columns out, and the
    carry itself never round-trips through the host."""

    X: jnp.ndarray        # (L, n_pad)
    R: jnp.ndarray        # (L, n_pad)
    Z: jnp.ndarray        # (L, n_pad)
    P: jnp.ndarray        # (L, n_pad)
    rz: jnp.ndarray       # (L,)
    it: jnp.ndarray       # int32 (L,)
    active: jnp.ndarray   # bool  (L,)
    bnorm: jnp.ndarray    # (L,)
    fidx: jnp.ndarray     # int32 (L,) — lane's factor row in the fleet
    tol: jnp.ndarray      # f32   (L,)
    maxiter: jnp.ndarray  # int32 (L,)


def fleet_matvec(fa: FleetArrays, fidx: jnp.ndarray,
                 Y: jnp.ndarray) -> jnp.ndarray:
    """Per-lane Laplacian matvec: lane ``l`` multiplies by the operator
    of factor ``fidx[l]`` (edge lists gathered from the fleet stack).
    Zero-weight padding edges contribute exactly zero."""
    src = fa.src[fidx]
    dst = fa.dst[fidx]
    w = fa.w[fidx]

    def one(s, d, ww, y):
        diff = ww * (y[s] - y[d])
        return jnp.zeros_like(y).at[s].add(diff).at[d].add(-diff)

    return jax.vmap(one)(src, dst, w, Y)


def fleet_precondition(fa: FleetArrays, fidx: jnp.ndarray, R: jnp.ndarray,
                       *, f_levels: int, b_levels: int,
                       kind: str = "factor",
                       interpret: Optional[bool] = None,
                       active=None) -> jnp.ndarray:
    """Per-lane preconditioner apply, dispatched on the **static** apply
    ``kind`` of the family that owns the fleet:

    * ``"factor"`` — ``(G D Gᵀ)⁺`` apply: forward masked trisolve → D⁻¹
      scale → backward masked trisolve, panels gathered per lane.  The
      level bounds are bucket-wide maxima; lanes whose factor has fewer
      levels stop selecting rows early (masked no-op), so over-padding
      the bound never changes a lane's result.  Used by the randomized
      AC factor and the incomplete-Cholesky families.
    * ``"spmv"`` — ``M r``: one lane-batched ELL SpMV of a materialized
      approximate inverse whose rows live in the forward-panel slots
      (``fcols``/``fvals``); the backward panels and ``dinv`` are inert.
      Used by SPAI and the flattened AMG operator — a single kernel
      launch per apply instead of ``f_levels + b_levels`` masked sweeps.

    ``kind`` must be static under jit (it selects the traced program).

    The static ``f_levels``/``b_levels`` ceilings bound compilation; the
    *trip count* of each trisolve is further bounded dynamically by the
    batch's live maximum true level count (``fa.fnlv``/``fa.bnlv``
    gathered per lane), so sweeps past every live lane's depth never
    launch.  ``active`` (optional bool ``(L,)``) masks frozen lanes out
    of the bound — their apply output is discarded by the caller's lane
    mask, so shrinking their sweep count cannot change any result.
    """
    # deferred: kernels.ops pulls in kernels.ref → repro.core, so a
    # top-level import here is a cycle whenever kernels.ops loads first
    from repro.kernels.ops import ell_spmv_fleet, trisolve_fleet
    if kind == "spmv":
        return ell_spmv_fleet(fa.fcols[fidx], fa.fvals[fidx], R,
                              interpret=interpret)
    if kind != "factor":
        raise ValueError(f"unknown preconditioner apply kind: {kind!r}")
    flv = fa.fnlv[fidx]
    blv = fa.bnlv[fidx]
    if active is not None:
        flv = jnp.where(active, flv, 1)
        blv = jnp.where(active, blv, 1)
    Y = trisolve_fleet(fa.fcols[fidx], fa.fvals[fidx], fa.flevel[fidx], R,
                       n_levels=f_levels, interpret=interpret,
                       lane_levels=flv)
    Z = Y * fa.dinv[fidx]
    return trisolve_fleet(fa.bcols[fidx], fa.bvals[fidx], fa.blevel[fidx],
                          Z, n_levels=b_levels, interpret=interpret,
                          lane_levels=blv)


def _fleet_project(Y: jnp.ndarray, nvalid: jnp.ndarray) -> jnp.ndarray:
    """Mean-zero projection restricted to each lane's true vertices.
    Padding entries are forced (back) to exactly 0 so padded reductions
    (norms, dot products) equal their unpadded counterparts."""
    nv = jnp.maximum(nvalid, 1).astype(Y.dtype)
    mean = jnp.sum(Y, axis=1) / nv
    vmask = jnp.arange(Y.shape[1], dtype=jnp.int32)[None, :] \
        < nvalid[:, None]
    return jnp.where(vmask, Y - mean[:, None], 0.0)


def pcg_fleet_init(fa: FleetArrays, fidx, B, tol, maxiter, *,
                   f_levels: int, b_levels: int, kind: str = "factor",
                   project: bool = True,
                   interpret: Optional[bool] = None) -> FleetPCGState:
    """Set up the fleet PCG carry for columns ``B`` of shape
    ``(L, n_pad)`` (each zero-padded past its factor's true n).  ``tol``
    and ``maxiter`` are per-lane arrays; lane ``l`` solves against
    factor ``fidx[l]``.  ``kind`` is the fleet's static apply kind (see
    :func:`fleet_precondition`)."""
    fidx = jnp.asarray(fidx, jnp.int32)
    nvalid = fa.nvalid[fidx]
    if project:
        B = _fleet_project(B, nvalid)
    bnorm = jnp.linalg.norm(B, axis=1)
    bnorm = jnp.where(bnorm > 0, bnorm, 1.0)
    R0 = B
    Z0 = fleet_precondition(fa, fidx, R0, f_levels=f_levels,
                            b_levels=b_levels, kind=kind,
                            interpret=interpret)
    if project:
        Z0 = _fleet_project(Z0, nvalid)
    rz0 = jnp.sum(R0 * Z0, axis=1)
    act0 = (jnp.linalg.norm(B, axis=1) / bnorm) > tol
    L = B.shape[0]
    return FleetPCGState(
        X=jnp.zeros_like(B), R=R0, Z=Z0, P=Z0, rz=rz0,
        it=jnp.zeros(L, jnp.int32), active=act0, bnorm=bnorm, fidx=fidx,
        tol=jnp.asarray(tol, jnp.float32),
        maxiter=jnp.asarray(maxiter, jnp.int32))


def _pcg_fleet_body(fa: FleetArrays, *, f_levels: int, b_levels: int,
                    kind: str = "factor", project: bool,
                    interpret: Optional[bool] = None):
    """One frozen-lane fleet PCG iteration as a pure
    ``FleetPCGState -> FleetPCGState`` closure over the **traced** fleet
    arrays — the factor-as-data restatement of ``_pcg_batched_body``.
    Lane independence is preserved: a lane's update reads only its own
    row and its own factor's fleet rows, so trajectories do not depend
    on batch composition, padding lanes, or step slicing."""
    def body(s: FleetPCGState) -> FleetPCGState:
        nvalid = fa.nvalid[s.fidx]
        AP = fleet_matvec(fa, s.fidx, s.P)
        pAp = jnp.sum(s.P * AP, axis=1)
        alpha = jnp.where(s.active,
                          s.rz / jnp.where(pAp != 0, pAp, 1.0), 0.0)
        Xn = s.X + alpha[:, None] * s.P
        Rn = s.R - alpha[:, None] * AP
        Zn = fleet_precondition(fa, s.fidx, Rn, f_levels=f_levels,
                                b_levels=b_levels, kind=kind,
                                interpret=interpret, active=s.active)
        if project:
            Zn = _fleet_project(Zn, nvalid)
        rz_new = jnp.sum(Rn * Zn, axis=1)
        beta = jnp.where(s.active,
                         rz_new / jnp.where(s.rz != 0, s.rz, 1.0), 0.0)
        Pn = Zn + beta[:, None] * s.P
        m = s.active[:, None]
        X = jnp.where(m, Xn, s.X)
        R = jnp.where(m, Rn, s.R)
        Z = jnp.where(m, Zn, s.Z)
        P = jnp.where(m, Pn, s.P)
        rz = jnp.where(s.active, rz_new, s.rz)
        it = s.it + s.active.astype(jnp.int32)
        relres = jnp.linalg.norm(R, axis=1) / s.bnorm
        active = s.active & (relres > s.tol) & (it < s.maxiter)
        return FleetPCGState(X=X, R=R, Z=Z, P=P, rz=rz, it=it,
                             active=active, bnorm=s.bnorm, fidx=s.fidx,
                             tol=s.tol, maxiter=s.maxiter)

    return body


def pcg_fleet_step(fa: FleetArrays, state: FleetPCGState, *, k: int,
                   f_levels: int, b_levels: int, kind: str = "factor",
                   project: bool = True,
                   interpret: Optional[bool] = None) -> FleetPCGState:
    """Advance every active lane by up to ``k`` iterations (early exit
    when all lanes freeze).  Step slicing is exact, as in
    ``pcg_batched_step``."""
    body = _pcg_fleet_body(fa, f_levels=f_levels, b_levels=b_levels,
                           kind=kind, project=project, interpret=interpret)

    def cond(c):
        s, j = c
        return jnp.any(s.active) & (j < k)

    def stepped(c):
        s, j = c
        return body(s), j + 1

    state, _ = jax.lax.while_loop(cond, stepped, (state, jnp.int32(0)))
    return state


def pcg_fleet_solve(fa: FleetArrays, fidx, B, tol, maxiter, *,
                    f_levels: int, b_levels: int, kind: str = "factor",
                    project: bool = True,
                    interpret: Optional[bool] = None) -> FleetPCGState:
    """One-shot fleet solve: init then iterate until every lane freezes.
    Runs the same body as ``pcg_fleet_step``, so an engine slicing the
    same solve into ticks takes bit-identical per-lane iterates."""
    state = pcg_fleet_init(fa, fidx, B, tol, maxiter, f_levels=f_levels,
                           b_levels=b_levels, kind=kind, project=project,
                           interpret=interpret)
    body = _pcg_fleet_body(fa, f_levels=f_levels, b_levels=b_levels,
                           kind=kind, project=project, interpret=interpret)
    return jax.lax.while_loop(lambda s: jnp.any(s.active), body, state)


def pcg_fleet_result(state: FleetPCGState, n: int) -> PCGResult:
    """Read a ``PCGResult`` off the fleet carry, sliced to true size."""
    relres = jnp.linalg.norm(state.R, axis=1) / state.bnorm
    return PCGResult(x=state.X[:, :n], iters=state.it, relres=relres,
                     converged=relres <= state.tol)


def pcg_np(matvec: Callable, precond: Callable, b: np.ndarray, *,
           tol: float = 1e-6, maxiter: int = 1000,
           project: bool = True) -> PCGResult:
    """Host PCG for baseline preconditioners (ichol, Jacobi, AMG)."""
    b = np.asarray(b, np.float64)
    if project:
        b = b - b.mean()
    bnorm = np.linalg.norm(b) or 1.0
    x = np.zeros_like(b)
    r = b.copy()
    z = np.asarray(precond(r), np.float64)
    if project:
        z = z - z.mean()
    p = z.copy()
    rz = float(r @ z)
    it = 0
    relres = np.linalg.norm(r) / bnorm
    while relres > tol and it < maxiter:
        Ap = np.asarray(matvec(p), np.float64)
        alpha = rz / float(p @ Ap)
        x += alpha * p
        r -= alpha * Ap
        z = np.asarray(precond(r), np.float64)
        if project:
            z = z - z.mean()
        rz_new = float(r @ z)
        beta = rz_new / rz
        p = z + beta * p
        rz = rz_new
        it += 1
        relres = np.linalg.norm(r) / bnorm
    return PCGResult(x=x, iters=np.int32(it), relres=np.float64(relres),
                     converged=relres <= tol)


def laplacian_pcg_jax(g: Graph, precond: Callable, b: jnp.ndarray,
                      **kw) -> PCGResult:
    src = jnp.asarray(g.src)
    dst = jnp.asarray(g.dst)
    w = jnp.asarray(g.w, dtype=b.dtype)
    mv = partial(laplacian_matvec, src, dst, w, g.n)
    return pcg_jax(mv, precond, b, **kw)


def laplacian_pcg_jax_batched(g: Graph, precond: Callable, B: jnp.ndarray,
                              **kw) -> PCGResult:
    """Batched Laplacian PCG; ``precond`` takes an ``(nrhs, n)`` block."""
    src = jnp.asarray(g.src)
    dst = jnp.asarray(g.dst)
    w = jnp.asarray(g.w, dtype=B.dtype)
    mv = jax.vmap(partial(laplacian_matvec, src, dst, w, g.n))
    return pcg_jax_batched(mv, precond, B, **kw)


def laplacian_pcg_np(g: Graph, precond: Callable, b: np.ndarray,
                     **kw) -> PCGResult:
    return pcg_np(lambda x: laplacian_matvec_np(g, x), precond, b, **kw)
