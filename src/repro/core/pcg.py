"""Preconditioned conjugate gradient — JAX (jit, production) and numpy
(host, baseline comparisons).

Laplacian systems are singular with nullspace span(1); both solvers keep
iterates mean-zero (standard projection, same as the paper's experimental
setup which reports relative residuals on Laplacian systems).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from .laplacian import Graph, laplacian_matvec, laplacian_matvec_np


class PCGResult(NamedTuple):
    x: jnp.ndarray
    iters: jnp.ndarray
    relres: jnp.ndarray
    converged: jnp.ndarray


class PCGBatchState(NamedTuple):
    """Carry of the batched PCG loop — exposed so a serving engine can
    drive solves incrementally (``pcg_batched_init`` → repeated
    ``pcg_batched_step``) instead of one closed ``while_loop``.  Lanes
    are independent (frozen-column masking), so a lane's trajectory does
    not depend on which other lanes share the batch or on how the
    iterations are sliced into steps."""

    X: jnp.ndarray        # (nrhs, n) iterate
    R: jnp.ndarray        # (nrhs, n) residual
    Z: jnp.ndarray        # (nrhs, n) preconditioned residual
    P: jnp.ndarray        # (nrhs, n) search direction
    rz: jnp.ndarray       # (nrhs,)
    it: jnp.ndarray       # int32 (nrhs,)
    active: jnp.ndarray   # bool  (nrhs,)
    bnorm: jnp.ndarray    # (nrhs,) — rhs norms (1.0 for zero rhs)


def pcg_jax(matvec: Callable, precond: Callable, b: jnp.ndarray, *,
            tol: float = 1e-6, maxiter: int = 1000,
            project: bool = True) -> PCGResult:
    """Standard PCG; runs under jit (while_loop)."""
    if project:
        b = b - jnp.mean(b)
    bnorm = jnp.linalg.norm(b)
    bnorm = jnp.where(bnorm > 0, bnorm, 1.0)

    x0 = jnp.zeros_like(b)
    r0 = b
    z0 = precond(r0)
    if project:
        z0 = z0 - jnp.mean(z0)
    p0 = z0
    rz0 = jnp.vdot(r0, z0)

    def cond(c):
        x, r, z, p, rz, it = c
        return (jnp.linalg.norm(r) / bnorm > tol) & (it < maxiter)

    def body(c):
        x, r, z, p, rz, it = c
        Ap = matvec(p)
        alpha = rz / jnp.vdot(p, Ap)
        x = x + alpha * p
        r = r - alpha * Ap
        z = precond(r)
        if project:
            z = z - jnp.mean(z)
        rz_new = jnp.vdot(r, z)
        beta = rz_new / rz
        p = z + beta * p
        return (x, r, z, p, rz_new, it + 1)

    x, r, z, p, rz, it = jax.lax.while_loop(
        cond, body, (x0, r0, z0, p0, rz0, jnp.int32(0)))
    relres = jnp.linalg.norm(r) / bnorm
    return PCGResult(x=x, iters=it, relres=relres, converged=relres <= tol)


def pcg_batched_init(matvec: Callable, precond: Callable, B: jnp.ndarray, *,
                     tol=1e-6, project: bool = True) -> PCGBatchState:
    """Set up the batched PCG carry for ``B`` of shape ``(nrhs, n)``.
    ``tol`` may be a scalar or a per-lane ``(nrhs,)`` array (mixed-tol
    continuous batching)."""
    if project:
        B = B - jnp.mean(B, axis=1, keepdims=True)
    bnorm = jnp.linalg.norm(B, axis=1)
    bnorm = jnp.where(bnorm > 0, bnorm, 1.0)
    nrhs = B.shape[0]

    R0 = B
    Z0 = precond(R0)
    if project:
        Z0 = Z0 - jnp.mean(Z0, axis=1, keepdims=True)
    rz0 = jnp.sum(R0 * Z0, axis=1)
    act0 = (jnp.linalg.norm(B, axis=1) / bnorm) > tol
    return PCGBatchState(X=jnp.zeros_like(B), R=R0, Z=Z0, P=Z0, rz=rz0,
                         it=jnp.zeros(nrhs, jnp.int32), active=act0,
                         bnorm=bnorm)


def _pcg_batched_body(matvec: Callable, precond: Callable, *, tol, maxiter,
                      project: bool):
    """One frozen-column batched PCG iteration as a pure
    ``PCGBatchState -> PCGBatchState`` closure — shared by the one-shot
    ``pcg_jax_batched`` loop and the serving engine's incremental
    ``pcg_batched_step``.  ``tol``/``maxiter`` may be scalars or per-lane
    arrays."""
    def _proj(Z):
        return Z - jnp.mean(Z, axis=1, keepdims=True) if project else Z

    def body(s: PCGBatchState) -> PCGBatchState:
        X, R, Z, P, rz, it, active = (s.X, s.R, s.Z, s.P, s.rz, s.it,
                                      s.active)
        AP = matvec(P)
        pAp = jnp.sum(P * AP, axis=1)
        alpha = jnp.where(active, rz / jnp.where(pAp != 0, pAp, 1.0), 0.0)
        Xn = X + alpha[:, None] * P
        Rn = R - alpha[:, None] * AP
        Zn = _proj(precond(Rn))
        rz_new = jnp.sum(Rn * Zn, axis=1)
        beta = jnp.where(active, rz_new / jnp.where(rz != 0, rz, 1.0), 0.0)
        Pn = Zn + beta[:, None] * P
        m = active[:, None]
        X = jnp.where(m, Xn, X)
        R = jnp.where(m, Rn, R)
        Z = jnp.where(m, Zn, Z)
        P = jnp.where(m, Pn, P)
        rz = jnp.where(active, rz_new, rz)
        it = it + active.astype(jnp.int32)
        relres = jnp.linalg.norm(R, axis=1) / s.bnorm
        active = active & (relres > tol) & (it < maxiter)
        return PCGBatchState(X=X, R=R, Z=Z, P=P, rz=rz, it=it,
                             active=active, bnorm=s.bnorm)

    return body


def pcg_batched_step(matvec: Callable, precond: Callable,
                     state: PCGBatchState, *, k: int, tol, maxiter,
                     project: bool = True) -> PCGBatchState:
    """Advance every active lane by up to ``k`` PCG iterations (early
    exit when all lanes freeze).  Slicing a solve into steps is exact:
    step-k-then-continue takes the same per-lane iterates as one closed
    loop."""
    body = _pcg_batched_body(matvec, precond, tol=tol, maxiter=maxiter,
                             project=project)

    def cond(c):
        s, j = c
        return jnp.any(s.active) & (j < k)

    def stepped(c):
        s, j = c
        return body(s), j + 1

    state, _ = jax.lax.while_loop(cond, stepped, (state, jnp.int32(0)))
    return state


def pcg_batched_result(state: PCGBatchState, tol) -> PCGResult:
    """Read a ``PCGResult`` off the current carry."""
    relres = jnp.linalg.norm(state.R, axis=1) / state.bnorm
    return PCGResult(x=state.X, iters=state.it, relres=relres,
                     converged=relres <= tol)


def pcg_jax_batched(matvec: Callable, precond: Callable, B: jnp.ndarray, *,
                    tol: float = 1e-6, maxiter: int = 1000,
                    project: bool = True) -> PCGResult:
    """Batched multi-RHS PCG: one ``while_loop`` drives every column of
    ``B`` (shape ``(nrhs, n)``) against the same operator/preconditioner.

    ``matvec``/``precond`` take and return ``(nrhs, n)`` blocks (vmap a
    single-vector closure, or pass a block closure that fuses the rhs
    axis, e.g. the multi-rhs ELL trisolve).  Converged columns are frozen
    by an active mask, so each column takes exactly the iterates of its
    independent single-rhs solve — results match ``pcg_jax`` per column
    instead of drifting while slow columns finish.
    """
    state = pcg_batched_init(matvec, precond, B, tol=tol, project=project)
    body = _pcg_batched_body(matvec, precond, tol=tol, maxiter=maxiter,
                             project=project)
    state = jax.lax.while_loop(lambda s: jnp.any(s.active), body, state)
    return pcg_batched_result(state, tol)


def pcg_np(matvec: Callable, precond: Callable, b: np.ndarray, *,
           tol: float = 1e-6, maxiter: int = 1000,
           project: bool = True) -> PCGResult:
    """Host PCG for baseline preconditioners (ichol, Jacobi, AMG)."""
    b = np.asarray(b, np.float64)
    if project:
        b = b - b.mean()
    bnorm = np.linalg.norm(b) or 1.0
    x = np.zeros_like(b)
    r = b.copy()
    z = np.asarray(precond(r), np.float64)
    if project:
        z = z - z.mean()
    p = z.copy()
    rz = float(r @ z)
    it = 0
    relres = np.linalg.norm(r) / bnorm
    while relres > tol and it < maxiter:
        Ap = np.asarray(matvec(p), np.float64)
        alpha = rz / float(p @ Ap)
        x += alpha * p
        r -= alpha * Ap
        z = np.asarray(precond(r), np.float64)
        if project:
            z = z - z.mean()
        rz_new = float(r @ z)
        beta = rz_new / rz
        p = z + beta * p
        rz = rz_new
        it += 1
        relres = np.linalg.norm(r) / bnorm
    return PCGResult(x=x, iters=np.int32(it), relres=np.float64(relres),
                     converged=relres <= tol)


def laplacian_pcg_jax(g: Graph, precond: Callable, b: jnp.ndarray,
                      **kw) -> PCGResult:
    src = jnp.asarray(g.src)
    dst = jnp.asarray(g.dst)
    w = jnp.asarray(g.w, dtype=b.dtype)
    mv = partial(laplacian_matvec, src, dst, w, g.n)
    return pcg_jax(mv, precond, b, **kw)


def laplacian_pcg_jax_batched(g: Graph, precond: Callable, B: jnp.ndarray,
                              **kw) -> PCGResult:
    """Batched Laplacian PCG; ``precond`` takes an ``(nrhs, n)`` block."""
    src = jnp.asarray(g.src)
    dst = jnp.asarray(g.dst)
    w = jnp.asarray(g.w, dtype=B.dtype)
    mv = jax.vmap(partial(laplacian_matvec, src, dst, w, g.n))
    return pcg_jax_batched(mv, precond, B, **kw)


def laplacian_pcg_np(g: Graph, precond: Callable, b: np.ndarray,
                     **kw) -> PCGResult:
    return pcg_np(lambda x: laplacian_matvec_np(g, x), precond, b, **kw)
