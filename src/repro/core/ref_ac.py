"""Sequential randomized-Cholesky oracle (paper Algorithms 1 + 2).

Right-looking, eager, one vertex at a time in label order — the reference
against which the parallel wavefront engine must match *bit-exactly*
(same per-vertex uniforms ⇒ same factor; DESIGN.md §2).

Data layout mirrors the classic formulation: the current graph's edges are
bucketed by their *min-label* endpoint ("owner column").  Because an edge's
min endpoint is always eliminated first (an alive edge (j,k), j<k keeps
dep[k] > 0), the owner bucket of vertex k holds exactly L(:,k)'s
off-diagonal entries when k's turn arrives.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from .laplacian import Graph
from .column_math import eliminate_column, column_uniforms, INVALID_ID


class DeviceFactor(NamedTuple):
    """Device-resident view of an ``ACFactor`` — the handoff currency of
    the factor→solve pipeline.  The wavefront engine emits one directly
    (its compaction already runs on device); host-built factors upload
    lazily via ``ACFactor.to_device()``.  All consumers downstream of the
    factorization (schedule builder, preconditioner, PCG) read these
    arrays, so the hot path never round-trips through numpy."""

    col_ptr: jnp.ndarray  # int32[n+1]
    rows: jnp.ndarray     # int32[nnz]
    vals: jnp.ndarray     # f32[nnz]
    D: jnp.ndarray        # f32[n]

    @property
    def n(self) -> int:
        return int(self.D.shape[0])

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    def to_device(self) -> "DeviceFactor":
        """Already device-resident — lets a bare ``DeviceFactor`` stand
        in wherever an ``ACFactor``-style payload is expected (e.g. the
        ichol family's cache attach path)."""
        return self


@dataclasses.dataclass
class ACFactor:
    """L ≈ G D Gᵀ with G unit-lower-triangular in elimination order.

    CSC arrays over *relabeled* vertex positions (0..n-1 = elimination
    order).  ``perm`` maps original vertex -> position; ``iperm`` inverse.
    """

    n: int
    col_ptr: np.ndarray   # int64[n+1]
    rows: np.ndarray      # int32[nnz]  (strictly > column index)
    vals: np.ndarray      # f32[nnz]    (G off-diagonal values, typically < 0)
    D: np.ndarray         # f32[n]
    perm: Optional[np.ndarray] = None   # original id -> position
    stats: Optional[dict] = None
    device: Optional[DeviceFactor] = None  # device-resident view (cached)

    @property
    def nnz(self) -> int:
        return int(self.rows.shape[0])

    def to_device(self) -> DeviceFactor:
        """Device-resident view; cached so repeated schedule builds and
        preconditioner constructions share one upload (or none at all
        when the factor came off the wavefront engine)."""
        if self.device is None:
            # stay eager even under an outer jit trace: the cached view
            # must hold real device buffers, never tracers
            with jax.ensure_compile_time_eval():
                self.device = DeviceFactor(
                    col_ptr=jnp.asarray(self.col_ptr, jnp.int32),
                    rows=jnp.asarray(self.rows, jnp.int32),
                    vals=jnp.asarray(self.vals),
                    D=jnp.asarray(self.D))
        return self.device

    def fill_ratio(self, g: Graph) -> float:
        """Paper Fig. 4 metric: 2·nnz(G) / nnz(L)."""
        nnz_L = 2 * g.m + g.n
        nnz_G = 2 * (self.nnz + self.n) - self.n
        return nnz_G / nnz_L

    def dense_G(self) -> np.ndarray:
        G = np.eye(self.n, dtype=np.float64)
        for c in range(self.n):
            lo, hi = self.col_ptr[c], self.col_ptr[c + 1]
            G[self.rows[lo:hi], c] = self.vals[lo:hi]
        return G

    def dense_M(self) -> np.ndarray:
        """Dense preconditioner matrix G D Gᵀ (tests only)."""
        G = self.dense_G()
        return (G * self.D[None, :].astype(np.float64)) @ G.T


def _next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


@partial(jax.jit, static_argnums=(4,))
def _elim_padded(ids, ws, valid, u, width):
    return eliminate_column(ids, ws, valid, u)


@partial(jax.jit, static_argnums=(2,))
def _uniforms(key, vertex, width):
    return column_uniforms(key, vertex, width)


def factorize_sequential(g: Graph, key: jax.Array,
                         dtype=np.float32) -> ACFactor:
    """Run AC sequentially in label order (labels = elimination order)."""
    n = g.n
    cols: List[List] = [[] for _ in range(n)]
    for s, d, w in zip(g.src, g.dst, g.w.astype(dtype)):
        cols[int(s)].append((int(d), dtype(w)))

    col_rows, col_vals = [], []
    D = np.zeros(n, dtype=dtype)
    for k in range(n):
        entries = cols[k]
        cols[k] = None  # free
        d = len(entries)
        if d == 0:
            col_rows.append(np.zeros(0, np.int32))
            col_vals.append(np.zeros(0, dtype))
            continue
        width = _next_pow2(d)
        ids = np.full(width, INVALID_ID, np.int32)
        ws = np.zeros(width, dtype)
        ids[:d] = [e[0] for e in entries]
        ws[:d] = [e[1] for e in entries]
        valid = np.zeros(width, bool)
        valid[:d] = True
        u = _uniforms(key, jnp.int32(k), width)
        res = _elim_padded(jnp.asarray(ids), jnp.asarray(ws),
                           jnp.asarray(valid), u, width)
        m = int(res.m)
        D[k] = np.asarray(res.ell_kk)
        col_rows.append(np.asarray(res.g_rows[:m]))
        col_vals.append(np.asarray(res.g_vals[:m]))
        ev = np.asarray(res.e_valid)
        e_lo = np.asarray(res.e_lo)[ev]
        e_hi = np.asarray(res.e_hi)[ev]
        e_w = np.asarray(res.e_w)[ev]
        for lo, hi, w in zip(e_lo, e_hi, e_w):
            cols[int(lo)].append((int(hi), dtype(w)))

    lens = np.array([r.shape[0] for r in col_rows], dtype=np.int64)
    col_ptr = np.zeros(n + 1, np.int64)
    np.cumsum(lens, out=col_ptr[1:])
    rows = (np.concatenate(col_rows) if col_ptr[-1] else np.zeros(0, np.int32))
    vals = (np.concatenate(col_vals) if col_ptr[-1] else np.zeros(0, dtype))
    return ACFactor(n=n, col_ptr=col_ptr, rows=rows.astype(np.int32),
                    vals=vals.astype(dtype), D=D)
