"""``FactorCache`` / ``Solver`` — the device-resident factor→solve
pipeline as a multi-tenant API.

The paper's production shape is *factor once, serve many solves*: the
randomized construction is cheap (little pre-processing, §4) and the
short-critical-path factor (§6.2) then amortizes over every rhs that
arrives.  A service amortizes further by keeping **many** live factors:

    cache = FactorCache(memory_budget_bytes=1 << 28)
    gid = cache.factor(graph, jax.random.key(0)).graph_id
    res = cache.solve(gid, b)        # route by graph id
    res = cache.solve(gid, B)        # (nrhs, n) block → batched PCG

``factor`` runs the wavefront engine, compacts the factor on device and
derives both triangular level schedules on device; the resulting
:class:`FactorHandle` caches the jitted preconditioner and one jitted
PCG per rhs-batch shape (bounded LRU), so repeated solves against the
same factor pay zero rebuild cost.  The cache itself is an LRU keyed by
a content fingerprint of ``(graph, key)`` and evicts whole handles when
the device-memory budget is exceeded.  ``factor_batched`` admits a fleet
in one vmapped XLA program (``parac.factorize_batched``).

``Solver`` keeps the original single-tenant surface (``factor`` then
``solve(B)`` against the most recent handle) as a thin subclass.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .laplacian import Graph, laplacian_matvec
from .ref_ac import ACFactor
from .parac import factorize_wavefront, factorize_batched
from .trisolve import (DeviceSchedule, build_schedules_device,
                       make_preconditioner_from_schedules)
from .pcg import PCGResult, pcg_jax, pcg_jax_batched


def graph_fingerprint(g: Graph, key: Optional[jax.Array] = None) -> str:
    """Content hash of a graph (and optionally the factorization key) —
    the cache identity of a factor.  Two structurally identical systems
    share a fingerprint, so resubmitting a known graph is a cache hit."""
    h = hashlib.blake2b(digest_size=12)
    h.update(np.int64(g.n).tobytes())
    h.update(np.ascontiguousarray(g.src).tobytes())
    h.update(np.ascontiguousarray(g.dst).tobytes())
    h.update(np.ascontiguousarray(g.w).tobytes())
    if key is not None:
        h.update(np.ascontiguousarray(jax.random.key_data(key)).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class FactorHandle:
    """A factored graph ready to serve solves.  Everything needed on the
    hot path (schedules, D⁻¹, edge arrays) is device-resident; jitted
    solve closures are cached per rhs-batch shape in a bounded LRU."""

    graph: Graph
    factor: ACFactor
    fwd: DeviceSchedule
    bwd: DeviceSchedule
    precondition: callable            # r (n,) or (n, nrhs) -> M⁺ r
    _src: jnp.ndarray
    _dst: jnp.ndarray
    _w: jnp.ndarray
    graph_id: str = ""
    max_cached_solves: int = 16
    _cache: "OrderedDict[Tuple, callable]" = dataclasses.field(
        default_factory=OrderedDict)

    @property
    def n(self) -> int:
        return self.graph.n

    @property
    def device_bytes(self) -> int:
        """Device-memory footprint of the handle's resident arrays
        (factor CSC + both ELL schedules + operator edge lists) — what
        the :class:`FactorCache` budget accounts."""
        dev = self.factor.to_device()
        arrays = [dev.col_ptr, dev.rows, dev.vals, dev.D,
                  self._src, self._dst, self._w]
        for sched in (self.fwd, self.bwd):
            arrays += [sched.row_ids, sched.cols, sched.vals, sched.level_of]
        return int(sum(a.nbytes for a in arrays))

    def matvec(self, x: jnp.ndarray) -> jnp.ndarray:
        return laplacian_matvec(self._src, self._dst, self._w, self.n, x)

    def solve(self, B, *, tol: float = 1e-6, maxiter: int = 1000,
              project: bool = True) -> PCGResult:
        """PCG-solve ``L x = b``.  ``B``: ``(n,)`` for one rhs or
        ``(nrhs, n)`` for a batch (all columns share this factor)."""
        B = jnp.asarray(B)
        if B.ndim not in (1, 2) or B.shape[-1] != self.n:
            raise ValueError(
                f"rhs must be (n,) or (nrhs, n) with n={self.n}, "
                f"got {B.shape}")
        key = (B.shape, str(B.dtype), float(tol), int(maxiter), project)
        fn = self._cache.get(key)
        if fn is None:
            fn = jax.jit(self._build_solve(B.ndim, tol, maxiter, project))
            self._cache[key] = fn
            while len(self._cache) > self.max_cached_solves:
                self._cache.popitem(last=False)
        else:
            self._cache.move_to_end(key)
        return fn(B)

    def _build_solve(self, ndim: int, tol: float, maxiter: int,
                     project: bool):
        mv = self.matvec
        pc = self.precondition
        if ndim == 1:
            return lambda b: pcg_jax(mv, pc, b, tol=tol, maxiter=maxiter,
                                     project=project)
        # batched: matvec vmaps over the rhs axis; the preconditioner
        # consumes the whole (n, nrhs) block in one fused trisolve.
        bmv = jax.vmap(mv)

        def bpc(R):
            return pc(R.T).T

        return lambda B: pcg_jax_batched(bmv, bpc, B, tol=tol,
                                         maxiter=maxiter, project=project)


class FactorCache:
    """Multi-tenant factor-once / solve-many frontend.

    Construction options are fixed per cache.  ``factor`` (or
    ``factor_batched`` / ``attach``) admits handles keyed by graph
    fingerprint; ``solve(graph_id, B)`` routes a rhs to its factor.
    Admission evicts least-recently-used handles while the summed
    ``device_bytes`` exceeds ``memory_budget_bytes`` (or the handle
    count exceeds ``max_handles``) — the newest handle is never evicted.
    """

    def __init__(self, *, chunk: int = 64, fill_slack: int = 32,
                 strict: bool = True, max_retries: int = 3,
                 dtype=np.float32,
                 memory_budget_bytes: Optional[int] = None,
                 max_handles: Optional[int] = None,
                 max_cached_solves: int = 16):
        self.chunk = chunk
        self.fill_slack = fill_slack
        self.strict = strict
        self.max_retries = max_retries
        self.dtype = dtype
        self.memory_budget_bytes = memory_budget_bytes
        self.max_handles = max_handles
        self.max_cached_solves = max_cached_solves
        self._handles: "OrderedDict[str, FactorHandle]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- admission ----------------------------------------------------------
    def factor(self, g: Graph, key: jax.Array, *,
               graph_id: Optional[str] = None) -> FactorHandle:
        """Factor ``g`` (cache hit if an identical ``(graph, key)`` is
        already live) and admit the handle."""
        gid = graph_id if graph_id is not None else graph_fingerprint(g, key)
        got = self._handles.get(gid)
        if got is not None:
            self.hits += 1
            self._handles.move_to_end(gid)
            return got
        self.misses += 1
        f = factorize_wavefront(
            g, key, chunk=self.chunk, fill_slack=self.fill_slack,
            strict=self.strict, max_retries=self.max_retries,
            dtype=self.dtype)
        return self.attach(g, f, graph_id=gid)

    def factor_batched(self, gs: Sequence[Graph], keys, *,
                       graph_ids: Optional[Sequence[str]] = None
                       ) -> List[FactorHandle]:
        """Admit a fleet: graphs not already cached factor together in
        one vmapped XLA program (``parac.factorize_batched``)."""
        gs = list(gs)
        if not isinstance(keys, jax.Array):
            keys = jnp.stack(list(keys))
        gids = list(graph_ids) if graph_ids is not None else [
            graph_fingerprint(g, keys[i]) for i, g in enumerate(gs)]
        todo = [i for i, gid in enumerate(gids) if gid not in self._handles]
        self.hits += len(gs) - len(todo)
        self.misses += len(todo)
        # strong refs for the whole call: a tight budget may LRU-evict a
        # sibling of this very fleet mid-admission — the caller still gets
        # every handle back (evicted ones simply aren't cached any more).
        fleet = {gid: self._handles[gid] for gid in gids
                 if gid in self._handles}
        if todo:
            fs = factorize_batched(
                [gs[i] for i in todo], jnp.stack([keys[i] for i in todo]),
                chunk=self.chunk, fill_slack=self.fill_slack,
                strict=self.strict, max_retries=self.max_retries,
                dtype=self.dtype)
            for i, f in zip(todo, fs):
                fleet[gids[i]] = self.attach(gs[i], f, graph_id=gids[i])
        for gid in gids:
            if gid in self._handles:
                self._handles.move_to_end(gid)
        return [fleet[gid] for gid in gids]

    def attach(self, g: Graph, f: ACFactor, *,
               graph_id: Optional[str] = None) -> FactorHandle:
        """Wrap an existing factor (e.g. from the sequential oracle) in a
        solve handle — same lifecycle, no re-factorization."""
        gid = graph_id if graph_id is not None else graph_fingerprint(g)
        fwd, bwd = build_schedules_device(f)
        handle = FactorHandle(
            graph=g, factor=f, fwd=fwd, bwd=bwd,
            precondition=make_preconditioner_from_schedules(
                fwd, bwd, f.to_device().D),
            _src=jnp.asarray(g.src), _dst=jnp.asarray(g.dst),
            _w=jnp.asarray(g.w, dtype=jnp.asarray(f.vals).dtype),
            graph_id=gid, max_cached_solves=self.max_cached_solves)
        self._handles[gid] = handle
        self._handles.move_to_end(gid)
        self._shrink()
        return handle

    def _shrink(self):
        """Evict LRU handles until budget/count bounds hold (the newest
        handle always survives)."""
        while len(self._handles) > 1 and (
                (self.max_handles is not None
                 and len(self._handles) > self.max_handles)
                or (self.memory_budget_bytes is not None
                    and self.device_bytes > self.memory_budget_bytes)):
            self._handles.popitem(last=False)
            self.evictions += 1

    # -- lookup / routing ---------------------------------------------------
    def peek(self, graph_id: str) -> Optional[FactorHandle]:
        """Non-faulting lookup that does not touch LRU order (lets a
        serving engine check whether its pinned handle is still the
        cached one)."""
        return self._handles.get(graph_id)

    def get(self, graph_id: str) -> FactorHandle:
        handle = self._handles.get(graph_id)
        if handle is None:
            raise KeyError(f"no live factor for graph_id={graph_id!r} "
                           f"({len(self._handles)} cached)")
        self._handles.move_to_end(graph_id)
        return handle

    def __contains__(self, graph_id: str) -> bool:
        return graph_id in self._handles

    def __len__(self) -> int:
        return len(self._handles)

    @property
    def graph_ids(self) -> List[str]:
        return list(self._handles)

    @property
    def device_bytes(self) -> int:
        return sum(h.device_bytes for h in self._handles.values())

    def evict(self, graph_id: str) -> None:
        if self._handles.pop(graph_id, None) is not None:
            self.evictions += 1

    def clear(self) -> None:
        self._handles.clear()

    def stats(self) -> Dict[str, int]:
        return dict(handles=len(self._handles), hits=self.hits,
                    misses=self.misses, evictions=self.evictions,
                    device_bytes=self.device_bytes)

    def solve(self, graph_id: str, B, **kw) -> PCGResult:
        return self.get(graph_id).solve(B, **kw)


class Solver(FactorCache):
    """Single-tenant compatibility surface over :class:`FactorCache`:
    ``factor``/``attach`` remember the most recent handle and ``solve``
    takes just the rhs.  Defaults to ``max_handles=1`` so factoring a
    sweep of graphs through one ``Solver`` keeps O(1) device memory,
    exactly like the pre-cache ``Solver`` did."""

    def __init__(self, **kw):
        kw.setdefault("max_handles", 1)
        super().__init__(**kw)
        self.handle: Optional[FactorHandle] = None

    def factor(self, g: Graph, key: jax.Array, **kw) -> FactorHandle:
        self.handle = super().factor(g, key, **kw)
        return self.handle

    def attach(self, g: Graph, f: ACFactor, **kw) -> FactorHandle:
        self.handle = super().attach(g, f, **kw)
        return self.handle

    def solve(self, B, **kw) -> PCGResult:
        if self.handle is None:
            raise RuntimeError("Solver.solve before Solver.factor")
        return self.handle.solve(B, **kw)
