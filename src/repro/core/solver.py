"""``FactorCache`` / ``Solver`` — the device-resident factor→solve
pipeline as a multi-tenant API.

The paper's production shape is *factor once, serve many solves*: the
randomized construction is cheap (little pre-processing, §4) and the
short-critical-path factor (§6.2) then amortizes over every rhs that
arrives.  A service amortizes further by keeping **many** live factors:

    cache = FactorCache(memory_budget_bytes=1 << 28)
    gid = cache.factor(graph, jax.random.key(0)).graph_id
    res = cache.solve(gid, b)        # route by graph id
    res = cache.solve(gid, B)        # (nrhs, n) block → batched PCG

``factor`` runs the wavefront engine, compacts the factor on device,
derives both triangular level schedules on device, and **admits the
factor to its shape-bucket fleet**: a :class:`FactorFleet` keyed by
``n_pad = pow2(n)`` that stacks every member's padded Laplacian edges,
row-indexed trisolve panels and D⁻¹ into one ``pcg.FleetArrays`` block.
Solves — direct ``FactorHandle.solve`` and the continuous-batching
``serve.SolveEngine`` alike — pass those arrays as **traced arguments**
to shared fleet PCG programs, so every factor in a bucket shares one
compiled step program and the two paths take bit-identical per-lane
iterates.  ``factor_batched`` admits a whole fleet in two batched XLA
programs (vmapped wavefront + vmapped schedule construction).

The cache itself is an LRU keyed by a content fingerprint of
``(graph, key)``; it evicts whole handles when the device-memory budget
is exceeded and supports per-handle staleness (``ttl_s`` wall-clock /
``max_age_ticks`` service ticks, clock injectable for tests) so a
resubmitted *modified* graph ages its ancestor fingerprint out instead
of accumulating near-duplicates under the budget.

``Solver`` keeps the original single-tenant surface (``factor`` then
``solve(B)`` against the most recent handle) as a thin subclass.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import time
import weakref
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.runtime import pad_k
from repro.obs.flight import NULL_FLIGHT
from .laplacian import Graph
from .ref_ac import ACFactor, DeviceFactor
from .parac import factorize_wavefront, factorize_batched, _next_pow2
from .trisolve import PackedSchedule, build_schedules_batched, _pad_dev
from .ichol import ichol_device_factor
from .amg import amg_ell_precond
from .spai import EllPrecond, spai_ell_precond
from .pcg import (PCGResult, FleetArrays, fleet_matvec,
                  fleet_precondition, pcg_fleet_solve, pcg_fleet_result)


_UNSET = object()


def graph_fingerprint(g: Graph, key: Optional[jax.Array] = None, *,
                      family: str = "ac",
                      params: Optional[Dict] = None) -> str:
    """Content hash of a graph (and optionally the factorization key,
    preconditioner family and construction params) — the cache identity
    of a preconditioner.  Two structurally identical systems built the
    same way share a fingerprint, so resubmitting a known graph is a
    cache hit; the same graph under two families (or two droptols) gets
    two distinct fingerprints and two cache rows.

    Args:
        g: the graph.
        key: factorization PRNG key (randomized families only).
        family: preconditioner family name (``"ac"`` leaves the hash
            identical to the historical graph-only fingerprint).
        params: family construction parameters (hashed by sorted repr).

    Returns:
        Hex digest string.
    """
    h = hashlib.blake2b(digest_size=12)
    h.update(np.int64(g.n).tobytes())
    h.update(np.ascontiguousarray(g.src).tobytes())
    h.update(np.ascontiguousarray(g.dst).tobytes())
    h.update(np.ascontiguousarray(g.w).tobytes())
    if key is not None:
        h.update(np.ascontiguousarray(jax.random.key_data(key)).tobytes())
    if family != "ac" or params:
        h.update(family.encode())
        h.update(repr(sorted((params or {}).items())).encode())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Preconditioner family registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PrecondFamily:
    """One registered preconditioner family.

    ``kind`` selects the fleet's **static** apply program (see
    ``pcg.fleet_precondition``): ``"factor"`` families ship a
    ``(G, D)`` triangular factor and apply via two masked fleet
    trisolves; ``"spmv"`` families ship a materialized approximate
    inverse in ELL rows and apply via one lane-batched SpMV.  ``build``
    constructs the host/device payload: ``build(g, key, dtype=...,
    **params)`` returning either an ``ACFactor``/``DeviceFactor``
    (factor kind) or an :class:`~repro.core.spai.EllPrecond` (spmv
    kind)."""

    name: str
    kind: str
    build: Callable


PRECOND_FAMILIES: Dict[str, PrecondFamily] = {}


def register_family(name: str, kind: str, build: Callable) -> PrecondFamily:
    """Register (or replace) a preconditioner family.

    Args:
        name: family name (``FactorCache.factor(..., family=name)``).
        kind: ``"factor"`` or ``"spmv"``.
        build: constructor ``(g, key, *, dtype, **params) -> payload``.

    Returns:
        The registered :class:`PrecondFamily`.

    Raises:
        ValueError: unknown ``kind``.
    """
    if kind not in ("factor", "spmv"):
        raise ValueError(f"unknown apply kind {kind!r}")
    fam = PrecondFamily(name=name, kind=kind, build=build)
    PRECOND_FAMILIES[name] = fam
    return fam


def get_family(name: str) -> PrecondFamily:
    """Look up a registered family.

    Raises:
        KeyError: no family registered under ``name``.
    """
    fam = PRECOND_FAMILIES.get(name)
    if fam is None:
        raise KeyError(f"unknown preconditioner family {name!r} "
                       f"(registered: {sorted(PRECOND_FAMILIES)})")
    return fam


register_family(
    "ac", "factor",
    # the randomized AC construction is special-cased in
    # ``FactorCache.factor`` (it alone batches through
    # ``factorize_batched``); this builder is the single-graph path
    lambda g, key, *, dtype=np.float32, chunk=64, fill_slack=32,
    strict=True, max_retries=3: factorize_wavefront(
        g, key, chunk=chunk, fill_slack=fill_slack, strict=strict,
        max_retries=max_retries, dtype=dtype))
register_family(
    "ichol", "factor",
    lambda g, key, *, dtype=np.float32, droptol=0.0, max_shift_tries=8:
    ichol_device_factor(g, droptol=droptol,
                        max_shift_tries=max_shift_tries, dtype=dtype))
register_family(
    "amg", "spmv",
    lambda g, key, *, dtype=np.float32, droptol=1e-3:
    amg_ell_precond(g, droptol=droptol, dtype=dtype))
register_family(
    "spai", "spmv",
    lambda g, key, *, dtype=np.float32, droptol=0.0:
    spai_ell_precond(g, droptol=droptol, dtype=dtype))


def _pad1(x: jnp.ndarray, size: int) -> jnp.ndarray:
    """Zero-pad a 1-D device array to ``size`` (shared fill-pad helper
    lives in ``trisolve._pad_dev``)."""
    return _pad_dev(x, size, 0)


def _grow(x: jnp.ndarray, shape: Tuple[int, ...]) -> jnp.ndarray:
    """Zero-pad ``x`` up to ``shape`` (every axis grows or stays)."""
    if tuple(x.shape) == tuple(shape):
        return x
    return jnp.pad(x, [(0, t - s) for s, t in zip(x.shape, shape)])


class _PaddedFactor:
    """One preconditioner's bucket-padded device arrays, ready for fleet
    admission: padded Laplacian edge lists, forward/backward
    :class:`PackedSchedule` panels and the padded inverse diagonal.

    ``"spmv"``-kind members reuse the same container: the approximate
    inverse's ELL rows ride in the *forward* panel slots (level 0
    everywhere — the SpMV apply never runs the level loop), the
    backward panels are inert 1-wide zeros and ``dinv`` is zero."""

    __slots__ = ("n", "n_pad", "src", "dst", "w", "fwd", "bwd", "dinv")

    def __init__(self, g: Graph, dev: DeviceFactor, fwd: PackedSchedule,
                 bwd: PackedSchedule):
        self.n = g.n
        self.n_pad = fwd.n_pad
        m_pad = max(_next_pow2(g.m), 1)
        with jax.ensure_compile_time_eval():
            self.src = _pad1(jnp.asarray(g.src, jnp.int32), m_pad)
            self.dst = _pad1(jnp.asarray(g.dst, jnp.int32), m_pad)
            self.w = _pad1(jnp.asarray(g.w, dev.vals.dtype), m_pad)
            D = dev.D
            dinv = jnp.where(D > 0, 1.0 / jnp.where(D > 0, D, 1.0), 0.0)
            self.dinv = _pad1(dinv, self.n_pad)
        self.fwd = fwd
        self.bwd = bwd

    @classmethod
    def from_ell(cls, g: Graph, op: EllPrecond) -> "_PaddedFactor":
        """Build the fleet-admissible view of a materialized approximate
        inverse: the ELL rows become a 1-level forward panel (padding
        rows/slots carry zero values, so they contribute exactly zero to
        the lane-batched SpMV)."""
        n_pad = max(_next_pow2(g.n), 1)
        with jax.ensure_compile_time_eval():
            cols = _grow(jnp.asarray(op.cols, jnp.int32), (n_pad, op.K))
            vals = _grow(jnp.asarray(op.vals), (n_pad, op.K))
            zeros_n = jnp.zeros((n_pad,), jnp.int32)
            fwd = PackedSchedule(n=g.n, n_pad=n_pad, n_levels=1, K=op.K,
                                 cols=cols, vals=vals, level_of=zeros_n)
            bwd = PackedSchedule(
                n=g.n, n_pad=n_pad, n_levels=1, K=1,
                cols=jnp.zeros((n_pad, 1), jnp.int32),
                vals=jnp.zeros((n_pad, 1), vals.dtype),
                level_of=zeros_n)
            dev = DeviceFactor(col_ptr=jnp.zeros((g.n + 1,), jnp.int32),
                               rows=jnp.zeros((0,), jnp.int32),
                               vals=jnp.zeros((0,), vals.dtype),
                               D=jnp.zeros((g.n,), vals.dtype))
        return cls(g, dev, fwd, bwd)


class FactorFleet:
    """Stacked, bucket-padded device preconditioners for one
    ``(family, shape-bucket, K-tier)`` (``n_pad = pow2(n)``; ``k_tier``
    the padded panel-width tier — see :meth:`FactorCache` K-tiering),
    plus the row bookkeeping that lets handles come and go.  ``kind`` is
    the fleet's static apply program (``"factor"`` trisolves /
    ``"spmv"``); a fleet never mixes kinds, so every member shares one
    compiled step program.  Sub-bucketing by K-tier keeps one hub-heavy
    factor (huge in-degree ⇒ wide trisolve panels) from inflating every
    bucket-mate's ``(n_pad, K)`` sweep to its width.

    ``arrays`` is the live :class:`pcg.FleetArrays` stack — the traced
    factor argument of every fleet PCG program.  Rows are claimed by
    weak reference: a row frees itself when its owning handle dies (an
    engine pinning an evicted handle keeps the row alive through the
    same reference) — the weakref callback pushes the row onto an O(1)
    free-heap — and admission reuses dead rows before growing the
    stack, so fleet memory is bounded by the peak number of *live*
    handles in the bucket, not by churn.  Growth along any axis
    (capacity, ``m_pad``, panel width ``K``) zero-pads — padding edges
    carry zero weight and padded panel slots zero values, so existing
    members' solves are unchanged.  :meth:`compact` is the inverse:
    it rebuilds the stack to the live rows so long-lived caches'
    ``fleet_device_bytes`` tracks live factors, not the high-water
    mark; every compaction bumps ``generation`` so engines holding
    device-resident lane state can re-sync their row indices.
    """

    def __init__(self, n_pad: int, family: str = "ac",
                 kind: str = "factor", k_tier: int = 0,
                 device: Optional[jax.Device] = None):
        self.n_pad = n_pad
        self.family = family
        self.kind = kind
        self.k_tier = k_tier       # padded panel-width tier (0 = untiered)
        # pinned accelerator for the stack (None = default device): every
        # stack rebuild commits `arrays` here, so the jitted fleet
        # programs that take them as traced args run on this device —
        # a cluster pins each replica's fleets to its own device
        self.device = device
        self.m_pad = 1
        self.Kf = 1
        self.Kb = 1
        self.f_levels = 1          # bucket-wide static level bounds
        self.b_levels = 1
        self.generation = 0        # bumped by compact(): row indices moved
        self.compactions = 0
        self.arrays: Optional[FleetArrays] = None
        self._rows: List[Optional[weakref.ref]] = []
        self._free: List[int] = []              # min-heap of dead rows
        self._ref2row: Dict[weakref.ref, int] = {}

    @property
    def capacity(self) -> int:
        return 0 if self.arrays is None else int(self.arrays.nvalid.shape[0])

    @property
    def live_rows(self) -> int:
        return sum(r is not None and r() is not None for r in self._rows)

    @property
    def free_rows(self) -> int:
        """Rows admittable without growing the stack: dead rows awaiting
        reuse (the free-heap) plus pow2 capacity slack past the current
        end."""
        return len(self._free) + max(self.capacity - len(self._rows), 0)

    @property
    def bytes_per_row(self) -> int:
        if self.arrays is None:
            return 0
        return sum(int(x.nbytes) // x.shape[0] for x in self.arrays)

    @property
    def device_bytes(self) -> int:
        """Total resident footprint of the stack — including dead rows
        awaiting reuse and pow2 capacity slack.  The stack is grow-only
        (rows recycle, axes never shrink: in-flight lanes hold row
        indices into it), so this can exceed the sum of live handles'
        per-row accounting; ``FactorCache.stats()`` surfaces it as
        ``fleet_device_bytes`` so budget users see the true number."""
        return 0 if self.arrays is None else \
            sum(int(x.nbytes) for x in self.arrays)

    @property
    def resident_device(self) -> Optional[str]:
        """Where the stack actually lives (read from the arrays, not the
        pin request) — ``None`` before the first admission.  The
        multi-device placement test asserts this matches the replica's
        assigned device."""
        if self.arrays is None:
            return None if self.device is None else str(self.device)
        return str(next(iter(self.arrays.src.devices())))

    def _row_died(self, ref: weakref.ref) -> None:
        """Weakref callback: the handle owning ``ref``'s row was
        collected — recycle the row onto the free-heap.  Refs retired by
        a :meth:`compact` are no longer in ``_ref2row`` and fall
        through harmlessly."""
        row = self._ref2row.pop(ref, None)
        if row is not None and row < len(self._rows) \
                and self._rows[row] is ref:
            self._rows[row] = None
            heapq.heappush(self._free, row)

    def _free_rows(self, k: int) -> List[int]:
        """Claim ``k`` distinct rows: recycled dead rows (ascending —
        heap pops) first, then fresh rows past the current end.  Every
        heap row precedes every fresh row, so the result is ascending by
        construction.  O(k log F) amortized — the old linear scan over
        the whole row list paid O(F) per admission once churn left dead
        rows scattered through a large stack."""
        rows: List[int] = []
        while len(rows) < k and self._free:
            rows.append(heapq.heappop(self._free))
        nxt = len(self._rows)
        while len(rows) < k:
            rows.append(nxt)
            nxt += 1
        return rows

    def admit(self, handle: "FactorHandle", pf: _PaddedFactor) -> int:
        """Claim a row for ``pf`` (reusing a dead row when possible) and
        scatter its arrays into the stack.  Returns the row index."""
        return self.admit_many([(handle, pf)])[0]

    def admit_many(self, pairs: Sequence[Tuple["FactorHandle",
                                               _PaddedFactor]]
                   ) -> List[int]:
        """Admit ``B`` factors in one stack update: the bucket grows
        **once** to the batch-wide ``(capacity, m_pad, K)`` envelope and
        every new row lands in a single scatter per field — O(B) device
        copies where per-factor ``admit`` paid O(B²) (each ``.at[].set``
        copies the whole stack).  Row claiming, growth envelopes and
        padded row contents are identical to ``B`` sequential admits
        (growth only ever zero-pads), so the resulting stack is
        bit-identical either way.  Returns the claimed row indices, in
        ``pairs`` order."""
        if not pairs:
            return []
        assert all(pf.n_pad == self.n_pad for _, pf in pairs)
        m_pad = max(self.m_pad, *(pf.src.shape[0] for _, pf in pairs))
        Kf = max(self.Kf, *(pf.fwd.K for _, pf in pairs))
        Kb = max(self.Kb, *(pf.bwd.K for _, pf in pairs))
        rows = self._free_rows(len(pairs))
        F = max(_next_pow2(max(rows) + 1), self.capacity)
        np_ = self.n_pad
        pf0 = pairs[0][1]
        with jax.ensure_compile_time_eval():
            a = self.arrays
            if a is None:
                a = FleetArrays(
                    src=jnp.zeros((F, m_pad), jnp.int32),
                    dst=jnp.zeros((F, m_pad), jnp.int32),
                    w=jnp.zeros((F, m_pad), pf0.w.dtype),
                    fcols=jnp.zeros((F, np_, Kf), jnp.int32),
                    fvals=jnp.zeros((F, np_, Kf), pf0.fwd.vals.dtype),
                    flevel=jnp.zeros((F, np_), jnp.int32),
                    bcols=jnp.zeros((F, np_, Kb), jnp.int32),
                    bvals=jnp.zeros((F, np_, Kb), pf0.bwd.vals.dtype),
                    blevel=jnp.zeros((F, np_), jnp.int32),
                    dinv=jnp.zeros((F, np_), pf0.dinv.dtype),
                    nvalid=jnp.zeros((F,), jnp.int32),
                    fnlv=jnp.ones((F,), jnp.int32),
                    bnlv=jnp.ones((F,), jnp.int32))
            else:
                a = FleetArrays(
                    src=_grow(a.src, (F, m_pad)),
                    dst=_grow(a.dst, (F, m_pad)),
                    w=_grow(a.w, (F, m_pad)),
                    fcols=_grow(a.fcols, (F, np_, Kf)),
                    fvals=_grow(a.fvals, (F, np_, Kf)),
                    flevel=_grow(a.flevel, (F, np_)),
                    bcols=_grow(a.bcols, (F, np_, Kb)),
                    bvals=_grow(a.bvals, (F, np_, Kb)),
                    blevel=_grow(a.blevel, (F, np_)),
                    dinv=_grow(a.dinv, (F, np_)),
                    nvalid=_grow(a.nvalid, (F,)),
                    fnlv=jnp.maximum(_grow(a.fnlv, (F,)), 1),
                    bnlv=jnp.maximum(_grow(a.bnlv, (F,)), 1))
            ix = jnp.asarray(np.asarray(rows, np.int32))
            self.arrays = FleetArrays(
                src=a.src.at[ix].set(jnp.stack(
                    [_pad1(pf.src, m_pad) for _, pf in pairs])),
                dst=a.dst.at[ix].set(jnp.stack(
                    [_pad1(pf.dst, m_pad) for _, pf in pairs])),
                w=a.w.at[ix].set(jnp.stack(
                    [_pad1(pf.w, m_pad) for _, pf in pairs])),
                fcols=a.fcols.at[ix].set(jnp.stack(
                    [_grow(pf.fwd.cols, (np_, Kf)) for _, pf in pairs])),
                fvals=a.fvals.at[ix].set(jnp.stack(
                    [_grow(pf.fwd.vals, (np_, Kf)) for _, pf in pairs])),
                flevel=a.flevel.at[ix].set(jnp.stack(
                    [pf.fwd.level_of for _, pf in pairs])),
                bcols=a.bcols.at[ix].set(jnp.stack(
                    [_grow(pf.bwd.cols, (np_, Kb)) for _, pf in pairs])),
                bvals=a.bvals.at[ix].set(jnp.stack(
                    [_grow(pf.bwd.vals, (np_, Kb)) for _, pf in pairs])),
                blevel=a.blevel.at[ix].set(jnp.stack(
                    [pf.bwd.level_of for _, pf in pairs])),
                dinv=a.dinv.at[ix].set(jnp.stack(
                    [pf.dinv for _, pf in pairs])),
                nvalid=a.nvalid.at[ix].set(jnp.asarray(
                    [pf.n for _, pf in pairs], jnp.int32)),
                fnlv=a.fnlv.at[ix].set(jnp.asarray(
                    [pf.fwd.n_levels for _, pf in pairs], jnp.int32)),
                bnlv=a.bnlv.at[ix].set(jnp.asarray(
                    [pf.bwd.n_levels for _, pf in pairs], jnp.int32)))
        if self.device is not None:
            # commit the rebuilt stack to the pinned device (no-op copy
            # once resident: growth/scatter of committed arrays already
            # ran there; only brand-new capacity pays a real transfer).
            # Committed arrays also pin every downstream jitted solve —
            # an adopted factor built on another device lands here.
            self.arrays = jax.device_put(self.arrays, self.device)
        self.m_pad, self.Kf, self.Kb = m_pad, Kf, Kb
        self.f_levels = max(self.f_levels,
                            *(pf.fwd.n_levels for _, pf in pairs))
        self.b_levels = max(self.b_levels,
                            *(pf.bwd.n_levels for _, pf in pairs))
        for (handle, _), row in zip(pairs, rows):
            ref = weakref.ref(handle, self._row_died)
            self._ref2row[ref] = row
            if row == len(self._rows):     # rows ascending: appends in order
                self._rows.append(ref)
            else:
                self._rows[row] = ref
        return rows

    def compact(self) -> int:
        """Rebuild the stack to its live rows: one gather per fleet
        array down to the live set, capacity re-padded to
        ``pow2(live)``.  Live handles' ``fleet_row`` indices are
        rewritten in place (their strong refs are held for the duration,
        so no row dies mid-rebuild) and ``generation`` is bumped so an
        engine holding device-resident lane state keyed by old row
        indices re-scatters its ``fidx`` before the next step.  Row
        *contents* are copied verbatim, so every live handle's solve is
        bit-identical before and after.  Returns the number of freed
        stack rows (0 when the stack is already at its pow2 floor)."""
        if self.arrays is None:
            return 0
        live: List[Tuple[int, "PreconditionerHandle"]] = []
        for i, r in enumerate(self._rows):
            h = r() if r is not None else None
            if h is not None:
                live.append((i, h))
        old_cap = self.capacity
        new_cap = max(_next_pow2(len(live)), 1)
        if new_cap >= old_cap:
            return 0
        old_idx = np.fromiter((i for i, _ in live), np.int32,
                              count=len(live))
        with jax.ensure_compile_time_eval():
            ix = jnp.asarray(old_idx)
            self.arrays = FleetArrays(*(
                _grow(x[ix], (new_cap,) + tuple(x.shape[1:]))
                for x in self.arrays))
        if self.device is not None:
            self.arrays = jax.device_put(self.arrays, self.device)
        freed = old_cap - new_cap
        self._ref2row.clear()               # retire old refs (callbacks
        self._free = []                     # on them become no-ops)
        self._rows = []
        for new_row, (_, h) in enumerate(live):
            h.fleet_row = new_row
            ref = weakref.ref(h, self._row_died)
            self._ref2row[ref] = new_row
            self._rows.append(ref)
        self.generation += 1
        self.compactions += 1
        return freed


@dataclasses.dataclass(eq=False)
class PreconditionerHandle:
    """A constructed preconditioner ready to serve solves — the one
    interface every family (randomized AC, ichol, AMG, SPAI) presents
    to the cache, the engine and direct callers: construct (via
    ``FactorCache.factor``) → apply (``precondition``/``solve``) →
    ``device_bytes`` → staleness (``ttl_s``/``max_age_ticks``).

    The hot-path data lives in the handle's ``(family, shape-bucket)``
    :class:`FactorFleet` (``fleet`` + ``fleet_row``) as stacked,
    bucket-padded device arrays; solves pass them as traced arguments to
    the shared fleet PCG programs (with the fleet's static apply
    ``kind``), so two handles in one fleet share compiled code.  Jitted
    solve closures are cached per rhs-batch shape in a bounded LRU."""

    graph: Graph
    factor: object          # family payload: ACFactor | DeviceFactor
    fleet: FactorFleet      # | EllPrecond
    fleet_row: int
    n_levels_fwd: int
    n_levels_bwd: int
    graph_id: str = ""
    family: str = "ac"
    construct_s: float = 0.0   # wall-clock construction cost (seconds)
    max_cached_solves: int = 16
    born_s: float = 0.0
    born_tick: int = 0
    ttl_s: Optional[float] = None
    max_age_ticks: Optional[int] = None
    _cache: "OrderedDict[Tuple, Callable]" = dataclasses.field(
        default_factory=OrderedDict)

    @property
    def n(self) -> int:
        return self.graph.n

    @property
    def n_pad(self) -> int:
        return self.fleet.n_pad

    @property
    def kind(self) -> str:
        """The fleet's static apply kind (``"factor"`` | ``"spmv"``)."""
        return self.fleet.kind

    @property
    def n_levels(self) -> int:
        """Forward critical-path length (levels) — the §6.2 figure of
        merit surfaced by benchmarks (1 for ``"spmv"`` families: their
        apply is level-free)."""
        return self.n_levels_fwd

    @property
    def device_bytes(self) -> int:
        """Device-memory footprint the :class:`FactorCache` budget
        accounts: the handle's row of the fleet stack (padded edges,
        both panel sets, D⁻¹) plus the family payload's own device
        residency (the compact device factor for factor kinds; spmv
        payloads are host-side, their device copy *is* the fleet
        row)."""
        f = self.factor
        if isinstance(f, (ACFactor, DeviceFactor)):
            dev = f.to_device()
            own = sum(int(a.nbytes)
                      for a in (dev.col_ptr, dev.rows, dev.vals, dev.D))
        else:
            own = 0
        return own + self.fleet.bytes_per_row

    def matvec(self, x: jnp.ndarray) -> jnp.ndarray:
        """``L x`` through the handle's fleet row (the padded edge lists
        already resident in the bucket stack — no per-handle copies)."""
        fa = self.fleet.arrays
        Y = jnp.zeros((1, self.n_pad), x.dtype).at[0, :self.n].set(x)
        return fleet_matvec(fa, self._fidx(1), Y)[0, :self.n]

    def _fidx(self, L: int) -> jnp.ndarray:
        return jnp.full((L,), self.fleet_row, jnp.int32)

    def precondition(self, r: jnp.ndarray) -> jnp.ndarray:
        """Apply this preconditioner: ``r -> (G D Gᵀ)⁺ r`` for factor
        kinds, ``r -> M r`` for spmv kinds, for ``r`` of shape ``(n,)``
        or ``(n, nrhs)`` — the fleet apply routed through this handle's
        fleet row (columns become lanes)."""
        fa = self.fleet.arrays
        fl, bl = self.fleet.f_levels, self.fleet.b_levels
        kind = self.fleet.kind
        n, n_pad = self.n, self.n_pad
        if r.ndim == 1:
            R = jnp.zeros((1, n_pad), r.dtype).at[0, :n].set(r)
            out = fleet_precondition(fa, self._fidx(1), R,
                                     f_levels=fl, b_levels=bl, kind=kind)
            return out[0, :n]
        R = jnp.zeros((r.shape[1], n_pad), r.dtype).at[:, :n].set(r.T)
        out = fleet_precondition(fa, self._fidx(r.shape[1]), R,
                                 f_levels=fl, b_levels=bl, kind=kind)
        return out[:, :n].T

    def solve(self, B, *, tol: float = 1e-6, maxiter: int = 1000,
              project: bool = True) -> PCGResult:
        """PCG-solve ``L x = b``.  ``B``: ``(n,)`` for one rhs or
        ``(nrhs, n)`` for a batch (all columns share this factor).
        Runs the fleet PCG one-shot loop over the handle's bucket
        arrays — the same body a :class:`serve.SolveEngine` ticks, so a
        served request reproduces these iterates bit-exactly."""
        B = jnp.asarray(B)
        if B.ndim not in (1, 2) or B.shape[-1] != self.n:
            raise ValueError(
                f"rhs must be (n,) or (nrhs, n) with n={self.n}, "
                f"got {B.shape}")
        fl, bl = self.fleet.f_levels, self.fleet.b_levels
        kind = self.fleet.kind
        key = (B.shape, str(B.dtype), float(tol), int(maxiter), project,
               fl, bl, kind)
        fn = self._cache.get(key)
        if fn is None:
            fn = jax.jit(self._build_solve(B.ndim, tol, maxiter, project,
                                           fl, bl, kind))
            self._cache[key] = fn
            while len(self._cache) > self.max_cached_solves:
                self._cache.popitem(last=False)
        else:
            self._cache.move_to_end(key)
        return fn(B, self.fleet.arrays, jnp.int32(self.fleet_row))

    def _build_solve(self, ndim: int, tol: float, maxiter: int,
                     project: bool, f_levels: int, b_levels: int,
                     kind: str = "factor"):
        # the fleet row rides in as a traced argument, not a closure
        # constant: a fleet compaction may move this handle to a new row
        # at any time, and the cached compiled solve must follow it
        n, n_pad = self.n, self.n_pad

        def run(B, fa, row):
            B2 = B if ndim == 2 else B[None]
            L = B2.shape[0]
            Bp = jnp.zeros((L, n_pad), B2.dtype).at[:, :n].set(B2)
            state = pcg_fleet_solve(
                fa, jnp.full((L,), row, jnp.int32), Bp,
                jnp.full((L,), tol, jnp.float32),
                jnp.full((L,), maxiter, jnp.int32),
                f_levels=f_levels, b_levels=b_levels, kind=kind,
                project=project)
            res = pcg_fleet_result(state, n)
            if ndim == 1:
                return PCGResult(x=res.x[0], iters=res.iters[0],
                                 relres=res.relres[0],
                                 converged=res.converged[0])
            return res

        return run


# Historical name: every pre-zoo call site (and the serving engine's
# type hints) used ``FactorHandle``; the interface is unchanged for the
# AC family, so the alias is permanent API.
FactorHandle = PreconditionerHandle


class FactorCache:
    """Multi-tenant factor-once / solve-many frontend.

    Construction options are fixed per cache.  ``factor`` (or
    ``factor_batched`` / ``attach``) admits handles keyed by graph
    fingerprint; ``solve(graph_id, B)`` routes a rhs to its factor.
    Admission evicts least-recently-used handles while the summed
    ``device_bytes`` exceeds ``memory_budget_bytes`` (or the handle
    count exceeds ``max_handles``) — the newest handle is never evicted.

    Staleness: handles admitted with ``ttl_s`` (seconds, against the
    injected ``clock``) or ``max_age_ticks`` (service ticks, advanced by
    ``advance_ticks`` — a serving engine calls it once per tick) expire
    on the next lookup/admission sweep, so resubmitting a modified graph
    ages its ancestor fingerprint out of the budget.  Defaults (``None``)
    never expire.
    """

    def __init__(self, *, chunk: int = 64, fill_slack: int = 32,
                 strict: bool = True, max_retries: int = 3,
                 dtype=np.float32,
                 memory_budget_bytes: Optional[int] = None,
                 max_handles: Optional[int] = None,
                 max_cached_solves: int = 16,
                 ttl_s: Optional[float] = None,
                 max_age_ticks: Optional[int] = None,
                 k_tiering: bool = True,
                 compact_threshold: Optional[float] = 0.5,
                 device: Optional[jax.Device] = None,
                 clock: Optional[Callable[[], float]] = None,
                 flight=None):
        self.chunk = chunk
        self.fill_slack = fill_slack
        self.strict = strict
        self.max_retries = max_retries
        self.dtype = dtype
        self.memory_budget_bytes = memory_budget_bytes
        self.max_handles = max_handles
        self.max_cached_solves = max_cached_solves
        self.ttl_s = ttl_s
        self.max_age_ticks = max_age_ticks
        # K-tiering sub-buckets fleets by padded panel width so a
        # hub-heavy member can't inflate narrow bucket-mates' panels;
        # False collapses every width into tier 0 (the pre-tiering
        # layout — kept for A/B benchmarking of the padding tax)
        self.k_tiering = k_tiering
        # compact a fleet when free_rows/capacity reaches this after an
        # eviction/expiry sweep (None = never compact)
        self.compact_threshold = compact_threshold
        # accelerator this cache's fleet stacks are pinned to (None =
        # default device).  Committing the stacks commits every jitted
        # fleet program that traces them, so one process can run N
        # caches on N devices with the router as the only cross-device
        # hop (see docs/architecture.md, disaggregation)
        self.device = device
        self._clock = clock if clock is not None else time.monotonic
        self.now_ticks = 0
        # one-way latch: True once any handle was admitted/refreshed
        # with a staleness policy — lets sweep_stale() stay O(1) on the
        # per-submit hot path of services that never use TTLs
        self._has_mortal = False
        self._handles: "OrderedDict[str, PreconditionerHandle]" = \
            OrderedDict()
        # family-heterogeneous: one fleet per (family, shape bucket,
        # K-tier) — families never share a stack, so each keeps its own
        # compiled step program and its own per-row memory accounting
        self._fleets: Dict[Tuple[str, int, int], FactorFleet] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.expirations = 0
        self.compactions = 0
        self.adoptions = 0         # factors constructed elsewhere, adopted
        # flight-recorder events for cache lifecycle transitions — a
        # post-mortem needs the eviction/expiry/compaction sequence that
        # preceded an incident, not just the end-state counters
        fl = flight if flight is not None else NULL_FLIGHT
        self._ev_cache_evict = fl.bind("cache_evict")
        self._ev_cache_expire = fl.bind("cache_expire")
        self._ev_compaction = fl.bind("compaction")
        self._ev_adopt = fl.bind("adopt")

    # -- staleness ----------------------------------------------------------
    def advance_ticks(self, k: int = 1) -> None:
        """Advance the service tick clock (engines call this per tick)."""
        self.now_ticks += k

    def _stale(self, h: FactorHandle, now_s: float) -> bool:
        if h.ttl_s is not None and now_s - h.born_s > h.ttl_s:
            return True
        if h.max_age_ticks is not None and \
                self.now_ticks - h.born_tick > h.max_age_ticks:
            return True
        return False

    def _refresh_policy(self, h: FactorHandle, ttl_s, max_age_ticks) -> None:
        """Explicit staleness arguments on a cache *hit* re-admit the
        handle: its policy is replaced and its birth stamps reset, so
        ``factor(..., ttl_s=...)`` means the same thing whether it
        factors or hits."""
        if ttl_s is _UNSET and max_age_ticks is _UNSET:
            return
        if ttl_s is not _UNSET:
            h.ttl_s = ttl_s
        if max_age_ticks is not _UNSET:
            h.max_age_ticks = max_age_ticks
        h.born_s = self._clock()
        h.born_tick = self.now_ticks
        if h.ttl_s is not None or h.max_age_ticks is not None:
            self._has_mortal = True

    def sweep_stale(self) -> int:
        """Evict every expired handle; returns how many were evicted.
        Runs automatically on admission and ``get`` lookups (O(1) until
        a staleness policy is first used)."""
        if not self._has_mortal:
            return 0
        now_s = self._clock()
        stale = [gid for gid, h in self._handles.items()
                 if self._stale(h, now_s)]
        for gid in stale:
            del self._handles[gid]
            self.expirations += 1
            self._ev_cache_expire(gid=gid)
        if stale:
            self._maybe_compact()
        return len(stale)

    def _maybe_compact(self) -> int:
        """Compact every fleet whose dead-row fraction crossed
        ``compact_threshold`` (called after evictions/expiries).
        Returns how many fleets were compacted."""
        if self.compact_threshold is None:
            return 0
        done = 0
        for fleet in self._fleets.values():
            cap = fleet.capacity
            if cap and fleet.free_rows / cap >= self.compact_threshold:
                if fleet.compact():
                    self.compactions += 1
                    self._ev_compaction(family=fleet.family,
                                        n_pad=fleet.n_pad,
                                        k_tier=fleet.k_tier)
                    done += 1
        return done

    def compact(self) -> int:
        """Unconditionally compact every fleet to its live rows
        (threshold ignored — the automatic trigger only fires on
        eviction/expiry sweeps, which can miss rows whose last external
        reference died later).  Returns how many fleets shrank."""
        done = 0
        for fleet in self._fleets.values():
            if fleet.compact():
                self.compactions += 1
                self._ev_compaction(family=fleet.family,
                                    n_pad=fleet.n_pad,
                                    k_tier=fleet.k_tier)
                done += 1
        return done

    # -- admission ----------------------------------------------------------
    def factor(self, g: Graph, key: jax.Array, *,
               graph_id: Optional[str] = None, family: str = "ac",
               precond_params: Optional[Dict] = None, ttl_s=_UNSET,
               max_age_ticks=_UNSET) -> PreconditionerHandle:
        """Construct a preconditioner for ``g`` and admit the handle
        (cache hit if an identical ``(graph, key, family, params)`` is
        already live and fresh).

        Args:
            g: graph to precondition.
            key: factorization PRNG key (ignored by deterministic
                families — ichol/amg/spai — but still part of the
                default fingerprint only for ``family="ac"``).
            graph_id: explicit cache key (defaults to the content
                fingerprint including family and params).
            family: registered preconditioner family
                (``"ac"``/``"ichol"``/``"amg"``/``"spai"``).
            precond_params: family construction parameters (e.g.
                ``{"droptol": 0.02}`` for icholt).
            ttl_s / max_age_ticks: staleness policy overrides.

        Returns:
            The admitted (or refreshed) :class:`PreconditionerHandle`.

        Raises:
            KeyError: ``family`` is not registered.
        """
        self.sweep_stale()
        fam = get_family(family)
        params = dict(precond_params or {})
        gid = graph_id if graph_id is not None else graph_fingerprint(
            g, key if family == "ac" else None, family=family,
            params=params)
        got = self._handles.get(gid)
        if got is not None:
            self.hits += 1
            self._handles.move_to_end(gid)
            self._refresh_policy(got, ttl_s, max_age_ticks)
            return got
        self.misses += 1
        t0 = time.perf_counter()
        if family == "ac":
            f = factorize_wavefront(
                g, key, chunk=self.chunk, fill_slack=self.fill_slack,
                strict=self.strict, max_retries=self.max_retries,
                dtype=self.dtype, **params)
        else:
            f = fam.build(g, key, dtype=self.dtype, **params)
        handle = self.attach(g, f, graph_id=gid, family=family,
                             ttl_s=ttl_s, max_age_ticks=max_age_ticks)
        handle.construct_s = time.perf_counter() - t0
        return handle

    def factor_batched(self, gs: Sequence[Graph], keys, *,
                       graph_ids: Optional[Sequence[str]] = None,
                       ttl_s=_UNSET, max_age_ticks=_UNSET
                       ) -> List[FactorHandle]:
        """Admit a fleet: graphs not already cached factor together in
        one vmapped XLA program (``parac.factorize_batched``) and their
        trisolve schedules derive in one vmapped pass alongside."""
        self.sweep_stale()
        gs = list(gs)
        if not isinstance(keys, jax.Array):
            keys = jnp.stack(list(keys))
        gids = list(graph_ids) if graph_ids is not None else [
            graph_fingerprint(g, keys[i]) for i, g in enumerate(gs)]
        todo = [i for i, gid in enumerate(gids) if gid not in self._handles]
        self.hits += len(gs) - len(todo)
        self.misses += len(todo)
        for gid in set(gids) - {gids[i] for i in todo}:
            self._refresh_policy(self._handles[gid], ttl_s, max_age_ticks)
        # strong refs for the whole call: a tight budget may LRU-evict a
        # sibling of this very fleet mid-admission — the caller still gets
        # every handle back (evicted ones simply aren't cached any more).
        fleet = {gid: self._handles[gid] for gid in gids
                 if gid in self._handles}
        if todo:
            fs, scheds = factorize_batched(
                [gs[i] for i in todo], jnp.stack([keys[i] for i in todo]),
                chunk=self.chunk, fill_slack=self.fill_slack,
                strict=self.strict, max_retries=self.max_retries,
                dtype=self.dtype, with_schedules=True)
            admitted = self._attach_many(
                [(gs[i], f, sch, gids[i], "ac")
                 for i, f, sch in zip(todo, fs, scheds)],
                ttl_s=ttl_s, max_age_ticks=max_age_ticks)
            fleet.update(admitted)
        for gid in gids:
            if gid in self._handles:
                self._handles.move_to_end(gid)
        return [fleet[gid] for gid in gids]

    def attach(self, g: Graph, f, *,
               graph_id: Optional[str] = None, family: str = "ac",
               schedules: Optional[Tuple[PackedSchedule,
                                         PackedSchedule]] = None,
               ttl_s=_UNSET, max_age_ticks=_UNSET) -> PreconditionerHandle:
        """Wrap an existing family payload (e.g. a factor from the
        sequential oracle, or a pre-built ``EllPrecond``) in a solve
        handle and admit it to its ``(family, shape-bucket)`` fleet —
        same lifecycle, no re-construction.

        Args:
            g: the payload's graph.
            f: family payload (``ACFactor``/``DeviceFactor`` for factor
                kinds, ``EllPrecond`` for spmv kinds).
            graph_id: explicit cache key (defaults to the graph+family
                fingerprint).
            family: registered family name (selects the fleet kind).
            schedules: short-circuits the per-factor schedule build
                when a batched one already ran (factor kinds only).
            ttl_s / max_age_ticks: staleness policy overrides.

        Returns:
            The admitted :class:`PreconditionerHandle`.
        """
        gid = graph_id if graph_id is not None else graph_fingerprint(
            g, family=family)
        (_, handle), = self._attach_many([(g, f, schedules, gid, family)],
                                         ttl_s=ttl_s,
                                         max_age_ticks=max_age_ticks)
        return handle

    def adopt(self, g: Graph, f, *, graph_id: str, family: str = "ac",
              schedules: Optional[Tuple[PackedSchedule,
                                        PackedSchedule]] = None,
              construct_s: float = 0.0, ttl_s=_UNSET,
              max_age_ticks=_UNSET) -> PreconditionerHandle:
        """Admit a preconditioner **constructed elsewhere** (a factor-tier
        replica, another process): the adopt path is device transfer +
        fleet-row scatter only — it never factors.  A live fresh handle
        for ``graph_id`` short-circuits as a hit (adopt is idempotent, so
        a tier shipping a factor that raced a colocated construction
        cannot double-claim fleet rows); otherwise the payload rides the
        normal ``attach`` lifecycle — ``admit_many`` commits its arrays
        to this cache's pinned device, which is where the cross-device
        hop happens.

        Args:
            g: the payload's graph.
            f: family payload (see :meth:`attach`).
            graph_id: cache key the factor was constructed under.
            family: registered family name.
            schedules: packed trisolve schedules built alongside the
                factor (skips the per-factor schedule build entirely).
            construct_s: construction wall-clock on the factor tier,
                recorded on the handle so telemetry attributes it there.
            ttl_s / max_age_ticks: staleness policy overrides.

        Returns:
            The adopted (or already-resident) handle.
        """
        self.sweep_stale()
        got = self._handles.get(graph_id)
        if got is not None:
            self.hits += 1
            self._handles.move_to_end(graph_id)
            self._refresh_policy(got, ttl_s, max_age_ticks)
            return got
        handle = self.attach(g, f, graph_id=graph_id, family=family,
                             schedules=schedules, ttl_s=ttl_s,
                             max_age_ticks=max_age_ticks)
        handle.construct_s = construct_s
        self.adoptions += 1
        self._ev_adopt(gid=graph_id, family=family,
                       construct_s=construct_s)
        return handle

    def _attach_many(self, items: Sequence[Tuple[Graph, object,
                                                 Optional[Tuple],
                                                 str, str]],
                     *, ttl_s=_UNSET, max_age_ticks=_UNSET
                     ) -> List[Tuple[str, PreconditionerHandle]]:
        """Admit a batch of ``(graph, payload, schedules|None, gid,
        family)``: members are grouped by ``(family, shape bucket)`` and
        each fleet's stack grows **once**, scattering all its new rows
        in one update (:meth:`FactorFleet.admit_many`) — per-factor
        ``attach`` in a loop pays O(B²) device copies for B same-bucket
        admissions.  Handles register in ``items`` order (LRU order
        preserved); the budget sweep runs once at the end."""
        built: List[Tuple[FactorFleet, PreconditionerHandle,
                          _PaddedFactor, str]] = []
        for g, f, schedules, gid, family in items:
            fam = get_family(family)
            if fam.kind == "spmv":
                pf = _PaddedFactor.from_ell(g, f)
                fwd, bwd = pf.fwd, pf.bwd
            else:
                dev = f.to_device()
                if schedules is None:
                    schedules = build_schedules_batched([dev])[0]
                fwd, bwd = schedules
                pf = _PaddedFactor(g, dev, fwd, bwd)
            # pow2 K-tier on the padded panel width (max of both panel
            # sets — the tier must cover whichever trisolve is wider);
            # tier 0 = tiering disabled, one fleet per (family, n_pad)
            k_tier = pad_k(max(fwd.K, bwd.K)) if self.k_tiering else 0
            fkey = (family, pf.n_pad, k_tier)
            fleet = self._fleets.get(fkey)
            if fleet is None:
                fleet = self._fleets[fkey] = FactorFleet(
                    pf.n_pad, family=family, kind=fam.kind, k_tier=k_tier,
                    device=self.device)
            handle = PreconditionerHandle(
                graph=g, factor=f, fleet=fleet, fleet_row=-1,
                n_levels_fwd=fwd.n_levels, n_levels_bwd=bwd.n_levels,
                graph_id=gid, family=family,
                max_cached_solves=self.max_cached_solves,
                born_s=self._clock(), born_tick=self.now_ticks,
                ttl_s=self.ttl_s if ttl_s is _UNSET else ttl_s,
                max_age_ticks=(self.max_age_ticks
                               if max_age_ticks is _UNSET
                               else max_age_ticks))
            built.append((fleet, handle, pf, gid))
        by_fleet: Dict[Tuple[str, int, int],
                       List[Tuple[PreconditionerHandle,
                                  _PaddedFactor]]] = {}
        for fleet, handle, pf, _ in built:
            by_fleet.setdefault((fleet.family, fleet.n_pad, fleet.k_tier),
                                []).append((handle, pf))
        for fkey, pairs in by_fleet.items():
            rows = self._fleets[fkey].admit_many(pairs)
            for (handle, _), row in zip(pairs, rows):
                handle.fleet_row = row
        out: List[Tuple[str, FactorHandle]] = []
        for _, handle, _, gid in built:
            if handle.ttl_s is not None or handle.max_age_ticks is not None:
                self._has_mortal = True
            self._handles[gid] = handle
            self._handles.move_to_end(gid)
            out.append((gid, handle))
        self._shrink()
        return out

    def _shrink(self):
        """Evict LRU handles until budget/count bounds hold (the newest
        handle always survives)."""
        evicted = False
        while len(self._handles) > 1 and (
                (self.max_handles is not None
                 and len(self._handles) > self.max_handles)
                or (self.memory_budget_bytes is not None
                    and self.device_bytes > self.memory_budget_bytes)):
            gid, _ = self._handles.popitem(last=False)
            self.evictions += 1
            self._ev_cache_evict(gid=gid, reason="budget")
            evicted = True
        if evicted:
            self._maybe_compact()

    # -- lookup / routing ---------------------------------------------------
    def peek(self, graph_id: str) -> Optional[FactorHandle]:
        """Non-faulting lookup that does not touch LRU order or sweep
        staleness (lets a serving engine check whether its pinned handle
        is still the cached one)."""
        return self._handles.get(graph_id)

    def fresh(self, graph_id: str) -> bool:
        """Non-mutating freshness probe: True iff ``graph_id`` has a live
        handle that would *not* be swept as stale on the next lookup.
        Unlike ``get`` it never sweeps, never touches LRU order and only
        reads — safe for a cluster router to call from outside the
        engine's driver thread."""
        h = self._handles.get(graph_id)
        return h is not None and not self._stale(h, self._clock())

    def capacity_probe(self) -> Dict[str, Optional[int]]:
        """Read-only headroom snapshot for cluster placement decisions:
        how much more factor state this cache can admit before evicting.
        ``free_bytes``/``free_handles`` are ``None`` when the matching
        bound is unset (unbounded); ``fleet_free_rows`` counts bucket
        rows reusable without growing any stack.

        Called from router threads while the serving driver thread may
        be admitting — the handle/fleet dicts are snapshotted with
        ``list()`` (one GIL-atomic copy) before iteration, so a
        concurrent insert can never raise mid-iteration; the numbers
        are advisory and may be one admission stale."""
        handles = list(self._handles.values())
        fleets = list(self._fleets.values())
        used = sum(h.device_bytes for h in handles)
        free_bytes = None if self.memory_budget_bytes is None else \
            max(self.memory_budget_bytes - used, 0)
        free_handles = None if self.max_handles is None else \
            max(self.max_handles - len(handles), 0)
        return dict(handles=len(handles),
                    free_handles=free_handles,
                    device_bytes=used,
                    free_bytes=free_bytes,
                    fleet_free_rows=sum(f.free_rows for f in fleets))

    def get(self, graph_id: str) -> FactorHandle:
        self.sweep_stale()
        handle = self._handles.get(graph_id)
        if handle is None:
            raise KeyError(f"no live factor for graph_id={graph_id!r} "
                           f"({len(self._handles)} cached)")
        self._handles.move_to_end(graph_id)
        return handle

    def __contains__(self, graph_id: str) -> bool:
        return graph_id in self._handles

    def __len__(self) -> int:
        return len(self._handles)

    @property
    def graph_ids(self) -> List[str]:
        return list(self._handles)

    @property
    def device_bytes(self) -> int:
        return sum(h.device_bytes for h in self._handles.values())

    @property
    def fleets(self) -> Dict[Tuple[str, int, int], FactorFleet]:
        """Live fleets keyed by ``(family, n_pad, k_tier)`` (read-only
        view)."""
        return dict(self._fleets)

    def evict(self, graph_id: str) -> None:
        if self._handles.pop(graph_id, None) is not None:
            self.evictions += 1
            self._ev_cache_evict(gid=graph_id, reason="explicit")
            self._maybe_compact()

    def clear(self) -> None:
        self._handles.clear()

    def stats(self) -> Dict:
        """Cache counters plus per-family memory accounting.

        Returns:
            Dict with hit/miss/eviction/``compactions`` counters, total
            and per-family ``device_bytes`` (``device_bytes_by_family``
            / ``handles_by_family``), the fleet-stack footprint
            (``fleet_device_bytes``, also split by family) and the live
            floor it compacts toward (``fleet_live_bytes`` = live rows
            × per-row bytes — the CI memory invariant compares the
            two).
        """
        # snapshot with list() (GIL-atomic copies): cluster telemetry
        # reads these from router threads while the driver may admit
        handles = list(self._handles.values())
        fleet_items = list(self._fleets.items())
        by_family_bytes: Dict[str, int] = {}
        by_family_handles: Dict[str, int] = {}
        for h in handles:
            by_family_bytes[h.family] = \
                by_family_bytes.get(h.family, 0) + h.device_bytes
            by_family_handles[h.family] = \
                by_family_handles.get(h.family, 0) + 1
        fleet_by_family: Dict[str, int] = {}
        for (family, _, _), f in fleet_items:
            fleet_by_family[family] = \
                fleet_by_family.get(family, 0) + f.device_bytes
        # actual placement attribution (read from the arrays, not the
        # pin request): the multi-device gate sums bytes per device
        fleet_by_device: Dict[str, int] = {}
        for _, f in fleet_items:
            dev = f.resident_device
            if dev is not None and f.device_bytes:
                fleet_by_device[dev] = \
                    fleet_by_device.get(dev, 0) + f.device_bytes
        return dict(handles=len(handles), hits=self.hits,
                    misses=self.misses, evictions=self.evictions,
                    expirations=self.expirations,
                    compactions=self.compactions,
                    adoptions=self.adoptions,
                    device=(str(self.device)
                            if self.device is not None else None),
                    fleet_device_bytes_by_device=fleet_by_device,
                    fleets=len(fleet_items),
                    device_bytes=sum(h.device_bytes for h in handles),
                    fleet_device_bytes=sum(f.device_bytes
                                           for _, f in fleet_items),
                    fleet_live_bytes=sum(f.live_rows * f.bytes_per_row
                                         for _, f in fleet_items),
                    handles_by_family=by_family_handles,
                    device_bytes_by_family=by_family_bytes,
                    fleet_device_bytes_by_family=fleet_by_family)

    def solve(self, graph_id: str, B, **kw) -> PCGResult:
        return self.get(graph_id).solve(B, **kw)


class Solver(FactorCache):
    """Single-tenant compatibility surface over :class:`FactorCache`:
    ``factor``/``attach`` remember the most recent handle and ``solve``
    takes just the rhs.  Defaults to ``max_handles=1`` so factoring a
    sweep of graphs through one ``Solver`` keeps O(1) device memory,
    exactly like the pre-cache ``Solver`` did."""

    def __init__(self, **kw):
        kw.setdefault("max_handles", 1)
        super().__init__(**kw)
        self.handle: Optional[FactorHandle] = None

    def factor(self, g: Graph, key: jax.Array, **kw) -> FactorHandle:
        self.handle = super().factor(g, key, **kw)
        return self.handle

    def attach(self, g: Graph, f: ACFactor, **kw) -> FactorHandle:
        self.handle = super().attach(g, f, **kw)
        return self.handle

    def solve(self, B, **kw) -> PCGResult:
        if self.handle is None:
            raise RuntimeError("Solver.solve before Solver.factor")
        return self.handle.solve(B, **kw)
