"""``Solver`` — the device-resident factor→solve pipeline as an API.

The paper's production shape is *factor once, serve many solves*: the
randomized construction is cheap (little pre-processing, §4) and the
short-critical-path factor (§6.2) then amortizes over every rhs that
arrives.  ``Solver`` packages that lifecycle:

    solver = Solver(chunk=256, fill_slack=32)
    handle = solver.factor(graph, jax.random.key(0))   # device-resident
    res = solver.solve(b)            # single rhs, jitted PCG
    res = solver.solve(B)            # (nrhs, n) block → batched PCG

``factor`` runs the wavefront engine, compacts the factor on device and
derives both triangular level schedules on device (``trisolve.
build_schedules_device``) — the handle caches the jitted preconditioner
and one jitted PCG per rhs-batch shape, so repeated solves against the
same factor pay zero rebuild cost.  Batched solves share the factor
through a fused multi-rhs trisolve (one gather-multiply-reduce per level
for the whole block), not nrhs sequential applies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .laplacian import Graph, laplacian_matvec
from .ref_ac import ACFactor
from .parac import factorize_wavefront
from .trisolve import (DeviceSchedule, build_schedules_device,
                       make_preconditioner_from_schedules)
from .pcg import PCGResult, pcg_jax, pcg_jax_batched


@dataclasses.dataclass
class FactorHandle:
    """A factored graph ready to serve solves.  Everything needed on the
    hot path (schedules, D⁻¹, edge arrays) is device-resident; jitted
    solve closures are cached per rhs-batch shape."""

    graph: Graph
    factor: ACFactor
    fwd: DeviceSchedule
    bwd: DeviceSchedule
    precondition: callable            # r (n,) or (n, nrhs) -> M⁺ r
    _src: jnp.ndarray
    _dst: jnp.ndarray
    _w: jnp.ndarray
    _cache: Dict[Tuple, callable] = dataclasses.field(default_factory=dict)

    @property
    def n(self) -> int:
        return self.graph.n

    def matvec(self, x: jnp.ndarray) -> jnp.ndarray:
        return laplacian_matvec(self._src, self._dst, self._w, self.n, x)

    def solve(self, B, *, tol: float = 1e-6, maxiter: int = 1000,
              project: bool = True) -> PCGResult:
        """PCG-solve ``L x = b``.  ``B``: ``(n,)`` for one rhs or
        ``(nrhs, n)`` for a batch (all columns share this factor)."""
        B = jnp.asarray(B)
        if B.ndim not in (1, 2) or B.shape[-1] != self.n:
            raise ValueError(
                f"rhs must be (n,) or (nrhs, n) with n={self.n}, "
                f"got {B.shape}")
        key = (B.shape, str(B.dtype), float(tol), int(maxiter), project)
        fn = self._cache.get(key)
        if fn is None:
            fn = jax.jit(self._build_solve(B.ndim, tol, maxiter, project))
            self._cache[key] = fn
        return fn(B)

    def _build_solve(self, ndim: int, tol: float, maxiter: int,
                     project: bool):
        mv = self.matvec
        pc = self.precondition
        if ndim == 1:
            return lambda b: pcg_jax(mv, pc, b, tol=tol, maxiter=maxiter,
                                     project=project)
        # batched: matvec vmaps over the rhs axis; the preconditioner
        # consumes the whole (n, nrhs) block in one fused trisolve.
        bmv = jax.vmap(mv)

        def bpc(R):
            return pc(R.T).T

        return lambda B: pcg_jax_batched(bmv, bpc, B, tol=tol,
                                         maxiter=maxiter, project=project)


class Solver:
    """Factor-once / solve-many frontend over the wavefront engine.

    Construction options are fixed per ``Solver``; each ``factor`` call
    produces (and remembers) a :class:`FactorHandle`, and ``solve``
    forwards to the most recent one.
    """

    def __init__(self, *, chunk: int = 64, fill_slack: int = 32,
                 strict: bool = True, max_retries: int = 3,
                 dtype=np.float32):
        self.chunk = chunk
        self.fill_slack = fill_slack
        self.strict = strict
        self.max_retries = max_retries
        self.dtype = dtype
        self.handle: Optional[FactorHandle] = None

    def factor(self, g: Graph, key: jax.Array) -> FactorHandle:
        f = factorize_wavefront(
            g, key, chunk=self.chunk, fill_slack=self.fill_slack,
            strict=self.strict, max_retries=self.max_retries,
            dtype=self.dtype)
        return self.attach(g, f)

    def attach(self, g: Graph, f: ACFactor) -> FactorHandle:
        """Wrap an existing factor (e.g. from the sequential oracle) in a
        solve handle — same lifecycle, no re-factorization."""
        fwd, bwd = build_schedules_device(f)
        self.handle = FactorHandle(
            graph=g, factor=f, fwd=fwd, bwd=bwd,
            precondition=make_preconditioner_from_schedules(
                fwd, bwd, f.to_device().D),
            _src=jnp.asarray(g.src), _dst=jnp.asarray(g.dst),
            _w=jnp.asarray(g.w, dtype=jnp.asarray(f.vals).dtype))
        return self.handle

    def solve(self, B, **kw) -> PCGResult:
        if self.handle is None:
            raise RuntimeError("Solver.solve before Solver.factor")
        return self.handle.solve(B, **kw)
