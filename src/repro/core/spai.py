"""Sparse approximate inverse (SPAI) preconditioner in ELL row layout.

The apply of a SPAI preconditioner is a single SpMV ``z = M r`` with a
*materialized* sparse approximate inverse ``M ≈ L⁺`` — which makes it a
perfect fit for the fleet's lane-batched ELL SpMV kernel
(``repro.kernels.spmv.ell_spmv_fleet_pallas``): one kernel launch per
PCG iteration instead of the ``f_levels + b_levels`` masked sweeps a
triangular factor pays.  This is the serving-side point of the SPAI
lineage (arxiv 2510.27517): trade construction-time least squares for a
branch-free, mega-batchable apply.

Construction here is the **factored** SPAI (FSAI, Kolotilina–Yeremin):
build a sparse lower-triangular ``G ≈ L_chol⁻¹`` by solving one small
SPD system per row over the row's lower-triangular sparsity pattern,
then materialize ``M = Gᵀ G`` — symmetric positive definite *by
construction*, unlike plain column-wise SPAI whose symmetrization can
go indefinite.  ``M``'s pattern is the 2-hop closure of the graph, so
rows densify with degree²; at the tiny/medium serving scales this repo
targets that is cheap, and :doc:`docs/preconditioners` documents the
restriction for larger graphs.

Host scipy/numpy construction (a quality baseline, like ``ichol`` and
``amg``); the product ``M`` ships to the device once via the family's
``FactorCache`` attach.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

from .laplacian import Graph, grounded_laplacian_coo


@dataclasses.dataclass
class EllPrecond:
    """A materialized approximate inverse ``M`` as padded ELL rows —
    the host-side payload of every ``"spmv"``-kind preconditioner
    family (SPAI, flattened AMG).

    Row ``i``'s nonzeros occupy ``cols[i, :]``/``vals[i, :]``; unused
    slots carry ``cols == 0, vals == 0`` so padded slots contribute
    exactly zero to the SpMV.  The fleet admission path scatters these
    rows into the bucket's forward-panel arrays and the apply runs as
    one ``ell_spmv_fleet`` launch.
    """

    n: int
    cols: np.ndarray    # int32[n, K]
    vals: np.ndarray    # f32[n, K]
    nnz: int = 0
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def K(self) -> int:
        return int(self.cols.shape[1])

    @property
    def device_bytes(self) -> int:
        """Bytes one device copy of the ELL rows would occupy (the
        fleet row is the actual resident copy; this sizes it)."""
        return int(self.cols.nbytes + self.vals.nbytes)

    def apply(self, r: np.ndarray) -> np.ndarray:
        """Host reference apply ``z = M r`` (tests/baselines; the
        serving path runs the fleet ELL kernel instead)."""
        return np.sum(self.vals * np.asarray(r, self.vals.dtype)[self.cols],
                      axis=1)


def dense_to_ell(M: np.ndarray, *, droptol: float = 0.0,
                 dtype=np.float32) -> EllPrecond:
    """Pack a dense symmetric approximate inverse into ELL rows.

    Entries with ``|m_ij| < droptol · max|M|`` are dropped (a global
    threshold keeps the drop mask symmetric, so the packed operator
    stays symmetric); diagonal entries are always kept.  ``K`` is the
    post-drop maximum row count.

    Args:
        M: dense ``(n, n)`` symmetric operator.
        droptol: relative drop threshold (``0.0`` keeps everything).
        dtype: value dtype of the packed rows.

    Returns:
        The packed :class:`EllPrecond`.
    """
    n = M.shape[0]
    if droptol > 0.0:
        mmax = float(np.abs(M).max())
        keep = np.abs(M) >= droptol * (mmax if mmax > 0.0 else 1.0)
    else:
        keep = np.abs(M) != 0.0
    np.fill_diagonal(keep, True)
    counts = keep.sum(axis=1)
    K = max(int(counts.max()), 1)
    cols = np.zeros((n, K), np.int32)
    vals = np.zeros((n, K), dtype)
    for i in range(n):
        js = np.nonzero(keep[i])[0]
        cols[i, :js.size] = js
        vals[i, :js.size] = M[i, js].astype(dtype)
    return EllPrecond(n=n, cols=cols, vals=vals, nnz=int(counts.sum()),
                      meta={"droptol": float(droptol)})


def fsai_lower(g: Graph, shift: float = 0.0) -> sp.csr_matrix:
    """Factored-SPAI lower triangle ``G ≈ L_chol⁻¹`` on the pattern of
    the grounded Laplacian.

    Row ``i``'s pattern is ``J = {j ≤ i : A[i, j] ≠ 0}``; the row
    solves the local SPD system ``A[J, J] y = e_last`` and is scaled by
    ``1/√y_last`` so ``G A Gᵀ`` has unit diagonal — the classical FSAI
    normalization, which makes ``Gᵀ G`` an SPD approximation of ``A⁻¹``.

    Args:
        g: graph whose grounded Laplacian to approximate.
        shift: optional relative diagonal shift (same meaning as
            ``ichol``'s Manteuffel retry shift).

    Returns:
        ``G`` as lower-triangular CSR.
    """
    i, j, v = grounded_laplacian_coo(g, shift)
    A = sp.coo_matrix((v, (i, j)), shape=(g.n, g.n)).tocsr()
    n = g.n
    rows_i: list = []
    rows_j: list = []
    rows_v: list = []
    for r in range(n):
        lo, hi = A.indptr[r], A.indptr[r + 1]
        J = A.indices[lo:hi]
        J = np.sort(J[J <= r])
        if J.size == 0 or J[-1] != r:
            J = np.append(J, r)
        Aloc = A[np.ix_(J, J)].toarray()
        e = np.zeros(J.size)
        e[-1] = 1.0
        y = np.linalg.solve(Aloc, e)
        ylast = y[-1]
        if ylast <= 0:                    # local breakdown: Jacobi row
            y = np.zeros(J.size)
            y[-1] = 1.0
            ylast = 1.0 / max(float(Aloc[-1, -1]), 1e-30)
            y[-1] = ylast
        gr = y / np.sqrt(ylast)
        rows_i.append(np.full(J.size, r, np.int64))
        rows_j.append(J.astype(np.int64))
        rows_v.append(gr)
    return sp.coo_matrix(
        (np.concatenate(rows_v),
         (np.concatenate(rows_i), np.concatenate(rows_j))),
        shape=(n, n)).tocsr()


def spai_ell_precond(g: Graph, *, droptol: float = 0.0,
                     dtype=np.float32) -> EllPrecond:
    """Build the SPAI family's ELL operator ``M = Gᵀ G`` for ``g``.

    ``G`` is the FSAI lower triangle (:func:`fsai_lower`), so ``M`` is
    SPD by construction; the product is formed sparsely and packed row
    by row (``droptol`` trims the 2-hop fill relative to the largest
    entry of ``M``).

    Args:
        g: graph to precondition.
        droptol: relative drop threshold on ``M``'s entries.
        dtype: value dtype of the packed rows.

    Returns:
        The packed :class:`EllPrecond` with construction metadata in
        ``meta`` (``{"family": "spai", "nnz_G": ...}``).
    """
    G = fsai_lower(g)
    M = (G.T @ G).toarray()
    out = dense_to_ell(M, droptol=droptol, dtype=dtype)
    out.meta.update(family="spai", nnz_G=int(G.nnz))
    return out
