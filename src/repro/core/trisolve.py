"""Level-scheduled sparse triangular solves for the G D Gᵀ preconditioner.

The paper (§6.2) observes that the *critical path* of the triangular DAG —
not raw nnz — governs parallel triangular-solve performance, and that
randomized factors have dramatically shorter critical paths than classical
ones (Fig. 4).  We exploit exactly that: rows are grouped by dependency
level (level(i) = 1 + max level over in-neighbours), and each level is one
data-parallel segment-reduce.  Level construction is a single host pass;
the solve itself is pure JAX (and the per-level gather-multiply-scatter is
the Pallas ``trisolve`` kernel's job on TPU).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .ref_ac import ACFactor


@dataclasses.dataclass
class LevelSchedule:
    """COO edges of a unit-triangular solve, grouped by target-row level."""

    n: int
    n_levels: int
    level_ptr: np.ndarray  # int64[n_levels+1] into the edge arrays
    e_dst: np.ndarray      # int32[nnz] — row being solved
    e_src: np.ndarray      # int32[nnz] — already-solved row it reads
    e_val: np.ndarray      # f32[nnz]
    level_of: np.ndarray   # int32[n]


def _levels_from_edges(n: int, dst: np.ndarray, src: np.ndarray,
                       val: np.ndarray) -> LevelSchedule:
    """Group solve edges by level.  Requires a topological order exists in
    which every edge goes forward; levels are computed by one sweep over
    edges sorted by dst's topological position (here: dst index order for
    the forward solve, reversed for the backward solve — callers arrange
    that dst indices are already topologically sorted)."""
    # longest-path levels via level-synchronous relaxation: converges in
    # (#levels) vectorized passes — no per-edge Python loop.
    level = np.zeros(n, np.int32)
    while True:
        cand = np.zeros(n, np.int32)
        np.maximum.at(cand, dst, level[src] + 1)
        new = np.maximum(level, cand)
        if np.array_equal(new, level):
            break
        level = new
    n_levels = int(level.max()) + 1 if n else 1
    edge_level = level[dst]
    eorder = np.argsort(edge_level, kind="stable")
    e_dst, e_src, e_val = dst[eorder], src[eorder], val[eorder]
    counts = np.bincount(edge_level[eorder], minlength=n_levels)
    level_ptr = np.zeros(n_levels + 1, np.int64)
    np.cumsum(counts, out=level_ptr[1:])
    return LevelSchedule(n=n, n_levels=n_levels, level_ptr=level_ptr,
                         e_dst=e_dst.astype(np.int32),
                         e_src=e_src.astype(np.int32),
                         e_val=e_val, level_of=level)


def build_schedules(f: ACFactor) -> Tuple[LevelSchedule, LevelSchedule]:
    """Forward (G y = r) and backward (Gᵀ x = z) level schedules.

    G is unit lower triangular in elimination positions; its CSC column k
    holds rows i > k with value G_ik.  Forward edge: (dst=i, src=k, v=G_ik)
    … wait, forward solve is  y_i = r_i − Σ_{k<i} G_ik y_k, so each CSC
    entry (i ∈ col k) is an edge dst=i, src=k.  Backward solve is
    x_k = z_k − Σ_{i>k} G_ik x_i: edge dst=k, src=i.  For the backward
    pass "topological position of dst" is n−1−k, handled by index flip.
    """
    n = f.n
    cols = np.repeat(np.arange(n, dtype=np.int32),
                     np.diff(f.col_ptr).astype(np.int64))
    fwd = _levels_from_edges(n, f.rows.astype(np.int32), cols, f.vals)
    # backward: flip indices so that ascending == reverse topological
    flip = (n - 1) - cols
    fsrc = (n - 1) - f.rows.astype(np.int32)
    bwd = _levels_from_edges(n, flip, fsrc, f.vals)
    return fwd, bwd


def solve_levels_np(sched: LevelSchedule, b: np.ndarray,
                    flip: bool = False) -> np.ndarray:
    """Host reference solve (numpy).  ``flip`` for the backward schedule
    (its indices are stored flipped)."""
    y = (b[::-1] if flip else b).astype(np.float64).copy()
    for lv in range(sched.n_levels):
        lo, hi = sched.level_ptr[lv], sched.level_ptr[lv + 1]
        if hi == lo:
            continue
        contrib = np.zeros(sched.n, np.float64)
        np.add.at(contrib, sched.e_dst[lo:hi],
                  sched.e_val[lo:hi].astype(np.float64) * y[sched.e_src[lo:hi]])
        y -= contrib
    return y[::-1] if flip else y


def make_jax_solver(sched: LevelSchedule, flip: bool = False):
    """Returns a jit-able ``b -> y`` closure; one segment-reduce per level."""
    per_level = []
    for lv in range(sched.n_levels):
        lo, hi = int(sched.level_ptr[lv]), int(sched.level_ptr[lv + 1])
        if hi == lo:
            continue
        per_level.append((jnp.asarray(sched.e_dst[lo:hi]),
                          jnp.asarray(sched.e_src[lo:hi]),
                          jnp.asarray(sched.e_val[lo:hi])))
    n = sched.n

    def solve(b: jnp.ndarray) -> jnp.ndarray:
        y = b[::-1] if flip else b
        for dst, src, val in per_level:
            contrib = jnp.zeros(n, y.dtype).at[dst].add(val * y[src])
            y = y - contrib
        return y[::-1] if flip else y

    return solve


def make_preconditioner(f: ACFactor):
    """jit-able ``r -> (G D Gᵀ)⁺ r`` via two level-scheduled solves."""
    fwd, bwd = build_schedules(f)
    fsolve = make_jax_solver(fwd)
    bsolve = make_jax_solver(bwd, flip=True)
    D = jnp.asarray(f.D)
    dinv = jnp.where(D > 0, 1.0 / jnp.where(D > 0, D, 1.0), 0.0)

    def apply(r: jnp.ndarray) -> jnp.ndarray:
        y = fsolve(r)
        z = y * dinv
        return bsolve(z)

    return apply


def precond_apply_np(f: ACFactor, r: np.ndarray) -> np.ndarray:
    fwd, bwd = build_schedules(f)
    y = solve_levels_np(fwd, r)
    dinv = np.where(f.D > 0, 1.0 / np.where(f.D > 0, f.D, 1.0), 0.0)
    return solve_levels_np(bwd, y * dinv, flip=True)
