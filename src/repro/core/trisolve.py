"""Level-scheduled sparse triangular solves for the G D Gᵀ preconditioner.

The paper (§6.2) observes that the *critical path* of the triangular DAG —
not raw nnz — governs parallel triangular-solve performance, and that
randomized factors have dramatically shorter critical paths than classical
ones (Fig. 4).  We exploit exactly that: rows are grouped by dependency
level (level(i) = 1 + max level over in-neighbours), and each level is one
data-parallel segment-reduce.

Two schedule builders live here:

* ``build_schedules`` / ``_levels_from_edges`` — the original host
  (numpy) construction, kept as the test oracle;
* ``build_schedules_device`` — the production path: level propagation
  runs on device under ``lax.while_loop`` and the per-level panels come
  out directly in the ELL layout consumed by ``repro.kernels.spmv``, so
  the factor→preconditioner handoff never round-trips through numpy
  (the wavefront engine already leaves the factor on device).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .ref_ac import ACFactor, DeviceFactor
# shared with the wavefront engine: one pow2 bucket-rounding policy and
# one run-rank (scatter offset) idiom across pools, schedules and fleets
from .parac import _next_pow2, _run_ranks


@dataclasses.dataclass
class LevelSchedule:
    """COO edges of a unit-triangular solve, grouped by target-row level."""

    n: int
    n_levels: int
    level_ptr: np.ndarray  # int64[n_levels+1] into the edge arrays
    e_dst: np.ndarray      # int32[nnz] — row being solved
    e_src: np.ndarray      # int32[nnz] — already-solved row it reads
    e_val: np.ndarray      # f32[nnz]
    level_of: np.ndarray   # int32[n]


def _levels_from_edges(n: int, dst: np.ndarray, src: np.ndarray,
                       val: np.ndarray) -> LevelSchedule:
    """Group solve edges by level.  Requires a topological order exists in
    which every edge goes forward; levels are computed by one sweep over
    edges sorted by dst's topological position (here: dst index order for
    the forward solve, reversed for the backward solve — callers arrange
    that dst indices are already topologically sorted)."""
    # longest-path levels via level-synchronous relaxation: converges in
    # (#levels) vectorized passes — no per-edge Python loop.
    level = np.zeros(n, np.int32)
    while True:
        cand = np.zeros(n, np.int32)
        np.maximum.at(cand, dst, level[src] + 1)
        new = np.maximum(level, cand)
        if np.array_equal(new, level):
            break
        level = new
    n_levels = int(level.max()) + 1 if n else 1
    edge_level = level[dst]
    eorder = np.argsort(edge_level, kind="stable")
    e_dst, e_src, e_val = dst[eorder], src[eorder], val[eorder]
    counts = np.bincount(edge_level[eorder], minlength=n_levels)
    level_ptr = np.zeros(n_levels + 1, np.int64)
    np.cumsum(counts, out=level_ptr[1:])
    return LevelSchedule(n=n, n_levels=n_levels, level_ptr=level_ptr,
                         e_dst=e_dst.astype(np.int32),
                         e_src=e_src.astype(np.int32),
                         e_val=e_val, level_of=level)


def build_schedules(f: ACFactor) -> Tuple[LevelSchedule, LevelSchedule]:
    """Forward (G y = r) and backward (Gᵀ x = z) level schedules.

    G is unit lower triangular in elimination positions; its CSC column k
    holds rows i > k with value G_ik.  Forward edge: (dst=i, src=k, v=G_ik)
    … wait, forward solve is  y_i = r_i − Σ_{k<i} G_ik y_k, so each CSC
    entry (i ∈ col k) is an edge dst=i, src=k.  Backward solve is
    x_k = z_k − Σ_{i>k} G_ik x_i: edge dst=k, src=i.  For the backward
    pass "topological position of dst" is n−1−k, handled by index flip.
    """
    n = f.n
    cols = np.repeat(np.arange(n, dtype=np.int32),
                     np.diff(f.col_ptr).astype(np.int64))
    fwd = _levels_from_edges(n, f.rows.astype(np.int32), cols, f.vals)
    # backward: flip indices so that ascending == reverse topological
    flip = (n - 1) - cols
    fsrc = (n - 1) - f.rows.astype(np.int32)
    bwd = _levels_from_edges(n, flip, fsrc, f.vals)
    return fwd, bwd


def solve_levels_np(sched: LevelSchedule, b: np.ndarray,
                    flip: bool = False) -> np.ndarray:
    """Host reference solve (numpy).  ``flip`` for the backward schedule
    (its indices are stored flipped)."""
    y = (b[::-1] if flip else b).astype(np.float64).copy()
    for lv in range(sched.n_levels):
        lo, hi = sched.level_ptr[lv], sched.level_ptr[lv + 1]
        if hi == lo:
            continue
        contrib = np.zeros(sched.n, np.float64)
        np.add.at(contrib, sched.e_dst[lo:hi],
                  sched.e_val[lo:hi].astype(np.float64) * y[sched.e_src[lo:hi]])
        y -= contrib
    return y[::-1] if flip else y


def make_jax_solver(sched: LevelSchedule, flip: bool = False):
    """Returns a jit-able ``b -> y`` closure; one segment-reduce per level."""
    per_level = []
    for lv in range(sched.n_levels):
        lo, hi = int(sched.level_ptr[lv]), int(sched.level_ptr[lv + 1])
        if hi == lo:
            continue
        per_level.append((jnp.asarray(sched.e_dst[lo:hi]),
                          jnp.asarray(sched.e_src[lo:hi]),
                          jnp.asarray(sched.e_val[lo:hi])))
    n = sched.n

    def solve(b: jnp.ndarray) -> jnp.ndarray:
        y = b[::-1] if flip else b
        for dst, src, val in per_level:
            contrib = jnp.zeros(n, y.dtype).at[dst].add(val * y[src])
            y = y - contrib
        return y[::-1] if flip else y

    return solve


# ---------------------------------------------------------------------------
# Device-side schedule construction (production path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DeviceSchedule:
    """Level schedule with rows pre-packed into ELL panels, built on
    device.  ``row_ids`` lists rows sorted by level; level ``lv`` owns
    rows ``row_ids[row_ptr[lv]:row_ptr[lv+1]]`` and the matching slabs of
    ``cols``/``vals`` — each slab is exactly the (rows, K) tile layout
    ``kernels.spmv.ell_spmv_pallas`` consumes.  Only ``row_ptr`` and
    ``n_levels`` live on host (loop bounds must be static); the data
    arrays are device-resident."""

    n: int
    n_levels: int
    K: int                  # panel width = max in-degree (≥ 1)
    row_ids: jnp.ndarray    # int32[n] — rows sorted by (level, row)
    row_ptr: np.ndarray     # int64[n_levels+1] into row_ids/cols/vals
    cols: jnp.ndarray       # int32[n, K] — in-edge sources, 0-padded
    vals: jnp.ndarray       # f32[n, K]   — in-edge values, 0-padded
    level_of: jnp.ndarray   # int32[n]


def _propagate_levels(dst, src, *, n: int):
    """Longest-path levels by iterative relaxation under ``while_loop`` —
    converges in (#levels) passes, all on device.

    Deliberately NOT ``@jax.jit``-wrapped: it always runs on concrete
    arrays under ``ensure_compile_time_eval`` (schedule construction is
    compile-time work), and jax 0.4.x mis-tracks inner-jit argument
    tracers in that nesting (jit → ensure_compile_time_eval → jit with a
    ``while_loop``), raising ``UnexpectedTracerError``.  Eager dispatch
    costs one primitive per line, once per factor."""
    def cond(c):
        return c[1]

    def body(c):
        level, _ = c
        cand = jnp.zeros(n, jnp.int32).at[dst].max(level[src] + 1,
                                                   mode="drop")
        new = jnp.maximum(level, cand)
        return new, jnp.any(new != level)

    level, _ = jax.lax.while_loop(
        cond, body, (jnp.zeros(n, jnp.int32), jnp.bool_(True)))
    return level


def _pack_ell_panels(dst, src, val, level, *, n: int, K: int):
    """Scatter solve edges into level-sorted ELL panels, one pass:
    rows sorted by level, each row's in-edges packed into its K-slot.
    Eager on purpose — see ``_propagate_levels``."""
    row_ids = jnp.argsort(level, stable=True).astype(jnp.int32)
    row_rank = jnp.zeros(n, jnp.int32).at[row_ids].set(
        jnp.arange(n, dtype=jnp.int32))
    eorder = jnp.argsort(dst, stable=True)
    sd, ss, swv = dst[eorder], src[eorder], val[eorder]
    rank = _run_ranks(sd)
    dest = row_rank[sd] * K + rank
    cols = jnp.zeros(n * K, jnp.int32).at[dest].set(ss).reshape(n, K)
    vals = jnp.zeros(n * K, val.dtype).at[dest].set(swv).reshape(n, K)
    return row_ids, cols, vals


def _schedule_from_edges_device(n: int, dst: jnp.ndarray, src: jnp.ndarray,
                                val: jnp.ndarray) -> DeviceSchedule:
    """Device schedule from COO solve edges (dst reads src).  Host work
    is limited to O(n_levels) slicing metadata — no per-edge loops.

    Schedule construction needs concrete metadata (panel width, level
    count), so it always runs at trace/compile time — callers may build
    preconditioners inside an outer ``jit`` (``ensure_compile_time_eval``
    keeps the concrete-array maths eager there).
    """
    if dst.shape[0] == 0:
        return DeviceSchedule(
            n=n, n_levels=1, K=1,
            row_ids=jnp.arange(n, dtype=jnp.int32),
            row_ptr=np.array([0, n], np.int64),
            cols=jnp.zeros((n, 1), jnp.int32),
            vals=jnp.zeros((n, 1), jnp.float32),
            level_of=jnp.zeros(n, jnp.int32))
    with jax.ensure_compile_time_eval():
        level = _propagate_levels(dst, src, n=n)
        indeg = jnp.zeros(n, jnp.int32).at[dst].add(1)
        K = max(int(indeg.max()), 1)
        row_ids, cols, vals = _pack_ell_panels(dst, src, val, level,
                                               n=n, K=K)
        level_h = np.asarray(level)        # O(n) metadata copy, no loop
    n_levels = int(level_h.max()) + 1
    row_ptr = np.searchsorted(np.sort(level_h),
                              np.arange(n_levels + 1)).astype(np.int64)
    return DeviceSchedule(n=n, n_levels=n_levels, K=K, row_ids=row_ids,
                          row_ptr=row_ptr, cols=cols, vals=vals,
                          level_of=level)


def build_schedules_device(
        f: ACFactor | DeviceFactor) -> Tuple[DeviceSchedule, DeviceSchedule]:
    """Forward/backward device schedules straight from the (device) factor.

    Edge derivation mirrors ``build_schedules``: CSC entry (i ∈ col k) is
    forward edge dst=i/src=k; the backward solve runs in flipped index
    space so ascending indices stay topological.
    """
    dev = f if isinstance(f, DeviceFactor) else f.to_device()
    n, nnz = dev.n, dev.nnz
    with jax.ensure_compile_time_eval():
        counts = jnp.diff(dev.col_ptr)
        cols_of = jnp.repeat(jnp.arange(n, dtype=jnp.int32), counts,
                             total_repeat_length=nnz)
        bsrc = (n - 1) - dev.rows
        bdst = (n - 1) - cols_of
    fwd = _schedule_from_edges_device(n, dev.rows, cols_of, dev.vals)
    bwd = _schedule_from_edges_device(n, bdst, bsrc, dev.vals)
    return fwd, bwd


# ---------------------------------------------------------------------------
# Batched (fleet) schedule construction — row-indexed panels for the
# shape-bucket mega-batching path
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PackedSchedule:
    """One triangular solve as **row-indexed** ELL panels: row ``i``'s
    in-edges occupy slot ``i`` of ``cols``/``vals`` (zero-padded to K),
    with ``level_of[i]`` its dependency level.  This is the layout the
    traced-argument solvers (``kernels.ops.trisolve_masked`` /
    ``trisolve_fleet``) consume: no level-sorted slabs, no host slicing
    metadata — the level loop masks on ``level_of`` instead, so panels
    from different factors stack into one fleet array and share one
    compiled program.  Unlike :class:`DeviceSchedule`, the backward
    schedule is kept in *original* index space (no flip): the masked
    level loop needs no topological index ordering."""

    n: int                  # true rows (rows n..n_pad are phantom)
    n_pad: int
    n_levels: int           # this factor's own level count (host int)
    K: int
    cols: jnp.ndarray       # int32[n_pad, K]
    vals: jnp.ndarray       # f32[n_pad, K]
    level_of: jnp.ndarray   # int32[n_pad] (0 for phantom rows)

    @property
    def device_bytes(self) -> int:
        return int(self.cols.nbytes + self.vals.nbytes
                   + self.level_of.nbytes)


def _propagate_levels_fleet(dst, src, *, n: int):
    """``_propagate_levels`` vmapped over a padded fleet: ``dst``/``src``
    are ``(B, E)`` with invalid (padding) edges marked ``dst == n`` so
    their relaxation drops.  One batched ``while_loop`` runs until every
    member converges — the whole fleet's level propagation is a single
    XLA program instead of B sequential ones."""
    return jax.vmap(partial(_propagate_levels, n=n))(dst, src)


def _pack_row_panels_fleet(dst, src, val, *, n: int, K: int):
    """Row-indexed ELL packing, vmapped: edge ``e`` lands in slot
    ``(dst_e, rank_e)`` where rank is the edge's position within its
    dst group.  Padding edges (``dst == n``) scatter out of range and
    drop.  Mirrors ``_pack_ell_panels`` minus the level-sort indirection
    (the masked solvers index panels by row id, not level rank)."""
    def one(d, s, v):
        eorder = jnp.argsort(d, stable=True)
        sd, ss, sv = d[eorder], s[eorder], v[eorder]
        rank = _run_ranks(sd)
        dest = sd * K + rank
        cols = jnp.zeros(n * K, jnp.int32).at[dest].set(
            ss, mode="drop").reshape(n, K)
        vals = jnp.zeros(n * K, v.dtype).at[dest].set(
            sv, mode="drop").reshape(n, K)
        return cols, vals

    return jax.vmap(one)(dst, src, val)


def _pad_dev(x, size, fill):
    return jnp.concatenate(
        [x, jnp.full((size - x.shape[0],), fill, x.dtype)]) \
        if x.shape[0] != size else x


def build_schedules_batched(
        devs: "List[DeviceFactor]", *,
        device: Optional["jax.Device"] = None,
) -> List[Tuple[PackedSchedule, PackedSchedule]]:
    """Forward/backward :class:`PackedSchedule`\\ s for a whole fleet of
    device factors in one shot: the level propagation (the
    ``while_loop`` half of ``build_schedules_device``) runs **once**,
    vmapped over a ``(2B, E_pad)`` edge batch holding every factor's
    forward and backward solve edges, and the panel packing is likewise
    one vmapped scatter.  Per-factor results are sliced back to each
    factor's own power-of-two padded shape (``n_pad = pow2(n)``,
    ``K = pow2(max in-degree)``) so a factor's padded schedule is a
    function of its content alone — independent of which fleet it was
    built with.  Forward edges: CSC entry (i ∈ col k) ⇒ dst=i, src=k;
    backward: dst=k, src=i, in original index space.

    ``device`` runs the whole derivation under that accelerator's
    default placement (factor-tier replicas schedule off the serving
    devices); outputs stay uncommitted for cheap adoption elsewhere.
    """
    if device is not None:
        with jax.default_device(device):
            return build_schedules_batched(devs)
    if not devs:
        return []
    B = len(devs)
    ns = [d.n for d in devs]
    nnzs = [d.nnz for d in devs]
    n_bat = _next_pow2(max(ns))
    E_bat = max(_next_pow2(max(nnzs)), 1)
    # all inputs are concrete device buffers (DeviceFactor's contract),
    # so everything below dispatches eagerly — deliberately NOT wrapped
    # in ensure_compile_time_eval: jax 0.4.x mis-tracks vmap-of-while
    # tracers under that context (UnexpectedTracerError).
    DST, SRC, VAL = [], [], []
    for d in devs:
        counts = jnp.diff(d.col_ptr)
        cols_of = jnp.repeat(jnp.arange(d.n, dtype=jnp.int32), counts,
                             total_repeat_length=d.nnz)
        rows = d.rows.astype(jnp.int32)
        vals = d.vals
        # forward then (later) backward rows share the padded vals
        DST.append(_pad_dev(rows, E_bat, n_bat))
        SRC.append(_pad_dev(cols_of, E_bat, 0))
        VAL.append(_pad_dev(vals, E_bat, 0))
    # second half of the batch: backward solve edges (dst=k, src=i)
    for b in range(B):
        DST.append(jnp.where(DST[b] < n_bat, SRC[b], n_bat))
        SRC.append(jnp.where(DST[b] < n_bat, DST[b], 0))
    VAL = VAL + VAL
    DSTa = jnp.stack(DST)
    SRCa = jnp.stack(SRC)
    VALa = jnp.stack(VAL)
    levels = _propagate_levels_fleet(DSTa, SRCa, n=n_bat)
    indeg = jax.vmap(
        lambda d: jnp.zeros(n_bat, jnp.int32).at[d].add(
            1, mode="drop"))(DSTa)
    K_bat = max(_next_pow2(int(indeg.max())), 1)
    COLS, VALS = _pack_row_panels_fleet(DSTa, SRCa, VALa,
                                        n=n_bat, K=K_bat)
    levels_h = np.asarray(levels)
    kmax_h = np.asarray(indeg.max(axis=1))

    out: List[Tuple[PackedSchedule, PackedSchedule]] = []
    for b in range(B):
        halves = []
        for row in (b, B + b):               # forward, then backward
            n = ns[b]
            n_pad = _next_pow2(n)
            K = max(_next_pow2(int(kmax_h[row])), 1)
            cols = jax.lax.slice(COLS[row], (0, 0), (n_pad, K))
            vals = jax.lax.slice(VALS[row], (0, 0), (n_pad, K))
            lvl = jax.lax.slice(levels[row], (0,), (n_pad,))
            halves.append(PackedSchedule(
                n=n, n_pad=n_pad,
                n_levels=int(levels_h[row, :n].max(initial=0)) + 1,
                K=K, cols=cols, vals=vals, level_of=lvl))
        out.append((halves[0], halves[1]))
    return out


def make_ell_solver(sched: DeviceSchedule, flip: bool = False):
    """jit-able unit-triangular solve over ELL panels; accepts a single
    rhs ``(n,)`` or a multi-rhs block ``(n, nrhs)`` (one fused gather-
    multiply-reduce per level for the whole block)."""
    panels = []
    with jax.ensure_compile_time_eval():
        for lv in range(1, sched.n_levels):  # level-0 rows lack in-edges
            lo, hi = int(sched.row_ptr[lv]), int(sched.row_ptr[lv + 1])
            if hi == lo:
                continue
            panels.append(
                (jax.lax.slice(sched.row_ids, (lo,), (hi,)),
                 jax.lax.slice(sched.cols, (lo, 0), (hi, sched.K)),
                 jax.lax.slice(sched.vals, (lo, 0), (hi, sched.K))))

    def solve(b: jnp.ndarray) -> jnp.ndarray:
        y = jnp.flip(b, axis=0) if flip else b
        for rows, cols, vals in panels:
            gathered = y[cols]                       # (R, K[, nrhs])
            v = vals if y.ndim == 1 else vals[:, :, None]
            contrib = jnp.sum(v * gathered, axis=1)
            y = y.at[rows].add(-contrib)             # rows touched once
        return jnp.flip(y, axis=0) if flip else y

    return solve


def make_preconditioner_from_schedules(fwd: DeviceSchedule,
                                       bwd: DeviceSchedule, D: jnp.ndarray):
    """``r -> (G D Gᵀ)⁺ r`` from pre-built device schedules (the Solver
    path: schedules are built once per factor and shared)."""
    fsolve = make_ell_solver(fwd)
    bsolve = make_ell_solver(bwd, flip=True)
    with jax.ensure_compile_time_eval():
        dinv = jnp.where(D > 0, 1.0 / jnp.where(D > 0, D, 1.0), 0.0)

    def apply(r: jnp.ndarray) -> jnp.ndarray:
        y = fsolve(r)
        z = y * (dinv if y.ndim == 1 else dinv[:, None])
        return bsolve(z)

    return apply


def make_preconditioner(f: ACFactor | DeviceFactor):
    """jit-able ``r -> (G D Gᵀ)⁺ r`` via two level-scheduled solves.

    Built from the device schedules (no numpy round-trip); supports a
    single rhs ``(n,)`` or a multi-rhs block ``(n, nrhs)``.
    """
    fwd, bwd = build_schedules_device(f)
    dev = f if isinstance(f, DeviceFactor) else f.to_device()
    return make_preconditioner_from_schedules(fwd, bwd, dev.D)


def precond_apply_np(f: ACFactor, r: np.ndarray) -> np.ndarray:
    fwd, bwd = build_schedules(f)
    y = solve_levels_np(fwd, r)
    dinv = np.where(f.D > 0, 1.0 / np.where(f.D > 0, f.D, 1.0), 0.0)
    return solve_levels_np(bwd, y * dinv, flip=True)
