from . import graphs  # noqa: F401
