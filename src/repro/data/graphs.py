"""Laptop-scale analogues of the paper's test-matrix families (Table 1).

| paper matrix                | generator here                      |
|-----------------------------|-------------------------------------|
| uniform 3D poisson          | ``grid3d(..., kind='uniform')``     |
| anisotropic 3D poisson      | ``grid3d(..., kind='aniso')``       |
| high contrast 3D poisson    | ``grid3d(..., kind='contrast')``    |
| parabolic_fem / apache2 …   | ``grid2d`` (2/5-point stencils)     |
| GAP-road / europe_osm       | ``road_like`` (sparse planar-ish)   |
| com-LiveJournal             | ``powerlaw`` (Barabási–Albert)      |
| delaunay_n24                | ``delaunay_like``                   |
| spe16m                      | ``grid3d(..., kind='contrast')``    |

All generators return a coalesced ``Graph`` with positive weights and a
deterministic seed.
"""
from __future__ import annotations

import numpy as np

from repro.core.laplacian import Graph


def grid2d(nx: int, ny: int, seed: int = 0, weighted: bool = True) -> Graph:
    rng = np.random.default_rng(seed)
    ii, jj = np.meshgrid(np.arange(nx), np.arange(ny), indexing="ij")
    vid = (ii * ny + jj).astype(np.int32)
    src = np.concatenate([vid[:-1, :].ravel(), vid[:, :-1].ravel()])
    dst = np.concatenate([vid[1:, :].ravel(), vid[:, 1:].ravel()])
    m = src.shape[0]
    w = rng.uniform(0.5, 2.0, m) if weighted else np.ones(m)
    return Graph(nx * ny, src.astype(np.int32), dst.astype(np.int32),
                 w.astype(np.float32))


def grid3d(nx: int, ny: int, nz: int, kind: str = "uniform",
           seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    ii, jj, kk = np.meshgrid(np.arange(nx), np.arange(ny), np.arange(nz),
                             indexing="ij")
    vid = (ii * ny * nz + jj * nz + kk).astype(np.int32)
    src = np.concatenate([vid[:-1, :, :].ravel(), vid[:, :-1, :].ravel(),
                          vid[:, :, :-1].ravel()])
    dst = np.concatenate([vid[1:, :, :].ravel(), vid[:, 1:, :].ravel(),
                          vid[:, :, 1:].ravel()])
    mx = vid[:-1, :, :].size
    my = vid[:, :-1, :].size
    m = src.shape[0]
    if kind == "uniform":
        w = np.ones(m)
    elif kind == "aniso":
        w = np.concatenate([np.full(mx, 100.0), np.full(my, 1.0),
                            np.full(m - mx - my, 0.01)])
    elif kind == "contrast":
        # high-contrast random coefficient field: log-uniform cellwise
        w = 10.0 ** rng.uniform(-3, 3, m)
    else:
        raise ValueError(kind)
    return Graph(nx * ny * nz, src.astype(np.int32), dst.astype(np.int32),
                 w.astype(np.float32))


def powerlaw(n: int, m_attach: int = 8, seed: int = 0) -> Graph:
    """Barabási–Albert preferential attachment (com-LiveJournal analogue:
    high density, hub vertices — the paper's hardest parallelism case)."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_attach))
    repeated: list = list(range(m_attach))
    src, dst = [], []
    for v in range(m_attach, n):
        ts = rng.choice(repeated, size=m_attach, replace=False) \
            if len(repeated) >= m_attach else targets
        for t in set(int(t) for t in ts):
            src.append(min(v, t))
            dst.append(max(v, t))
            repeated.append(t)
            repeated.append(v)
    w = rng.uniform(0.5, 2.0, len(src))
    return Graph(n, np.array(src, np.int32), np.array(dst, np.int32),
                 w.astype(np.float32)).coalesce()


def road_like(n_side: int, extra_frac: float = 0.1, seed: int = 0) -> Graph:
    """Sparse near-planar graph (road-network analogue): 2D grid with a
    fraction of random diagonal shortcuts and strong weight variation."""
    rng = np.random.default_rng(seed)
    g = grid2d(n_side, n_side, seed=seed)
    n_extra = int(extra_frac * g.m)
    i = rng.integers(0, n_side - 1, n_extra)
    j = rng.integers(0, n_side - 1, n_extra)
    s = (i * n_side + j).astype(np.int32)
    d = ((i + 1) * n_side + (j + 1)).astype(np.int32)
    src = np.concatenate([g.src, np.minimum(s, d)])
    dst = np.concatenate([g.dst, np.maximum(s, d)])
    w = np.concatenate([g.w, rng.uniform(0.1, 10.0, n_extra).astype(np.float32)])
    return Graph(g.n, src, dst, w).coalesce()


def delaunay_like(n: int, seed: int = 0) -> Graph:
    """Delaunay triangulation of random points (delaunay_n24 analogue)."""
    from scipy.spatial import Delaunay
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1, (n, 2))
    tri = Delaunay(pts)
    e = np.concatenate([tri.simplices[:, [0, 1]], tri.simplices[:, [1, 2]],
                        tri.simplices[:, [0, 2]]])
    lo = e.min(axis=1).astype(np.int32)
    hi = e.max(axis=1).astype(np.int32)
    w = rng.uniform(0.5, 2.0, lo.shape[0]).astype(np.float32)
    return Graph(n, lo, hi, w).coalesce()


def random_regular(n: int, d: int = 4, seed: int = 0) -> Graph:
    """Random d-regular expander (well-conditioned sanity case)."""
    import networkx as nx
    G = nx.random_regular_graph(d, n, seed=seed)
    e = np.array(G.edges(), np.int32)
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.5, 2.0, e.shape[0]).astype(np.float32)
    return Graph(n, e.min(axis=1).astype(np.int32),
                 e.max(axis=1).astype(np.int32), w).coalesce()


SUITE_MICRO = {
    # sub-100-vertex graphs (two shape buckets) for tests and benchmarks
    # that measure orchestration — routing, scheduling — not solve scale
    "grid2d_micro": lambda: grid2d(6, 6, seed=3),
    "powerlaw_micro": lambda: powerlaw(80, 4, seed=3),
    "road_micro": lambda: road_like(6, seed=4),
}

SUITE_TINY = {
    # sub-second graphs for CI smoke jobs and service traces
    "grid2d_tiny": lambda: grid2d(12, 12, seed=3),
    "powerlaw_tiny": lambda: powerlaw(300, 5, seed=3),
    "road_tiny": lambda: road_like(10, seed=4),
}

SUITE = {
    "grid2d_64": lambda: grid2d(64, 64, seed=1),
    "grid3d_uniform_16": lambda: grid3d(16, 16, 16, "uniform", seed=2),
    "grid3d_aniso_16": lambda: grid3d(16, 16, 16, "aniso", seed=3),
    "grid3d_contrast_16": lambda: grid3d(16, 16, 16, "contrast", seed=4),
    "road_64": lambda: road_like(64, seed=5),
    "powerlaw_4k": lambda: powerlaw(4096, 8, seed=6),
    "delaunay_4k": lambda: delaunay_like(4096, seed=7),
    "regular_4k": lambda: random_regular(4096, 4, seed=8),
}

SUITE_LARGE = {
    "grid2d_256": lambda: grid2d(256, 256, seed=11),
    "grid3d_uniform_32": lambda: grid3d(32, 32, 32, "uniform", seed=12),
    "grid3d_contrast_32": lambda: grid3d(32, 32, 32, "contrast", seed=13),
    "road_256": lambda: road_like(256, seed=14),
    "powerlaw_50k": lambda: powerlaw(50_000, 8, seed=15),
    "delaunay_50k": lambda: delaunay_like(50_000, seed=16),
}
