"""Deterministic synthetic token pipeline.

Design goals (the ones that matter at 1000+ nodes):

* **stateless addressing** — batch ``i`` is a pure function of
  (seed, step), so any host can (re)produce its shard after restart or
  elastic resharding without replaying the stream;
* **per-host sharding** — each host materialises only its slice of the
  global batch (``host_slice``), matching ``jax.make_array_from_callback``;
* **prefetch** — a small background thread keeps ``depth`` batches ready.

The generator is a mixture of Zipf-distributed unigrams and short
repeated motifs, which gives a non-degenerate loss curve for the
examples (quickstart trains ~100M params on it).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional, Tuple

import numpy as np


class SyntheticTokens:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, zipf_a: float = 1.3,
                 motif_len: int = 16, n_motifs: int = 512):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.zipf_a = zipf_a
        rng = np.random.default_rng(seed)
        self.motifs = rng.integers(0, vocab, (n_motifs, motif_len),
                                   dtype=np.int32)

    def batch_at(self, step: int, lo: int = 0,
                 hi: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Rows [lo, hi) of the global batch for ``step`` — pure function."""
        hi = self.global_batch if hi is None else hi
        rows = []
        for r in range(lo, hi):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, r]))
            seq = rng.integers(
                1, self.vocab,
                self.seq_len + 1).astype(np.int32)
            # overlay zipf-heavy tokens
            z = rng.zipf(self.zipf_a, self.seq_len + 1).astype(np.int64)
            seq = np.where(z < self.vocab, z.astype(np.int32), seq)
            # paste motifs (so the model has something learnable)
            for _ in range(4):
                m = self.motifs[rng.integers(0, len(self.motifs))]
                p = rng.integers(0, self.seq_len + 1 - m.size)
                seq[p:p + m.size] = m
            rows.append(seq)
        arr = np.stack(rows)
        return arr[:, :-1], arr[:, 1:]

    def prefetch(self, start_step: int, depth: int = 2,
                 lo: int = 0, hi: Optional[int] = None) -> Iterator:
        """Background-thread prefetching iterator from ``start_step``."""
        q: queue.Queue = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def worker():
            s = start_step
            while not stop.is_set():
                q.put((s, self.batch_at(s, lo, hi)))
                s += 1

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
