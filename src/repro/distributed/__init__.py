from .steps import (make_train_step, make_prefill, make_decode_step,  # noqa: F401
                    train_state_specs, batch_axes_for, cache_pspecs)
