"""Activation-sharding context.

Model code is mesh-agnostic; the step builders install the active mesh
here and layers pin their big intermediates with ``constrain(x, ...)``
(logical axis names, same vocabulary as the param rules).  Without a
mesh installed (unit tests, examples on one device) ``constrain`` is the
identity.  Axes whose dimension does not divide the mesh extent are
silently dropped (e.g. batch=1 long-decode replicates batch).
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Any, Dict, Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE: Dict[str, Any] = {"mesh": None, "rules": None, "batch_axes": None}


def install(mesh, rules: Dict[str, Any], batch_axes: Sequence[str]):
    _STATE.update(mesh=mesh, rules=dict(rules), batch_axes=tuple(batch_axes))


def clear():
    _STATE.update(mesh=None, rules=None, batch_axes=None)


@contextmanager
def use(mesh, rules, batch_axes):
    old = dict(_STATE)
    install(mesh, rules, batch_axes)
    try:
        yield
    finally:
        _STATE.update(old)


def constrain(x, *axes: Optional[str]):
    """axes: one logical name (or None) per dim of x; 'batch' maps to the
    installed batch mesh axes."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    rules = _STATE["rules"]
    parts = []
    used = set()
    for i, a in enumerate(axes):
        if a is None:
            parts.append(None)
            continue
        m = _STATE["batch_axes"] if a == "batch" else rules.get(a)
        if m is None or m == ():
            parts.append(None)
            continue
        names = tuple(n for n in ((m,) if isinstance(m, str) else tuple(m))
                      if n not in used)
        size = math.prod(mesh.shape[n] for n in names)
        if not names or size <= 1 or x.shape[i] % size != 0:
            parts.append(None)
        else:
            used.update(names)
            parts.append(names[0] if len(names) == 1 else names)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))
