"""Distributed train / prefill / decode step builders.

Sharding summary (baseline layout; EXPERIMENTS.md §Perf iterates on it):

  params      TP on ``model`` (heads / mlp / experts / vocab) and
              FSDP on ``data`` (the ``embed`` logical axis) — ZeRO-3-style;
              XLA SPMD inserts the weight all-gathers at use sites.
  activations batch -> ("pod", "data"); features unsharded between ops
              (XLA propagates TP shardings through the layer body).
  kv caches   batch -> ("pod","data") when divisible, kv_seq -> "model"
              (+ any batch-unused data axes) — the flash-decoding layout.
  opt state   same tree/specs as params (fully sharded).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import transformer as tf
from repro.models.common import (abstract_params, init_params, param_pspecs,
                                 rules_for_mesh)
from repro.models.config import ModelConfig
from repro.optim import adamw_init, adamw_update, OptState
from repro.configs.shapes import ShapeCell
from repro.distributed import ctx


# ---------------------------------------------------------------------------
# batch / cache sharding helpers
# ---------------------------------------------------------------------------

def batch_axes_for(mesh, batch: int) -> Tuple[str, ...]:
    """Greedy assignment of (pod, data) mesh axes to the batch dim."""
    axes = []
    rem = batch
    for a in ("pod", "data"):
        if a in mesh.axis_names and rem % mesh.shape[a] == 0:
            axes.append(a)
            rem //= mesh.shape[a]
    return tuple(axes)


def kv_seq_axes(mesh, batch: int):
    baxes = batch_axes_for(mesh, batch)
    return ["model"] + [a for a in ("pod", "data")
                        if a in mesh.axis_names and a not in baxes]


def cache_pspecs(cfg: ModelConfig, mesh, batch: int, seq_len: int):
    """PartitionSpecs for decode-cache pytrees."""
    baxes = batch_axes_for(mesh, batch)
    b = tuple(baxes) or None
    seq_axes = kv_seq_axes(mesh, batch)

    def seq_spec(length: int):
        axes = []
        rem_axes = list(seq_axes)
        size = 1
        for a in rem_axes:
            if length % (size * mesh.shape[a]) == 0:
                axes.append(a)
                size *= mesh.shape[a]
        return tuple(axes) or None

    def one(kind: str):
        if kind in ("attn", "local"):
            L = min(seq_len, cfg.local_window) if (
                kind == "local" and cfg.local_window) else seq_len
            kv = {"k": P(b, seq_spec(L), None, None),
                  "v": P(b, seq_spec(L), None, None)}
            return kv
        if kind == "ssm":
            return {"h": P(b, "model", None, None),
                    "conv": {"x": P(b, None, "model"),
                             "B": P(b, None, None),
                             "C": P(b, None, None)}}
        if kind == "rglru":
            return {"h": P(b, "model"), "conv": P(b, None, "model")}
        raise ValueError(kind)

    n_periods, rem = tf._split_layers(cfg)   # honors force_unroll/enc-dec
    specs: Dict[str, Any] = {}
    if n_periods:
        specs["scan"] = {}
        for t, kind in enumerate(cfg.pattern):
            one_spec = one(kind)
            specs["scan"][f"pos{t}"] = jax.tree.map(
                lambda s: P(None, *s), one_spec,
                is_leaf=lambda x: isinstance(x, P))
    specs["rem"] = [one(cfg.layer_kinds[n_periods * len(cfg.pattern) + t])
                    for t in range(rem)]
    return specs


def _data_pspec(mesh, batch: int, extra_dims: int = 1):
    b = batch_axes_for(mesh, batch)
    return P(b or None, *([None] * extra_dims))


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def train_state_specs(cfg: ModelConfig, mesh, fsdp: bool = True):
    rules = rules_for_mesh(mesh)
    if not fsdp:
        rules["embed"] = None          # replicate weights over "data"
    pspecs = param_pspecs(tf.pdefs(cfg), rules, mesh)
    opt_specs = OptState(mu=pspecs, nu=pspecs, count=P())
    return pspecs, opt_specs


def make_train_step(cfg: ModelConfig, mesh, cell: ShapeCell, *,
                    lr: float = 3e-4, grad_accum: int = 8,
                    fsdp: bool = True, moe_weight_gather: bool = False,
                    donate: bool = True):
    """Returns (step_fn, in_shardings, out_shardings) ready for jax.jit.

    ``grad_accum`` splits the global batch into sequential microbatches
    with fp32 (sharded) gradient accumulation — the standard trick that
    brings per-device activation footprint down to HBM size at global
    batch 256 × 4k while keeping the optimizer math identical.
    """
    pspecs, opt_specs = train_state_specs(cfg, mesh, fsdp=fsdp)
    tok_spec = _data_pspec(mesh, cell.global_batch)
    b_axes = batch_axes_for(mesh, cell.global_batch)
    rules = rules_for_mesh(mesh)
    if not fsdp:
        rules["embed"] = None
    if moe_weight_gather:
        # keep MoE token buffers batch-sharded only; the expert GEMM then
        # all-gathers expert *weights* over `model` instead of
        # all-reducing token buffers (EXPERIMENTS.md §Perf cell B)
        rules["experts"] = None
    A = grad_accum
    while cell.global_batch % A or (cell.global_batch // A) % max(
            1, __import__("math").prod(mesh.shape[a] for a in b_axes)):
        A -= 1   # largest accum factor keeping microbatches shardable
    mb = cell.global_batch // A

    def step(params, opt, tokens, targets, enc_frames=None):
        with ctx.use(mesh, rules, b_axes):
            def lf(p, tok, tgt, enc):
                return tf.loss_fn(p, cfg, tok, tgt, enc)

            def micro(carry, xs):
                g_acc, l_acc, ce_acc, aux_acc = carry
                tok, tgt = xs[0], xs[1]
                enc = xs[2] if enc_frames is not None else None
                (loss, (cel, aux)), g = jax.value_and_grad(
                    lf, has_aux=True)(params, tok, tgt, enc)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss, ce_acc + cel,
                        aux_acc + aux), ()

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            z = jnp.zeros((), jnp.float32)
            xs = (tokens.reshape(A, mb, -1), targets.reshape(A, mb, -1))
            if enc_frames is not None:
                xs = xs + (enc_frames.reshape((A, mb) + enc_frames.shape[1:]),)
            (grads, loss, cel, aux), _ = jax.lax.scan(
                micro, (g0, z, z, z), xs)
            grads = jax.tree.map(lambda g: g / A, grads)
            params2, opt2, gnorm = adamw_update(grads, opt, params, lr=lr)
        metrics = {"loss": loss / A, "ce": cel / A, "aux": aux / A,
                   "gnorm": gnorm}
        return params2, opt2, metrics

    ns = lambda s: NamedSharding(mesh, s)
    in_sh = (jax.tree.map(ns, pspecs),
             jax.tree.map(ns, opt_specs),
             ns(tok_spec), ns(tok_spec))
    if cfg.is_encoder_decoder:
        in_sh = in_sh + (ns(P(b_axes or None, None, None)),)
    out_sh = (jax.tree.map(ns, pspecs), jax.tree.map(ns, opt_specs),
              {k: ns(P()) for k in ("loss", "ce", "aux", "gnorm")})
    return step, in_sh, out_sh


def make_prefill(cfg: ModelConfig, mesh, cell: ShapeCell):
    pspecs, _ = train_state_specs(cfg, mesh)
    tok_spec = _data_pspec(mesh, cell.global_batch)
    cspecs = cache_pspecs(cfg, mesh, cell.global_batch, cell.seq_len)
    ns = lambda s: NamedSharding(mesh, s)

    rules = rules_for_mesh(mesh)
    b_axes = batch_axes_for(mesh, cell.global_batch)

    def fn(params, tokens, enc_frames=None):
        with ctx.use(mesh, rules, b_axes):
            logits, caches = tf.prefill(params, cfg, tokens, cell.seq_len,
                                        enc_frames=enc_frames)
        return logits, caches

    in_sh = (jax.tree.map(ns, pspecs), ns(tok_spec))
    if cfg.is_encoder_decoder:
        in_sh = in_sh + (ns(P(b_axes or None, None, None)),)
    out_sh = (ns(_data_pspec(mesh, cell.global_batch, 2)),
              jax.tree.map(ns, cspecs, is_leaf=lambda x: isinstance(x, P)))
    return fn, in_sh, out_sh


def make_decode_step(cfg: ModelConfig, mesh, cell: ShapeCell, *,
                     feature_shard=None, fsdp: bool = True):
    pspecs, _ = train_state_specs(cfg, mesh, fsdp=fsdp)
    cspecs = cache_pspecs(cfg, mesh, cell.global_batch, cell.seq_len)
    tok_spec = _data_pspec(mesh, cell.global_batch)
    b_axes = batch_axes_for(mesh, cell.global_batch)
    ns = lambda s: NamedSharding(mesh, s)

    rules = rules_for_mesh(mesh)
    rules["kv_seq"] = tuple(kv_seq_axes(mesh, cell.global_batch))
    if feature_shard is None:
        # auto: single-stream decode leaves "data" idle for batch — use it
        # for activation features (adopted in §Perf cell A: 3.1× memory)
        feature_shard = "data" not in batch_axes_for(mesh, cell.global_batch)
    if feature_shard:
        # single-stream decode: batch can't use "data" — shard activation
        # features on it instead (2D TP; weights stay 2D-sharded)
        rules["act_embed"] = "data"

    def fn(params, caches, tokens, cache_pos, enc_out=None):
        with ctx.use(mesh, rules, b_axes):
            logits, new_caches = tf.decode_step(params, cfg, caches, tokens,
                                                cache_pos, enc_out=enc_out)
        return logits, new_caches

    cache_sh = jax.tree.map(ns, cspecs, is_leaf=lambda x: isinstance(x, P))
    in_sh = (jax.tree.map(ns, pspecs), cache_sh, ns(tok_spec), ns(P()))
    if cfg.is_encoder_decoder:
        in_sh = in_sh + (ns(P(b_axes or None, None, None)),)
    out_sh = (ns(P(b_axes or None, "model")), cache_sh)
    return fn, in_sh, out_sh


def make_abstract_inputs(cfg: ModelConfig, mesh, cell: ShapeCell,
                         dtype=jnp.bfloat16):
    """Abstract (params, opt, inputs) for .lower() — no allocation."""
    params = abstract_params(tf.pdefs(cfg), dtype)
    if cell.kind == "train":
        opt = OptState(
            mu=jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape,
                                                           jnp.float32),
                            params),
            nu=jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape,
                                                           jnp.float32),
                            params),
            count=jax.ShapeDtypeStruct((), jnp.int32))
        return params, opt
    if cell.kind == "decode":
        caches = jax.eval_shape(
            lambda: tf.init_caches(cfg, cell.global_batch, cell.seq_len,
                                   dtype))
        return params, caches
    return (params,)
