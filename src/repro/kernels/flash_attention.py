"""Pallas flash attention (forward) — the fusion that closes the
S²-logits memory gap quantified in EXPERIMENTS.md §Perf cell B: the
(S×S) score tile never leaves VMEM, so HBM traffic drops from
O(S²) to O(S·d) per head.

Blocked online-softmax (Dao et al.): grid over (batch·heads, q-tiles);
the kernel keeps a q tile plus running (max, denom, acc) registers and
loops over KV tiles with `jax.lax.fori_loop`.  Causal masking skips
fully-masked KV tiles via the loop bound.

Interpret-mode validated against the pure-jnp oracle
(`ref.flash_attention_ref`); on TPU hardware the same call lowers with
MXU dots and VMEM tiling.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_k, causal,
            q_tile):
    q = q_ref[...]                      # (Tq, d)
    Tq, d = q.shape
    S = k_ref.shape[0]
    qi = pl.program_id(1)
    q0 = qi * q_tile                    # global row offset of this q tile

    nblocks = S // block_k
    if causal:
        # last KV tile that intersects the causal triangle
        nblocks = jnp.minimum(nblocks,
                              (q0 + Tq + block_k - 1) // block_k)

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (pl.dslice(kb * block_k, block_k),
                            slice(None)))          # (Tk, d)
        v = pl.load(v_ref, (pl.dslice(kb * block_k, block_k),
                            slice(None)))
        s = jax.lax.dot_general(
            q.astype(jnp.float32) * scale, k.astype(jnp.float32),
            (((1,), (1,)), ((), ())))              # (Tq, Tk)
        if causal:
            rows = q0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((Tq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Tq,), jnp.float32)
    a0 = jnp.zeros((Tq, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nblocks, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, q_tile: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q,k,v: [B, H, S, d] (same S for q and kv).  Returns [B, H, S, d].

    S must divide by q_tile and block_k (pad outside if needed)."""
    B, H, S, d = q.shape
    assert S % q_tile == 0 and S % block_k == 0, (S, q_tile, block_k)
    scale = 1.0 / math.sqrt(d)
    qf = q.reshape(B * H, S, d)
    kf = k.reshape(B * H, S, d)
    vf = v.reshape(B * H, S, d)
    grid = (B * H, S // q_tile)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=block_k,
                          causal=causal, q_tile=q_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, q_tile, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, S, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, S, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_tile, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, d)
