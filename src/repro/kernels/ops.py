"""Jit'd public wrappers around the Pallas kernels: padding, layout
conversion, and level-scheduled triangular solve built on the SpMV
kernel.  ``interpret=None`` everywhere: the mode is resolved per
process by :mod:`repro.kernels.runtime` (``REPRO_PALLAS_INTERPRET``
env override, else interpret on CPU and native on GPU/TPU backends).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .sample_clique import sample_clique_pallas, INVALID_ID
from .runtime import resolve_interpret
from .spmv import (ell_spmv_pallas, ell_spmv_multi_pallas,
                   ell_spmv_fleet_pallas)
from . import ref as kref


def _next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


@partial(jax.jit, static_argnames=("interpret", "block_rows"))
def sample_clique(ids, ws, fill, u, *, interpret: Optional[bool] = None,
                  block_rows: int = 8):
    """Batched vertex elimination.  ids/ws/u: [R, W]; fill: [R].
    Pads W to a power of two and dispatches to the Pallas kernel."""
    interpret = resolve_interpret(interpret)
    R, W = ids.shape
    W2 = max(_next_pow2(W), 2)
    if W2 != W:
        pad = ((0, 0), (0, W2 - W))
        ids = jnp.pad(ids, pad, constant_values=INVALID_ID)
        ws = jnp.pad(ws, pad)
        u = jnp.pad(u, pad, constant_values=0.5)
    return sample_clique_pallas(ids, ws, fill, u, block_rows=block_rows,
                                interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def ell_spmv(cols, vals, x, *, interpret: Optional[bool] = None):
    return ell_spmv_pallas(cols, vals, x, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def ell_spmv_multi(cols, vals, x, *, interpret: Optional[bool] = None):
    """Multi-rhs ELL SpMV; x: [n, B] → y: [R, B]."""
    return ell_spmv_multi_pallas(cols, vals, x, interpret=interpret)


def graph_to_ell(src: np.ndarray, dst: np.ndarray, w: np.ndarray,
                 n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Laplacian rows in ELL layout (diagonal + negated off-diagonals)."""
    deg = np.zeros(n, np.int64)
    np.add.at(deg, src, 1)
    np.add.at(deg, dst, 1)
    K = int(deg.max()) + 1                       # +1 for the diagonal
    cols = np.zeros((n, K), np.int32)
    vals = np.zeros((n, K), np.float32)
    fill = np.ones(n, np.int64)                  # slot 0 = diagonal
    cols[:, 0] = np.arange(n)
    for s, d, ww in zip(src, dst, w):
        vals[s, 0] += ww
        vals[d, 0] += ww
        cols[s, fill[s]] = d
        vals[s, fill[s]] = -ww
        fill[s] += 1
        cols[d, fill[d]] = s
        vals[d, fill[d]] = -ww
        fill[d] += 1
    return cols, vals


def schedule_to_ell(sched) -> Tuple[np.ndarray, ...]:
    """Pad a trisolve LevelSchedule into per-level ELL rows.

    Returns (row_ids, cols, vals, level_ptr) with rows grouped by level;
    each row padded to the level's max in-degree.  Vectorized: per-level
    packing is a stable sort + rank scatter, no per-edge Python loop.
    """
    rows_all, cols_all, vals_all, ptr = [], [], [], [0]
    for lv in range(sched.n_levels):
        lo, hi = int(sched.level_ptr[lv]), int(sched.level_ptr[lv + 1])
        if hi == lo:
            ptr.append(ptr[-1])
            continue
        dst = sched.e_dst[lo:hi]
        uniq, inv = np.unique(dst, return_inverse=True)
        counts = np.bincount(inv)
        K = int(counts.max())
        # rank of each edge within its dst group (edges already grouped
        # arbitrarily; stable sort by inv gives contiguous groups)
        order = np.argsort(inv, kind="stable")
        starts = np.zeros(uniq.size + 1, np.int64)
        np.cumsum(counts, out=starts[1:])
        rank = np.arange(hi - lo) - np.repeat(starts[:-1], counts)
        cols = np.zeros((uniq.size, K), np.int32)
        vals = np.zeros((uniq.size, K), np.float32)
        cols[inv[order], rank] = sched.e_src[lo:hi][order]
        vals[inv[order], rank] = sched.e_val[lo:hi][order]
        rows_all.append(uniq.astype(np.int32))
        cols_all.append(cols)
        vals_all.append(vals)
        ptr.append(ptr[-1] + uniq.size)
    return rows_all, cols_all, vals_all, np.asarray(ptr)


def trisolve_levels(level_rows, level_cols, level_vals, b, flip: bool = False,
                    interpret: Optional[bool] = None):
    """Level-scheduled unit-triangular solve driven by the SpMV kernel."""
    y = jnp.asarray(b[::-1] if flip else b)
    for rows, cols, vals in zip(level_rows, level_cols, level_vals):
        rows = jnp.asarray(rows)
        upd = y[rows] - ell_spmv(jnp.asarray(cols), jnp.asarray(vals), y,
                                 interpret=interpret)
        y = y.at[rows].set(upd)
    return y[::-1] if flip else y


@partial(jax.jit, static_argnames=("interpret",))
def ell_spmv_fleet(cols, vals, x, *, interpret: Optional[bool] = None):
    """Lane-batched ELL SpMV; cols/vals: [L, R, K], x: [L, n] → [L, R]."""
    return ell_spmv_fleet_pallas(cols, vals, x, interpret=interpret)


def trisolve_masked(cols, vals, level_of, y, *, n_levels: int,
                    interpret: Optional[bool] = None):
    """Level-masked unit-triangular solve with **traced** panel arguments.

    ``cols``/``vals`` are row-indexed ELL panels ``(n, K)`` (row ``i``'s
    in-edges live in slot ``i``, zero-padded), ``level_of`` the dependency
    level per row, ``y`` the rhs ``(n,)``.  Unlike ``trisolve_panels``,
    nothing here is a closed-over constant or host-sliced slab: the whole
    schedule rides in as arrays, and the only static is the level-loop
    bound — so one compiled program serves every factor whose padded
    shapes (and level bound) match.  Each level runs the full-row SpMV
    and commits only the rows at that level; rows above ``level_of``'s
    true maximum are never selected, so over-padding ``n_levels`` (to a
    bucket-wide bound) does not change the result.
    """
    def body(lv, y):
        contrib = ell_spmv(cols, vals, y, interpret=interpret)
        return jnp.where(level_of == lv, y - contrib, y)

    return jax.lax.fori_loop(1, n_levels, body, y)


def trisolve_fleet(cols, vals, level_of, y, *, n_levels: int,
                   interpret: Optional[bool] = None, lane_levels=None):
    """Lane-batched ``trisolve_masked``: cols/vals ``(L, n, K)``,
    ``level_of`` ``(L, n)``, ``y`` ``(L, n)`` — each lane solves against
    its own panels (gathered from a stacked factor fleet by the caller).

    ``n_levels`` is the static bucket-wide ceiling.  ``lane_levels``
    (optional, ``(L,)`` int32, traced) carries each lane's *true* level
    count: when given, the loop runs a ``while_loop`` bounded by the
    batch's live maximum instead of a ``fori_loop`` to the ceiling, so
    sweeps past every live lane's depth are never launched.  Bit-exact
    either way: a level ``lv >= lane_levels[l]`` selects no rows of lane
    ``l`` (``level_of`` never reaches it), so skipping it only removes
    no-op sweeps."""
    def sweep(lv, y):
        contrib = ell_spmv_fleet(cols, vals, y, interpret=interpret)
        return jnp.where(level_of == lv, y - contrib, y)

    if lane_levels is None:
        return jax.lax.fori_loop(1, n_levels, sweep, y)

    bound = jnp.minimum(jnp.max(lane_levels).astype(jnp.int32),
                        jnp.int32(n_levels))

    def cond(carry):
        lv, _ = carry
        return lv < bound

    def body(carry):
        lv, y = carry
        return lv + jnp.int32(1), sweep(lv, y)

    _, y = jax.lax.while_loop(cond, body, (jnp.int32(1), y))
    return y


def trisolve_panels(sched, b, flip: bool = False,
                    interpret: Optional[bool] = None):
    """Unit-triangular solve over a ``trisolve.DeviceSchedule``'s ELL
    panels, driven by the Pallas SpMV kernels — the device-built panels
    are consumed as-is (same (rows, K) tiles, no repacking).  ``b`` may
    be ``(n,)`` or ``(n, B)``; the multi-rhs kernel serves a whole block
    per level."""
    y = jnp.flip(jnp.asarray(b), axis=0) if flip else jnp.asarray(b)
    kernel = ell_spmv if y.ndim == 1 else ell_spmv_multi
    for lv in range(1, sched.n_levels):   # level-0 rows have no in-edges
        lo, hi = int(sched.row_ptr[lv]), int(sched.row_ptr[lv + 1])
        if hi == lo:
            continue
        rows = jax.lax.slice(sched.row_ids, (lo,), (hi,))
        cols = jax.lax.slice(sched.cols, (lo, 0), (hi, sched.K))
        vals = jax.lax.slice(sched.vals, (lo, 0), (hi, sched.K))
        y = y.at[rows].add(-kernel(cols, vals, y, interpret=interpret))
    return jnp.flip(y, axis=0) if flip else y
