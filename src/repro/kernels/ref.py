"""Pure-jnp oracles for every kernel (the allclose/bit-exact baselines).

``sample_clique_ref`` IS the shared column math used by both the
sequential oracle and the wavefront engine — the kernel must match it
bit-for-bit (same Hillis-Steele bracketing by construction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.column_math import eliminate_column


def sample_clique_ref(ids, ws, fill, u):
    """Vectorized reference over rows.  Same outputs as the kernel."""
    W = ids.shape[1]
    valid = jax.lax.broadcasted_iota(jnp.int32, ids.shape, 1) < fill[:, None]
    res = jax.vmap(eliminate_column)(ids, ws, valid, u)
    return (res.g_rows, res.g_vals, res.m[:, None], res.ell_kk[:, None],
            res.e_lo, res.e_hi, res.e_w, res.e_valid)


def ell_spmv_ref(cols, vals, x):
    return jnp.sum(vals * x[cols], axis=1)


def trisolve_level_ref(cols, vals, b_rows, y):
    """One level of the unit-lower solve: y_rows = b_rows − Σ v·y[col]."""
    return b_rows - jnp.sum(vals * y[cols], axis=1)


def flash_attention_ref(q, k, v, causal: bool = True):
    """Plain softmax attention oracle.  q,k,v: [B,H,S,d]."""
    import math
    S = q.shape[2]
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
