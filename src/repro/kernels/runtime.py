"""Kernel runtime policy: one place that decides how Pallas kernels
lower (interpret vs native) and how panel widths pad.

Every kernel entry point used to hard-code ``interpret: bool = True`` —
correct for the CPU container the repo grew up in, but it meant a real
GPU/TPU run silently interpreted every kernel unless each call site was
patched.  The resolver inverts that: call sites default ``interpret=None``
and the leaves ask :func:`resolve_interpret`, which honours (in order)

1. an explicit ``interpret=`` argument (tests pin behaviour this way),
2. the ``REPRO_PALLAS_INTERPRET`` environment variable
   (``1/true/yes/on`` force interpret, ``0/false/no/off`` force native),
3. the backend: native on real accelerators (``gpu``/``tpu``/``cuda``/
   ``rocm``), interpret on CPU.

``pad_k`` is the companion policy for ELL panel widths: on interpret/CPU
runs the historical power-of-two rounding is kept (cheap, and what every
existing schedule builder produced); when lowering natively the width is
rounded up to a lane-friendly multiple (``REPRO_PALLAS_LANE``, default
128 — the TPU lane count and a warp-coalescing-friendly GPU stride) so
``(Rb, K)`` value/index tiles land on (8, 128)-aligned shapes.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

_TRUTHY = frozenset({"1", "true", "yes", "on"})
_FALSY = frozenset({"0", "false", "no", "off"})

# resolved lazily and cached: jax.default_backend() initializes the
# backend, which we don't want at import time
_cached_default: Optional[bool] = None


def _env_interpret() -> Optional[bool]:
    raw = os.environ.get("REPRO_PALLAS_INTERPRET")
    if raw is None:
        return None
    v = raw.strip().lower()
    if v in _TRUTHY:
        return True
    if v in _FALSY:
        return False
    raise ValueError(
        f"REPRO_PALLAS_INTERPRET={raw!r}: expected one of "
        f"{sorted(_TRUTHY | _FALSY)}")


def default_interpret() -> bool:
    """The process-wide interpret default (env override, else backend)."""
    global _cached_default
    if _cached_default is None:
        env = _env_interpret()
        if env is not None:
            _cached_default = env
        else:
            _cached_default = jax.default_backend() not in (
                "gpu", "tpu", "cuda", "rocm")
    return _cached_default


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Resolve an ``interpret=`` argument: explicit value wins, else the
    cached process default (env var, else backend autodetect)."""
    if interpret is not None:
        return bool(interpret)
    return default_interpret()


def refresh() -> None:
    """Drop the cached default (tests that mutate the env call this)."""
    global _cached_default
    _cached_default = None


def lane_multiple() -> int:
    """Panel-width quantum for native lowering (``REPRO_PALLAS_LANE``)."""
    return int(os.environ.get("REPRO_PALLAS_LANE", "128"))


def _next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()


def pad_k(k: int) -> int:
    """Pad an ELL panel width to the runtime's tiling policy.

    Interpret runs keep power-of-two rounding (matches every schedule
    the repo has ever built, so interpret-mode goldens are unchanged);
    native runs round up to the lane multiple so the trailing dimension
    of ``(rows, K)`` tiles is lane-aligned.
    """
    k = max(int(k), 1)
    if default_interpret():
        return _next_pow2(k)
    lane = lane_multiple()
    return max(((k + lane - 1) // lane) * lane, lane)
