"""Pallas TPU kernel for batched vertex elimination (paper Algorithm 4,
lines 14-23): merge multi-edges, sort by weight, suffix sums, and
inverse-CDF spanning-tree sampling — for a whole wavefront tile at once.

TPU adaptation of the paper's per-thread-block work:

  * CUB block sort            -> bitonic compare-exchange network on VPU
                                 lanes (jnp.roll + select; no lane gather)
  * warp prefix/suffix sums   -> Hillis-Steele shifts (identical add
                                 bracketing to core.column_math.hs_cumsum,
                                 so results are BITWISE equal to the ref)
  * per-lane binary search    -> comparison-count matrix (W×W in VMEM)
  * `sid[j]` lane gathers     -> one-hot matmuls (MXU-friendly)

Tile layout: grid over row-blocks; each block holds (Rb, W) lanes in
VMEM with W a power of two (columns padded by ops.py).  VMEM budget is
dominated by the (Rb, W, W) comparison matrices — ops.py picks Rb so the
working set stays < 8 MiB.

The kernel is validated in interpret mode against the pure-jnp oracle
(`ref.py` == core.column_math) with *exact* equality — the same
schedule-independence guarantee the wavefront engine is tested for.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INVALID_ID = jnp.iinfo(jnp.int32).max
NEG_INF = float("-inf")


def _lane_iota(shape):
    return jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1)


def _shift_right(x, k, fill):
    """x[i-k] with ``fill`` shifted in (exact no-op lanes for scans)."""
    return jnp.where(_lane_iota(x.shape) >= k, jnp.roll(x, k, axis=-1),
                     jnp.asarray(fill, x.dtype))


def _shift_left(x, k, fill):
    W = x.shape[-1]
    return jnp.where(_lane_iota(x.shape) < W - k, jnp.roll(x, -k, axis=-1),
                     jnp.asarray(fill, x.dtype))


def _hs_cumsum(x):
    """Hillis-Steele inclusive prefix sum — identical bracketing to
    core.column_math.hs_cumsum (bitwise-equal results)."""
    W = x.shape[-1]
    k = 1
    while k < W:
        x = x + _shift_right(x, k, 0.0)
        k *= 2
    return x


def _hs_suffix_sum(x):
    return jnp.flip(_hs_cumsum(jnp.flip(x, -1)), -1)


def _bitonic(keys: Tuple[jnp.ndarray, ...], payload: Tuple[jnp.ndarray, ...]):
    """Ascending bitonic sort along lanes by lexicographic ``keys``
    (lane index appended as final tiebreak -> strict total order).
    Returns (sorted_keys, sorted_payload)."""
    arrs = list(keys) + list(payload) + [_lane_iota(keys[0].shape)]
    nk = len(keys) + 0
    W = keys[0].shape[-1]
    idx = _lane_iota(keys[0].shape)

    def less(a_keys, b_keys):
        lt = jnp.zeros(a_keys[0].shape, bool)
        eq = jnp.ones(a_keys[0].shape, bool)
        for a, b in zip(a_keys, b_keys):
            lt = lt | (eq & (a < b))
            eq = eq & (a == b)
        return lt

    k = 2
    while k <= W:
        j = k // 2
        while j >= 1:
            partners = [jnp.where((idx & j) != 0, jnp.roll(a, j, -1),
                                  jnp.roll(a, -j, -1)) for a in arrs]
            self_keys = tuple(arrs[i] for i in range(nk)) + (arrs[-1],)
            part_keys = tuple(partners[i] for i in range(nk)) + (partners[-1],)
            psel = less(part_keys, self_keys)      # partner < self
            is_lo = (idx & j) == 0
            ascending = (idx & k) == 0 if k < W else jnp.ones(idx.shape, bool)
            take = jnp.where(is_lo == ascending, psel, ~psel)
            arrs = [jnp.where(take, p, a) for a, p in zip(arrs, partners)]
            j //= 2
        k *= 2
    out = arrs[:-1]
    return tuple(out[:nk]), tuple(out[nk:])


def _segmented_suffix_max(vals, seg):
    """Per-lane max over the tail of its segment (contiguous equal seg)."""
    W = vals.shape[-1]
    k = 1
    while k < W:
        nv = _shift_left(vals, k, NEG_INF)
        ns = _shift_left(seg, k, -1)
        vals = jnp.where(ns == seg, jnp.maximum(vals, nv), vals)
        k *= 2
    return vals


def _onehot_gather(values, j_idx, dtype):
    """values[r, j_idx[r, i]] via a one-hot matmul (no lane gather)."""
    W = values.shape[-1]
    oh = (j_idx[:, :, None] ==
          jax.lax.broadcasted_iota(jnp.int32, j_idx.shape + (W,),
                                   2)).astype(dtype)
    return jnp.einsum("rij,rj->ri", oh, values.astype(dtype))


def _kernel(ids_ref, ws_ref, fill_ref, u_ref,
            g_rows_ref, g_vals_ref, m_ref, ell_ref,
            e_lo_ref, e_hi_ref, e_w_ref, e_valid_ref):
    ids = ids_ref[...]
    ws = ws_ref[...]
    fill = fill_ref[...]           # (Rb, 1)
    u = u_ref[...]
    Rb, W = ids.shape
    pos = _lane_iota(ids.shape)
    valid = pos < fill
    ids = jnp.where(valid, ids, INVALID_ID)
    ws = jnp.where(valid, ws, 0.0)

    # ---- stage 1: merge multi-edges (sort by (id, w), run sums) ---------
    (ids_s, ws_s), () = _bitonic((ids, ws), ())
    prev_id = _shift_right(ids_s, 1, INVALID_ID)
    is_start = ((ids_s != prev_id) | (pos == 0)) & (ids_s != INVALID_ID)
    cs = _hs_cumsum(ws_s)
    cs_end = _segmented_suffix_max(cs, ids_s)      # cs at each run's end
    prev_cs = _shift_right(cs, 1, 0.0)
    run_sum = cs_end - prev_cs
    merged_id = jnp.where(is_start, ids_s, INVALID_ID)
    merged_w = jnp.where(is_start, run_sum, 0.0)
    m = jnp.sum(is_start, axis=-1, keepdims=True).astype(jnp.int32)
    ell = jnp.max(jnp.where(ids_s != INVALID_ID, cs, 0.0), axis=-1,
                  keepdims=True)
    safe_ell = jnp.where(ell > 0, ell, 1.0)

    # compact to the front: sort by (merged_id,)
    (g_rows,), (g_w,) = _bitonic((merged_id,), (merged_w,))
    g_vals = jnp.where(g_rows != INVALID_ID, -g_w / safe_ell, 0.0)

    # ---- stage 2: sampling sort (invalid lanes to the FRONT) -------------
    sort_w = jnp.where(g_rows != INVALID_ID, g_w,
                       jnp.asarray(NEG_INF, g_w.dtype))
    (sw, sid), (sval,) = _bitonic((sort_w, g_rows), (g_w,))
    sval = jnp.where(sid != INVALID_ID, sval, 0.0)
    S = _hs_suffix_sum(sval)
    S1 = _shift_left(S, 1, 0.0)

    # ---- stage 3: inverse-CDF sampling ------------------------------------
    first = W - m                                     # (Rb, 1)
    i_log = jnp.clip(pos - first, 0, W - 1)
    up = _onehot_gather(u, i_log, u.dtype)
    thresh = S1 - up * S1
    c = jnp.sum((S1[:, None, :] <= thresh[:, :, None]).astype(jnp.int32),
                axis=-1)
    j_idx = jnp.minimum(jnp.maximum(pos + 1, W - c), W - 1)
    e_valid = (pos >= first) & (pos < W - 1) & (m >= 2)
    # exact int gather via one-hot: f32 mantissa only covers ints < 2^24,
    # so gather the id in two 15-bit halves
    b_hi = _onehot_gather((sid >> 15).astype(jnp.float32), j_idx,
                          jnp.float32).astype(jnp.int32)
    b_lo = _onehot_gather((sid & 0x7FFF).astype(jnp.float32), j_idx,
                          jnp.float32).astype(jnp.int32)
    b = (b_hi << 15) | b_lo
    a = sid
    e_lo = jnp.where(e_valid, jnp.minimum(a, b), INVALID_ID)
    e_hi = jnp.where(e_valid, jnp.maximum(a, b), INVALID_ID)
    e_w = jnp.where(e_valid, S1 * sval / safe_ell, 0.0)

    g_rows_ref[...] = g_rows
    g_vals_ref[...] = g_vals
    m_ref[...] = m
    ell_ref[...] = ell
    e_lo_ref[...] = e_lo
    e_hi_ref[...] = e_hi
    e_w_ref[...] = e_w
    e_valid_ref[...] = e_valid


def sample_clique_pallas(ids, ws, fill, u, *, block_rows: int = 8,
                         interpret: bool = True):
    """Batched elimination.  ids/ws/u: [R, W] (W power of two),
    fill: [R] valid counts.  Returns the same tuple as the reference.
    """
    R, W = ids.shape
    assert W & (W - 1) == 0, "W must be a power of two"
    Rb = max(1, min(block_rows, R))
    while R % Rb:
        Rb -= 1
    grid = (R // Rb,)
    row_spec = pl.BlockSpec((Rb, W), lambda r: (r, 0))
    one_spec = pl.BlockSpec((Rb, 1), lambda r: (r, 0))
    out_shapes = (
        jax.ShapeDtypeStruct((R, W), jnp.int32),    # g_rows
        jax.ShapeDtypeStruct((R, W), ws.dtype),     # g_vals
        jax.ShapeDtypeStruct((R, 1), jnp.int32),    # m
        jax.ShapeDtypeStruct((R, 1), ws.dtype),     # ell
        jax.ShapeDtypeStruct((R, W), jnp.int32),    # e_lo
        jax.ShapeDtypeStruct((R, W), jnp.int32),    # e_hi
        jax.ShapeDtypeStruct((R, W), ws.dtype),     # e_w
        jax.ShapeDtypeStruct((R, W), jnp.bool_),    # e_valid
    )
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, one_spec, row_spec],
        out_specs=(row_spec, row_spec, one_spec, one_spec,
                   row_spec, row_spec, row_spec, row_spec),
        out_shape=out_shapes,
        interpret=interpret,
    )(ids, ws, fill[:, None].astype(jnp.int32), u)
