"""Pallas ELL-format SpMV — the PCG solve-phase hot loop (paper §6:
both the randomized factor application and CG's matvec are
bandwidth-bound; ELL padding makes the access pattern rectangular, the
TPU-friendly replacement for cuSPARSE's CSR vector kernels).

Layout: rows padded to a fixed ``K`` nonzeros (ELLPACK).  The dense
vector x lives wholly in VMEM (fits for n ≤ ~2M fp32 — the laptop-scale
regime; beyond that rows are bucketed into column-sliced panels, same
kernel per panel).  Each grid step processes a (Rb, K) row tile:
gather x at the tile's column indices, multiply by the tile's values,
reduce along K.

The same kernel executes the *level-scheduled triangular solve* step:
``y_level = b_level − ELL_rows_level @ y`` (ops.trisolve_levels), which
is how the paper's critical-path analysis (Fig. 4) maps onto TPU.

Validated in interpret mode; on real TPU the x-gather lowers via
dynamic-slice loops (small K) — noted in DESIGN.md §7.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]                 # (Rb, K) int32, padded with 0
    vals = vals_ref[...]                 # (Rb, K) f32, padded with 0.0
    x = x_ref[...]                       # (n,) f32 — whole vector in VMEM
    contrib = vals * x[cols]
    y_ref[...] = jnp.sum(contrib, axis=1, keepdims=True)


def ell_spmv_pallas(cols, vals, x, *, block_rows: int = 256,
                    interpret: bool = True):
    """y[i] = Σ_k vals[i,k] · x[cols[i,k]].  cols/vals: [R, K]; x: [n]."""
    R, K = cols.shape
    n = x.shape[0]
    Rb = max(1, min(block_rows, R))
    while R % Rb:
        Rb -= 1
    grid = (R // Rb,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((Rb, K), lambda r: (r, 0)),
                  pl.BlockSpec((Rb, K), lambda r: (r, 0)),
                  pl.BlockSpec((n,), lambda r: (0,))],
        out_specs=pl.BlockSpec((Rb, 1), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 1), vals.dtype),
        interpret=interpret,
    )(cols, vals, x)[:, 0]
