"""Pallas ELL-format SpMV — the PCG solve-phase hot loop (paper §6:
both the randomized factor application and CG's matvec are
bandwidth-bound; ELL padding makes the access pattern rectangular, the
TPU-friendly replacement for cuSPARSE's CSR vector kernels).

Layout: rows padded to a fixed ``K`` nonzeros (ELLPACK).  The dense
vector x lives wholly in VMEM (fits for n ≤ ~2M fp32 — the laptop-scale
regime; beyond that rows are bucketed into column-sliced panels, same
kernel per panel).  Each grid step processes a (Rb, K) row tile:
gather x at the tile's column indices, multiply by the tile's values,
reduce along K.

The same kernel executes the *level-scheduled triangular solve* step:
``y_level = b_level − ELL_rows_level @ y`` (ops.trisolve_levels), which
is how the paper's critical-path analysis (Fig. 4) maps onto TPU.

Validated in interpret mode; on real TPU the x-gather lowers via
dynamic-slice loops (small K) — noted in DESIGN.md §7.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import resolve_interpret


def _spmv_kernel(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]                 # (Rb, K) int32, padded with 0
    vals = vals_ref[...]                 # (Rb, K) f32, padded with 0.0
    x = x_ref[...]                       # (n,) f32 — whole vector in VMEM
    contrib = vals * x[cols]
    y_ref[...] = jnp.sum(contrib, axis=1, keepdims=True)


def _pick_block_rows(R: int, block_rows: int) -> int:
    """Largest divisor of R that is ≤ block_rows (grid must tile R),
    preferring sublane multiples of 8 so fp32 row tiles land on the
    (8, 128) TPU tile grid.  Power-of-two ``R`` (the fleet's bucket
    shapes) picks the same value either way; ragged ``R`` only falls
    back to a non-multiple-of-8 divisor when no aligned one exists."""
    cap = max(1, min(block_rows, R))
    aligned = cap - cap % 8
    while aligned >= 8:
        if R % aligned == 0:
            return aligned
        aligned -= 8
    Rb = cap
    while R % Rb:
        Rb -= 1
    return Rb


def ell_spmv_pallas(cols, vals, x, *, block_rows: int = 256,
                    interpret: Optional[bool] = None):
    """y[i] = Σ_k vals[i,k] · x[cols[i,k]].  cols/vals: [R, K]; x: [n]."""
    interpret = resolve_interpret(interpret)
    R, K = cols.shape
    n = x.shape[0]
    Rb = _pick_block_rows(R, block_rows)
    grid = (R // Rb,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((Rb, K), lambda r: (r, 0)),
                  pl.BlockSpec((Rb, K), lambda r: (r, 0)),
                  pl.BlockSpec((n,), lambda r: (0,))],
        out_specs=pl.BlockSpec((Rb, 1), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 1), vals.dtype),
        interpret=interpret,
    )(cols, vals, x)[:, 0]


def _spmv_fleet_kernel(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[0]                   # (Rb, K) int32 — one lane's tile
    vals = vals_ref[0]                   # (Rb, K) f32
    x = x_ref[0]                         # (n,) f32 — the lane's own vector
    contrib = vals * x[cols]
    y_ref[0, :] = jnp.sum(contrib, axis=1)


def ell_spmv_fleet_pallas(cols, vals, x, *, block_rows: int = 256,
                          interpret: Optional[bool] = None):
    """Lane-batched ELL SpMV: Y[l, i] = Σ_k vals[l,i,k] · x[l, cols[l,i,k]].

    cols/vals: [L, R, K]; x: [L, n].  Every lane carries its *own* panel
    arrays — the shape-bucket mega-batching formulation, where panels are
    gathered per lane from a stacked fleet of factors and passed as traced
    arguments (no per-factor closure constants, so one compiled program
    serves every factor in the bucket).  The grid walks (lane, row-tile);
    each step gathers the lane's x at the tile's column indices,
    multiplies by the tile's values and reduces along K — identical
    per-tile math to ``ell_spmv_pallas``, so a lane's result does not
    depend on how many lanes share the batch.
    """
    interpret = resolve_interpret(interpret)
    L, R, K = cols.shape
    n = x.shape[1]
    Rb = _pick_block_rows(R, block_rows)
    grid = (L, R // Rb)
    return pl.pallas_call(
        _spmv_fleet_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, Rb, K), lambda l, r: (l, r, 0)),
                  pl.BlockSpec((1, Rb, K), lambda l, r: (l, r, 0)),
                  pl.BlockSpec((1, n), lambda l, r: (l, 0))],
        out_specs=pl.BlockSpec((1, Rb), lambda l, r: (l, r)),
        out_shape=jax.ShapeDtypeStruct((L, R), vals.dtype),
        interpret=interpret,
    )(cols, vals, x)


def _spmv_multi_kernel(cols_ref, vals_ref, x_ref, y_ref):
    cols = cols_ref[...]                 # (Rb, K) int32, padded with 0
    vals = vals_ref[...]                 # (Rb, K) f32, padded with 0.0
    x = x_ref[...]                       # (n, B) f32 — rhs block in VMEM
    contrib = vals[:, :, None] * x[cols]         # (Rb, K, B)
    y_ref[...] = jnp.sum(contrib, axis=1)


def ell_spmv_multi_pallas(cols, vals, x, *, block_rows: int = 256,
                          interpret: Optional[bool] = None):
    """Multi-rhs ELL SpMV: Y[i, b] = Σ_k vals[i,k] · x[cols[i,k], b].

    cols/vals: [R, K]; x: [n, B].  One kernel pass serves the whole rhs
    block — the solve-phase shape of the Solver's batched PCG, where the
    factor (and its level panels) are shared across B simultaneous
    systems.  Bandwidth per row is amortized: the (Rb, K) index/value
    tiles are read once for all B columns.
    """
    interpret = resolve_interpret(interpret)
    R, K = cols.shape
    n, B = x.shape
    Rb = _pick_block_rows(R, block_rows)
    grid = (R // Rb,)
    return pl.pallas_call(
        _spmv_multi_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((Rb, K), lambda r: (r, 0)),
                  pl.BlockSpec((Rb, K), lambda r: (r, 0)),
                  pl.BlockSpec((n, B), lambda r: (0, 0))],
        out_specs=pl.BlockSpec((Rb, B), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, B), vals.dtype),
        interpret=interpret,
    )(cols, vals, x)
