"""Solve-cluster launcher: replay a seeded request trace through a
multi-replica :class:`repro.serve.SolveCluster` and report routing and
latency numbers per policy.

    PYTHONPATH=src python -m repro.launch.cluster --suite tiny \
        --replicas 2 --routing affinity --requests 48 --skew 1.2 \
        --arrival-rate 100 --replicate-above 50

The trace is the same seeded-Poisson mixed trace the single-engine
service replays (``repro.launch.serve.make_trace``), optionally
**skewed** (Zipf-like graph choice) so one hot graph dominates — the
workload where factor-affinity routing and hot-factor replication pay.
Requests are *registered* with the cluster, never pre-factored: the
replay shows the cold-placement cost on first touch, the affinity-hit
economics after, and (with ``--replicate-above``) the hot graph being
promoted onto a second replica.
"""
from __future__ import annotations

import argparse
import json
import time


def build_cluster(*, suite="tiny", replicas=2, routing="affinity",
                  slots=8, iters_per_tick=8, chunk=128, fill_slack=32,
                  policy="fifo", max_skips=None, max_queue=256,
                  overload="reject", replicate_above=None,
                  rate_window_s=1.0, replica_ttl_s=30.0,
                  precond="ac", select_epsilon=0.1, seed=0):
    """Stand up the cluster and register (not factor) the suite graphs.
    Returns ``(cluster, sizes)`` with graph ids = suite names."""
    from repro.data import graphs
    from repro.serve import SolveCluster
    from repro.launch.serve import SMALL_NAMES

    spec = graphs.SUITE_MICRO if suite == "micro" else \
        graphs.SUITE_TINY if suite == "tiny" else \
        {k: graphs.SUITE[k] for k in SMALL_NAMES}
    built = {name: make() for name, make in spec.items()}
    cluster = SolveCluster(
        replicas=replicas, routing=routing, slots=slots,
        iters_per_tick=iters_per_tick, admission=policy,
        max_skips=max_skips, max_queue=max_queue, overload=overload,
        replicate_above=replicate_above, rate_window_s=rate_window_s,
        replica_ttl_s=replica_ttl_s, precond=precond,
        select_epsilon=select_epsilon, seed=seed,
        cache_kw=dict(chunk=chunk, fill_slack=fill_slack, strict=False))
    import jax
    for i, (name, g) in enumerate(built.items()):
        cluster.register(g, jax.random.key(i), graph_id=name)
    return cluster, {name: g.n for name, g in built.items()}


def replay_trace_cluster(cluster, trace):
    """Open-loop replay: submit each request at its ``arrival_s`` (the
    router runs in the submitting thread; replica driver threads do the
    serving), wait for all futures, return the shared service metrics
    plus routing counters.  Shed requests (ClusterOverloadedError) are
    dropped and counted, exactly like the frontend's reject mode."""
    import concurrent.futures
    from repro.serve import ClusterOverloadedError
    from repro.launch.serve import trace_metrics
    futs = []
    t0 = time.perf_counter()
    for req in trace:
        now = time.perf_counter() - t0
        if req.arrival_s > now:
            time.sleep(req.arrival_s - now)
        try:
            futs.append(cluster.submit_request(req))
        except ClusterOverloadedError:
            pass                       # shed: counted in ClusterStats
    concurrent.futures.wait(futs)
    t_serve = time.perf_counter() - t0
    done = [f.result() for f in futs if f.exception() is None]
    metrics = trace_metrics(trace, done, t_serve)
    cs = cluster.stats()
    metrics["cluster"] = cs.as_dict()
    metrics["per_replica_completed"] = {
        r.index: r.frontend.completed for r in cs.per_replica}
    return metrics, done


def run_cluster(*, suite="tiny", requests=48, replicas=2,
                routing="affinity", slots=8, iters_per_tick=8,
                max_nrhs=4, chunk=128, seed=0, skew=None,
                arrival_rate=None, policy="fifo", max_skips=None,
                max_queue=256, overload="reject", replicate_above=None,
                rate_window_s=1.0, replica_ttl_s=30.0,
                precond="ac", select_epsilon=0.1, deadline_ms=None):
    """Build the cluster, replay one trace, close, return metrics."""
    from repro.launch.serve import make_trace
    cluster, sizes = build_cluster(
        suite=suite, replicas=replicas, routing=routing, slots=slots,
        iters_per_tick=iters_per_tick, chunk=chunk, policy=policy,
        max_skips=max_skips, max_queue=max_queue, overload=overload,
        replicate_above=replicate_above, rate_window_s=rate_window_s,
        replica_ttl_s=replica_ttl_s, precond=precond,
        select_epsilon=select_epsilon, seed=seed)
    gids = list(sizes)
    trace = make_trace(gids, sizes, requests, seed=seed,
                       max_nrhs=min(max_nrhs, slots),
                       arrival_rate=arrival_rate, skew=skew,
                       deadline_s=deadline_ms / 1e3 if deadline_ms
                       else None)
    try:
        metrics, done = replay_trace_cluster(cluster, trace)
    finally:
        cluster.close()
    metrics = dict(suite=suite, graphs=len(gids), replicas=replicas,
                   routing=routing, slots=slots, policy=policy,
                   precond=precond, skew=skew,
                   arrival_rate=arrival_rate, seed=seed,
                   **metrics)
    return metrics, done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="tiny",
                    choices=["micro", "tiny", "small"])
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--routing", default="affinity",
                    choices=["affinity", "p2c", "rr"],
                    help="cluster routing policy (factor affinity / "
                         "power-of-two-choices / round robin)")
    ap.add_argument("--replicate-above", type=float, default=None,
                    help="hot-factor replication threshold (req/s over "
                         "the rate window); omit to disable")
    ap.add_argument("--replica-ttl-s", type=float, default=30.0,
                    help="TTL stamped on replicated hot-factor copies "
                         "(drives demotion via cache staleness)")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--iters-per-tick", type=int, default=8)
    ap.add_argument("--max-nrhs", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skew", type=float, default=None,
                    help="Zipf-like graph-choice skew (hot-graph trace); "
                         "omit for the round-robin mixed trace")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="open-loop Poisson arrival rate (req/s)")
    ap.add_argument("--policy", default="fifo",
                    choices=["fifo", "priority", "deadline"],
                    help="per-replica admission policy")
    ap.add_argument("--max-skips", type=int, default=None)
    ap.add_argument("--max-queue", type=int, default=256)
    ap.add_argument("--overload", default="reject",
                    choices=["block", "reject"])
    ap.add_argument("--precond", default="ac",
                    choices=["ac", "ichol", "amg", "spai", "auto"],
                    help="preconditioner family requests serve under; "
                         "'auto' = adaptive per-graph selection")
    ap.add_argument("--select-epsilon", type=float, default=0.1,
                    help="exploration probability for --precond auto")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="stamp every request with this SLO budget "
                         "(the adaptive selector filters on it)")
    ap.add_argument("--json", default=None,
                    help="write metrics (incl. ClusterStats) to JSON")
    args = ap.parse_args()

    metrics, done = run_cluster(
        suite=args.suite, requests=args.requests, replicas=args.replicas,
        routing=args.routing, slots=args.slots,
        iters_per_tick=args.iters_per_tick, max_nrhs=args.max_nrhs,
        chunk=args.chunk, seed=args.seed, skew=args.skew,
        arrival_rate=args.arrival_rate, policy=args.policy,
        max_skips=args.max_skips, max_queue=args.max_queue,
        overload=args.overload, replicate_above=args.replicate_above,
        replica_ttl_s=args.replica_ttl_s, precond=args.precond,
        select_epsilon=args.select_epsilon, deadline_ms=args.deadline_ms)

    c = metrics["cluster"]
    print(f"suite={metrics['suite']} replicas={metrics['replicas']} "
          f"routing={c['policy']} policy={metrics['policy']} "
          f"precond={metrics['precond']} skew={metrics['skew']}")
    if c.get("selector"):
        sel = c["selector"]
        print(f"selector: picks={sel['picks']} "
              f"by_family={sel['picks_by_family']} "
              f"explores={sel['explores']} cold={sel['cold_picks']} "
              f"deadline_misses={sel['deadline_misses']}")
    print(f"served {metrics['completed']}/{metrics['requests']} requests "
          f"({metrics['rhs_total']} rhs, {metrics['converged']} converged) "
          f"in {metrics['serve_s']:.2f}s; shed={c['shed']}")
    print(f"routing: hit_rate={c['hit_rate']:.2f} "
          f"(hits={c['affinity_hits']} misses={c['affinity_misses']}) "
          f"replications={c['replications']} demotions={c['demotions']} "
          f"ejections={c['ejections']} hot_graphs={c['hot_graphs']}")
    print(f"e2e p50={metrics['latency_p50_s']*1e3:.0f}ms "
          f"p95={metrics['latency_p95_s']*1e3:.0f}ms  "
          f"queueing p95={metrics['queue_wait_p95_s']*1e3:.0f}ms  "
          f"per-replica completed="
          f"{metrics['per_replica_completed']}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(metrics, fh, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
