"""Solve-cluster launcher: replay a seeded request trace through a
multi-replica :class:`repro.serve.SolveCluster` and report routing and
latency numbers per policy.

    PYTHONPATH=src python -m repro.launch.cluster --suite tiny \
        --replicas 2 --routing affinity --requests 48 --skew 1.2 \
        --arrival-rate 100 --replicate-above 50

The trace is the same seeded-Poisson mixed trace the single-engine
service replays (``repro.launch.serve.make_trace``), optionally
**skewed** (Zipf-like graph choice) so one hot graph dominates — the
workload where factor-affinity routing and hot-factor replication pay.
Requests are *registered* with the cluster, never pre-factored: the
replay shows the cold-placement cost on first touch, the affinity-hit
economics after, and (with ``--replicate-above``) the hot graph being
promoted onto a second replica.
"""
from __future__ import annotations

import argparse
import json
import time


def build_cluster(*, suite="tiny", replicas=2, routing="affinity",
                  slots=8, iters_per_tick=8, chunk=128, fill_slack=32,
                  policy="fifo", max_skips=None, max_queue=256,
                  overload="reject", replicate_above=None,
                  rate_window_s=1.0, replica_ttl_s=30.0,
                  precond="ac", select_epsilon=0.1, seed=0,
                  factor_replicas=0, devices=None,
                  metrics=None, tracer=None, detector=None,
                  flight=None, health=None):
    """Stand up the cluster and register (not factor) the suite graphs.
    Returns ``(cluster, sizes)`` with graph ids = suite names."""
    from repro.data import graphs
    from repro.serve import SolveCluster
    from repro.launch.serve import SMALL_NAMES

    spec = graphs.SUITE_MICRO if suite == "micro" else \
        graphs.SUITE_TINY if suite == "tiny" else \
        {k: graphs.SUITE[k] for k in SMALL_NAMES}
    built = {name: make() for name, make in spec.items()}
    cluster = SolveCluster(
        replicas=replicas, routing=routing, slots=slots,
        iters_per_tick=iters_per_tick, admission=policy,
        max_skips=max_skips, max_queue=max_queue, overload=overload,
        replicate_above=replicate_above, rate_window_s=rate_window_s,
        replica_ttl_s=replica_ttl_s, precond=precond,
        select_epsilon=select_epsilon, seed=seed,
        factor_replicas=factor_replicas, devices=devices,
        metrics=metrics, tracer=tracer, detector=detector,
        flight=flight, health=health,
        cache_kw=dict(chunk=chunk, fill_slack=fill_slack, strict=False))
    import jax
    for i, (name, g) in enumerate(built.items()):
        cluster.register(g, jax.random.key(i), graph_id=name)
    return cluster, {name: g.n for name, g in built.items()}


def replay_trace_cluster(cluster, trace):
    """Open-loop replay: submit each request at its ``arrival_s`` (the
    router runs in the submitting thread; replica driver threads do the
    serving), wait for all futures, return the shared service metrics
    plus routing counters.  Shed requests (ClusterOverloadedError) are
    dropped and counted, exactly like the frontend's reject mode."""
    import concurrent.futures
    from repro.serve import ClusterOverloadedError
    from repro.launch.serve import trace_metrics
    futs = []
    t0 = time.perf_counter()
    for req in trace:
        now = time.perf_counter() - t0
        if req.arrival_s > now:
            time.sleep(req.arrival_s - now)
        try:
            futs.append(cluster.submit_request(req))
        except ClusterOverloadedError:
            pass                       # shed: counted in ClusterStats
    concurrent.futures.wait(futs)
    t_serve = time.perf_counter() - t0
    done = [f.result() for f in futs if f.exception() is None]
    metrics = trace_metrics(trace, done, t_serve)
    cs = cluster.stats()
    metrics["cluster"] = cs.as_dict()
    metrics["per_replica_completed"] = {
        r.index: r.frontend.completed for r in cs.per_replica}
    return metrics, done


def run_cluster(*, suite="tiny", requests=48, replicas=2,
                routing="affinity", slots=8, iters_per_tick=8,
                max_nrhs=4, chunk=128, seed=0, skew=None,
                arrival_rate=None, policy="fifo", max_skips=None,
                max_queue=256, overload="reject", replicate_above=None,
                rate_window_s=1.0, replica_ttl_s=30.0,
                precond="ac", select_epsilon=0.1, deadline_ms=None,
                factor_replicas=0, devices=None,
                metrics=None, tracer=None, detector=None,
                flight=None, health=None):
    """Build the cluster, replay one trace, close, return metrics."""
    from repro.launch.serve import make_trace
    cluster, sizes = build_cluster(
        suite=suite, replicas=replicas, routing=routing, slots=slots,
        iters_per_tick=iters_per_tick, chunk=chunk, policy=policy,
        max_skips=max_skips, max_queue=max_queue, overload=overload,
        replicate_above=replicate_above, rate_window_s=rate_window_s,
        replica_ttl_s=replica_ttl_s, precond=precond,
        select_epsilon=select_epsilon, seed=seed,
        factor_replicas=factor_replicas, devices=devices,
        metrics=metrics, tracer=tracer, detector=detector,
        flight=flight, health=health)
    gids = list(sizes)
    trace = make_trace(gids, sizes, requests, seed=seed,
                       max_nrhs=min(max_nrhs, slots),
                       arrival_rate=arrival_rate, skew=skew,
                       deadline_s=deadline_ms / 1e3 if deadline_ms
                       else None)
    try:
        metrics, done = replay_trace_cluster(cluster, trace)
    finally:
        cluster.close()
    metrics = dict(suite=suite, graphs=len(gids), replicas=replicas,
                   routing=routing, slots=slots, policy=policy,
                   precond=precond, skew=skew,
                   arrival_rate=arrival_rate, seed=seed,
                   factor_replicas=factor_replicas,
                   **metrics)
    return metrics, done


# -- factor storm: cold construction burst over a warm solve stream --------

def _storm_suite(k: int, seed: int):
    """``k`` cold graphs shaped like the micro suite (same pow2 shape
    buckets, fresh seeds): their adoptions reuse the warm fleet's
    already-compiled admit programs, so the disaggregated run measures
    the steady-state adopt cost, not a compile."""
    from repro.data import graphs
    makers = [lambda s: graphs.grid2d(6, 6, seed=s),
              lambda s: graphs.powerlaw(80, 4, seed=s),
              lambda s: graphs.road_like(6, seed=s)]
    return [(f"storm_{i}", makers[i % len(makers)](seed + 101 + i))
            for i in range(k)]


def run_factor_storm(*, replicas=2, factor_replicas=0, storm_graphs=4,
                     warm_dt_s=0.25, settle_s=2.0, slots=8,
                     iters_per_tick=8, chunk=128, seed=0,
                     max_queue=1024, devices=None,
                     metrics=None, tracer=None,
                     flight=None, health=None):
    """The disaggregation benchmark: a steady warm solve stream with a
    burst of cold factorizations layered on top.

    The micro suite is pre-factored and pre-solved (warm placements,
    warm compiles), then a submitter thread streams one warm solve
    every ``warm_dt_s`` while ``storm_graphs`` cold graphs are all
    submitted at once from a thread pool.  Colocated
    (``factor_replicas=0``) the constructions run on the serving
    drivers and the warm stream stalls behind them (visible in
    ``control_s``); disaggregated they queue on the factor tier and the
    drivers only pay adoptions.  The warm stream runs until the storm
    resolves (plus ``settle_s``), so it spans the storm on any machine
    speed; warm-request e2e p95 is the headline number.

    Each run carries its own :class:`~repro.obs.MetricsRegistry` (or a
    caller-supplied one — e.g. the bench's ``--prom`` dump) and a
    :class:`~repro.obs.SustainedThresholdDetector` watching the cluster
    queue gauge, so the storm doubles as the overload-detection fixture:
    the colocated burst should trip it, a quiet stream should not.  The
    detector snapshot rides back in the ``overload`` key."""
    import threading
    import concurrent.futures as cf
    import numpy as np
    import jax
    from repro.obs import MetricsRegistry, SustainedThresholdDetector
    from repro.obs.histogram import summarize
    from repro.serve import ClusterOverloadedError

    registry = metrics if metrics is not None else MetricsRegistry()
    # thresholds sized to the storm shape: the warm stream alone keeps
    # the cluster queue near zero, while a colocated burst stalls the
    # drivers and piles warm submits up well past a handful
    detector = SustainedThresholdDetector(
        registry, high_queue=3.0, low_queue=1.0,
        window_s=0.5, sustain_s=0.2, cool_s=0.5)
    cluster, sizes = build_cluster(
        suite="micro", replicas=replicas, routing="affinity",
        slots=slots, iters_per_tick=iters_per_tick, chunk=chunk,
        max_queue=max_queue, seed=seed,
        factor_replicas=factor_replicas, devices=devices,
        metrics=registry, tracer=tracer, detector=detector,
        flight=flight, health=health)
    try:
        warm_gids = list(sizes)
        rng = np.random.default_rng(seed)
        from repro.data import graphs as graphmod
        spec = graphmod.SUITE_MICRO
        # warm placements + warm compiles (factor, admit, step): the
        # storm must hit a steady-state cluster, not a cold one
        for i, (name, make) in enumerate(spec.items()):
            cluster.factor(make(), jax.random.key(i), graph_id=name)
        warm_rhs = {g: rng.standard_normal(sizes[g]).astype(np.float32)
                    for g in warm_gids}
        for g in warm_gids:
            cluster.submit(g, warm_rhs[g], tol=1e-5).result()

        storm = _storm_suite(storm_graphs, seed)
        for i, (name, g) in enumerate(storm):
            cluster.register(g, jax.random.key(1000 + i), graph_id=name)
        # rhs drawn up front: the shared Generator is not thread-safe
        # and the storm submits from a pool
        storm_rhs = {name: rng.standard_normal(g.n).astype(np.float32)
                     for name, g in storm}

        warm_futs, warm_shed = [], [0]
        stop = threading.Event()

        def warm_loop():
            i = 0
            while not stop.is_set():
                gid = warm_gids[i % len(warm_gids)]
                try:
                    warm_futs.append(
                        cluster.submit(gid, warm_rhs[gid], tol=1e-5))
                except (ClusterOverloadedError, RuntimeError):
                    warm_shed[0] += 1
                i += 1
                time.sleep(warm_dt_s)

        streamer = threading.Thread(target=warm_loop, daemon=True)
        t0 = time.perf_counter()
        streamer.start()
        # the storm: every cold graph at once (a cold submit blocks its
        # submitter on the factor future, hence the pool)
        with cf.ThreadPoolExecutor(max_workers=len(storm)) as pool:
            storm_futs = [
                pool.submit(
                    lambda name=name: cluster.submit(
                        name, storm_rhs[name], tol=1e-5).result())
                for name, g in storm]
            storm_res = [f.result() for f in storm_futs]
        storm_s = time.perf_counter() - t0
        time.sleep(settle_s)
        stop.set()
        streamer.join(timeout=10.0)
        cluster.drain(timeout=120.0)

        lat = sorted(
            max(r.finish_time - r.submit_time, 0.0)
            for r in (f.result() for f in warm_futs
                      if f.exception() is None))
        cs = cluster.stats().as_dict()
        return dict(
            factor_replicas=factor_replicas, replicas=replicas,
            storm_graphs=len(storm), storm_s=storm_s,
            storm_converged=sum(r.status == "converged"
                                for r in storm_res),
            warm_requests=len(lat), warm_shed=warm_shed[0],
            warm_dt_s=warm_dt_s, seed=seed,
            **summarize(lat, prefix="warm_", unit="s"),
            solve_control_s=sum(r["frontend"]["control_s"]
                                for r in cs["per_replica"]),
            solve_control_calls=sum(r["frontend"]["control_calls"]
                                    for r in cs["per_replica"]),
            adoptions=cs["adoptions"], factor_dedups=cs["factor_dedups"],
            overload=cs["overload"], cluster=cs,
            flight=(flight.stats() if flight is not None else None))
    finally:
        cluster.close(drain=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="tiny",
                    choices=["micro", "tiny", "small"])
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--routing", default="affinity",
                    choices=["affinity", "p2c", "rr"],
                    help="cluster routing policy (factor affinity / "
                         "power-of-two-choices / round robin)")
    ap.add_argument("--replicate-above", type=float, default=None,
                    help="hot-factor replication threshold (req/s over "
                         "the rate window); omit to disable")
    ap.add_argument("--replica-ttl-s", type=float, default=30.0,
                    help="TTL stamped on replicated hot-factor copies "
                         "(drives demotion via cache staleness)")
    ap.add_argument("--factor-replicas", type=int, default=0,
                    help="dedicated factor-tier replicas (0 = colocated "
                         "construction on the serving drivers)")
    ap.add_argument("--devices", default=None,
                    help="comma-separated device assignment for solve "
                         "then factor replicas (e.g. 'cpu:0,cpu:1' or "
                         "'0,1,2'); default round-robins jax.devices()")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--iters-per-tick", type=int, default=8)
    ap.add_argument("--max-nrhs", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skew", type=float, default=None,
                    help="Zipf-like graph-choice skew (hot-graph trace); "
                         "omit for the round-robin mixed trace")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="open-loop Poisson arrival rate (req/s)")
    ap.add_argument("--policy", default="fifo",
                    choices=["fifo", "priority", "deadline"],
                    help="per-replica admission policy")
    ap.add_argument("--max-skips", type=int, default=None)
    ap.add_argument("--max-queue", type=int, default=256)
    ap.add_argument("--overload", default="reject",
                    choices=["block", "reject"])
    ap.add_argument("--precond", default="ac",
                    choices=["ac", "ichol", "amg", "spai", "auto"],
                    help="preconditioner family requests serve under; "
                         "'auto' = adaptive per-graph selection")
    ap.add_argument("--select-epsilon", type=float, default=0.1,
                    help="exploration probability for --precond auto")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="stamp every request with this SLO budget "
                         "(the adaptive selector filters on it)")
    ap.add_argument("--json", default=None,
                    help="write metrics (incl. ClusterStats) to JSON")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve a Prometheus /metrics scrape endpoint "
                         "on this port for the run (0 = ephemeral)")
    ap.add_argument("--trace-json", default=None,
                    help="export per-request lifecycle spans as Chrome "
                         "trace_event JSON (chrome://tracing, Perfetto)")
    ap.add_argument("--postmortem-dir", default=None,
                    help="arm the flight recorder: any incident (driver "
                         "crash, replica ejection, sustained overload, "
                         "SLO-miss streak) dumps the recent event ring "
                         "plus a stats/metrics sample to JSONL here")
    args = ap.parse_args()

    from repro.obs import (MetricsRegistry, SustainedThresholdDetector,
                           Tracer, maybe_serve)
    registry = (MetricsRegistry() if args.metrics_port is not None
                else None)
    tracer = Tracer() if args.trace_json else None
    detector = (SustainedThresholdDetector(registry)
                if registry is not None else None)
    flight = health = None
    if args.postmortem_dir or registry is not None:
        from repro.obs import FlightRecorder, HealthMonitor
        flight = FlightRecorder(postmortem_dir=args.postmortem_dir,
                                slo_miss_streak=8)
        flight.attach(registry=registry)
        health = HealthMonitor(registry, flight=flight)
    server = maybe_serve(registry, args.metrics_port)
    if server is not None:
        print(f"metrics: http://localhost:{server.port}/metrics")

    try:
        metrics, done = run_cluster(
            suite=args.suite, requests=args.requests,
            replicas=args.replicas,
            routing=args.routing, slots=args.slots,
            iters_per_tick=args.iters_per_tick, max_nrhs=args.max_nrhs,
            chunk=args.chunk, seed=args.seed, skew=args.skew,
            arrival_rate=args.arrival_rate, policy=args.policy,
            max_skips=args.max_skips, max_queue=args.max_queue,
            overload=args.overload, replicate_above=args.replicate_above,
            replica_ttl_s=args.replica_ttl_s, precond=args.precond,
            select_epsilon=args.select_epsilon,
            deadline_ms=args.deadline_ms,
            factor_replicas=args.factor_replicas, devices=args.devices,
            metrics=registry, tracer=tracer, detector=detector,
            flight=flight, health=health)
    finally:
        if server is not None:
            server.close()
        if flight is not None:
            flight.flush(timeout=5.0)
            fs = flight.stats()
            if fs["dump_paths"]:
                print("post-mortem dumps: "
                      + ", ".join(fs["dump_paths"]))
    if tracer is not None and args.trace_json:
        n_ev = tracer.export_chrome(args.trace_json)
        print(f"wrote {args.trace_json} ({n_ev} trace events)")

    c = metrics["cluster"]
    print(f"suite={metrics['suite']} replicas={metrics['replicas']} "
          f"routing={c['policy']} policy={metrics['policy']} "
          f"precond={metrics['precond']} skew={metrics['skew']}")
    if c.get("selector"):
        sel = c["selector"]
        print(f"selector: picks={sel['picks']} "
              f"by_family={sel['picks_by_family']} "
              f"explores={sel['explores']} cold={sel['cold_picks']} "
              f"deadline_misses={sel['deadline_misses']}")
    print(f"served {metrics['completed']}/{metrics['requests']} requests "
          f"({metrics['rhs_total']} rhs, {metrics['converged']} converged) "
          f"in {metrics['serve_s']:.2f}s; shed={c['shed']}")
    print(f"routing: hit_rate={c['hit_rate']:.2f} "
          f"(hits={c['affinity_hits']} misses={c['affinity_misses']}) "
          f"replications={c['replications']} demotions={c['demotions']} "
          f"ejections={c['ejections']} hot_graphs={c['hot_graphs']}")
    if c.get("overload"):
        ov = c["overload"]
        print(f"overload: state={ov['state']} "
              f"rec={ov['recommendation']} "
              f"transitions={ov['transitions']} "
              f"queue_mean={ov['queue_mean']:.1f}")
    if c.get("factor_tier"):
        ft = c["factor_tier"]
        print(f"factor tier: replicas={ft['replicas']} "
              f"factored={sum(w['factored'] for w in ft['per_replica'])} "
              f"coalesced={ft['coalesced_factorizations']} "
              f"dedups={ft['dedups']} adoptions={ft['adoptions']} "
              f"failovers={ft['failovers']} "
              f"factor_s={ft['factor_s']:.1f}")
    print(f"e2e p50={metrics['latency_p50_s']*1e3:.0f}ms "
          f"p95={metrics['latency_p95_s']*1e3:.0f}ms  "
          f"queueing p95={metrics['queue_wait_p95_s']*1e3:.0f}ms  "
          f"per-replica completed="
          f"{metrics['per_replica_completed']}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(metrics, fh, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
