import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import — jax locks the
device count at first init.  This module is the ONLY place that sets it;
tests and benchmarks see the real single device.

Per cell:
  1. build the production config (padded heads/vocab) and the mesh
     (16×16 single-pod or 2×16×16 multi-pod),
  2. jit the cell's step (train_step / prefill / serve decode) with
     explicit in/out shardings, ``.lower()`` on ShapeDtypeStructs,
     ``.compile()``,
  3. record memory_analysis(), cost_analysis(), and the collective
     schedule parsed from the optimized HLO,
  4. compile two unrolled probe programs (1 and 2 pattern periods) and
     extrapolate per-layer costs (see launch/roofline.py for why).

Usage:
  python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-probes]
  python -m repro.launch.dryrun --arch qwen3-14b --all-shapes --multi-pod
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp


def _build_step(cfg, mesh, cell, probe: bool = False, variant=None):
    from repro.distributed.steps import (make_train_step, make_prefill,
                                         make_decode_step,
                                         make_abstract_inputs)
    from repro.configs.shapes import input_specs

    v = variant or {}
    specs = input_specs(cfg, cell)
    if cell.kind == "train":
        # probes lower without the microbatch scan so HloCostAnalysis sees
        # the whole step's layer work (the scan body is counted once)
        step, in_sh, out_sh = make_train_step(
            cfg, mesh, cell, grad_accum=1 if probe else
            v.get("grad_accum", 8), fsdp=v.get("fsdp", True),
            moe_weight_gather=v.get("moe_weight_gather", False))
        params, opt = make_abstract_inputs(cfg, mesh, cell)
        args = (params, opt, specs["tokens"], specs["targets"])
        if cfg.is_encoder_decoder:
            args = args + (specs["enc_frames"],)
    elif cell.kind == "prefill":
        step, in_sh, out_sh = make_prefill(cfg, mesh, cell)
        (params,) = make_abstract_inputs(cfg, mesh, cell)
        args = (params, specs["tokens"])
        if cfg.is_encoder_decoder:
            args = args + (specs["enc_frames"],)
    else:
        step, in_sh, out_sh = make_decode_step(
            cfg, mesh, cell, feature_shard=v.get("feature_shard", None),
            fsdp=v.get("fsdp", True))
        params, caches = make_abstract_inputs(cfg, mesh, cell)
        args = (params, caches, specs["tokens"], specs["cache_pos"])
        if cfg.is_encoder_decoder:
            args = args + (specs["enc_out"],)
    return step, in_sh, out_sh, args


def run_cell(arch: str, shape: str, multi_pod: bool, probes: bool = True,
             verbose: bool = True, variant=None, cfg_override=None):
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES, cell_applicable
    from repro.launch.mesh import make_production_mesh
    from repro.launch import roofline as rl

    cell = SHAPES[shape]
    cfg = get_config(arch, production=True)
    if cfg_override:
        cfg = dataclasses.replace(cfg, **cfg_override)
    ok, why = cell_applicable(cfg, cell)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "applicable": ok, "note": why}
    if not ok:
        rec["status"] = "skipped"
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 512 if multi_pod else 256

    def compile_cfg(c, tag):
        step, in_sh, out_sh, args = _build_step(
            c, mesh, cell, probe=tag.startswith("probe"), variant=variant)
        t0 = time.time()
        donate = tuple(range(2)) if cell.kind == "train" else ()
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        if verbose:
            print(f"  [{tag}] lower {t1-t0:.1f}s compile {t2-t1:.1f}s",
                  flush=True)
        return compiled, t2 - t0

    try:
        compiled, secs = compile_cfg(cfg, "full")
        ma = compiled.memory_analysis()
        rec.update(status="ok", compile_s=round(secs, 1), mem={
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_bytes": int(ma.argument_size_in_bytes
                              + ma.temp_size_in_bytes),
        })
        full_cost = rl.cost_point(compiled)
        rec["cost_full_scanbody_once"] = dataclasses.asdict(full_cost)

        if probes:
            period = len(cfg.pattern)
            p1 = dataclasses.replace(cfg, n_layers=period, force_unroll=True)
            p2 = dataclasses.replace(cfg, n_layers=2 * period,
                                     force_unroll=True)
            c1, _ = compile_cfg(p1, "probe1")
            c2, _ = compile_cfg(p2, "probe2")
            cp1, cp2 = rl.cost_point(c1), rl.cost_point(c2)
            cost = rl.extrapolate(cp1, cp2, cfg.n_layers, period)
            rec["cost"] = dataclasses.asdict(cost)
            terms = rl.roofline_terms(cost)
            mf = rl.model_flops(cfg, cell, chips)
            terms["model_flops_per_dev"] = mf
            terms["useful_fraction"] = mf / cost.flops if cost.flops else 0.0
            rec["roofline"] = terms
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--all-shapes", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-probes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    from repro.configs import list_archs
    from repro.configs.shapes import SHAPES

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.all_shapes or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
                fp = outdir / f"{tag}.json"
                if fp.exists():
                    print(f"skip (exists): {tag}", flush=True)
                    continue
                print(f"=== {tag}", flush=True)
                t0 = time.time()
                rec = run_cell(arch, shape, mp, probes=not args.skip_probes)
                rec["wall_s"] = round(time.time() - t0, 1)
                fp.write_text(json.dumps(rec, indent=1))
                print(f"  -> {rec['status']} ({rec['wall_s']}s)", flush=True)


if __name__ == "__main__":
    main()
