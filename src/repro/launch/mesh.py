"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count`` before first jax init and only
then builds meshes.
"""
from __future__ import annotations

import jax


def mesh_axis_types(n_axes: int) -> dict:
    """``axis_types=`` kwarg for ``jax.make_mesh`` when this jax exposes
    the explicit-sharding ``AxisType`` API; older builds type axes Auto
    implicitly, so the kwarg is simply omitted."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_types(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"),
                         **mesh_axis_types(2))
