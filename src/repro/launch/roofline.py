"""Roofline-term extraction from compiled dry-run artifacts.

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s
per ICI link.  Terms are computed from *per-device* HLO quantities (XLA
compiles one SPMD program per device):

    compute_s    = flops_per_device    / PEAK_FLOPS
    memory_s     = bytes_per_device    / HBM_BW
    collective_s = coll_bytes_per_dev  / LINK_BW

Methodology note (documented in EXPERIMENTS.md §Roofline): XLA's
HloCostAnalysis counts a ``while`` (lax.scan) body ONCE, not
trip_count times.  We therefore compile two *unrolled probe* programs
with 1 and 2 pattern-periods of layers and extrapolate:

    total(L) = probe1 + (L - period) / period * (probe2 - probe1)

which is exact for homogeneous periods (all ten archs).  The full-depth
program is still lowered + compiled — that is the dry-run pass/fail and
the source of memory_analysis() — only flops/bytes/collective-bytes come
from the probes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*[^=]*?\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[tok_dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in (post-SPMD) HLO text."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m or "-done(" in line:
            continue
        op = m.group(1)
        # operands live inside the outermost parens after the op name
        start = line.index("(", m.start())
        depth, end = 0, len(line)
        for i in range(start, len(line)):
            if line[i] == "(":
                depth += 1
            elif line[i] == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        args = line[start:end]
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(args))
        out[op] = out.get(op, 0) + nbytes
    return out


@dataclasses.dataclass
class CostPoint:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    coll_by_op: Dict[str, int]


def cost_point(compiled) -> CostPoint:
    ca = compiled.cost_analysis()
    text = compiled.as_text()
    coll = collective_bytes(text)
    return CostPoint(flops=float(ca.get("flops", 0.0)),
                     bytes_accessed=float(ca.get("bytes accessed", 0.0)),
                     coll_bytes=float(sum(coll.values())),
                     coll_by_op=coll)


def extrapolate(probe1: CostPoint, probe2: CostPoint, n_layers: int,
                period: int) -> CostPoint:
    k = (n_layers - period) / period

    def ex(a, b):
        return a + k * (b - a)

    ops = set(probe1.coll_by_op) | set(probe2.coll_by_op)
    coll = {o: int(ex(probe1.coll_by_op.get(o, 0),
                      probe2.coll_by_op.get(o, 0))) for o in ops}
    return CostPoint(flops=ex(probe1.flops, probe2.flops),
                     bytes_accessed=ex(probe1.bytes_accessed,
                                       probe2.bytes_accessed),
                     coll_bytes=ex(probe1.coll_bytes, probe2.coll_bytes),
                     coll_by_op=coll)


def roofline_terms(cost: CostPoint) -> Dict[str, float]:
    compute_s = cost.flops / PEAK_FLOPS
    memory_s = cost.bytes_accessed / HBM_BW
    collective_s = cost.coll_bytes / LINK_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    total = max(compute_s, memory_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": total,
        "roofline_fraction": compute_s / total if total > 0 else 0.0,
    }


def model_flops(cfg, cell, chips: int) -> float:
    """Analytic MODEL_FLOPS per device: 6·N_active·tokens (train) or
    2·N_active·tokens (inference) — the 'useful compute' yardstick."""
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens / chips
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens / chips
    tokens = cell.global_batch  # one step
    return 2.0 * n_active * tokens / chips
