"""Solve-service launcher: multi-tenant continuous-batching engine over
a generated graph suite, replaying a mixed request trace.

    PYTHONPATH=src python -m repro.launch.serve --suite tiny \
        --requests 24 --slots 8 --iters-per-tick 8 --arrival-rate 50

Spins up a :class:`FactorCache` (batched fleet factorization + batched
schedule construction), submits a seeded trace of interleaved single-
and multi-RHS requests with mixed tolerances, drains the device-resident
:class:`SolveEngine`, and reports throughput and latency percentiles —
the service-level view of the paper's factor-once / serve-many
economics.

With ``--arrival-rate R`` the trace becomes **open-loop**: request
inter-arrival gaps are seeded Poisson (exponential with mean ``1/R``
seconds) and the replay submits each request at its arrival time rather
than all at once, so the report separates *queueing delay*
(submit → lane admission) and *end-to-end* latency from pure *service*
latency (admission → finish).  Without it the replay is closed-loop
(every request arrives at t=0) and queueing delay measures head-of-line
blocking only.

``--async`` drives the same replay through the
:class:`repro.serve.SolveFrontend` — a background engine-driver thread
with futures resolved on retirement and a bounded ingress queue — and
``--policy {fifo,priority,deadline}`` selects the admission scheduler
(``--max-skips`` bounds backfill; ``--deadline-ms`` stamps a per-request
SLO budget that the deadline policy orders by and enforces via
hopeless-lane eviction):

    PYTHONPATH=src python -m repro.launch.serve --suite tiny \
        --requests 24 --arrival-rate 50 --async --policy deadline \
        --deadline-ms 2000
"""
from __future__ import annotations

import argparse
import json
import time


from repro.obs.histogram import percentile

# suite names resolved against the canonical registry in repro.data.graphs
# (no local re-definitions: one source of truth for generator params/seeds)
SMALL_NAMES = ("grid2d_64", "grid3d_uniform_16", "powerlaw_4k")


def make_trace(gids, sizes, n_requests, *, seed=0, max_nrhs=4,
               tols=(1e-4, 1e-6), arrival_rate=None, deadline_s=None,
               skew=None):
    """Seeded mixed trace: round-robin-ish graph choice, ~1/3 multi-RHS,
    alternating tolerances — deliberately interleaved so consecutive
    requests rarely share a factor.  All randomness (rhs content *and*
    Poisson arrival gaps) comes from the one seeded generator, so a
    trace is reproducible across runs and artifacts.  ``deadline_s``
    stamps every request with the same relative SLO budget (deadline
    policies order by it and evict hopeless lanes).

    ``skew`` switches graph choice from round-robin to a seeded
    Zipf-like draw (weight ∝ 1/(rank+1)^skew over ``gids`` order) — the
    hot-graph workload the cluster's factor-affinity routing and
    hot-factor replication are measured on."""
    import numpy as np
    from repro.serve import SolveRequest
    rng = np.random.default_rng(seed)
    if skew is not None:
        w = 1.0 / np.arange(1, len(gids) + 1) ** float(skew)
        picks = rng.choice(len(gids), size=n_requests, p=w / w.sum())
    reqs = []
    arrival = 0.0
    for rid in range(n_requests):
        gid = gids[int(picks[rid])] if skew is not None \
            else gids[rid % len(gids)]
        n = sizes[gid]
        nrhs = int(rng.integers(2, max_nrhs + 1)) \
            if (max_nrhs > 1 and rid % 3 == 2) else 1
        b = rng.normal(size=(nrhs, n) if nrhs > 1 else n).astype(np.float32)
        b -= b.mean(axis=-1, keepdims=True)
        if arrival_rate:
            arrival += float(rng.exponential(1.0 / arrival_rate))
        reqs.append(SolveRequest(rid=rid, graph_id=gid, b=b,
                                 tol=tols[rid % len(tols)], maxiter=500,
                                 arrival_s=arrival, deadline_s=deadline_s))
    return reqs


def build_service(*, suite="tiny", slots=8, iters_per_tick=8, chunk=128,
                  fill_slack=32, memory_budget_mb=None, policy="fifo",
                  max_skips=None, precond="ac", precond_params=None,
                  metrics=None, tracer=None, flight=None, health=None):
    """Stand up the service: generate the graph suite, admit the fleet
    to a :class:`FactorCache`, wrap it in a :class:`SolveEngine` with
    the named admission policy.  ``precond`` selects the preconditioner
    family the suite is factored under (``"ac"`` uses the batched
    fleet factorization; other registered families construct per graph;
    ``"auto"`` pre-factors the AC fallback and lets the replay factor
    other families on demand as its selector explores).  Returns
    ``(engine, sizes, factor_s, registry)`` — ``registry`` maps
    ``graph_id -> (graph, key)`` so adaptive replays can construct
    additional families lazily; reuse the engine across trace replays
    so jitted step programs amortize."""
    import jax
    from repro.data import graphs
    from repro.core.solver import FactorCache
    from repro.serve import SolveEngine, make_policy

    spec = graphs.SUITE_MICRO if suite == "micro" else \
        graphs.SUITE_TINY if suite == "tiny" else \
        {k: graphs.SUITE[k] for k in SMALL_NAMES}
    built = {name: make() for name, make in spec.items()}
    keys = {name: jax.random.key(i) for i, name in enumerate(built)}
    cache = FactorCache(
        chunk=chunk, fill_slack=fill_slack, strict=False,
        memory_budget_bytes=(memory_budget_mb * (1 << 20)
                             if memory_budget_mb else None),
        flight=flight)
    t0 = time.perf_counter()
    if precond in ("ac", "auto"):
        cache.factor_batched(list(built.values()),
                             [keys[name] for name in built],
                             graph_ids=list(built.keys()))
        if precond == "auto":
            # pre-build every other family too: the adaptive replay's
            # selector then chooses among *resident* factors, so an
            # exploration pick pays its serve cost, not a mid-trace
            # construction stall that would punish whatever request
            # happened to trigger it
            from repro.core.solver import PRECOND_FAMILIES
            for fam in sorted(PRECOND_FAMILIES):
                if fam == "ac":
                    continue
                for name, g in built.items():
                    cache.factor(g, keys[name], graph_id=f"{name}::{fam}",
                                 family=fam)
    else:
        for name, g in built.items():
            cache.factor(g, keys[name], graph_id=name, family=precond,
                         precond_params=precond_params)
    t_factor = time.perf_counter() - t0
    eng = SolveEngine(cache, slots=slots, iters_per_tick=iters_per_tick,
                      admission=make_policy(policy, max_skips=max_skips),
                      metrics=metrics, tracer=tracer,
                      flight=flight, health=health)
    if health is not None:
        health.watch_engine(eng)
        health.watch_cache(cache)
    registry = {name: (g, keys[name]) for name, g in built.items()}
    return eng, {name: g.n for name, g in built.items()}, t_factor, registry


def trace_metrics(trace, done, t_serve):
    """Service metrics over completed requests — shared by the sync and
    async replay paths so their reports are directly comparable."""
    import numpy as np
    e2e = [r.latency_s for r in done]
    queue = [r.queue_wait_s for r in done]
    service = [r.service_s for r in done]
    rhs_total = sum(r.nrhs for r in done)
    return dict(
        requests=len(trace), completed=len(done), rhs_total=rhs_total,
        converged=int(sum(bool(r.converged) for r in done)),
        deadline_missed=int(sum(r.status == "deadline_missed"
                                for r in done)),
        serve_s=t_serve,
        requests_per_s=len(done) / t_serve if t_serve > 0 else 0.0,
        rhs_per_s=rhs_total / t_serve if t_serve > 0 else 0.0,
        latency_p50_s=percentile(e2e, 50),
        latency_p95_s=percentile(e2e, 95),
        latency_max_s=percentile(e2e, 100),
        queue_wait_p50_s=percentile(queue, 50),
        queue_wait_p95_s=percentile(queue, 95),
        service_p50_s=percentile(service, 50),
        service_p95_s=percentile(service, 95),
        iters_total=int(sum(int(np.sum(r.iters)) for r in done
                            if r.iters is not None)))


def replay_trace(eng, trace):
    """Replay a trace (open-loop when requests carry arrival offsets:
    each request is submitted at its ``arrival_s``), drain the engine,
    return service metrics.  Queueing delay (submit → admission) and
    end-to-end latency (submit → finish) are reported separately from
    service latency (admission → finish)."""
    from collections import deque
    pending = deque(trace)
    done = []
    t0 = time.perf_counter()
    while pending or eng.busy:
        now = time.perf_counter() - t0
        while pending and pending[0].arrival_s <= now:
            eng.submit(pending.popleft())
        if eng.busy:
            done.extend(eng.tick())
        elif pending:
            time.sleep(min(pending[0].arrival_s - now, 0.01))
    t_serve = time.perf_counter() - t0
    return trace_metrics(trace, done, t_serve), done


def replay_trace_auto(eng, trace, *, registry, selector):
    """Adaptive-family replay: each request's preconditioner family is
    picked by ``selector`` at submit time (cold graphs fall back to AC),
    the family's factor is constructed lazily into the engine's cache on
    first pick (the construction stall is *in* the open-loop clock —
    exploration pays its real cost), and every retirement is fed back
    via ``selector.observe``.  Same metrics dict as
    :func:`replay_trace`."""
    import numpy as np
    from collections import deque
    pending = deque(trace)
    done = []
    t0 = time.perf_counter()

    def _observe(r):
        base, _, fam = r.graph_id.partition("::")
        missed = r.status == "deadline_missed" or (
            r.deadline_s is not None and r.latency_s > r.deadline_s)
        # the lifecycle stamps carry the deconflated signal: pure
        # service seconds as the serve estimate, the lazily-paid
        # construction (stamped below) as its own component
        serve = r.service_s if r.admit_time > 0.0 else r.latency_s
        selector.observe(
            base, fam or "ac", wall_s=r.latency_s, serve_s=serve,
            construct_s=r.factor_wait_s if r.factor_mode else None,
            iters=int(np.max(r.iters)) if r.iters is not None else None,
            ok=r.status == "converged", deadline_ok=not missed)

    while pending or eng.busy:
        now = time.perf_counter() - t0
        while pending and pending[0].arrival_s <= now:
            req = pending.popleft()
            fam = selector.pick(req.graph_id, deadline_s=req.deadline_s)
            gid = req.graph_id if fam == "ac" \
                else f"{req.graph_id}::{fam}"
            if not eng.cache.fresh(gid):
                g, key = registry[req.graph_id]
                t_f0 = time.perf_counter()
                eng.cache.factor(g, key, graph_id=gid, family=fam)
                req.factor_wait_s = time.perf_counter() - t_f0
                req.factor_mode = "factor"
            req.graph_id = gid
            eng.submit(req)
        if eng.busy:
            for r in eng.tick():
                _observe(r)
                done.append(r)
        elif pending:
            time.sleep(min(pending[0].arrival_s - now, 0.01))
    t_serve = time.perf_counter() - t0
    return trace_metrics(trace, done, t_serve), done


def replay_trace_async(frontend, trace):
    """Open-loop replay through the async frontend: the caller thread
    only *submits* (at each request's ``arrival_s``); the frontend's
    driver thread runs the engine and resolves futures on retirement.
    Returns the same metrics dict as :func:`replay_trace`."""
    import concurrent.futures
    from repro.serve import EngineOverloadedError
    futs = []
    t0 = time.perf_counter()
    for req in trace:
        now = time.perf_counter() - t0
        if req.arrival_s > now:
            time.sleep(req.arrival_s - now)
        try:
            futs.append(frontend.submit_request(req))
        except EngineOverloadedError:
            pass           # reject-mode backpressure: shed, keep going
            # (frontend.stats().rejected counts it; completed < requests
            # in the metrics shows the shortfall)
    concurrent.futures.wait(futs)
    t_serve = time.perf_counter() - t0
    done = [f.result() for f in futs if f.exception() is None]
    return trace_metrics(trace, done, t_serve), done


def run_service(*, suite="tiny", requests=24, slots=8, iters_per_tick=8,
                max_nrhs=4, chunk=128, fill_slack=32, seed=0,
                memory_budget_mb=None, warmup_requests=0,
                arrival_rate=None, policy="fifo", max_skips=None,
                deadline_ms=None, use_async=False, max_queue=256,
                overload="block", precond="ac", precond_params=None,
                select_epsilon=0.2, skew=None, return_engine=False,
                metrics=None, tracer=None, flight=None, health=None):
    """Build the service, replay a trace, return a metrics dict.  With
    ``warmup_requests`` > 0 a throwaway trace is replayed first through
    the *same* engine so the measured replay excludes jit compiles.
    ``use_async`` routes the replay through :class:`SolveFrontend`
    (background driver thread, futures, bounded ingress queue).
    ``precond`` fixes the serving preconditioner family, or ``"auto"``
    replays through an :class:`~repro.serve.AdaptiveSelector` (sync
    replay only); ``skew`` makes the trace Zipf-hot."""
    if precond == "auto" and use_async:
        raise ValueError("--precond auto uses the sync replay loop "
                         "(selector feedback rides eng.tick retirement)")
    eng, sizes, t_factor, registry = build_service(
        suite=suite, slots=slots, iters_per_tick=iters_per_tick,
        chunk=chunk, fill_slack=fill_slack,
        memory_budget_mb=memory_budget_mb, policy=policy,
        max_skips=max_skips, precond=precond,
        precond_params=precond_params, metrics=metrics, tracer=tracer,
        flight=flight, health=health)
    gids = list(sizes)
    deadline_s = deadline_ms / 1e3 if deadline_ms else None
    selector = None
    if precond == "auto":
        from repro.serve import AdaptiveSelector
        selector = AdaptiveSelector(seed=seed, epsilon=select_epsilon)
    if warmup_requests:
        # same seed: the warmup trace is a prefix-identical replay (sans
        # arrival gaps), so every admission shape and bucket step program
        # of the measured trace is already compiled.  No deadlines: a
        # slow compile tick must not evict warmup lanes.
        if selector is not None:
            # compile pass first, *outside* the selector: serve every
            # family on every graph at every pow2 admission width the
            # trace can produce, so each (family, bucket) step program
            # *and* admit scatter shape is built before the selector
            # ever times a family — otherwise first-serve compiles
            # masquerade as the family being expensive and poison the
            # bandit's estimates
            import numpy as np
            from repro.core.parac import _next_pow2
            from repro.serve import SolveRequest
            wrng = np.random.default_rng(seed + 1)
            widths = sorted({_next_pow2(j)
                             for j in range(1, min(max_nrhs, slots) + 1)})
            fam_trace = []
            for name in gids:
                for fam in ("ac", "ichol", "amg", "spai"):
                    for j in widths:
                        wb = wrng.normal(
                            size=(j, sizes[name])).astype(np.float32)
                        wb -= wb.mean(axis=1, keepdims=True)
                        fam_trace.append(SolveRequest(
                            rid=-1 - len(fam_trace),
                            graph_id=(name if fam == "ac"
                                      else f"{name}::{fam}"),
                            b=wb if j > 1 else wb[0],
                            tol=1e-6, maxiter=500))
            replay_trace(eng, fam_trace)
        warm = make_trace(gids, sizes, warmup_requests, seed=seed,
                          max_nrhs=min(max_nrhs, slots), skew=skew)
        if selector is not None:
            replay_trace_auto(eng, warm, registry=registry,
                              selector=selector)
        else:
            replay_trace(eng, warm)
    trace = make_trace(gids, sizes, requests, seed=seed,
                       max_nrhs=min(max_nrhs, slots),
                       arrival_rate=arrival_rate, deadline_s=deadline_s,
                       skew=skew)
    ticks_before = eng.ticks                 # exclude warmup from metrics
    frontend_stats = None
    if use_async:
        from repro.serve import SolveFrontend
        with SolveFrontend(eng, max_queue=max_queue,
                           overload=overload, metrics=metrics,
                           flight=flight) as fe:
            metrics, done = replay_trace_async(fe, trace)
            fs = fe.stats()
            frontend_stats = dict(submitted=fs.submitted,
                                  completed=fs.completed,
                                  failed=fs.failed, rejected=fs.rejected,
                                  queue_peak=fs.queue_peak,
                                  max_queue=fs.max_queue)
    elif selector is not None:
        metrics, done = replay_trace_auto(eng, trace, registry=registry,
                                          selector=selector)
    else:
        metrics, done = replay_trace(eng, trace)
    ticks = eng.ticks - ticks_before
    metrics = dict(suite=suite, graphs=len(gids), slots=slots,
                   iters_per_tick=iters_per_tick, factor_s=t_factor,
                   ticks=ticks,
                   ticks_per_s=(ticks / metrics["serve_s"]
                                if metrics["serve_s"] > 0 else 0.0),
                   arrival_rate=arrival_rate, seed=seed,
                   policy=policy, mode="async" if use_async else "sync",
                   precond=precond,
                   selector=(selector.stats() if selector is not None
                             else None),
                   frontend=frontend_stats,
                   cache=eng.cache.stats(),
                   engine=eng.stats().as_dict(),
                   tracing=(tracer.stats() if tracer is not None else None),
                   **metrics)
    if return_engine:      # benchmarks reuse the factored cache (sweeps)
        return metrics, done, eng
    return metrics, done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="tiny",
                    choices=["micro", "tiny", "small"])
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--iters-per-tick", type=int, default=8)
    ap.add_argument("--max-nrhs", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="open-loop Poisson arrival rate (requests/sec); "
                         "omit for closed-loop (all arrive at t=0)")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="drive the replay through the SolveFrontend "
                         "(background engine thread + futures)")
    ap.add_argument("--policy", default="fifo",
                    choices=["fifo", "priority", "deadline"],
                    help="admission scheduler (fifo = head-of-line "
                         "blocking; priority/deadline backfill narrow "
                         "requests past a blocked wide head)")
    ap.add_argument("--max-skips", type=int, default=None,
                    help="backfill starvation bound (admission rounds a "
                         "blocked request may be skipped)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="stamp every request with this SLO budget; the "
                         "deadline policy orders by it and evicts "
                         "hopeless lanes (status=deadline_missed)")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="async frontend ingress bound (backpressure)")
    ap.add_argument("--overload", default="block",
                    choices=["block", "reject"],
                    help="async backpressure: block submitters or "
                         "reject with EngineOverloadedError")
    ap.add_argument("--precond", default="ac",
                    choices=["ac", "ichol", "amg", "spai", "auto"],
                    help="preconditioner family the suite serves under; "
                         "'auto' = adaptive per-graph selection "
                         "(epsilon-greedy on serving telemetry)")
    ap.add_argument("--select-epsilon", type=float, default=0.2,
                    help="exploration probability for --precond auto")
    ap.add_argument("--skew", type=float, default=None,
                    help="Zipf-like graph-choice skew (hot-graph trace)")
    ap.add_argument("--memory-budget-mb", type=int, default=None)
    ap.add_argument("--json", default=None,
                    help="write service metrics to this JSON file")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve a Prometheus scrape endpoint on this "
                         "port for the replay's lifetime "
                         "(curl localhost:PORT/metrics)")
    ap.add_argument("--trace-json", default=None,
                    help="record per-request lifecycle spans and write "
                         "Chrome trace_event JSON here "
                         "(chrome://tracing / Perfetto)")
    ap.add_argument("--postmortem-dir", default=None,
                    help="arm the flight recorder: structured lifecycle "
                         "events ring-buffer in memory, and any incident "
                         "(driver crash, SLO-miss streak) dumps the last "
                         "events + a metrics sample to JSONL files here")
    args = ap.parse_args()

    from repro.obs import MetricsRegistry, Tracer, maybe_serve
    registry = MetricsRegistry() \
        if (args.metrics_port is not None) else None
    tracer = Tracer() if args.trace_json else None
    flight = health = None
    if args.postmortem_dir or registry is not None:
        from repro.obs import FlightRecorder, HealthMonitor
        flight = FlightRecorder(postmortem_dir=args.postmortem_dir,
                                slo_miss_streak=8)
        flight.attach(registry=registry)
        health = HealthMonitor(registry, flight=flight)
    server = maybe_serve(registry, args.metrics_port)
    if server is not None:
        print(f"metrics: http://localhost:{server.port}/metrics")

    try:
        metrics, done = run_service(
            suite=args.suite, requests=args.requests, slots=args.slots,
            iters_per_tick=args.iters_per_tick, max_nrhs=args.max_nrhs,
            chunk=args.chunk, seed=args.seed,
            memory_budget_mb=args.memory_budget_mb,
            arrival_rate=args.arrival_rate, policy=args.policy,
            max_skips=args.max_skips, deadline_ms=args.deadline_ms,
            use_async=args.use_async, max_queue=args.max_queue,
            overload=args.overload, precond=args.precond,
            select_epsilon=args.select_epsilon, skew=args.skew,
            metrics=registry, tracer=tracer, flight=flight, health=health)
    finally:
        if server is not None:
            server.close()
        if flight is not None:
            flight.flush(timeout=5.0)
            fs = flight.stats()
            if fs["dump_paths"]:
                print("post-mortem dumps: "
                      + ", ".join(fs["dump_paths"]))
    if tracer is not None:
        n = tracer.export_chrome(args.trace_json)
        print(f"wrote {n} trace events to {args.trace_json}")

    print(f"suite={metrics['suite']} graphs={metrics['graphs']} "
          f"factor_batched={metrics['factor_s']:.2f}s "
          f"mode={metrics['mode']} policy={metrics['policy']} "
          f"precond={metrics['precond']}")
    if metrics["selector"]:
        sel = metrics["selector"]
        print(f"selector: picks={sel['picks']} "
              f"by_family={sel['picks_by_family']} "
              f"explores={sel['explores']} cold={sel['cold_picks']} "
              f"deadline_misses={sel['deadline_misses']}")
    print(f"served {metrics['completed']}/{metrics['requests']} requests "
          f"({metrics['rhs_total']} rhs, {metrics['converged']} converged) "
          f"in {metrics['serve_s']:.2f}s over {metrics['slots']} slots, "
          f"{metrics['ticks']} ticks ({metrics['ticks_per_s']:.1f}/s)")
    print(f"throughput: {metrics['requests_per_s']:.1f} req/s "
          f"({metrics['rhs_per_s']:.1f} rhs/s incl. compile)  "
          f"e2e p50={metrics['latency_p50_s']*1e3:.0f}ms "
          f"p95={metrics['latency_p95_s']*1e3:.0f}ms "
          f"max={metrics['latency_max_s']*1e3:.0f}ms")
    print(f"queueing: p50={metrics['queue_wait_p50_s']*1e3:.0f}ms "
          f"p95={metrics['queue_wait_p95_s']*1e3:.0f}ms  "
          f"service: p50={metrics['service_p50_s']*1e3:.0f}ms "
          f"p95={metrics['service_p95_s']*1e3:.0f}ms"
          + (f"  (open-loop @ {metrics['arrival_rate']:.1f} req/s)"
             if metrics["arrival_rate"] else "  (closed-loop)"))
    eng_d = metrics["engine"]
    if eng_d["policy"] != "fifo" or metrics["deadline_missed"]:
        print(f"scheduler[{eng_d['policy']}]: "
              f"admitted={eng_d['admitted_reqs']} "
              f"backfill_skips={eng_d['backfill_skips']} "
              f"(bound {eng_d['max_skips']}/req, "
              f"{eng_d['skipped_reqs']} skipped) "
              f"deadline_evictions={eng_d['deadline_evictions']} "
              f"missed={metrics['deadline_missed']}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(metrics, fh, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
