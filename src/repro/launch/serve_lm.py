"""LM serving launcher.  **Deprecated** — kept only as a substrate
exercise over the seed's token engine (``serve.lm_engine``, itself
deprecated).  It does not share the solve engine, scheduler or async
frontend; the production service CLI is ``repro.launch.serve`` (use
``--async --policy {fifo,priority,deadline}`` there).

    PYTHONPATH=src python -m repro.launch.serve_lm --arch qwen3-14b \
        --requests 8 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import transformer as tf
    from repro.models.common import init_params
    from repro.serve import ServeEngine, Request

    cfg = get_smoke_config(args.arch)
    if cfg.is_encoder_decoder:
        raise SystemExit("use examples/ for enc-dec serving")
    params = init_params(tf.pdefs(cfg), jax.random.key(0), jnp.float32)
    eng = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab, args.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for i in range(args.requests)]
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    ticks = 0
    while (not eng.queue.empty()) or any(a is not None for a in eng.active):
        eng.tick()
        ticks += 1
        if ticks > 10_000:
            break
    dt = time.time() - t0
    tok = sum(len(r.out_tokens or []) for r in reqs)
    print(f"arch={cfg.name} served {len(reqs)} requests, {tok} tokens in "
          f"{dt:.2f}s ({tok/dt:.1f} tok/s incl. compile) over "
          f"{args.slots} slots, {ticks} ticks")


if __name__ == "__main__":
    main()
