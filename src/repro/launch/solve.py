"""Laplacian-solver launcher — the paper's pipeline as a CLI.

    PYTHONPATH=src python -m repro.launch.solve --graph grid3d_uniform_16 \
        --ordering nnz-sort --tol 1e-6

Also exposes the *batched* construction path (``--batch N``): N
independent Laplacians factorized concurrently under one jit — the
incremental-sparsification / many-graph regime where the distributed
mesh shards whole problems (DESIGN.md §2: the scalable axis for an O(1)
arithmetic-intensity algorithm is across problems, not within one).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="grid2d_64")
    ap.add_argument("--ordering", default="nnz-sort")
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--maxiter", type=int, default=500)
    ap.add_argument("--chunk", type=int, default=256)
    ap.add_argument("--batch", type=int, default=0,
                    help="factorize N seeded replicas concurrently")
    ap.add_argument("--nrhs", type=int, default=1,
                    help="solve N right-hand sides in one batched PCG "
                         "sharing the factor")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.data import graphs
    from repro.core.parac import factorize_wavefront
    from repro.core.solver import Solver
    from repro.core.ordering import ORDERINGS
    from repro.core import etree

    g = graphs.SUITE[args.graph]() if args.graph in graphs.SUITE \
        else graphs.SUITE_LARGE[args.graph]()
    perm = ORDERINGS[args.ordering](g, seed=0) \
        if args.ordering in ("random", "nnz-sort") \
        else ORDERINGS[args.ordering](g)
    gp = g.permute(perm).coalesce()
    print(f"graph={args.graph} n={g.n} m={g.m} ordering={args.ordering}")

    if args.batch:
        t0 = time.time()
        for i in range(args.batch):
            f = factorize_wavefront(gp, jax.random.key(i), chunk=args.chunk,
                                    strict=False)
        print(f"batched construction: {args.batch} factors in "
              f"{time.time()-t0:.2f}s "
              f"({(time.time()-t0)/args.batch:.3f}s each)")
        return

    solver = Solver(chunk=args.chunk)
    t0 = time.time()
    handle = solver.factor(gp, jax.random.key(0))
    f = handle.factor
    print(f"factor: {time.time()-t0:.2f}s nnz={f.nnz} "
          f"fill={f.fill_ratio(g):.2f} rounds={f.stats['rounds']} "
          f"height={etree.actual_etree_height(f)} "
          f"levels={handle.n_levels}")

    rng = np.random.default_rng(0)
    iperm = np.argsort(perm)
    if args.nrhs > 1:
        B = rng.normal(size=(args.nrhs, g.n))
        B -= B.mean(axis=1, keepdims=True)
        Bp = jnp.asarray(B[:, iperm], jnp.float32)
        t0 = time.time()
        res = solver.solve(Bp, tol=args.tol, maxiter=args.maxiter)
        jax.block_until_ready(res.x)
        it = np.asarray(res.iters)
        rr = np.asarray(res.relres)
        print(f"batched solve: {time.time()-t0:.2f}s nrhs={args.nrhs} "
              f"iters={it.min()}..{it.max()} max_relres={rr.max():.2e} "
              f"converged={bool(np.all(np.asarray(res.converged)))}")
        return

    b = rng.normal(size=g.n)
    b -= b.mean()
    bp = jnp.asarray(b[iperm], jnp.float32)
    t0 = time.time()
    res = solver.solve(bp, tol=args.tol, maxiter=args.maxiter)
    jax.block_until_ready(res.x)
    print(f"solve: {time.time()-t0:.2f}s iters={int(res.iters)} "
          f"relres={float(res.relres):.2e} converged={bool(res.converged)}")


if __name__ == "__main__":
    main()
