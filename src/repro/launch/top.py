"""Live fleet dashboard: scrape ``--metrics-port`` endpoints, render
replica/bucket occupancy, rates, latency quantiles, and overload /
numerical-health state.

Runs against anything that exposes the Prometheus text endpoint the
serving stack serves (``MetricsServer``) — one process or a whole
fleet::

    python -m repro.launch.top 9100 9101            # live curses view
    python -m repro.launch.top 127.0.0.1:9100 --once  # plain text (CI,
                                                      # bug reports)
    python -m repro.launch.top dump.prom --once     # offline: a saved
                                                    # scrape file

Everything here is stdlib (``curses`` is imported lazily, only for the
live view) and nothing imports jax/numpy or the serving stack — the
dashboard must start fast and must not compete with the fleet it is
watching.
"""
from __future__ import annotations

import argparse
import os
import re
import sys
import time
import urllib.request
from typing import Dict, List, Optional, Tuple

_SAMPLE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})? (.+)$")
_LABEL = re.compile(r'(\w+)="((?:[^"\\]|\\.)*)"')

Samples = Dict[str, List[Tuple[Dict[str, str], float]]]


def parse_prom(text: str) -> Samples:
    """Parse Prometheus text exposition format 0.0.4 into
    ``{metric_name: [(labels, value), ...]}``.

    >>> s = parse_prom('# HELP x y\\n# TYPE x counter\\n'
    ...                'x{a="1",b="z"} 3.0\\nplain 2\\n')
    >>> s['x']
    [({'a': '1', 'b': 'z'}, 3.0)]
    >>> s['plain']
    [({}, 2.0)]
    """
    out: Samples = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        if not m:
            continue
        name, raw_labels, raw_value = m.groups()
        try:
            value = float(raw_value)
        except ValueError:
            continue
        labels = {k: v.replace('\\"', '"').replace("\\\\", "\\")
                  for k, v in _LABEL.findall(raw_labels or "")}
        out.setdefault(name, []).append((labels, value))
    return out


def scrape(endpoint: str, timeout: float = 2.0) -> Samples:
    """Fetch and parse one endpoint.  Accepts a full URL, a
    ``host:port``, a bare port (→ ``127.0.0.1:port``), or a path to a
    saved ``.prom`` scrape file (offline bug-report mode)."""
    if "://" in endpoint:
        url = endpoint
    elif os.path.exists(endpoint) or endpoint.endswith(".prom"):
        with open(endpoint) as fh:
            return parse_prom(fh.read())
    else:
        hostport = endpoint if ":" in endpoint else f"127.0.0.1:{endpoint}"
        url = f"http://{hostport}/metrics"
    with urllib.request.urlopen(url, timeout=timeout) as resp:
        return parse_prom(resp.read().decode("utf-8", "replace"))


def _total(samples: Samples, name: str,
           match: Optional[Dict[str, str]] = None) -> float:
    tot = 0.0
    for labels, value in samples.get(name, []):
        if match and any(labels.get(k) != v for k, v in match.items()):
            continue
        tot += value
    return tot


def _by_label(samples: Samples, name: str, label: str) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for labels, value in samples.get(name, []):
        key = labels.get(label, "")
        out[key] = out.get(key, 0.0) + value
    return out


def _quantile(samples: Samples, name: str, q: float) -> Optional[float]:
    """Quantile from cumulative ``le``-labeled histogram buckets,
    summed across replicas, linearly interpolated within the bucket."""
    cum: Dict[float, float] = {}
    for labels, value in samples.get(name + "_bucket", []):
        le = labels.get("le", "")
        bound = float("inf") if le in ("+Inf", "inf") else float(le)
        cum[bound] = cum.get(bound, 0.0) + value
    if not cum:
        return None
    bounds = sorted(cum)
    total = cum[bounds[-1]]
    if total <= 0:
        return None
    target = q * total
    prev_bound = 0.0
    prev_cum = 0.0
    for b in bounds:
        c = cum[b]
        if c >= target:
            if b == float("inf"):
                return prev_bound
            span = c - prev_cum
            frac = (target - prev_cum) / span if span > 0 else 1.0
            return prev_bound + frac * (b - prev_bound)
        prev_bound, prev_cum = b, c
    return bounds[-1]


def summarize_endpoint(samples: Samples) -> Dict[str, object]:
    """Aggregate one scrape into the dashboard's display model."""
    completed = _by_label(samples, "repro_engine_completed_total",
                          "status")
    routed = _total(samples, "repro_cluster_routed_total")
    hits = _total(samples, "repro_cluster_routed_total", {"hit": "1"})
    drift = _by_label(samples, "repro_health_drift", "family")
    buckets: List[Tuple[str, float]] = []
    for labels, value in samples.get("repro_fleet_lane_occupancy", []):
        tag = "{}/{}/K{}".format(labels.get("family", "?"),
                                 labels.get("n_pad", "?"),
                                 labels.get("k_tier", "?"))
        buckets.append((tag, value))
    buckets.sort(key=lambda kv: (-kv[1], kv[0]))
    return {
        "ticks": _total(samples, "repro_engine_ticks_total"),
        "admitted": _total(samples, "repro_engine_admitted_total"),
        "completed": completed,
        "done": sum(completed.values()),
        "queue": _total(samples, "repro_engine_queue_depth"),
        "lanes": _total(samples, "repro_engine_active_lanes"),
        "shed": _total(samples, "repro_frontend_rejected_total")
                + _total(samples, "repro_cluster_shed_total"),
        "routed": routed,
        "hit_rate": hits / routed if routed else None,
        "p50": _quantile(samples, "repro_engine_latency_seconds", 0.50),
        "p95": _quantile(samples, "repro_engine_latency_seconds", 0.95),
        "overload": _total(samples, "repro_cluster_overload_state"),
        "healthy": _total(samples, "repro_cluster_healthy_replicas"),
        "drift": {k: v for k, v in drift.items() if v},
        "quarantines": _total(samples,
                              "repro_health_quarantines_total"),
        "waste": _total(samples, "repro_fleet_sweep_waste_ratio"),
        "watermark": _total(samples, "repro_fleet_bytes_watermark"),
        "buckets": buckets,
        "incidents": _total(samples, "repro_flight_incidents"),
    }


def _fmt(v: Optional[float], unit: str = "", digits: int = 1) -> str:
    if v is None:
        return "-"
    if unit == "s":
        if v < 1e-3:
            return f"{v * 1e6:.0f}us"
        if v < 1.0:
            return f"{v * 1e3:.{digits}f}ms"
        return f"{v:.{digits}f}s"
    if unit == "B":
        for suff in ("B", "KiB", "MiB", "GiB"):
            if abs(v) < 1024 or suff == "GiB":
                return f"{v:.{digits}f}{suff}"
            v /= 1024
    return f"{v:.{digits}f}"


def render_lines(endpoint: str, info: Dict[str, object],
                 rates: Optional[Dict[str, float]] = None) -> List[str]:
    """Render one endpoint's summary as plain text lines (shared by
    ``--once`` and the curses view)."""
    rates = rates or {}
    over = "OVERLOADED" if info["overload"] else "ok"
    lines = [f"== {endpoint} ==",
             "  ticks {:.0f} ({}/s)  queue {:.0f}  lanes {:.0f}  "
             "healthy {:.0f}  state {}".format(
                 info["ticks"], _fmt(rates.get("ticks")),
                 info["queue"], info["lanes"], info["healthy"], over)]
    comp = "  ".join(f"{k}={v:.0f}" for k, v in
                     sorted(info["completed"].items())) or "none"
    lines.append(
        "  admitted {:.0f}  done {:.0f} ({}/s)  shed {:.0f}  [{}]".format(
            info["admitted"], info["done"], _fmt(rates.get("done")),
            info["shed"], comp))
    hit = info["hit_rate"]
    lines.append("  latency p50 {}  p95 {}  affinity {}".format(
        _fmt(info["p50"], "s"), _fmt(info["p95"], "s"),
        "-" if hit is None else f"{hit:.0%}"))
    drift = info["drift"]
    health = ("drifting: " + ", ".join(
        f"{k}({v:.0f})" for k, v in sorted(drift.items()))
        if drift else "no drift")
    lines.append(
        "  health: {}  quarantines {:.0f}  incidents {:.0f}".format(
            health, info["quarantines"], info["incidents"]))
    lines.append("  fleet: waste {:.1%}  watermark {}".format(
        info["waste"], _fmt(info["watermark"], "B", 0)))
    for tag, n in info["buckets"][:8]:
        bar = "#" * min(int(n), 40)
        lines.append(f"    {tag:<24} {n:>4.0f} {bar}")
    return lines


def _collect(endpoints: List[str], timeout: float
             ) -> Dict[str, Optional[Dict[str, object]]]:
    out: Dict[str, Optional[Dict[str, object]]] = {}
    for ep in endpoints:
        try:
            out[ep] = summarize_endpoint(scrape(ep, timeout))
        except Exception:
            out[ep] = None
    return out


def _rates(prev: Dict[str, object], cur: Dict[str, object],
           dt: float) -> Dict[str, float]:
    if dt <= 0:
        return {}
    return {k: (float(cur[k]) - float(prev[k])) / dt
            for k in ("ticks", "done")}


def once(endpoints: List[str], timeout: float = 2.0,
         out=None) -> int:
    """Plain-text render; exit code 1 only when every endpoint fails."""
    out = out if out is not None else sys.stdout
    infos = _collect(endpoints, timeout)
    any_ok = False
    for ep, info in infos.items():
        if info is None:
            print(f"== {ep} ==\n  scrape failed", file=out)
            continue
        any_ok = True
        print("\n".join(render_lines(ep, info)), file=out)
    return 0 if any_ok else 1


def live(endpoints: List[str], interval: float = 1.0,
         timeout: float = 2.0) -> int:
    import curses

    def _loop(stdscr):
        curses.use_default_colors()
        stdscr.nodelay(True)
        prev: Dict[str, Tuple[float, Dict[str, object]]] = {}
        while True:
            now = time.monotonic()
            infos = _collect(endpoints, timeout)
            stdscr.erase()
            row = 0

            def put(text: str) -> None:
                nonlocal row
                try:
                    stdscr.addstr(row, 0, text)
                except curses.error:
                    pass
                row += 1

            put("repro top — {} endpoint(s) — q to quit".format(
                len(endpoints)))
            for ep, info in infos.items():
                if info is None:
                    put(f"== {ep} ==  scrape failed")
                    continue
                rates = {}
                if ep in prev:
                    t0, p = prev[ep]
                    rates = _rates(p, info, now - t0)
                prev[ep] = (now, info)
                for line in render_lines(ep, info, rates):
                    put(line)
            stdscr.refresh()
            deadline = time.monotonic() + interval
            while time.monotonic() < deadline:
                ch = stdscr.getch()
                if ch in (ord("q"), 27):
                    return 0
                time.sleep(0.05)

    return curses.wrapper(_loop)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-top",
        description="live dashboard over repro metrics endpoints")
    ap.add_argument("endpoints", nargs="+",
                    help="port, host:port, URL, or saved .prom file")
    ap.add_argument("--once", action="store_true",
                    help="plain-text render and exit (CI, bug reports)")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="live refresh seconds")
    ap.add_argument("--timeout", type=float, default=2.0,
                    help="per-scrape timeout seconds")
    args = ap.parse_args(argv)
    if args.once:
        return once(args.endpoints, timeout=args.timeout)
    return live(args.endpoints, interval=args.interval,
                timeout=args.timeout)


if __name__ == "__main__":
    raise SystemExit(main())
