"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b \
        --steps 100 --smoke            # reduced config, host devices

On a real cluster the same entrypoint runs under
``jax.distributed.initialize`` with the production mesh; here the
--smoke path exercises the identical Trainer/step code on CPU.
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.configs.shapes import ShapeCell
    from repro.launch.mesh import make_host_mesh
    from repro.train import Trainer, TrainConfig

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(1, 1)
    cell = ShapeCell("cli", "train", args.seq, args.batch)
    tcfg = TrainConfig(steps=args.steps, ckpt_every=max(args.steps // 2, 1),
                       ckpt_dir=args.ckpt_dir, lr=args.lr,
                       grad_accum=args.grad_accum, log_every=10)
    tr = Trainer(cfg, mesh, cell, tcfg)
    resumed = tr.init_or_restore()
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"resumed={resumed} start_step={tr.step}")
    tr.run(on_step=lambda s, m: print(m))


if __name__ == "__main__":
    main()
