"""Grouped-query attention: train/prefill and cached decode paths.

Sharding layout (DESIGN.md, EXPERIMENTS.md §Dry-run):

* q heads are padded to ``cfg.padded_heads`` and sharded on the ``model``
  mesh axis; padded heads have zero o-proj rows so outputs (and gradients
  into real weights) are unaffected.
* kv heads are *replicated* over ``model`` (they rarely divide 16) and
  expanded per-device to the local q heads with a static gather.
* decode caches are laid out ``[batch, kv_seq, kv_heads, head_dim]`` with
  ``batch -> (pod, data)`` and ``kv_seq -> model``: the flash-decoding
  split-KV schedule then *emerges from XLA SPMD* — softmax over the
  sharded kv_seq axis lowers to tiny all-reduces of per-shard max/sum
  followed by a psum of the weighted values.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .common import PDef, rms_norm, rope, softcap
from .config import ModelConfig
from repro.distributed.ctx import constrain

NEG_INF = -2.0e38


def attn_pdefs(cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.padded_heads, cfg.n_kv_heads
    p = {
        "wq": PDef((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": PDef((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": PDef((d, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": PDef((H, hd, d), ("heads", "head_dim", "embed"),
                   init="zeros" if H != cfg.n_heads else "normal"),
    }
    if cfg.qkv_bias:
        p["bq"] = PDef((H, hd), ("heads", "head_dim"), init="zeros")
        p["bk"] = PDef((KV, hd), ("kv_heads", "head_dim"), init="zeros")
        p["bv"] = PDef((KV, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = PDef((hd,), ("head_dim",), init="zeros")
        p["k_norm"] = PDef((hd,), ("head_dim",), init="zeros")
    return p


def _grouped_ok(cfg: ModelConfig) -> bool:
    """Grouped (expansion-free) GQA path: only when heads need no padding
    and divide evenly into kv groups."""
    return (cfg.padded_heads == cfg.n_heads
            and cfg.n_heads % max(cfg.n_kv_heads, 1) == 0)


def _q_to_kv_map(cfg: ModelConfig) -> np.ndarray:
    """Padded q-head index -> kv-head index (real heads keep GQA groups)."""
    group = cfg.n_heads // cfg.n_kv_heads
    m = np.zeros(cfg.padded_heads, np.int32)
    m[: cfg.n_heads] = np.arange(cfg.n_heads) // group
    return m  # padded heads point at kv 0; their wo rows are zero


def _project_qkv(p, cfg: ModelConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _banded_local_attn(qg, k, v, scale: float, window: int, softcap_v):
    """Exact sliding-window attention over diagonal bands: each W-sized
    query block attends to its own and the previous key block only —
    score bytes drop from O(S*S) to O(S*2W) (EXPERIMENTS.md §Perf).

    qg: [B,S,KV,G,hd]; k,v: [B,S,KV,hd]; requires S % window == 0.
    """
    B, S, KV, G, hd = qg.shape
    W = window
    nb = S // W
    qb = qg.reshape(B, nb, W, KV, G, hd)
    kb = k.reshape(B, nb, W, KV, hd)
    vb = v.reshape(B, nb, W, KV, hd)
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    kcat = jnp.concatenate([kprev, kb], axis=2)       # [B,nb,2W,KV,hd]
    vcat = jnp.concatenate([vprev, vb], axis=2)
    logits = jnp.einsum("bnwKGh,bnuKh->bnKGwu", qb * scale, kcat,
                        preferred_element_type=jnp.float32)
    logits = softcap_v(logits)
    bidx = jnp.arange(nb, dtype=jnp.int32)[:, None, None]
    ipos = bidx * W + jnp.arange(W, dtype=jnp.int32)[None, :, None]
    jpos = (bidx - 1) * W + jnp.arange(2 * W, dtype=jnp.int32)[None, None, :]
    mask = (jpos >= 0) & (jpos <= ipos) & (ipos - jpos < W)
    logits = jnp.where(mask[None, :, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(qg.dtype)
    out = jnp.einsum("bnKGwu,bnuKh->bnwKGh", probs, vcat)
    return out.reshape(B, S, KV, G, hd)


def attn_fwd(p, cfg: ModelConfig, x, *, local: bool,
             positions: Optional[jnp.ndarray] = None,
             kv_mask: Optional[jnp.ndarray] = None,
             return_cache: bool = False):
    """Full-sequence (train / prefill) attention.  x: [B, S, D]."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q, k, v = _project_qkv(p, cfg, x, positions)
    q = constrain(q, "batch", None, "heads", None)
    scale = cfg.head_dim ** -0.5
    # banded path: exact sliding window over diagonal blocks (no S*S scores)
    banded = (local and cfg.local_window and S % cfg.local_window == 0
              and S > cfg.local_window and kv_mask is None)
    if banded:
        H, hd = q.shape[2], q.shape[3]
        sc = lambda l: softcap(l, cfg.attn_softcap)
        if _grouped_ok(cfg):
            KV = cfg.n_kv_heads
            qg = q.reshape(B, S, KV, H // KV, hd)
            out = _banded_local_attn(qg, k, v, scale, cfg.local_window, sc)
        else:
            kmap = jnp.asarray(_q_to_kv_map(cfg))
            ke = jnp.take(k, kmap, axis=2)
            ve = jnp.take(v, kmap, axis=2)
            qg = q.reshape(B, S, H, 1, hd)
            out = _banded_local_attn(qg, ke, ve, scale, cfg.local_window, sc)
        out = out.reshape(B, S, H, hd)
        out = constrain(out, "batch", None, "heads", None)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        y = constrain(y, "batch", None, "act_embed")
        if return_cache:
            return y, {"k": k, "v": v}
        return y
    i = positions[:, None, :, None]
    j = positions[:, None, None, :]
    mask = j <= i
    if local and cfg.local_window:
        mask &= (i - j) < cfg.local_window
    if kv_mask is not None:
        mask &= kv_mask[:, None, None, :]
    if _grouped_ok(cfg):
        # no head padding: grouped einsum, no KV expansion copy
        B, S, H, hd = q.shape
        KV = cfg.n_kv_heads
        G = H // KV
        qg = q.reshape(B, S, KV, G, hd)
        logits = jnp.einsum("bsKGh,btKh->bKGst", qg * scale, k,
                            preferred_element_type=jnp.float32)
        logits = softcap(logits, cfg.attn_softcap)
        logits = jnp.where(mask[:, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bKGst,btKh->bsKGh", probs, v).reshape(B, S, H, hd)
    else:
        kmap = jnp.asarray(_q_to_kv_map(cfg))
        ke = constrain(jnp.take(k, kmap, axis=2),
                       "batch", None, "heads", None)
        ve = constrain(jnp.take(v, kmap, axis=2),
                       "batch", None, "heads", None)
        logits = jnp.einsum("bshk,bthk->bhst", q * scale, ke,
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, "batch", "heads", None, None)
        logits = softcap(logits, cfg.attn_softcap)
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhst,bthk->bshk", probs, ve)
    out = constrain(out, "batch", None, "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = constrain(y, "batch", None, "act_embed")
    if return_cache:
        return y, {"k": k, "v": v}
    return y


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    hd, KV = cfg.head_dim, cfg.n_kv_heads
    return {
        "k": jnp.zeros((batch, max_len, KV, hd), dtype),
        "v": jnp.zeros((batch, max_len, KV, hd), dtype),
    }


def attn_decode(p, cfg: ModelConfig, x, cache, cache_pos, *, local: bool):
    """Single-token cached decode.  x: [B, 1, D]; cache_pos: the *true*
    sequence position (scalar int).

    Local layers use a rolling buffer of length ``local_window``: position
    p lives at slot p % window, k/v are stored pre-rotated at absolute
    positions, and the buffer membership itself enforces the window (every
    resident entry is within the last ``window`` positions).  Global
    layers write at slot ``cache_pos`` directly.  With kv_seq sharded on
    ``model``, XLA lowers the softmax to the split-KV (flash-decoding)
    schedule.
    """
    B = x.shape[0]
    rolling = bool(local and cfg.local_window)
    L = cache["k"].shape[1]
    slot = (cache_pos % L) if rolling else cache_pos
    positions = jnp.full((B, 1), cache_pos, jnp.int32)  # true pos for rope
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    scale = cfg.head_dim ** -0.5
    t = jnp.arange(L, dtype=jnp.int32)
    # slots written so far: t <= cache_pos covers warm-up; once the rolling
    # buffer has wrapped every slot is valid and in-window by construction.
    mask = t[None, None, None, :] <= cache_pos
    if _grouped_ok(cfg):
        B_, S_, H_, hd_ = q.shape
        KV = cfg.n_kv_heads
        G = H_ // KV
        kc = constrain(k, "batch", "kv_seq", None, None)
        vc = constrain(v, "batch", "kv_seq", None, None)
        qg = q.reshape(B_, S_, KV, G, hd_)
        logits = jnp.einsum("bsKGh,btKh->bKGst", qg * scale, kc,
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, "batch", None, None, None, "kv_seq")
        logits = softcap(logits, cfg.attn_softcap)
        logits = jnp.where(mask[:, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bKGst,btKh->bsKGh", probs, vc)             .reshape(B_, S_, H_, hd_)
    else:
        kmap = jnp.asarray(_q_to_kv_map(cfg))
        ke = constrain(jnp.take(k, kmap, axis=2),
                       "batch", "kv_seq", None, None)
        ve = constrain(jnp.take(v, kmap, axis=2),
                       "batch", "kv_seq", None, None)
        logits = jnp.einsum("bshk,bthk->bhst", q * scale, ke,
                            preferred_element_type=jnp.float32)  # [B,H,1,T]
        logits = constrain(logits, "batch", None, None, "kv_seq")
        logits = softcap(logits, cfg.attn_softcap)
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhst,bthk->bshk", probs, ve)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": k, "v": v}


def cross_attn_pdefs(cfg: ModelConfig) -> dict:
    """Whisper-style cross attention (bias, no rope)."""
    d, hd, H = cfg.d_model, cfg.head_dim, cfg.padded_heads
    return {
        "wq": PDef((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": PDef((d, H, hd), ("embed", "heads", "head_dim")),
        "wv": PDef((d, H, hd), ("embed", "heads", "head_dim")),
        "wo": PDef((H, hd, d), ("heads", "head_dim", "embed"),
                   init="zeros" if H != cfg.n_heads else "normal"),
        "bq": PDef((H, hd), ("heads", "head_dim"), init="zeros"),
        "bv": PDef((H, hd), ("heads", "head_dim"), init="zeros"),
    }


def cross_attn_fwd(p, cfg: ModelConfig, x, enc_kv):
    """x: [B, S, D] queries; enc_kv: dict(k, v) precomputed [B, T, H, hd]."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]) + p["bq"]
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bshk,bthk->bhst", q * scale, enc_kv["k"],
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthk->bshk", probs, enc_kv["v"])
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def encode_cross_kv(p, cfg: ModelConfig, enc_out):
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"]) + p["bv"]
    return {"k": k, "v": v}
