"""Parameter/spec system and shared numeric building blocks.

Parameters are plain pytrees (nested dicts) of arrays.  Every leaf is
declared as a :class:`PDef` carrying its shape, *logical* axis names and
initializer.  Three interpreters walk the same declaration tree:

  * ``abstract_params``  -> ShapeDtypeStruct leaves (dry-run, no memory)
  * ``init_params``      -> materialized arrays (smoke tests, examples)
  * ``param_pspecs``     -> PartitionSpec leaves via logical->mesh rules

Logical axis names are mapped to mesh axes by :data:`DEFAULT_RULES`
(MaxText-style).  Axes that do not divide the mesh axis size must be
padded by the config (``pad_to``) — divisibility is validated at spec
construction so a dry-run failure is an error in the config, not in XLA.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# parameter declarations
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis names per dim
    init: str = "normal"                     # normal | zeros | ones | embed
    scale: float = 1.0                       # fan-in style scale override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaf_init(key, pd: PDef, dtype):
    if pd.init == "zeros":
        return jnp.zeros(pd.shape, dtype)
    if pd.init == "ones":
        return jnp.ones(pd.shape, dtype)
    fan_in = pd.shape[0] if len(pd.shape) > 1 else pd.shape[0]
    std = pd.scale / math.sqrt(max(fan_in, 1))
    if pd.init == "embed":
        std = pd.scale
    return (jax.random.normal(key, pd.shape, jnp.float32) * std).astype(dtype)


def is_pdef(x) -> bool:
    return isinstance(x, PDef)


def abstract_params(tree, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda pd: jax.ShapeDtypeStruct(pd.shape, dtype), tree,
        is_leaf=is_pdef)


def init_params(tree, key, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_pdef)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_leaf_init(k, pd, dtype) for k, pd in zip(keys, leaves)])


# ---------------------------------------------------------------------------
# logical axis -> mesh axis rules
# ---------------------------------------------------------------------------

# mesh axes: ("pod", "data", "model").  Single-pod mesh omits "pod".
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,               # sequence kept local in the baseline layout
    "kv_seq": "model",         # decode caches: overridden per-cell by
                               # make_decode_step (model + unused batch axes)
    "vocab": "model",
    # FSDP/ZeRO-3: weight matrices are additionally sharded over "data"
    # along their embed dim; XLA all-gathers them at use sites.
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "layers": None,
    "state": None,             # ssm state dim
    "ssm_heads": "model",
    "rec": "model",            # rg-lru recurrence features
    "conv": None,
    # activation feature dims (residual stream).  None by default; the
    # decode-step builder maps it to "data" for single-stream decode so
    # weights stay 2D-sharded and matmuls run distributed (psum) instead
    # of all-gathering weight shards (EXPERIMENTS.md §Perf).
    "act_embed": None,
}


def rules_for_mesh(mesh) -> Dict[str, Any]:
    """Drop mesh axes not present (e.g. 'pod' on the single-pod mesh)."""
    names = set(mesh.axis_names)
    out = {}
    for k, v in DEFAULT_RULES.items():
        if isinstance(v, tuple):
            vv = tuple(a for a in v if a in names)
            out[k] = vv if vv else None
        else:
            out[k] = v if v in names else None
    return out


# axes that silently fall back to replication when the dim does not divide
# the mesh extent (kv heads are often < 16; the attention layout replicates
# them and expands per-device — see models/attention.py)
SOFT_AXES = frozenset({"kv_heads"})


def logical_to_pspec(axes: Sequence[Optional[str]], rules: Dict[str, Any],
                     shape: Optional[Sequence[int]] = None,
                     mesh=None) -> P:
    parts = []
    for i, a in enumerate(axes):
        m = rules.get(a) if a is not None else None
        if m is not None and shape is not None and mesh is not None:
            size = math.prod(mesh.shape[x] for x in
                             ((m,) if isinstance(m, str) else m))
            if shape[i] % size != 0:
                if a in SOFT_AXES:
                    m = None
                else:
                    raise ValueError(
                        f"logical axis {a!r} (dim {shape[i]}) not divisible "
                        f"by mesh extent {size}; pad the config (pad_to)")
        parts.append(m)
    return P(*parts)


def param_pspecs(tree, rules: Dict[str, Any], mesh=None):
    return jax.tree.map(
        lambda pd: logical_to_pspec(pd.axes, rules, pd.shape, mesh), tree,
        is_leaf=is_pdef)


def pad_to(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


# ---------------------------------------------------------------------------
# numeric building blocks
# ---------------------------------------------------------------------------


def rms_norm(x, gamma, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma + beta).astype(x.dtype)


def rope(x, positions, theta: float = 1e4):
    """Rotary embedding.  x: [..., seq, heads, head_dim]; positions [..., seq]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, half]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(logits, cap: float):
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


ACT = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}
