"""Unified model configuration for all assigned architectures.

One config describes a pattern-interleaved decoder stack (dense attention,
local attention, Mamba-2 SSD, RG-LRU), dense or MoE MLPs, plus the
whisper encoder-decoder special case.  Sharding-induced padding
(``pad_heads_multiple``, ``pad_vocab_multiple``) is explicit: padded q/kv
heads have zero output-projection rows and padded vocab rows never win
the softmax, so logical outputs are unchanged; the FLOP overhead is
reported per arch in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from .common import pad_to


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # repeating block pattern; entries: attn | local | ssm | rglru
    pattern: Tuple[str, ...] = ("attn",)
    # attention flavour
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    local_window: int = 0
    attn_softcap: float = 0.0
    # mlp
    mlp_act: str = "silu"
    use_post_norm: bool = False
    # moe
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # ssm (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    ssm_groups: int = 1
    # rglru (recurrentgemma)
    rglru_width: int = 0            # recurrence width (defaults to d_model)
    rglru_conv: int = 4
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500         # precomputed frame embeddings (stub)
    use_layer_norm_bias: bool = False
    # embeddings / misc
    tie_embeddings: bool = False
    emb_scale: bool = False         # gemma-style sqrt(d) embedding scale
    norm_eps: float = 1e-6
    # sharding-induced padding (1 = no padding; 16 on the production mesh)
    pad_heads_multiple: int = 1
    pad_vocab_multiple: int = 1
    # numerics
    remat: bool = True
    # dry-run probes: unroll every layer (no scan) so cost_analysis counts
    # each layer explicitly (see launch/roofline.py methodology)
    force_unroll: bool = False

    # ---- derived ---------------------------------------------------------
    @property
    def padded_heads(self) -> int:
        return pad_to(self.n_heads, self.pad_heads_multiple)

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab, max(self.pad_vocab_multiple, 1))

    @property
    def ssm_heads(self) -> int:
        return (self.d_model * self.ssm_expand) // self.ssm_head_dim

    @property
    def rec_width(self) -> int:
        return self.rglru_width or self.d_model

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        reps = math.ceil(self.n_layers / len(self.pattern))
        return tuple((self.pattern * reps)[: self.n_layers])

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.head_dim
        n = self.padded_vocab * d                       # embedding
        if not self.tie_embeddings:
            n += self.padded_vocab * d
        for kind in self.layer_kinds:
            if kind in ("attn", "local"):
                n += d * (self.n_heads + 2 * self.n_kv_heads) * hd
                n += self.n_heads * hd * d
            elif kind == "ssm":
                di = d * self.ssm_expand
                n += d * (2 * di + 2 * self.ssm_groups * self.ssm_state
                          + self.ssm_heads)
                n += di * d
            elif kind == "rglru":
                r = self.rec_width
                n += d * 2 * r + r * d + 3 * r
            if self.n_experts:
                n += d * self.n_experts                 # router
                n += self.n_experts * 3 * d * self.moe_d_ff
                n += self.n_shared_experts * 3 * d * self.d_ff
            elif self.d_ff:
                n += 3 * d * self.d_ff
        if self.is_encoder_decoder:
            for _ in range(self.n_encoder_layers):
                n += 4 * d * d + 3 * d * self.d_ff      # enc self-attn + mlp
                n += 4 * d * d                          # dec cross-attn
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        inactive = (self.n_experts - self.top_k) * 3 * self.d_model \
            * self.moe_d_ff * len(self.layer_kinds)
        return full - inactive
