"""Dense gated MLPs and token-choice MoE.

MoE uses the permute -> grouped-GEMM -> unpermute formulation (sort-based
dispatch with a static per-expert capacity) rather than GShard's
``[groups, seq, experts, capacity]`` one-hot einsum — the one-hot dispatch
tensor is O(S·E·C) and does not fit at seq_len 4096 with 64 experts.
The rank-within-expert computation is the same sort + run-start trick the
ParAC engine uses for slab scatters (repro.core.parac).

Expert weights are sharded ``experts -> model`` (expert parallelism);
the scatter into expert buffers lowers to the all-to-all-style collective
permutes XLA SPMD chooses for the production mesh.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import PDef, ACT
from .config import ModelConfig
from repro.distributed.ctx import constrain


def mlp_pdefs(cfg: ModelConfig, d_ff: int) -> dict:
    d = cfg.d_model
    return {
        "w_gate": PDef((d, d_ff), ("embed", "mlp")),
        "w_up": PDef((d, d_ff), ("embed", "mlp")),
        "w_down": PDef((d_ff, d), ("mlp", "embed")),
    }


def mlp_fwd(p, cfg: ModelConfig, x):
    act = ACT[cfg.mlp_act]
    h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) \
        * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = constrain(h, "batch", None, "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return constrain(y, "batch", None, "act_embed")


def moe_pdefs(cfg: ModelConfig) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    p = {
        "router": PDef((d, E), ("embed", None)),
        "w_gate": PDef((E, d, f), ("experts", "embed", "expert_mlp")),
        "w_up": PDef((E, d, f), ("experts", "embed", "expert_mlp")),
        "w_down": PDef((E, f, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_pdefs(cfg, cfg.d_ff * cfg.n_shared_experts)
    return p


def _rank_in_group(keys: jnp.ndarray) -> jnp.ndarray:
    """Occurrence rank of each element within its (sorted) key group."""
    n = keys.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), keys[1:] != keys[:-1]])
    run_start = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(is_start, idx, 0))
    return idx - run_start


def moe_fwd(p, cfg: ModelConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output, aux_loss).  x: [B, S, D].

    *Grouped* dispatch: each batch row is an independent routing group
    (GShard-style groups == data shards), so the sort/scatter stays local
    to the data shard and only the expert dimension moves across the
    ``model`` axis (the all-to-all).  A global sort would destroy the
    batch sharding and replicate token buffers on every device.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, K)                      # [B, S, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(eid[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # ---- permute within each group: sort (B, S·K) by expert --------------
    # capacity per (group, expert): cf·S·K/E, floored so that single-token
    # decode groups are dropless (each expert gets ≤ 1 of a token's K).
    C = min(S * K, max(int(cfg.capacity_factor * S * K / E), 4))
    a_exp = eid.reshape(B, S * K).astype(jnp.int32)
    a_gate = gate.reshape(B, S * K)
    order = jnp.argsort(a_exp, axis=-1, stable=True)         # [B, S*K]
    s_exp = jnp.take_along_axis(a_exp, order, axis=-1)
    s_tok = order // K
    s_gate = jnp.take_along_axis(a_gate, order, axis=-1)
    idx = jnp.arange(S * K, dtype=jnp.int32)[None, :]
    is_start = jnp.concatenate(
        [jnp.ones((B, 1), bool), s_exp[:, 1:] != s_exp[:, :-1]], axis=1)
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(is_start, idx, 0), axis=1)
    rank = idx - run_start
    fits = rank < C
    slot = jnp.where(fits, s_exp * C + rank, E * C)          # drop overflow
    # all gathers/scatters are vmapped over the group dim so they lower
    # with operand-batching dims — the SPMD partitioner then keeps them
    # sharded on batch instead of falling back to replication.
    gathered = jax.vmap(lambda xr, tr: xr[tr])(x, s_tok)     # [B,S*K,D]
    buf = jax.vmap(
        lambda sl, g: jnp.zeros((E * C, D), x.dtype).at[sl].set(
            g, mode="drop"))(slot, gathered).reshape(B, E, C, D)
    buf = constrain(buf, "batch", "experts", None, None)

    # ---- grouped GEMMs (expert-parallel over 'model') --------------------
    act = ACT[cfg.mlp_act]
    h = act(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) \
        * jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = constrain(h, "batch", "experts", None, None)
    y = jnp.einsum("becf,efd->becd", h, p["w_down"])
    y = constrain(y, "batch", "experts", None, None).reshape(B, E * C, D)

    # ---- unpermute + combine ---------------------------------------------
    contrib = jax.vmap(lambda yr, sl: yr[sl])(
        y, jnp.minimum(slot, E * C - 1)) \
        * s_gate[..., None].astype(x.dtype)
    out = jax.vmap(
        lambda st, cb: jnp.zeros((S, D), x.dtype).at[st].add(cb))(
        s_tok, jnp.where(fits[..., None], contrib, 0))
    out = constrain(out, "batch", None, None)
    if cfg.n_shared_experts:
        out = out + mlp_fwd(p["shared"], cfg, x)
    return out, aux
