"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

h_t = a_t · h_{t-1} + √(1 − a_t²) · (i_t ⊙ x_t),   a_t = a^(c·r_t)

with a = sigmoid(Λ) per channel, r/i input-dependent sigmoid gates, c=8.
Training uses an associative scan over time (affine recurrence); decode
keeps an O(1) ``[B, rec_width]`` state — hence ``long_500k`` runs for
this family.  The block is conv1d(k=4) -> RG-LRU -> gated output, as in
the paper's recurrent block.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import PDef
from .config import ModelConfig
from repro.distributed.ctx import constrain

_C = 8.0


def rglru_pdefs(cfg: ModelConfig) -> dict:
    d, r, K = cfg.d_model, cfg.rec_width, cfg.rglru_conv
    return {
        "w_in": PDef((d, r), ("embed", "rec")),
        "w_gate": PDef((d, r), ("embed", "rec")),
        "conv": PDef((K, r), ("conv", "rec"), init="normal", scale=0.5),
        "w_r": PDef((r, r), ("embed", "rec")),
        "w_i": PDef((r, r), ("embed", "rec")),
        "lam": PDef((r,), ("rec",), init="ones", scale=1.0),
        "w_out": PDef((r, d), ("rec", "embed")),
    }


def _conv_tail(x, w, tail):
    K = w.shape[0]
    pad = tail if tail is not None else jnp.zeros(
        (x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out, xp[:, -(K - 1):, :]


def rglru_fwd(p, cfg: ModelConfig, x, *, state=None,
              return_state: bool = False):
    """x: [B,S,D].  state: dict(h:[B,r], conv:[B,K-1,r])."""
    xb = constrain(jnp.einsum("bsd,dr->bsr", x, p["w_in"]),
                   "batch", None, "rec")
    gate = constrain(jnp.einsum("bsd,dr->bsr", x, p["w_gate"]),
                     "batch", None, "rec")
    xc, tail = _conv_tail(xb, p["conv"],
                          state["conv"] if state is not None else None)
    r = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", xc, p["w_r"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", xc, p["w_i"])
                       .astype(jnp.float32))
    # log a_t = c · r_t · log sigmoid(Λ)  (≤ 0)
    log_a = _C * r * jax.nn.log_sigmoid(8.0 * p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    v = mult * i * xc.astype(jnp.float32)
    # affine scan h_t = a_t h_{t-1} + v_t
    def combine(e1, e2):
        a1, v1 = e1
        a2, v2 = e2
        return a1 * a2, v2 + a2 * v1
    if state is not None:
        v = v.at[:, 0, :].add(a[:, 0, :] * state["h"])
    ascan, h = jax.lax.associative_scan(combine, (a, v), axis=1)
    y = (h.astype(x.dtype) * jax.nn.gelu(gate, approximate=True))
    out = jnp.einsum("bsr,rd->bsd", y, p["w_out"])
    if return_state:
        return out, {"h": h[:, -1, :], "conv": tail}
    return out


def rglru_init_state(cfg: ModelConfig, batch: int, dtype):
    r, K = cfg.rec_width, cfg.rglru_conv
    return {"h": jnp.zeros((batch, r), jnp.float32),
            "conv": jnp.zeros((batch, K - 1, r), dtype)}


def rglru_decode(p, cfg: ModelConfig, x, state):
    return rglru_fwd(p, cfg, x, state=state, return_state=True)
