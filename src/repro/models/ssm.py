"""Mamba-2 (SSD — state-space duality) mixer: chunked training form and
O(1)-state decode step  [arXiv:2405.21060].

Training runs the standard chunked SSD decomposition with chunk length Q:
intra-chunk quadratic (attention-like with decay mask) + inter-chunk
state recurrence via an associative scan over chunks.  Decode keeps a
``[B, H, N, P]`` state and a rolling depthwise-conv tail — this is why
``long_500k`` runs for this family (DESIGN.md §4).

Sharding: ssm heads -> ``model`` (64 heads / 16 = 4 per device for
mamba2-1.3b); B̄/C̄ group projections are replicated (G=1).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import PDef, rms_norm
from .config import ModelConfig
from repro.distributed.ctx import constrain


def ssm_pdefs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = d * cfg.ssm_expand
    H, N, G, K = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_conv
    return {
        "wz": PDef((d, di), ("embed", "mlp")),
        "wx": PDef((d, di), ("embed", "mlp")),
        "wB": PDef((d, G * N), ("embed", None)),
        "wC": PDef((d, G * N), ("embed", None)),
        "wdt": PDef((d, H), ("embed", "ssm_heads")),
        "conv_x": PDef((K, di), ("conv", "mlp"), init="normal", scale=0.5),
        "conv_B": PDef((K, G * N), ("conv", None), init="normal", scale=0.5),
        "conv_C": PDef((K, G * N), ("conv", None), init="normal", scale=0.5),
        "A_log": PDef((H,), ("ssm_heads",), init="zeros"),
        "D": PDef((H,), ("ssm_heads",), init="ones"),
        "dt_bias": PDef((H,), ("ssm_heads",), init="zeros"),
        "norm": PDef((di,), ("mlp",), init="zeros"),
        "wo": PDef((di, d), ("mlp", "embed")),
    }


def _causal_conv(x, w, tail: Optional[jnp.ndarray] = None):
    """Depthwise causal conv.  x: [B,S,C], w: [K,C]; tail: [B,K-1,C]."""
    K = w.shape[0]
    pad = tail if tail is not None else jnp.zeros(
        (x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out), xp[:, -(K - 1):, :]


def _ssd_chunked(xh, dt, A, B_, C_, Q: int, h0=None):
    """Chunked SSD.  xh:[B,S,H,P] dt:[B,S,H] A:[H] B_,C_:[B,S,H,N].

    Returns (y:[B,S,H,P], h_last:[B,H,N,P])."""
    B, S, H, P = xh.shape
    N = B_.shape[-1]
    nc = S // Q
    r = lambda t: t.reshape((B, nc, Q) + t.shape[2:])
    xc, dtc, Bc, Cc = r(xh), r(dt), r(B_), r(C_)
    a = dtc * A                                  # [B,nc,Q,H] log-decay (<0)
    cum = jnp.cumsum(a, axis=2)
    # intra-chunk: y_i += Σ_{j≤i} exp(cum_i − cum_j)·dt_j·(C_i·B_j)·x_j
    # mask the *exponent* (not the result): exp at masked i<j positions
    # overflows and 0·inf = NaN in the cotangent otherwise.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,nc,i,j,H]
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    decay = jnp.exp(jnp.where(tri, diff, -jnp.inf))
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc)
    w = scores * decay * dtc[:, :, None, :, :]
    w = constrain(w, "batch", None, None, None, "ssm_heads")
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w.astype(xh.dtype), xc)
    # chunk summaries: state_c = Σ_j exp(cum_last − cum_j)·dt_j·B_j ⊗ x_j
    seg = jnp.exp(cum[:, :, -1:, :] - cum) * dtc                  # [B,nc,Q,H]
    states = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp", seg, Bc, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                       # [B,nc,H]
    # inter-chunk recurrence: h_c = chunk_decay_c · h_{c-1} + states_c
    def combine(e1, e2):
        d1, s1 = e1
        d2, s2 = e2
        return d1 * d2, s2 + d2[..., None, None] * s1
    dscan, sscan = jax.lax.associative_scan(
        combine, (chunk_decay, states), axis=1)
    if h0 is not None:
        sscan = sscan + dscan[..., None, None] * h0[:, None]
    h_prev = jnp.concatenate(
        [h0[:, None] if h0 is not None else jnp.zeros_like(sscan[:, :1]),
         sscan[:, :-1]], axis=1)                                   # [B,nc,H,N,P]
    y_inter = jnp.einsum("bcihn,bchnp->bcihp",
                         (Cc * jnp.exp(cum)[..., None]).astype(xh.dtype),
                         h_prev.astype(xh.dtype))
    y = (y_intra + y_inter).reshape(B, S, H, P)
    h_last = sscan[:, -1]
    return y, h_last


def ssm_fwd(p, cfg: ModelConfig, x, *, state=None, return_state: bool = False):
    """x: [B,S,D].  state: dict(h, conv) for prefill continuation."""
    B, S, D = x.shape
    di = D * cfg.ssm_expand
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    G = cfg.ssm_groups
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xs = jnp.einsum("bsd,de->bse", x, p["wx"])
    Br = jnp.einsum("bsd,de->bse", x, p["wB"])
    Cr = jnp.einsum("bsd,de->bse", x, p["wC"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"])
    tails = state["conv"] if state is not None else None
    K = cfg.ssm_conv
    xs, tail_x = _causal_conv(xs, p["conv_x"],
                              tails["x"] if tails else None)
    Bc, tail_B = _causal_conv(Br, p["conv_B"],
                              tails["B"] if tails else None)
    Cc, tail_C = _causal_conv(Cr, p["conv_C"],
                              tails["C"] if tails else None)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = constrain(xs.reshape(B, S, H, P), "batch", None, "ssm_heads", None)
    rep = H // G
    Bh = jnp.repeat(Bc.reshape(B, S, G, N), rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(Cc.reshape(B, S, G, N), rep, axis=2).astype(jnp.float32)
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    h0 = state["h"] if state is not None else None
    y, h_last = _ssd_chunked(xh, dt, A, Bh, Ch, Q, h0=h0)
    y = y + xh * p["D"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"])
    if return_state:
        return out, {"h": h_last,
                     "conv": {"x": tail_x, "B": tail_B, "C": tail_C}}
    return out


def ssm_init_state(cfg: ModelConfig, batch: int, dtype):
    di = cfg.d_model * cfg.ssm_expand
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    GN = cfg.ssm_groups * cfg.ssm_state
    K = cfg.ssm_conv
    return {
        "h": jnp.zeros((batch, H, N, P), jnp.float32),
        "conv": {"x": jnp.zeros((batch, K - 1, di), dtype),
                 "B": jnp.zeros((batch, K - 1, GN), dtype),
                 "C": jnp.zeros((batch, K - 1, GN), dtype)},
    }


def ssm_decode(p, cfg: ModelConfig, x, state):
    """Single-token decode.  x: [B,1,D]."""
    out, new_state = ssm_fwd(p, cfg, x, state=state, return_state=True)
    return out, new_state
