"""Unified pattern-interleaved decoder stack + whisper encoder-decoder.

Layers follow ``cfg.pattern`` repeated over ``n_layers`` (e.g. gemma3 is
``(local,)*5 + (attn,)`` and recurrentgemma ``(rglru, rglru, local)``).
Whole periods are scanned (``lax.scan`` over stacked params — keeps the
HLO small enough to compile 62-layer models against 512 devices); the
remainder layers are unrolled.

Public entry points (all pure functions of (params, inputs)):
  * ``pdefs(cfg)``                   — parameter declaration tree
  * ``fwd_train(params, cfg, tokens[, enc_frames])`` -> logits
  * ``loss_fn``                      — CE + z-loss + MoE aux
  * ``prefill`` / ``decode_step``    — cached serving paths
  * ``init_caches``                  — decode cache pytrees
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import PDef, rms_norm, layer_norm, is_pdef
from .config import ModelConfig
from . import attention as attn
from . import mlp as mlp_mod
from . import ssm as ssm_mod
from . import rglru as rglru_mod
from repro.distributed.ctx import constrain


# ---------------------------------------------------------------------------
# parameter declarations
# ---------------------------------------------------------------------------

def _norm_pdefs(cfg: ModelConfig) -> Dict[str, PDef]:
    if cfg.use_layer_norm_bias:
        return {"g": PDef((cfg.d_model,), (None,), init="ones"),
                "b": PDef((cfg.d_model,), (None,), init="zeros")}
    return {"g": PDef((cfg.d_model,), (None,), init="zeros")}


def _apply_norm(p, cfg: ModelConfig, x):
    if cfg.use_layer_norm_bias:
        return layer_norm(x, p["g"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["g"], cfg.norm_eps)


def _mixer_pdefs(cfg: ModelConfig, kind: str) -> dict:
    if kind in ("attn", "local"):
        return attn.attn_pdefs(cfg)
    if kind == "ssm":
        return ssm_mod.ssm_pdefs(cfg)
    if kind == "rglru":
        return rglru_mod.rglru_pdefs(cfg)
    raise ValueError(kind)


def _layer_pdefs(cfg: ModelConfig, kind: str) -> dict:
    p = {"ln1": _norm_pdefs(cfg), "mixer": _mixer_pdefs(cfg, kind)}
    if cfg.use_post_norm:
        p["pn1"] = _norm_pdefs(cfg)
    if cfg.n_experts:
        p["ln2"] = _norm_pdefs(cfg)
        p["mlp"] = mlp_mod.moe_pdefs(cfg)
    elif cfg.d_ff:
        p["ln2"] = _norm_pdefs(cfg)
        p["mlp"] = mlp_mod.mlp_pdefs(cfg, cfg.d_ff)
    if cfg.use_post_norm and "mlp" in p:
        p["pn2"] = _norm_pdefs(cfg)
    return p


def _stack_pdefs(tree, n: int):
    return jax.tree.map(
        lambda pd: PDef((n,) + pd.shape, ("layers",) + pd.axes,
                        init=pd.init, scale=pd.scale),
        tree, is_leaf=is_pdef)


def _split_layers(cfg: ModelConfig) -> Tuple[int, int]:
    if cfg.is_encoder_decoder or cfg.force_unroll:
        return 0, cfg.n_layers        # whisper/probes: fully unrolled
    period = len(cfg.pattern)
    return cfg.n_layers // period, cfg.n_layers % period


def pdefs(cfg: ModelConfig) -> dict:
    n_periods, rem = _split_layers(cfg)
    d = cfg.d_model
    p: Dict[str, Any] = {
        "embed": PDef((cfg.padded_vocab, d), ("vocab", "embed"),
                      init="embed", scale=0.02),
        "final_norm": _norm_pdefs(cfg),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = PDef((d, cfg.padded_vocab), ("embed", "vocab"))
    if n_periods:
        p["scan"] = {
            f"pos{t}": _stack_pdefs(_layer_pdefs(cfg, kind), n_periods)
            for t, kind in enumerate(cfg.pattern)}
    base = n_periods * len(cfg.pattern)
    p["rem"] = [_layer_pdefs(cfg, cfg.layer_kinds[base + t])
                for t in range(rem)]
    if cfg.is_encoder_decoder:
        p["enc"] = {
            "layers": [
                {"ln1": _norm_pdefs(cfg), "attn": attn.attn_pdefs(cfg),
                 "ln2": _norm_pdefs(cfg),
                 "mlp": mlp_mod.mlp_pdefs(cfg, cfg.d_ff)}
                for _ in range(cfg.n_encoder_layers)],
            "final_norm": _norm_pdefs(cfg),
        }
        p["cross"] = [
            {"ln": _norm_pdefs(cfg), "attn": attn.cross_attn_pdefs(cfg)}
            for _ in range(cfg.n_layers)]
    return p


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _block_train(p, cfg: ModelConfig, kind: str, x, aux):
    h = _apply_norm(p["ln1"], cfg, x)
    if kind in ("attn", "local"):
        h = attn.attn_fwd(p["mixer"], cfg, h, local=(kind == "local"))
    elif kind == "ssm":
        h = ssm_mod.ssm_fwd(p["mixer"], cfg, h)
    elif kind == "rglru":
        h = rglru_mod.rglru_fwd(p["mixer"], cfg, h)
    if cfg.use_post_norm:
        h = _apply_norm(p["pn1"], cfg, h)
    x = x + h
    if "mlp" in p:
        h = _apply_norm(p["ln2"], cfg, x)
        if cfg.n_experts:
            h, a = mlp_mod.moe_fwd(p["mlp"], cfg, h)
            aux = aux + a
        else:
            h = mlp_mod.mlp_fwd(p["mlp"], cfg, h)
        if cfg.use_post_norm:
            h = _apply_norm(p["pn2"], cfg, h)
        x = x + h
    return x, aux


def _block_decode(p, cfg: ModelConfig, kind: str, x, cache, cache_pos):
    h = _apply_norm(p["ln1"], cfg, x)
    if kind in ("attn", "local"):
        h, cache = attn.attn_decode(p["mixer"], cfg, h, cache, cache_pos,
                                    local=(kind == "local"))
    elif kind == "ssm":
        h, cache = ssm_mod.ssm_decode(p["mixer"], cfg, h, cache)
    elif kind == "rglru":
        h, cache = rglru_mod.rglru_decode(p["mixer"], cfg, h, cache)
    if cfg.use_post_norm:
        h = _apply_norm(p["pn1"], cfg, h)
    x = x + h
    if "mlp" in p:
        h = _apply_norm(p["ln2"], cfg, x)
        if cfg.n_experts:
            h, _ = mlp_mod.moe_fwd(p["mlp"], cfg, h)
        else:
            h = mlp_mod.mlp_fwd(p["mlp"], cfg, h)
        if cfg.use_post_norm:
            h = _apply_norm(p["pn2"], cfg, h)
        x = x + h
    return x, cache


# ---------------------------------------------------------------------------
# embedding / logits
# ---------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.emb_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return constrain(x, "batch", None, "act_embed")


def _logits(params, cfg: ModelConfig, x):
    x = _apply_norm(params["final_norm"], cfg, x)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    return constrain(logits.astype(jnp.float32), "batch", None, "vocab")


def _run_stack(params, cfg: ModelConfig, x, train: bool):
    aux = jnp.zeros((), jnp.float32)
    n_periods, rem = _split_layers(cfg)

    def period_fn(carry, pslice):
        xx, aa = carry
        for t, kind in enumerate(cfg.pattern):
            xx, aa = _block_train(pslice[f"pos{t}"], cfg, kind, xx, aa)
        return (xx, aa), ()

    if n_periods:
        fn = jax.checkpoint(period_fn) if (cfg.remat and train) else period_fn
        (x, aux), _ = jax.lax.scan(fn, (x, aux), params["scan"])
    base = n_periods * len(cfg.pattern)
    blk = jax.checkpoint(_block_train, static_argnums=(1, 2)) \
        if (cfg.remat and train) else _block_train
    for t in range(rem):
        x, aux = blk(params["rem"][t], cfg, cfg.layer_kinds[base + t], x, aux)
    return x, aux


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, enc_frames):
    """Whisper encoder over precomputed frame embeddings [B, T, D]."""
    T = enc_frames.shape[1]
    pos = _sinusoid(T, cfg.d_model, enc_frames.dtype)
    x = enc_frames + pos[None]
    for lp in params["enc"]["layers"]:
        h = _apply_norm(lp["ln1"], cfg, x)
        h = attn.attn_fwd(lp["attn"], cfg, h, local=False,
                          kv_mask=None, positions=jnp.zeros(
                              (x.shape[0], T), jnp.int32))  # no-rope: pos 0
        x = x + h
        h = _apply_norm(lp["ln2"], cfg, x)
        x = x + mlp_mod.mlp_fwd(lp["mlp"], cfg, h)
    return _apply_norm(params["enc"]["final_norm"], cfg, x)


def _sinusoid(T: int, d: int, dtype):
    pos = jnp.arange(T, dtype=jnp.float32)[:, None]
    div = jnp.exp(-jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (math.log(10000.0) / d))[None, :]
    pe = jnp.concatenate([jnp.sin(pos * div), jnp.cos(pos * div)], axis=-1)
    return pe[:, :d].astype(dtype)


def fwd_train(params, cfg: ModelConfig, tokens,
              enc_frames: Optional[jnp.ndarray] = None):
    """Teacher-forced forward -> (logits [B,S,Vp], aux_loss)."""
    x = _embed(params, cfg, tokens)
    if cfg.is_encoder_decoder:
        x = x + _sinusoid(tokens.shape[1], cfg.d_model, x.dtype)[None]
        enc_out = encode(params, cfg, enc_frames)
        aux = jnp.zeros((), jnp.float32)
        for li in range(cfg.n_layers):
            x, aux = _block_train(_get_layer(params, cfg, li), cfg,
                                  cfg.layer_kinds[li], x, aux)
            cp = params["cross"][li]
            x = x + attn.cross_attn_fwd(
                cp["attn"], cfg, _apply_norm(cp["ln"], cfg, x),
                attn.encode_cross_kv(cp["attn"], cfg, enc_out))
        return _logits(params, cfg, x), aux
    x, aux = _run_stack(params, cfg, x, train=True)
    return _logits(params, cfg, x), aux


def _get_layer(params, cfg: ModelConfig, li: int):
    n_periods, rem = _split_layers(cfg)
    period = len(cfg.pattern)
    if li < n_periods * period:
        c, t = divmod(li, period)
        return jax.tree.map(lambda a: a[c], params["scan"][f"pos{t}"])
    return params["rem"][li - n_periods * period]


def loss_fn(params, cfg: ModelConfig, tokens, targets,
            enc_frames: Optional[jnp.ndarray] = None):
    logits, aux = fwd_train(params, cfg, tokens, enc_frames)
    # all vocab-length ops stay elementwise over the vocab-sharded logits:
    # a take_along_axis gather here would force an unsharded fp32 copy
    # (~40 GB/device at 152k vocab) — use an iota-mask reduction instead.
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    if cfg.padded_vocab != cfg.vocab:
        logits = jnp.where(iota < cfg.vocab, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0),
                   axis=-1)
    ce = jnp.mean(lse - gold)
    zloss = 1e-4 * jnp.mean(jnp.square(lse))
    return ce + zloss + cfg.router_aux_weight * aux, (ce, aux)


# ---------------------------------------------------------------------------
# serving: caches, prefill, decode
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype):
    if kind in ("attn", "local"):
        length = min(max_len, cfg.local_window) if (
            kind == "local" and cfg.local_window) else max_len
        return attn.init_cache(cfg, batch, length, dtype)
    if kind == "ssm":
        return ssm_mod.ssm_init_state(cfg, batch, dtype)
    if kind == "rglru":
        return rglru_mod.rglru_init_state(cfg, batch, dtype)
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    n_periods, rem = _split_layers(cfg)
    caches: Dict[str, Any] = {}
    if n_periods:
        caches["scan"] = {}
        for t, kind in enumerate(cfg.pattern):
            one = _layer_cache(cfg, kind, batch, max_len, dtype)
            caches["scan"][f"pos{t}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_periods,) + a.shape),
                one)
    base = n_periods * len(cfg.pattern)
    caches["rem"] = [_layer_cache(cfg, cfg.layer_kinds[base + t], batch,
                                  max_len, dtype) for t in range(rem)]
    return caches


def decode_step(params, cfg: ModelConfig, caches, tokens, cache_pos,
                enc_out: Optional[jnp.ndarray] = None):
    """One decode step.  tokens: [B, 1] int32; cache_pos: scalar int32.

    Local-attention caches are rolling buffers of ``local_window``;
    positions are taken modulo the buffer length for those layers.
    """
    x = _embed(params, cfg, tokens)
    if cfg.is_encoder_decoder:
        x = x + _sinusoid_at(cache_pos, cfg.d_model, x.dtype)
        new_rem = []
        for li in range(cfg.n_layers):
            c, nc = _decode_one(params, cfg, li, x, caches["rem"][li],
                                cache_pos)
            x = c
            # cross attention after self-attn block
            cp = params["cross"][li]
            x = x + attn.cross_attn_fwd(
                cp["attn"], cfg, _apply_norm(cp["ln"], cfg, x),
                attn.encode_cross_kv(cp["attn"], cfg, enc_out))
            new_rem.append(nc)
        logits = _logits(params, cfg, x)[:, 0]
        return logits, {"rem": new_rem}

    n_periods, rem = _split_layers(cfg)
    new_caches: Dict[str, Any] = {}
    if n_periods:
        def period_fn(carry, slices):
            xx, = carry
            pslice, cslice = slices
            ncs = {}
            for t, kind in enumerate(cfg.pattern):
                xx, nc = _block_decode(pslice[f"pos{t}"], cfg, kind, xx,
                                       cslice[f"pos{t}"], cache_pos)
                ncs[f"pos{t}"] = nc
            return (xx,), ncs
        (x,), new_scan = jax.lax.scan(
            period_fn, (x,), (params["scan"], caches["scan"]))
        new_caches["scan"] = new_scan
    new_caches["rem"] = []
    base = n_periods * len(cfg.pattern)
    for t in range(rem):
        x, nc = _block_decode(params["rem"][t], cfg,
                              cfg.layer_kinds[base + t], x,
                              caches["rem"][t], cache_pos)
        new_caches["rem"].append(nc)
    logits = _logits(params, cfg, x)[:, 0]
    return logits, new_caches


def _decode_one(params, cfg, li, x, cache, cache_pos):
    kind = cfg.layer_kinds[li]
    return _block_decode(_get_layer(params, cfg, li), cfg, kind, x,
                         cache, cache_pos)


def _sinusoid_at(pos, d: int, dtype):
    div = jnp.exp(-jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (math.log(10000.0) / d))
    ang = pos.astype(jnp.float32) * div
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[:d]
    return pe.astype(dtype)[None, None, :]


def prefill(params, cfg: ModelConfig, tokens, max_len: int,
            enc_frames: Optional[jnp.ndarray] = None, dtype=jnp.bfloat16):
    """Full-sequence forward that also fills decode caches.

    Returns (logits [B,S,Vp], caches).  For recurrent blocks the state
    after the last position is stored; for attention the K/V of all
    positions are written into buffers of length ``max_len``.
    """
    B, S = tokens.shape
    x = _embed(params, cfg, tokens)
    if cfg.is_encoder_decoder:
        # whisper: encode once, run decoder layers filling self-attn caches
        x = x + _sinusoid(S, cfg.d_model, x.dtype)[None]
        enc_out = encode(params, cfg, enc_frames)
        caches = init_caches(cfg, B, max_len, dtype)
        for li in range(cfg.n_layers):
            p = params["rem"][li]
            h = _apply_norm(p["ln1"], cfg, x)
            h, kv = attn.attn_fwd(p["mixer"], cfg, h, local=False,
                                  return_cache=True)
            cache = caches["rem"][li]
            caches["rem"][li] = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], kv["k"].astype(cache["k"].dtype),
                    (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], kv["v"].astype(cache["v"].dtype),
                    (0, 0, 0, 0))}
            x = x + h
            cp = params["cross"][li]
            x = x + attn.cross_attn_fwd(
                cp["attn"], cfg, _apply_norm(cp["ln"], cfg, x),
                attn.encode_cross_kv(cp["attn"], cfg, enc_out))
            if "mlp" in p:
                h = _apply_norm(p["ln2"], cfg, x)
                x = x + mlp_mod.mlp_fwd(p["mlp"], cfg, h)
        return _logits(params, cfg, x), caches
    caches = init_caches(cfg, B, max_len, dtype)
    n_periods, rem = _split_layers(cfg)

    def apply_block_prefill(p, kind, xx, cache):
        h = _apply_norm(p["ln1"], cfg, xx)
        if kind in ("attn", "local"):
            h, kv = attn.attn_fwd(p["mixer"], cfg, h,
                                  local=(kind == "local"), return_cache=True)
            L = cache["k"].shape[1]
            if kind == "local" and cfg.local_window and S > L:
                # keep the last window, aligned to position mod window
                ks, vs = kv["k"][:, -L:], kv["v"][:, -L:]
                shift = S % L
                ks = jnp.roll(ks, shift, axis=1)
                vs = jnp.roll(vs, shift, axis=1)
                cache = {"k": ks.astype(cache["k"].dtype),
                         "v": vs.astype(cache["v"].dtype)}
            else:
                cache = {
                    "k": jax.lax.dynamic_update_slice(
                        cache["k"], kv["k"].astype(cache["k"].dtype),
                        (0, 0, 0, 0)),
                    "v": jax.lax.dynamic_update_slice(
                        cache["v"], kv["v"].astype(cache["v"].dtype),
                        (0, 0, 0, 0)),
                }
        elif kind == "ssm":
            h, cache = ssm_mod.ssm_fwd(p["mixer"], cfg, h, return_state=True)
        elif kind == "rglru":
            h, cache = rglru_mod.rglru_fwd(p["mixer"], cfg, h,
                                           return_state=True)
        if cfg.use_post_norm:
            h = _apply_norm(p["pn1"], cfg, h)
        xx = xx + h
        if "mlp" in p:
            h = _apply_norm(p["ln2"], cfg, xx)
            if cfg.n_experts:
                h, _ = mlp_mod.moe_fwd(p["mlp"], cfg, h)
            else:
                h = mlp_mod.mlp_fwd(p["mlp"], cfg, h)
            if cfg.use_post_norm:
                h = _apply_norm(p["pn2"], cfg, h)
            xx = xx + h
        return xx, cache

    if n_periods:
        def period_fn(carry, slices):
            xx, = carry
            pslice, cslice = slices
            ncs = {}
            for t, kind in enumerate(cfg.pattern):
                xx, nc = apply_block_prefill(pslice[f"pos{t}"], kind, xx,
                                             cslice[f"pos{t}"])
                ncs[f"pos{t}"] = nc
            return (xx,), ncs
        (x,), new_scan = jax.lax.scan(
            period_fn, (x,), (params["scan"], caches["scan"]))
        caches["scan"] = new_scan
    base = n_periods * len(cfg.pattern)
    for t in range(rem):
        x, nc = apply_block_prefill(params["rem"][t],
                                    cfg.layer_kinds[base + t], x,
                                    caches["rem"][t])
        caches["rem"][t] = nc
    return _logits(params, cfg, x), caches
