"""Observability control plane: metrics registry + time series,
Prometheus scrape endpoint, request lifecycle tracing, overload
detection, flight recorder + post-mortem dumps, numerical-health
instruments.  See ``docs/observability.md`` for the metric glossary
and wiring quickstarts."""
from repro.obs.flight import NULL_FLIGHT, FlightRecorder, NullFlight
from repro.obs.health import HealthMonitor
from repro.obs.histogram import (DEFAULT_LATENCY_BUCKETS_S, bucket_index,
                                 percentile, quantile_from_counts, summarize)
from repro.obs.overload import OverloadDetector, SustainedThresholdDetector
from repro.obs.prometheus import MetricsServer, maybe_serve, render
from repro.obs.registry import (NULL, CardinalityError, Counter, Gauge,
                                Histogram, MetricsRegistry, NullRegistry)
from repro.obs.tracing import (RequestTrace, Span, Tracer,
                               trace_from_request)

__all__ = [
    "DEFAULT_LATENCY_BUCKETS_S", "bucket_index", "percentile",
    "quantile_from_counts", "summarize",
    "NULL", "CardinalityError", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "NullRegistry",
    "MetricsServer", "maybe_serve", "render",
    "RequestTrace", "Span", "Tracer", "trace_from_request",
    "OverloadDetector", "SustainedThresholdDetector",
    "NULL_FLIGHT", "FlightRecorder", "NullFlight", "HealthMonitor",
]
