"""Flight recorder: a bounded ring of typed structured events with
post-mortem dumps on incidents.

The metrics registry answers "how much / how fast"; the flight
recorder answers "what happened, in what order" when something breaks.
Serving layers record typed events — ``admit``, ``retire``, ``evict``,
``adopt``, ``compaction``, ``eject``/``readmit``, ``failover``,
``detector_transition`` — into one thread-safe ring buffer, stamped
with the request's ``rid``/``trace_id`` so a dump cross-references the
Chrome trace (``--trace-json``) row for row.

Hook pattern matches ``metrics=``/``tracer=``: layers take
``flight=None`` and substitute :data:`NULL_FLIGHT`; call sites
pre-bind event kinds once at construction (:meth:`FlightRecorder.bind`
returns a callable ``_BoundEvent``) so the hot path pays one dict
build + one lock acquire per event and never a branch on "is the
recorder on".  Nothing here touches the device.

**Incidents** — a driver crash, a replica ejection, a sustained-
overload flip, or a configurable SLO-miss streak — trigger a
**post-mortem dump**: JSONL of the last ``dump_events`` events plus a
``ClusterStats`` snapshot and a registry sample, written to
``postmortem_dir``.  The dump runs on a short-lived daemon thread:
incidents are detected *under* serving locks (the router ejects inside
the cluster lock; ``SolveCluster.stats()`` takes that same lock), so
the trigger path only snapshots the ring under the recorder lock and
defers the stats/registry/file work.  :meth:`flush` joins outstanding
dump threads (tests and launchers call it before asserting/exiting);
``max_dumps`` bounds a crash loop's disk damage.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time
from collections import deque
from typing import Callable, Dict, List, Optional


class _BoundEvent:
    """A pre-bound event emitter: kind + static labels frozen at bind
    time, per-event fields merged in ``__call__``.  One of these per
    (call site, kind) lives for the recorder's lifetime."""

    __slots__ = ("_rec", "_kind", "_static")

    def __init__(self, rec: "FlightRecorder", kind: str, static: Dict):
        self._rec = rec
        self._kind = kind
        self._static = static

    def __call__(self, **fields) -> None:
        self._rec._record(self._kind, self._static, fields)


class _NullEvent:
    __slots__ = ()

    def __call__(self, **fields) -> None:
        pass


_NULL_EVENT = _NullEvent()


class NullFlight:
    """Inert recorder: binds no-op events, drops records, never dumps.
    Layers hold this when ``flight=None`` so instrumented code stays
    branch-free (same contract as the NULL metrics registry)."""

    def bind(self, kind: str, **static) -> _NullEvent:
        return _NULL_EVENT

    def record(self, kind: str, **fields) -> None:
        pass

    def incident(self, reason: str, **context) -> None:
        return None

    def dump(self, reason: str, **context) -> Optional[str]:
        return None

    def attach(self, *, stats_fn=None, registry=None) -> None:
        pass

    def flush(self, timeout: Optional[float] = None) -> bool:
        return True

    def events(self, last: Optional[int] = None) -> List[Dict]:
        return []

    def stats(self) -> Dict[str, object]:
        return {"recorded": 0, "dropped": 0, "incidents": 0, "dumps": 0}


NULL_FLIGHT = NullFlight()


def _registry_series(registry) -> Dict[str, Dict[str, object]]:
    """Compact one-line-able snapshot of every registered series:
    ``{metric: {"{a=b}": value | {"count": n, "sum": s}}}``."""
    out: Dict[str, Dict[str, object]] = {}
    for m in registry.collect():
        series: Dict[str, object] = {}
        for key, child in m.children():
            lbl = "{" + ",".join(
                f"{n}={v}" for n, v in zip(m.label_names, key)) + "}" \
                if key else ""
            snap = child.snapshot()
            if isinstance(snap, tuple):          # histogram
                total, s, _counts = snap
                series[lbl] = {"count": total, "sum": s}
            else:
                series[lbl] = snap
        out[m.name] = series
    return out


class FlightRecorder:
    """Thread-safe bounded ring buffer of typed structured events.

    Args:
        capacity: ring size; the oldest events fall off (counted as
            ``dropped``) — the recorder must never hoard host memory.
        postmortem_dir: where incident dumps land (``None`` disables
            dumping; events still record and :meth:`events` still
            answers).
        dump_events: how many trailing events a dump carries.
        slo_miss_streak: ``N`` consecutive ``retire`` events with
            ``status="deadline_missed"`` raise an ``slo_miss_streak``
            incident (``None`` disables the trigger).
        max_dumps: incident-dump cap per recorder lifetime (a crash
            loop must not fill the disk); explicit :meth:`dump` calls
            are not capped.
        clock: injectable event timestamp source (tests); defaults to
            ``time.perf_counter`` — the serving layers' clock, so event
            ``t`` joins request lifecycle stamps directly.
    """

    def __init__(self, *, capacity: int = 4096,
                 postmortem_dir: Optional[str] = None,
                 dump_events: int = 512,
                 slo_miss_streak: Optional[int] = None,
                 max_dumps: int = 8,
                 clock: Optional[Callable[[], float]] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.postmortem_dir = postmortem_dir
        self.dump_events = dump_events
        self.max_dumps = max_dumps
        self._slo_miss_streak = slo_miss_streak
        self._clock = clock if clock is not None else time.perf_counter
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=capacity)
        self._seq = 0
        self._slo_streak = 0
        self.recorded = 0
        self.dropped = 0
        self.incidents = 0
        self.dumps = 0
        self.dump_errors = 0
        self.dump_paths: List[str] = []
        self._stats_fn: Optional[Callable[[], Dict]] = None
        self._registry = None
        self._gauges = None
        self._threads_lock = threading.Lock()
        self._dump_threads: List[threading.Thread] = []

    # -- wiring --------------------------------------------------------------
    def attach(self, *, stats_fn: Optional[Callable[[], Dict]] = None,
               registry=None) -> None:
        """Late-bind the incident-dump context: ``stats_fn`` (e.g.
        ``lambda: cluster.stats().as_dict()``) and the metrics registry
        to sample.  Both are called on the dump thread, never under
        serving locks held by the trigger."""
        if stats_fn is not None:
            self._stats_fn = stats_fn
        if registry is not None:
            self._registry = registry
            if self._gauges is None:
                self._gauges = {
                    "recorded": registry.gauge(
                        "repro_flight_events",
                        "events recorded by the flight recorder"),
                    "dropped": registry.gauge(
                        "repro_flight_dropped",
                        "events aged off the flight-recorder ring"),
                    "incidents": registry.gauge(
                        "repro_flight_incidents",
                        "incidents (crash/eject/overload/SLO-streak) "
                        "seen by the flight recorder"),
                    "dumps": registry.gauge(
                        "repro_flight_dumps",
                        "post-mortem dumps written"),
                }
                registry.on_collect(self._collect_gauges)

    def _collect_gauges(self, reg) -> None:
        st = self.stats()
        for key, g in self._gauges.items():
            g.set(float(st[key]))

    def bind(self, kind: str, **static) -> _BoundEvent:
        """Pre-bind an event kind plus static fields (replica index,
        component name) — the off-hot-path half of every call site."""
        return _BoundEvent(self, kind, dict(static))

    # -- recording -----------------------------------------------------------
    def record(self, kind: str, **fields) -> None:
        """One-shot record (cold call sites); hot paths use a bound
        event from :meth:`bind` instead."""
        self._record(kind, None, fields)

    def _record(self, kind: str, static: Optional[Dict],
                fields: Dict) -> None:
        streak_hit = None
        with self._lock:
            self._seq += 1
            ev: Dict[str, object] = {"seq": self._seq,
                                     "t": self._clock(), "kind": kind}
            if static:
                ev.update(static)
            if fields:
                ev.update(fields)
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)
            self.recorded += 1
            if self._slo_miss_streak is not None and kind == "retire":
                if fields.get("status") == "deadline_missed":
                    self._slo_streak += 1
                    if self._slo_streak >= self._slo_miss_streak:
                        streak_hit = self._slo_streak
                        self._slo_streak = 0
                else:
                    self._slo_streak = 0
        if streak_hit is not None:
            self.incident("slo_miss_streak", streak=streak_hit)

    # -- incidents and dumps -------------------------------------------------
    def incident(self, reason: str, **context) -> None:
        """Record an ``incident`` event and (when a ``postmortem_dir``
        is configured and the dump cap has room) write a post-mortem on
        a daemon thread.  Safe to call under serving locks: only the
        ring snapshot happens synchronously."""
        self._record("incident", {"reason": reason}, context)
        with self._lock:
            self.incidents += 1
            if self.postmortem_dir is None or self.dumps >= self.max_dumps:
                return
            self.dumps += 1
            n = self.dumps
            snapshot = list(self._events)[-self.dump_events:]
            rec_stats = self._stats_locked()
        path = self._dump_path(n, reason)
        th = threading.Thread(
            target=self._write_dump,
            args=(path, reason, context, snapshot, rec_stats),
            name="flight-postmortem", daemon=True)
        with self._threads_lock:
            self._dump_threads.append(th)
        th.start()

    def dump(self, reason: str, **context) -> Optional[str]:
        """Synchronous dump (benches, bug reports): writes immediately
        on the calling thread and returns the path.  Do not call under
        a lock that :attr:`attach`'s ``stats_fn`` needs."""
        with self._lock:
            if self.postmortem_dir is None:
                return None
            self.dumps += 1
            n = self.dumps
            snapshot = list(self._events)[-self.dump_events:]
            rec_stats = self._stats_locked()
        path = self._dump_path(n, reason)
        self._write_dump(path, reason, context, snapshot, rec_stats)
        return path

    def flush(self, timeout: Optional[float] = None) -> bool:
        """Join outstanding dump threads; returns ``False`` if any is
        still writing at the timeout."""
        deadline = None if timeout is None else \
            time.monotonic() + timeout
        with self._threads_lock:
            pending = list(self._dump_threads)
        ok = True
        for th in pending:
            t = None if deadline is None else \
                max(deadline - time.monotonic(), 0.0)
            th.join(timeout=t)
            ok = ok and not th.is_alive()
        with self._threads_lock:
            self._dump_threads = [t for t in self._dump_threads
                                  if t.is_alive()]
        return ok

    def _dump_path(self, n: int, reason: str) -> str:
        safe = re.sub(r"[^A-Za-z0-9_.-]+", "_", reason)[:40] or "incident"
        return os.path.join(self.postmortem_dir,
                            f"postmortem-{n:03d}-{safe}.jsonl")

    def _write_dump(self, path: str, reason: str, context: Dict,
                    snapshot: List[Dict], rec_stats: Dict) -> None:
        # a failing post-mortem must never take serving down with it —
        # errors are counted, not raised
        try:
            os.makedirs(self.postmortem_dir, exist_ok=True)
            lines = [json.dumps(
                {"type": "incident", "reason": reason,
                 "wall_time": time.time(), "context": context,
                 "recorder": rec_stats}, default=str)]
            for ev in snapshot:
                lines.append(json.dumps({"type": "event", **ev},
                                        default=str))
            if self._stats_fn is not None:
                try:
                    st = self._stats_fn()
                except Exception as exc:
                    st = {"error": repr(exc)}
                lines.append(json.dumps(
                    {"type": "cluster_stats", "stats": st}, default=str))
            if self._registry is not None:
                try:
                    series = _registry_series(self._registry)
                except Exception as exc:
                    series = {"error": repr(exc)}
                lines.append(json.dumps(
                    {"type": "metrics", "series": series}, default=str))
            with open(path, "w") as fh:
                fh.write("\n".join(lines) + "\n")
            with self._lock:
                self.dump_paths.append(path)
        except Exception:
            with self._lock:
                self.dump_errors += 1

    # -- reads ---------------------------------------------------------------
    def events(self, last: Optional[int] = None) -> List[Dict]:
        """Snapshot of the ring (oldest first); ``last`` trims to the
        trailing N."""
        with self._lock:
            evs = list(self._events)
        return evs[-last:] if last is not None else evs

    def _stats_locked(self) -> Dict[str, object]:
        return {"recorded": self.recorded, "dropped": self.dropped,
                "capacity": self.capacity, "incidents": self.incidents,
                "dumps": self.dumps, "dump_errors": self.dump_errors,
                "dump_paths": list(self.dump_paths),
                "slo_streak": self._slo_streak}

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return self._stats_locked()
