"""Numerical-health instruments: convergence diagnostics, EWMA drift
detection, and fleet-utilization gauges.

The metrics control plane watches *time*; this module watches
*quality*.  The paper's preconditioner randomizes its fill-in pattern
per construction (and rchol reports the same construction-to-
construction variance in iteration counts), so "is this family still
converging like its own history says it should" is a first-class
serving observable, not a test-time property.

:class:`HealthMonitor` consumes one :meth:`observe_retirement` per
retired request (host-side floats the engine already gathered — no
device syncs) and exports:

* per-family convergence series — final relres, retirements by status,
  the **efficiency ratio** (recent-iterations EWMA over the family's
  own slow baseline EWMA for that graph; 1.0 = on baseline, above =
  degrading), and maxiter / deadline-miss streaks;
* an **EWMA drift detector**: per ``(graph, family)``, a slow baseline
  (``baseline_alpha``) and a fast tracker (``fast_alpha``) over
  iteration counts; once ``min_samples`` iteration samples are in and
  ``fast > drift_ratio × slow`` the pair is flagged **drifting**, a
  quarantine fires (``on_quarantine(gid, family)`` — the cluster wires
  this to :meth:`AdaptiveSelector.quarantine`), and a
  ``health_drift`` flight event records the flip;
* fleet-utilization gauges via the registry's pull-style ``on_collect``
  path — lane occupancy per ``(family, n_pad, K_tier)`` bucket,
  padded-vs-live sweep waste over the occupied lanes, and a
  per-device fleet-bytes high-watermark — so the routing/serving hot
  paths never pay for them.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional

from .flight import NULL_FLIGHT
from .registry import NULL as _NULL_METRICS


class HealthMonitor:
    """Per-retirement convergence diagnostics + drift quarantine +
    fleet-utilization gauges, exported through one registry.

    Args:
        registry: the :class:`~repro.obs.registry.MetricsRegistry` to
            export into (``None`` keeps host-side tracking but exports
            nothing).
        baseline_alpha: slow EWMA weight — the family's own history.
        fast_alpha: fast EWMA weight — what it is doing lately.
        drift_ratio: ``fast > drift_ratio * slow`` flags drift.
        min_samples: iteration samples required before the detector may
            flag (a cold graph's first noisy constructions must not
            quarantine a family).
        on_quarantine: ``(gid, family) ->`` callback fired once per
            flagged pair (exceptions swallowed — health must not take
            serving down).
        flight: optional :class:`~repro.obs.flight.FlightRecorder` —
            drift flips are recorded as ``health_drift`` events.
    """

    def __init__(self, registry=None, *, baseline_alpha: float = 0.05,
                 fast_alpha: float = 0.5, drift_ratio: float = 1.5,
                 min_samples: int = 8,
                 on_quarantine: Optional[Callable[[str, str], None]] = None,
                 flight=None):
        if not 0.0 < baseline_alpha <= 1.0 or not 0.0 < fast_alpha <= 1.0:
            raise ValueError("EWMA alphas must be in (0, 1]")
        if drift_ratio <= 1.0:
            raise ValueError("drift_ratio must be > 1.0")
        self.registry = registry
        self.baseline_alpha = baseline_alpha
        self.fast_alpha = fast_alpha
        self.drift_ratio = drift_ratio
        self.min_samples = min_samples
        self.on_quarantine = on_quarantine
        self._flight = flight if flight is not None else NULL_FLIGHT
        self._ev_drift = self._flight.bind("health_drift")
        reg = registry if registry is not None else _NULL_METRICS
        self._m_relres = reg.gauge(
            "repro_health_final_relres",
            "final relative residual of the most recent retirement",
            ("family",))
        self._m_retire = reg.counter(
            "repro_health_retirements_total",
            "retirements observed by the health monitor, by final status",
            ("family", "status"))
        self._m_eff = reg.gauge(
            "repro_health_efficiency_ratio",
            "fast/slow iteration EWMA of the most recent retirement's "
            "(graph, family); 1.0 = on its own baseline, above = "
            "degrading", ("family",))
        self._m_maxiter = reg.gauge(
            "repro_health_maxiter_streak",
            "worst current consecutive-maxiter streak over the family's "
            "tracked graphs", ("family",))
        self._m_miss = reg.gauge(
            "repro_health_deadline_miss_streak",
            "worst current consecutive deadline-miss streak over the "
            "family's tracked graphs", ("family",))
        self._m_drift = reg.gauge(
            "repro_health_drift",
            "(graph, family) pairs currently flagged as drifting",
            ("family",))
        self._m_quar = reg.counter(
            "repro_health_quarantines_total",
            "drift quarantines fired", ("family",))
        # fleet-utilization gauges (pull-style: set in _collect only)
        self._m_lanes = reg.gauge(
            "repro_fleet_lane_occupancy",
            "occupied solve lanes per engine bucket",
            ("family", "n_pad", "k_tier"), max_series=256)
        self._m_waste = reg.gauge(
            "repro_fleet_sweep_waste_ratio",
            "padded-minus-live fraction of sweep rows over occupied "
            "lanes (0 = every padded row is live work)")
        self._m_watermark = reg.gauge(
            "repro_fleet_bytes_watermark",
            "high-watermark of fleet device bytes", ("device",))
        self._lock = threading.Lock()
        # (gid, family) -> {n, n_it, slow, fast, maxiter_streak,
        #                   miss_streak, drifting}
        self._hist: Dict[tuple, Dict] = {}
        self._by_family: Dict[str, List[Dict]] = {}
        self.observed = 0
        self.quarantines = 0
        self._engines: List = []
        self._caches: List = []
        self._watermarks: Dict[str, float] = {}
        self._collect_registered = False

    # -- per-retirement diagnostics -----------------------------------------
    def observe_retirement(self, *, gid: str, family: str,
                           iters: Optional[int], relres: Optional[float],
                           status: str,
                           deadline_missed: bool = False) -> None:
        """Feed one retired request's host-side convergence outcome.
        ``iters`` is the request's block-max iteration count (``None``
        when the engine gathered none — e.g. an evicted lane)."""
        fire = None
        with self._lock:
            self.observed += 1
            self._m_retire.labels(family=family, status=status).inc()
            if relres is not None:
                self._m_relres.labels(family=family).set(float(relres))
            key = (gid, family)
            rec = self._hist.get(key)
            if rec is None:
                rec = {"n": 0, "n_it": 0, "slow": 0.0, "fast": 0.0,
                       "maxiter_streak": 0, "miss_streak": 0,
                       "drifting": False}
                self._hist[key] = rec
                self._by_family.setdefault(family, []).append(rec)
            rec["n"] += 1
            rec["maxiter_streak"] = rec["maxiter_streak"] + 1 \
                if status == "maxiter" else 0
            rec["miss_streak"] = rec["miss_streak"] + 1 \
                if (deadline_missed or status == "deadline_missed") else 0
            fam_recs = self._by_family[family]
            self._m_maxiter.labels(family=family).set(
                max(r["maxiter_streak"] for r in fam_recs))
            self._m_miss.labels(family=family).set(
                max(r["miss_streak"] for r in fam_recs))
            if iters is not None:
                it = float(iters)
                if rec["n_it"] == 0:
                    rec["slow"] = rec["fast"] = it
                else:
                    a, b = self.baseline_alpha, self.fast_alpha
                    rec["slow"] += a * (it - rec["slow"])
                    rec["fast"] += b * (it - rec["fast"])
                rec["n_it"] += 1
                eff = rec["fast"] / rec["slow"] if rec["slow"] > 0 else 1.0
                self._m_eff.labels(family=family).set(eff)
                if (not rec["drifting"]
                        and rec["n_it"] >= self.min_samples
                        and rec["fast"] > self.drift_ratio * rec["slow"]):
                    rec["drifting"] = True
                    self.quarantines += 1
                    self._m_quar.labels(family=family).inc()
                    self._m_drift.labels(family=family).set(
                        sum(r["drifting"] for r in fam_recs))
                    fire = (gid, family, eff)
        if fire is not None:
            gid_f, fam_f, eff_f = fire
            self._ev_drift(gid=gid_f, family=fam_f,
                           efficiency=round(eff_f, 3))
            cb = self.on_quarantine
            if cb is not None:
                try:
                    cb(gid_f, fam_f)
                except Exception:
                    pass

    # -- fleet utilization (pull-style) --------------------------------------
    def watch_engine(self, engine) -> None:
        """Register an engine whose bucket/lane occupancy the collect
        callback mirrors into gauges at sample/scrape time."""
        self._engines.append(engine)
        self._register_collect()

    def watch_cache(self, cache) -> None:
        """Register a cache whose per-device fleet bytes feed the
        high-watermark gauge."""
        self._caches.append(cache)
        self._register_collect()

    def _register_collect(self) -> None:
        if self.registry is not None and not self._collect_registered:
            self.registry.on_collect(self._collect)
            self._collect_registered = True

    def _collect(self, reg) -> None:
        lanes_by_bucket: Dict[tuple, int] = {}
        live = padded = 0
        for eng in list(self._engines):
            for key, bl in list(eng._buckets.items()):
                fam, n_pad, k_tier = key
                k = (str(fam), str(n_pad), str(k_tier))
                lanes_by_bucket[k] = (lanes_by_bucket.get(k, 0)
                                      + int(bl.n_active))
            for lane in list(eng.lanes):
                if lane is None:
                    continue
                h = lane.req._handle
                if h is not None:
                    live += int(h.n)
                    padded += int(h.n_pad)
        for k, v in lanes_by_bucket.items():
            self._m_lanes.labels(family=k[0], n_pad=k[1],
                                 k_tier=k[2]).set(v)
        self._m_waste.set(1.0 - live / padded if padded else 0.0)
        for cache in list(self._caches):
            try:
                by_dev = cache.stats().get(
                    "fleet_device_bytes_by_device", {}) or {}
            except Exception:
                continue
            for dev, b in by_dev.items():
                dev = str(dev)
                cur = self._watermarks.get(dev, 0.0)
                if b > cur:
                    self._watermarks[dev] = cur = float(b)
                self._m_watermark.labels(device=dev).set(cur)

    # -- telemetry ------------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """Host-side summary for ``ClusterStats.health`` / reports."""
        with self._lock:
            drifting = sorted(
                f"{g}::{f}" for (g, f), r in self._hist.items()
                if r["drifting"])
            fams: Dict[str, Dict] = {}
            for (g, f), r in self._hist.items():
                d = fams.setdefault(f, {"tracked": 0, "drifting": 0,
                                        "max_maxiter_streak": 0,
                                        "max_deadline_miss_streak": 0})
                d["tracked"] += 1
                d["drifting"] += int(r["drifting"])
                d["max_maxiter_streak"] = max(d["max_maxiter_streak"],
                                              r["maxiter_streak"])
                d["max_deadline_miss_streak"] = max(
                    d["max_deadline_miss_streak"], r["miss_streak"])
            return {"observed": self.observed,
                    "tracked": len(self._hist),
                    "quarantines": self.quarantines,
                    "drifting": drifting, "families": fams,
                    "fleet_bytes_watermark": dict(self._watermarks)}
