"""Shared latency accounting: exact percentiles over raw samples and
fixed log-scale histogram buckets with quantile estimation.

This module is the one home for the percentile/summary code that used
to be re-derived privately by ``repro.launch.serve.trace_metrics`` and
the bench scripts' sorted-list lambdas, and it defines the bucket
layout every :class:`repro.obs.registry.Histogram` shares — so a
latency histogram scraped off the registry and a percentile printed by
a bench report agree on what they measure.

Buckets are log-scale (five per decade, ~1.58x spacing) from 10 µs to
~600 s: wide enough to cover a jit-compile-tainted cold solve and fine
enough that a windowed quantile read off bucket counts lands within one
bucket ratio of the exact value.
"""
from __future__ import annotations

from bisect import bisect_left
from typing import Dict, Sequence

# log-scale bucket upper bounds in seconds: 5 per decade, 1e-5 .. ~6e2.
# An overflow (+Inf) bucket rides implicitly at the end of every count
# array (len(counts) == len(bounds) + 1).
DEFAULT_LATENCY_BUCKETS_S = tuple(
    round(m * 10.0 ** d, 12)
    for d in range(-5, 3)
    for m in (1.0, 1.58, 2.51, 3.98, 6.31))


def percentile(xs, q: float) -> float:
    """Exact percentile of raw samples (``q`` in [0, 100]); 0.0 on an
    empty sequence.  The one implementation behind ``trace_metrics``
    and every bench report."""
    import numpy as np
    return float(np.percentile(np.asarray(xs, float), q)) if len(xs) \
        else 0.0


def summarize(xs, *, prefix: str = "", unit: str = "s") -> Dict[str, float]:
    """p50/p95/max summary dict over raw samples, keyed
    ``{prefix}p50_{unit}`` etc. — the shape the launch reports and
    bench JSON artifacts share."""
    return {
        f"{prefix}p50_{unit}": percentile(xs, 50),
        f"{prefix}p95_{unit}": percentile(xs, 95),
        f"{prefix}max_{unit}": percentile(xs, 100),
    }


def bucket_index(bounds: Sequence[float], v: float) -> int:
    """Index of the bucket ``v`` falls in: the first bound >= v, or
    ``len(bounds)`` for the overflow bucket."""
    return bisect_left(bounds, v)


def quantile_from_counts(bounds: Sequence[float], counts: Sequence[int],
                         q: float) -> float:
    """Estimate the ``q``-quantile (``q`` in [0, 1]) from per-bucket
    counts (``len(counts) == len(bounds) + 1``; the last entry is the
    overflow bucket).  Linear interpolation inside the landing bucket;
    the overflow bucket clamps to the top bound.  0.0 when empty."""
    total = sum(counts)
    if total == 0:
        return 0.0
    rank = q * total
    acc = 0.0
    for i, c in enumerate(counts):
        if c == 0:
            continue
        if acc + c >= rank:
            if i >= len(bounds):          # overflow: clamp to top bound
                return float(bounds[-1])
            lo = bounds[i - 1] if i > 0 else 0.0
            hi = bounds[i]
            frac = (rank - acc) / c
            return float(lo + frac * (hi - lo))
        acc += c
    return float(bounds[-1])
