"""Pluggable overload detection over the registry's time-series view.

This is the signal half of the ROADMAP's elastic-control-plane item
(modeled on vLLM production-stack's ``overload_detector/``): a
detector consumes sampled series — sustained queue depth and arrival
rate — and emits a state plus a scale recommendation that
:class:`~repro.serve.cluster.router.SolveCluster` logs into
``ClusterStats.overload``.  Actuation (spawning/draining replicas)
lands in a later PR; the hysteresis here is what makes that actuation
safe to wire up (no flapping on a single burst sample).

State machine of :class:`SustainedThresholdDetector`::

    ok ── mean queue > high for >= sustain_s ──> overloaded
    overloaded ── mean queue < low for >= cool_s ──> ok

Thresholds compare the *windowed mean* of the queue-depth gauge (and
optionally the arrival-rate counter), so a one-sample spike neither
trips it nor resets the cooldown.
"""
from __future__ import annotations

from typing import Dict, Optional

from .registry import MetricsRegistry


class OverloadDetector:
    """Interface: call :meth:`update` from a host-side loop already
    holding a timestamp; read :meth:`stats` into telemetry."""

    name = "null"

    def update(self, now: float) -> str:
        """Advance the detector; returns the current state
        (``"ok"`` or ``"overloaded"``)."""
        return "ok"

    @property
    def state(self) -> str:
        return "ok"

    @property
    def recommendation(self) -> str:
        """``"scale_up"`` / ``"scale_down"`` / ``"hold"``."""
        return "hold"

    def stats(self) -> Dict[str, object]:
        return {"detector": self.name, "state": self.state,
                "recommendation": self.recommendation}


class SustainedThresholdDetector(OverloadDetector):
    """Queue-depth thresholds with hysteresis and sustain windows.

    Args:
        registry: the sampled :class:`MetricsRegistry` to read.
        queue_metric: gauge name carrying queue depth.
        arrival_metric: optional counter whose windowed rate is
            reported alongside (diagnostic; not part of the trigger
            unless ``high_rate`` is set).
        high_queue: windowed mean queue depth that, sustained for
            ``sustain_s``, flips the state to ``overloaded``.
        low_queue: mean depth that, sustained for ``cool_s``, flips it
            back — strictly below ``high_queue`` (the hysteresis band).
        high_rate: optional arrival-rate trigger OR-ed with the queue
            trigger.
        window_s: averaging window for each :meth:`update` reading.
        sustain_s: seconds the high reading must persist before
            entering ``overloaded`` (a single burst sample holds).
        cool_s: seconds the low reading must persist before leaving.
        idle_down_s: with the fleet idle (mean queue ~0) this long, the
            recommendation becomes ``scale_down``.
    """

    name = "sustained_threshold"

    def __init__(self, registry: MetricsRegistry, *,
                 queue_metric: str = "repro_cluster_queue_depth",
                 arrival_metric: Optional[str] =
                 "repro_cluster_arrivals_total",
                 high_queue: float = 8.0, low_queue: float = 2.0,
                 high_rate: Optional[float] = None,
                 window_s: float = 1.0, sustain_s: float = 0.5,
                 cool_s: float = 1.0, idle_down_s: float = 5.0):
        if low_queue >= high_queue:
            raise ValueError(
                f"hysteresis band requires low_queue < high_queue, got "
                f"low={low_queue} high={high_queue}")
        self.registry = registry
        self.queue_metric = queue_metric
        self.arrival_metric = arrival_metric
        self.high_queue = high_queue
        self.low_queue = low_queue
        self.high_rate = high_rate
        self.window_s = window_s
        self.sustain_s = sustain_s
        self.cool_s = cool_s
        self.idle_down_s = idle_down_s
        self._state = "ok"
        self._high_since: Optional[float] = None
        self._low_since: Optional[float] = None
        self._idle_since: Optional[float] = None
        self._last: Dict[str, float] = {"queue_mean": 0.0, "queue_max": 0.0,
                                        "arrival_rate": 0.0}
        self.transitions = 0
        self.updates = 0

    # -- the state machine ---------------------------------------------------
    def update(self, now: float) -> str:
        self.updates += 1
        q = self.registry.gauge_stats(self.queue_metric,
                                      window_s=self.window_s, now=now)
        rate = self.registry.rate(self.arrival_metric,
                                  window_s=self.window_s, now=now) \
            if self.arrival_metric else 0.0
        self._last = {"queue_mean": q["mean"], "queue_max": q["max"],
                      "arrival_rate": rate}
        hot = q["n"] > 0 and q["mean"] > self.high_queue
        if self.high_rate is not None and rate > self.high_rate:
            hot = True
        cold = q["n"] == 0 or q["mean"] < self.low_queue
        idle = q["n"] == 0 or q["mean"] <= 1e-9

        if self._state == "ok":
            if hot:
                if self._high_since is None:
                    self._high_since = now
                if now - self._high_since >= self.sustain_s:
                    self._state = "overloaded"
                    self.transitions += 1
                    self._low_since = None
            else:
                self._high_since = None
        else:
            if cold:
                if self._low_since is None:
                    self._low_since = now
                if now - self._low_since >= self.cool_s:
                    self._state = "ok"
                    self.transitions += 1
                    self._high_since = None
            else:
                self._low_since = None
        self._idle_since = (self._idle_since or now) if idle else None
        self._now = now
        return self._state

    @property
    def state(self) -> str:
        return self._state

    @property
    def recommendation(self) -> str:
        if self._state == "overloaded":
            return "scale_up"
        if self._idle_since is not None and \
                getattr(self, "_now", 0.0) - self._idle_since \
                >= self.idle_down_s:
            return "scale_down"
        return "hold"

    def stats(self) -> Dict[str, object]:
        return {
            "detector": self.name,
            "state": self._state,
            "recommendation": self.recommendation,
            "transitions": self.transitions,
            "updates": self.updates,
            "queue_mean": self._last["queue_mean"],
            "queue_max": self._last["queue_max"],
            "arrival_rate": self._last["arrival_rate"],
            "high_queue": self.high_queue,
            "low_queue": self.low_queue,
        }
