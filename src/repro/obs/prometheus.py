"""Prometheus text-exposition rendering and a stdlib scrape endpoint.

``render(registry)`` emits text format version 0.0.4 (``# HELP`` /
``# TYPE`` headers, cumulative ``_bucket{le=...}`` histogram lines with
a ``+Inf`` bucket, ``_sum``/``_count``), and :class:`MetricsServer`
serves it from a background :class:`~http.server.ThreadingHTTPServer`
— no third-party client library, per the no-new-deps rule.  Enable it
with ``--metrics-port`` on ``launch/serve.py`` / ``launch/cluster.py``
and scrape with ``curl localhost:<port>/metrics``.
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from .registry import Counter, Gauge, Histogram, MetricsRegistry

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class _ReuseAddrServer(ThreadingHTTPServer):
    # back-to-back replays on a fixed --metrics-port must not trip over
    # the previous run's TIME_WAIT socket
    allow_reuse_address = True
    daemon_threads = True


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _fmt_labels(names, values, extra=()) -> str:
    pairs = [f'{n}="{_escape(v)}"' for n, v in zip(names, values)]
    pairs.extend(f'{n}="{_escape(v)}"' for n, v in extra)
    return "{" + ",".join(pairs) + "}" if pairs else ""


def _fmt_num(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def render(registry: MetricsRegistry) -> str:
    """Render every registered metric as Prometheus text exposition."""
    out = []
    for m in registry.collect():
        out.append(f"# HELP {m.name} {_escape(m.help)}")
        out.append(f"# TYPE {m.name} {m.kind}")
        if isinstance(m, Histogram):
            for key, child in m.children():
                total, s, counts = child.snapshot()
                acc = 0
                for bound, c in zip(m.buckets, counts):
                    acc += c
                    le = _fmt_labels(m.label_names, key,
                                     extra=[("le", _fmt_num(bound))])
                    out.append(f"{m.name}_bucket{le} {acc}")
                le = _fmt_labels(m.label_names, key, extra=[("le", "+Inf")])
                out.append(f"{m.name}_bucket{le} {total}")
                lbl = _fmt_labels(m.label_names, key)
                out.append(f"{m.name}_sum{lbl} {_fmt_num(s)}")
                out.append(f"{m.name}_count{lbl} {total}")
        elif isinstance(m, (Counter, Gauge)):
            for key, child in m.children():
                lbl = _fmt_labels(m.label_names, key)
                out.append(f"{m.name}{lbl} {_fmt_num(child.value)}")
    return "\n".join(out) + "\n"


class MetricsServer:
    """Background scrape endpoint: ``GET /metrics`` renders the
    registry; anything else 404s.  Daemon threads, so a hung scraper
    never blocks interpreter exit; still, call :meth:`close` (or use as
    a context manager) to release the port deterministically.

    ``port=0`` binds an ephemeral port — read it back from
    :attr:`port` (the tests do this to avoid collisions).
    """

    def __init__(self, registry: MetricsRegistry, *, port: int = 0,
                 host: str = "0.0.0.0"):
        self.registry = registry

        srv_registry = registry

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):                      # noqa: N802 (stdlib API)
                if self.path.rstrip("/") not in ("", "/metrics"):
                    self.send_error(404)
                    return
                body = render(srv_registry).encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, fmt, *args):     # silence per-scrape spam
                pass

        self._httpd = _ReuseAddrServer((host, port), _Handler)
        self.port = self._httpd.server_address[1]
        self._closed = False
        self._close_lock = threading.Lock()
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="metrics-server",
            daemon=True)
        self._thread.start()

    def close(self) -> None:
        """Stop serving and release the port.  Idempotent — launchers
        and tests may close from both a finally block and an exit
        handler."""
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def maybe_serve(registry: Optional[MetricsRegistry],
                port: Optional[int]) -> Optional[MetricsServer]:
    """``--metrics-port`` helper: start a server iff both a real
    registry and a port were given."""
    if registry is None or port is None:
        return None
    return MetricsServer(registry, port=port)
