"""Thread-safe metrics registry with a ring-buffered time-series view.

Every serving layer (engine tick loop, frontend ingress, cluster
router, factor tier, cache) binds its instruments against one
:class:`MetricsRegistry` so the whole stack is scrapable behind a
single endpoint (:mod:`repro.obs.prometheus`) and queryable as time
series (windowed counter rates, gauge stats, histogram quantiles) —
the signal the overload detector and the ROADMAP's autoscaling path
consume.

Design constraints, in order:

* **off-hot-path** — an instrument update is one uncontended lock
  acquire and a float add; call sites pre-bind children
  (``self._m_ticks = reg.counter(...)`` once, ``.inc()`` per tick) and
  pass :data:`NULL` when observability is off, so the uninstrumented
  path stays free (the serve bench gates instrumented ticks/s at
  >= 0.98x uninstrumented);
* **bounded label cardinality** — each metric caps its label sets
  (default 64) and *raises* :class:`CardinalityError` past the cap:
  an unbounded label (per-request id, per-graph fingerprint) is a
  memory leak and a scrape bomb, and failing loudly at the offending
  call site beats silently dropping series.  Label values must come
  from bounded sets (replica index, family, policy, status);
* **explicit sampling** — the ring buffer advances only when a caller
  already on a host-side boundary invokes :meth:`sample` /
  :meth:`maybe_sample` with *its* clock (injectable everywhere else in
  the repo, so here too).  No background thread, no device syncs.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .histogram import (DEFAULT_LATENCY_BUCKETS_S, bucket_index,
                        quantile_from_counts)


class CardinalityError(ValueError):
    """A metric was asked for more label sets than its cap — an
    unbounded label (request id, graph fingerprint) leaked into the
    label schema.  Raised at the offending ``labels()`` call."""


# ---------------------------------------------------------------------------
# Children: the per-label-set value holders (the hot-path objects)
# ---------------------------------------------------------------------------

class _CounterChild:
    """Monotonic float counter.  ``inc`` is a lock-guarded
    read-modify-write: GIL scheduling can preempt between the read and
    the write, so bare ``+=`` from N threads loses updates."""

    __slots__ = ("_lock", "_v")

    def __init__(self):
        self._lock = threading.Lock()
        self._v = 0.0

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._v += v

    @property
    def value(self) -> float:
        return self._v

    def snapshot(self):
        return self._v


class _GaugeChild:
    """Last-write-wins float gauge (queue depth, active lanes)."""

    __slots__ = ("_lock", "_v")

    def __init__(self):
        self._lock = threading.Lock()
        self._v = 0.0

    def set(self, v: float) -> None:
        self._v = float(v)        # single store: GIL-atomic

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._v += v

    def dec(self, v: float = 1.0) -> None:
        self.inc(-v)

    @property
    def value(self) -> float:
        return self._v

    def snapshot(self):
        return self._v


class _HistogramChild:
    """Fixed-bucket histogram: per-bucket counts + running sum.  The
    bucket bounds live on the parent metric (shared, immutable)."""

    __slots__ = ("_lock", "_bounds", "counts", "total", "sum")

    def __init__(self, bounds: Sequence[float]):
        self._lock = threading.Lock()
        self._bounds = bounds
        self.counts = [0] * (len(bounds) + 1)
        self.total = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        i = bucket_index(self._bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.total += 1
            self.sum += v

    def snapshot(self) -> Tuple[int, float, Tuple[int, ...]]:
        with self._lock:
            return (self.total, self.sum, tuple(self.counts))

    def quantile(self, q: float) -> float:
        """Lifetime quantile estimate from the live bucket counts."""
        return quantile_from_counts(self._bounds, self.snapshot()[2], q)


# ---------------------------------------------------------------------------
# Metrics: name + label schema + children
# ---------------------------------------------------------------------------

class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 label_names: Sequence[str] = (), *,
                 max_series: int = 64):
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self.max_series = max_series
        self._lock = threading.Lock()
        self._children: Dict[Tuple[str, ...], object] = {}
        self._default = None
        if not self.label_names:
            self._default = self._new_child()
            self._children[()] = self._default

    def _new_child(self):
        raise NotImplementedError

    def labels(self, **kv):
        """Child for one label-value set (created on first use; cached
        after — pre-bind at construction time, not per update).  Raises
        :class:`CardinalityError` past ``max_series`` label sets."""
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.label_names}, "
                f"got {tuple(sorted(kv))}")
        key = tuple(str(kv[n]) for n in self.label_names)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.get(key)
                if child is None:
                    if len(self._children) >= self.max_series:
                        raise CardinalityError(
                            f"metric {self.name!r} exceeded its label-"
                            f"cardinality cap ({self.max_series} series); "
                            f"label values must come from a bounded set "
                            f"(offending set: "
                            f"{dict(zip(self.label_names, key))})")
                    child = self._children[key] = self._new_child()
        return child

    def children(self) -> List[Tuple[Tuple[str, ...], object]]:
        with self._lock:
            return list(self._children.items())


class Counter(_Metric):
    kind = "counter"

    def _new_child(self):
        return _CounterChild()

    def inc(self, v: float = 1.0) -> None:
        self._default.inc(v)

    @property
    def value(self) -> float:
        return self._default.value


class Gauge(_Metric):
    kind = "gauge"

    def _new_child(self):
        return _GaugeChild()

    def set(self, v: float) -> None:
        self._default.set(v)

    def inc(self, v: float = 1.0) -> None:
        self._default.inc(v)

    def dec(self, v: float = 1.0) -> None:
        self._default.dec(v)

    @property
    def value(self) -> float:
        return self._default.value


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help="", label_names=(), *,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S,
                 max_series: int = 64):
        self.buckets = tuple(buckets)
        super().__init__(name, help, label_names, max_series=max_series)

    def _new_child(self):
        return _HistogramChild(self.buckets)

    def observe(self, v: float) -> None:
        self._default.observe(v)

    def quantile(self, q: float) -> float:
        return self._default.quantile(q)


# ---------------------------------------------------------------------------
# Null objects: the zero-overhead "observability off" path
# ---------------------------------------------------------------------------

class _NullChild:
    __slots__ = ()

    def inc(self, v=1.0):
        pass

    def dec(self, v=1.0):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass

    value = 0.0

    def quantile(self, q):
        return 0.0

    def snapshot(self):
        return 0.0

    def labels(self, **kv):
        return self


class NullRegistry:
    """Registry-shaped no-op.  Instrumented call sites hold real
    instrument objects either way, so the hot path never branches on
    "is observability on" — it just calls a method that does nothing.
    Use the shared :data:`NULL` singleton."""

    _child = _NullChild()

    def counter(self, name, help="", labels=(), **kw):
        return self._child

    def gauge(self, name, help="", labels=(), **kw):
        return self._child

    def histogram(self, name, help="", labels=(), **kw):
        return self._child

    def on_collect(self, fn):
        pass

    def remove_collect(self, fn):
        pass

    def sample(self, now):
        pass

    def maybe_sample(self, now):
        pass

    def series(self, name, labels=None):
        return []

    def rate(self, name, *, window_s, now=None, labels=None):
        return 0.0

    def gauge_stats(self, name, *, window_s, now=None, labels=None):
        return {"mean": 0.0, "max": 0.0, "n": 0}

    def quantile(self, name, q, *, window_s=None, now=None, labels=None):
        return 0.0

    def collect(self):
        return []


NULL = NullRegistry()


# ---------------------------------------------------------------------------
# The registry
# ---------------------------------------------------------------------------

class MetricsRegistry:
    """Named instruments + ring-buffered samples.

    ::

        reg = MetricsRegistry()
        ticks = reg.counter("repro_engine_ticks_total", "engine ticks")
        ticks.inc()
        reg.sample(now=clock())                  # advance the ring
        reg.rate("repro_engine_ticks_total", window_s=1.0, now=clock())

    Args:
        ring: samples retained per series (the time-series window).
        sample_interval_s: minimum spacing :meth:`maybe_sample`
            enforces, so hot loops can call it unconditionally.
    """

    def __init__(self, *, ring: int = 512,
                 sample_interval_s: float = 0.05):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}
        self._ring = ring
        self._interval = sample_interval_s
        self._last_sample: Optional[float] = None
        # (name, label-values) -> deque[(t, snapshot)]
        self._series: Dict[Tuple[str, Tuple[str, ...]], deque] = {}
        self._callbacks: List[Callable[["MetricsRegistry"], None]] = []

    # -- instrument creation (idempotent by name) ---------------------------
    def _get(self, cls, name, help, labels, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, labels, **kw)
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = (), *,
                max_series: int = 64) -> Counter:
        return self._get(Counter, name, help, labels,
                         max_series=max_series)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = (), *,
              max_series: int = 64) -> Gauge:
        return self._get(Gauge, name, help, labels,
                         max_series=max_series)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (), *,
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_S,
                  max_series: int = 64) -> Histogram:
        return self._get(Histogram, name, help, labels,
                         buckets=buckets, max_series=max_series)

    # -- collect callbacks (pull-style mirrors of snapshot counters) --------
    def on_collect(self, fn: Callable[["MetricsRegistry"], None]) -> None:
        """Register ``fn(registry)`` to run before every sample/scrape —
        the pull path for components whose counters live elsewhere
        (``FactorCache.stats()``, router counters): the callback mirrors
        them into gauges without touching the component's hot path."""
        with self._lock:
            self._callbacks.append(fn)

    def remove_collect(self, fn) -> None:
        with self._lock:
            try:
                self._callbacks.remove(fn)
            except ValueError:
                pass

    def _run_callbacks(self) -> None:
        with self._lock:
            cbs = list(self._callbacks)
        for fn in cbs:
            try:
                fn(self)
            except Exception:
                pass      # a torn-down component must not kill sampling

    # -- sampling (the time-series write path) ------------------------------
    def sample(self, now: float) -> None:
        """Snapshot every instrument into the ring at time ``now``
        (caller's clock — injectable, like every clock in this repo)."""
        self._run_callbacks()
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            for key, child in m.children():
                sk = (m.name, key)
                dq = self._series.get(sk)
                if dq is None:
                    dq = self._series[sk] = deque(maxlen=self._ring)
                dq.append((now, child.snapshot()))
        self._last_sample = now

    def maybe_sample(self, now: float) -> bool:
        """Sample only if ``sample_interval_s`` elapsed — safe to call
        from a per-tick / per-submit loop."""
        if self._last_sample is not None and \
                now - self._last_sample < self._interval:
            return False
        self.sample(now)
        return True

    # -- time-series reads --------------------------------------------------
    def _pick_series(self, name: str, labels: Optional[Dict] = None):
        m = self._metrics.get(name)
        if m is None:
            return []
        if labels is not None:
            key = tuple(str(labels[n]) for n in m.label_names)
            dq = self._series.get((name, key))
            return [list(dq)] if dq else []
        return [list(dq) for (n, _), dq in list(self._series.items())
                if n == name]

    def series(self, name: str, labels: Optional[Dict] = None):
        """Raw sampled ``(t, value)`` pairs (single series: exact label
        set, or the metric's only series; multiple series return
        concatenated)."""
        out = []
        for s in self._pick_series(name, labels):
            out.extend(s)
        return sorted(out, key=lambda tv: tv[0])

    def _window(self, seq, window_s, now):
        if now is None:
            now = seq[-1][0] if seq else 0.0
        lo = now - window_s
        return [(t, v) for t, v in seq if lo <= t <= now]

    def rate(self, name: str, *, window_s: float,
             now: Optional[float] = None,
             labels: Optional[Dict] = None) -> float:
        """Windowed counter rate: summed over label sets, computed as
        last-minus-first inside the window over elapsed time.  0.0
        with fewer than two samples in the window."""
        total = 0.0
        for seq in self._pick_series(name, labels):
            w = self._window(seq, window_s, now)
            if len(w) >= 2:
                dt = w[-1][0] - w[0][0]
                if dt > 0:
                    total += max(w[-1][1] - w[0][1], 0.0) / dt
        return total

    def gauge_stats(self, name: str, *, window_s: float,
                    now: Optional[float] = None,
                    labels: Optional[Dict] = None) -> Dict[str, float]:
        """Mean/max/count of gauge samples inside the window (summing
        across label sets per timestamp would conflate replicas — this
        aggregates the sample population instead, which is what a
        sustained-threshold detector wants)."""
        vals = []
        for seq in self._pick_series(name, labels):
            vals.extend(v for _, v in self._window(seq, window_s, now))
        if not vals:
            return {"mean": 0.0, "max": 0.0, "n": 0}
        return {"mean": sum(vals) / len(vals), "max": max(vals),
                "n": len(vals)}

    def quantile(self, name: str, q: float, *,
                 window_s: Optional[float] = None,
                 now: Optional[float] = None,
                 labels: Optional[Dict] = None) -> float:
        """Histogram quantile.  Windowed: from the bucket-count *delta*
        between the window's edge samples (the distribution of
        observations inside the window); unwindowed: from the live
        lifetime counts."""
        m = self._metrics.get(name)
        if not isinstance(m, Histogram):
            return 0.0
        if window_s is None:
            counts = None
            for _, child in m.children():
                c = child.snapshot()[2]
                counts = c if counts is None else \
                    tuple(a + b for a, b in zip(counts, c))
            return quantile_from_counts(m.buckets, counts or (), q)
        counts = None
        for seq in self._pick_series(name, labels):
            w = self._window(seq, window_s, now)
            if len(w) < 2:
                continue
            first, last = w[0][1][2], w[-1][1][2]
            delta = tuple(max(b - a, 0) for a, b in zip(first, last))
            counts = delta if counts is None else \
                tuple(a + b for a, b in zip(counts, delta))
        return quantile_from_counts(m.buckets, counts or (), q)

    # -- scrape support -----------------------------------------------------
    def collect(self) -> List[_Metric]:
        """Metrics in registration order, callbacks run first (so
        pull-style gauges are fresh at scrape time)."""
        self._run_callbacks()
        with self._lock:
            return list(self._metrics.values())
