"""Per-request lifecycle tracing: span records and Chrome trace export.

A request's wall-clock decomposes into a contiguous partition of
``[submit_time, finish_time]``::

    route          submit .. +route_s          router decision + retries
    factor|adopt   .. +factor_wait_s           cold-path construction wait
    queue          .. admit_time               admission queue (head block)
    first_tick     admit .. first_tick_time    scatter-in + first step call
    solve          first_tick .. finish_time   PCG ticks to convergence

Stages a request never paid (warm hit -> no factor span; engine
recorded no first tick -> solve covers admit..finish) collapse to
nothing rather than to zero-length lies, and because the partition is
contiguous the span durations sum to the reported e2e latency exactly
— the acceptance bound (<= 5%) only absorbs float rounding.

Spans come from stamps the serving layers already cross on the host
side (`SolveRequest.submit_time` / `admit_time` / `finish_time` plus
the new ``route_s`` / ``factor_wait_s`` / ``first_tick_time``), so
tracing adds no device syncs; the engine stamps first ticks only when
a tracer is attached.

Export is Chrome ``trace_event`` JSON (``{"traceEvents": [...]}``,
complete events ``ph="X"``, microsecond ``ts``/``dur``) — loads
directly in ``chrome://tracing`` / Perfetto.  ``pid`` is the replica
(one track group per replica), ``tid`` is the request id (one row per
request), so a request's spans nest on their own row and cross-replica
interleaving is visible at a glance.
"""
from __future__ import annotations

import json
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# The lifecycle stages, in partition order.
STAGES = ("route", "factor", "adopt", "queue", "first_tick", "solve")


@dataclass(frozen=True)
class Span:
    """One contiguous stage of a request's lifetime, in the engine
    clock's coordinates (seconds)."""
    name: str
    start: float
    end: float

    @property
    def dur_s(self) -> float:
        return max(self.end - self.start, 0.0)


@dataclass
class RequestTrace:
    """The full lifecycle record for one retired request."""
    rid: int
    graph_id: str
    family: str = ""
    policy: str = ""
    status: str = ""
    replica: int = -1
    device: str = ""
    trace_id: str = ""
    spans: List[Span] = field(default_factory=list)
    attrs: Dict[str, object] = field(default_factory=dict)

    @property
    def start(self) -> float:
        return self.spans[0].start if self.spans else 0.0

    @property
    def end(self) -> float:
        return self.spans[-1].end if self.spans else 0.0

    @property
    def e2e_s(self) -> float:
        return max(self.end - self.start, 0.0)

    @property
    def span_sum_s(self) -> float:
        return sum(s.dur_s for s in self.spans)


def trace_from_request(req, *, family: str = "", policy: str = "",
                       replica: int = -1,
                       device: str = "") -> Optional[RequestTrace]:
    """Build a :class:`RequestTrace` from a retired
    :class:`~repro.serve.engine.SolveRequest`'s host-side stamps.
    Returns ``None`` if the request never finished (no partition to
    report)."""
    if req.finish_time <= 0.0 or req.submit_time <= 0.0:
        return None
    t = req.submit_time
    end = req.finish_time
    spans: List[Span] = []

    def push(name: str, lo: float, hi: float) -> float:
        hi = min(max(hi, lo), end)
        if hi > lo:
            spans.append(Span(name, lo, hi))
        return hi

    route_s = getattr(req, "route_s", 0.0)
    factor_s = getattr(req, "factor_wait_s", 0.0)
    mode = getattr(req, "factor_mode", "") or "factor"
    first = getattr(req, "first_tick_time", 0.0)
    admit = req.admit_time if req.admit_time > 0.0 else t

    cur = push("route", t, t + route_s)
    cur = push("adopt" if mode == "adopt" else "factor", cur, cur + factor_s)
    cur = push("queue", cur, max(admit, cur))
    if first > cur:
        cur = push("first_tick", cur, first)
    push("solve", cur, end)

    iters = req.iters
    max_iters = int(max(iters)) if iters is not None and len(iters) else 0
    if replica < 0:
        replica = getattr(req, "replica", -1)
    return RequestTrace(
        rid=req.rid, graph_id=req.graph_id, family=family,
        policy=policy, status=req.status, replica=replica, device=device,
        trace_id=getattr(req, "trace_id", ""),
        spans=spans,
        attrs={"iters": max_iters, "nrhs": req.nrhs,
               "factor_mode": getattr(req, "factor_mode", "") or ""})


class Tracer:
    """Thread-safe bounded sink of :class:`RequestTrace` records.

    Layers that can emit a trace take ``tracer=None`` and call
    :meth:`record` only when one is attached; the deque bound keeps a
    long replay from hoarding host memory (the oldest traces fall off).
    """

    def __init__(self, *, capacity: int = 8192):
        self._lock = threading.Lock()
        self._traces: deque = deque(maxlen=capacity)
        self.dropped = 0
        self._seen = 0

    def record(self, trace: Optional[RequestTrace]) -> None:
        if trace is None:
            return
        with self._lock:
            if len(self._traces) == self._traces.maxlen:
                self.dropped += 1
            self._traces.append(trace)
            self._seen += 1

    def traces(self) -> List[RequestTrace]:
        with self._lock:
            return list(self._traces)

    def __len__(self) -> int:
        return len(self._traces)

    # -- Chrome trace_event export -----------------------------------------
    def chrome_events(self) -> List[Dict]:
        """Complete events (``ph="X"``) with µs timestamps relative to
        the earliest span — pid=replica, tid=request id, so spans nest
        per request row under per-replica track groups."""
        traces = self.traces()
        if not traces:
            return []
        t0 = min(tr.start for tr in traces if tr.spans)
        events: List[Dict] = []
        named: set = set()
        for tr in traces:
            pid = tr.replica if tr.replica >= 0 else 0
            if pid not in named:
                named.add(pid)
                events.append({
                    "name": "process_name", "ph": "M", "pid": pid,
                    "args": {"name": f"replica {pid}" if tr.replica >= 0
                             else "engine"}})
            for sp in tr.spans:
                events.append({
                    "name": sp.name, "ph": "X", "cat": "request",
                    "pid": pid, "tid": tr.rid,
                    "ts": (sp.start - t0) * 1e6,
                    "dur": sp.dur_s * 1e6,
                    "args": {"rid": tr.rid, "graph_id": tr.graph_id,
                             "trace_id": tr.trace_id,
                             "family": tr.family, "policy": tr.policy,
                             "status": tr.status, "device": tr.device,
                             **tr.attrs}})
        return events

    def export_chrome(self, path: str) -> int:
        """Write ``{"traceEvents": [...]}`` JSON; returns the event
        count (0 writes an empty-but-valid file)."""
        events = self.chrome_events()
        with open(path, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, f)
        return len(events)

    # -- aggregate reads ----------------------------------------------------
    def stage_seconds(self) -> Dict[str, float]:
        """Total seconds spent per stage across recorded traces — the
        construct-vs-serve attribution the selector and reports read."""
        out: Dict[str, float] = {}
        for tr in self.traces():
            for sp in tr.spans:
                out[sp.name] = out.get(sp.name, 0.0) + sp.dur_s
        return out

    def stats(self) -> Dict[str, object]:
        with self._lock:
            n, dropped = len(self._traces), self.dropped
            seen = self._seen
        return {"recorded": n, "seen": seen, "dropped": dropped,
                "stage_s": self.stage_seconds()}
