from .adamw import adamw_init, adamw_update, OptState          # noqa: F401
from .schedule import wsd_schedule, cosine_schedule            # noqa: F401
