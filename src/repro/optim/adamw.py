"""AdamW with fp32 moments over (possibly bf16) parameters.

Moments inherit each parameter's sharding (same tree structure), so the
optimizer state is fully sharded — with the FSDP-style param rules this
is ZeRO-3-equivalent placement.  Global-norm clipping included.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    mu: dict
    nu: dict
    count: jnp.ndarray


def adamw_init(params) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(mu=jax.tree.map(f32, params),
                    nu=jax.tree.map(f32, params),
                    count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, state: OptState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        step = (m / c1) / (jnp.sqrt(v / c2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, OptState(mu=new_mu, nu=new_nu, count=count), gnorm
