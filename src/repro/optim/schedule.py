"""LR schedules: cosine and warmup-stable-decay."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = final_frac * peak_lr + (1 - final_frac) * peak_lr \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)


def wsd_schedule(step, *, peak_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.2):
    s = step.astype(jnp.float32)
    decay_start = total * (1 - decay_frac)
    warm = peak_lr * s / max(warmup, 1)
    dec = peak_lr * jnp.clip((total - s) / max(total - decay_start, 1),
                             0.0, 1.0)
    return jnp.where(s < warmup, warm,
                     jnp.where(s < decay_start, peak_lr, dec))
