from .engine import SolveEngine, SolveRequest, EngineStats  # noqa: F401
from .admission import (AdmissionPolicy, FIFOAdmission,  # noqa: F401
                        PriorityAdmission, DeadlineAdmission, make_policy)
from .frontend import (SolveFrontend, FrontendStats,  # noqa: F401
                       EngineOverloadedError)
from .cluster import (SolveCluster, ClusterStats,  # noqa: F401
                      ClusterOverloadedError, EngineReplica, ReplicaStats,
                      AdaptiveSelector, make_routing)
