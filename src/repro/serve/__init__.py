from .engine import SolveEngine, SolveRequest, EngineStats  # noqa: F401
from .lm_engine import ServeEngine, Request  # noqa: F401
