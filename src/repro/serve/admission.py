"""Pluggable admission scheduling for the solve service.

PR 3 made lane state device-resident; admission stayed an inline FIFO
inside ``SolveEngine._admit`` — fair, but a wide request at the head of
the queue idles every free lane behind it (head-of-line blocking).
This module factors the *decision* out of the engine into a policy
object the engine consults once per tick:

* :class:`FIFOAdmission` — strict submission order with head-of-line
  blocking; byte-for-byte the engine's historical behavior (it is the
  engine's default, so sync ``SolveEngine`` users see no change);
* :class:`PriorityAdmission` — priority classes (lower value = more
  urgent) with **backfill**: when the most-urgent waiting request does
  not fit the free lanes, later narrow requests may skip ahead into
  them;
* :class:`DeadlineAdmission` — earliest-deadline-first ordering (then
  priority, then arrival) with the same backfill machinery, plus
  ``evict_hopeless = True``: the engine retires lanes whose deadline can
  no longer be met with a ``deadline_missed`` status instead of letting
  them squat on fleet slots.

**Starvation bound.**  Backfill is capped: each *admission round* (one
``select`` call with a non-empty queue) in which at least one request is
admitted past a blocked, more-urgent request increments the blocked
request's ``sched_skips``.  Once ``sched_skips == max_skips`` the
request becomes a **barrier** — nothing behind it in the policy order
may be admitted until it fits.  Hence a skipped request waits at most
``max_skips`` backfill rounds once it is the most-urgent blocked
request, and ``backfill_skips <= max_skips * skipped_reqs`` is a hard
counter invariant (gated in CI by
``benchmarks.check_serve_regression``).

Policies only *order and bound* admission; the engine still performs
the jitted scatter per admitted request, so serving stays bit-exact
with direct ``FactorHandle.solve`` regardless of policy — scheduling
changes *when* a request's lanes start, never what they compute.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, TYPE_CHECKING

if TYPE_CHECKING:                                     # pragma: no cover
    from .engine import SolveRequest


class AdmissionPolicy:
    """Decides which waiting requests to admit into free lanes.

    ``select`` receives a snapshot of the waiting queue (submission
    order) and the number of free lanes, and returns the requests to
    admit *this round*, in admission order; the engine scatters each and
    removes it from the queue.  The policy must only return requests
    whose combined ``nrhs`` fits ``free``.

    ``evict_hopeless`` tells the engine to retire active lanes whose
    request can no longer meet its deadline (see
    :class:`DeadlineAdmission`).
    """

    name = "base"
    max_skips = 0
    evict_hopeless = False

    def __init__(self) -> None:
        self.rounds = 0            # select calls with a non-empty queue
        self.backfill_skips = 0    # total skip increments across requests
        self.skipped_reqs = 0      # requests that were ever skipped
        self.barrier_rounds = 0    # rounds cut short by a starvation barrier

    def select(self, waiting: Sequence["SolveRequest"], free: int, *,
               now: float) -> List["SolveRequest"]:
        raise NotImplementedError

    def counters(self) -> Dict[str, int]:
        return dict(sched_rounds=self.rounds,
                    backfill_skips=self.backfill_skips,
                    skipped_reqs=self.skipped_reqs,
                    barrier_rounds=self.barrier_rounds)


class _OrderedBackfill(AdmissionPolicy):
    """Shared machinery: admit greedily in policy order, let later
    requests backfill past blocked ones, stop at a starvation barrier.

    Subclasses define ``_key(req)`` — the policy order (ascending; ties
    broken by engine submission sequence, which ``_key`` must include
    last for stability).
    """

    def __init__(self, max_skips: int = 8):
        super().__init__()
        if max_skips < 0:
            raise ValueError("max_skips must be >= 0")
        self.max_skips = max_skips

    def _key(self, req: "SolveRequest", now: float):
        raise NotImplementedError

    def select(self, waiting: Sequence["SolveRequest"], free: int, *,
               now: float) -> List["SolveRequest"]:
        if not waiting:
            return []
        self.rounds += 1
        order = sorted(waiting, key=lambda r: self._key(r, now))
        take: List["SolveRequest"] = []
        blocked: List["SolveRequest"] = []   # more-urgent, didn't fit
        skipped: List["SolveRequest"] = []   # blocked AND passed over
        for r in order:
            if r.nrhs <= free:
                take.append(r)
                free -= r.nrhs
                for b in blocked:            # this admission skips past b
                    if b not in skipped:
                        skipped.append(b)
            else:
                if r.sched_skips >= self.max_skips:
                    # starvation barrier: r has been skipped its full
                    # allowance — nothing behind it may backfill until
                    # it admits (requests *before* it in policy order
                    # are more urgent, not backfill, so `take` stands).
                    # Only a real seal counts as a barrier round: under
                    # max_skips == 0 this branch is plain head-of-line
                    # blocking, not a seal.
                    if self.max_skips > 0:
                        self.barrier_rounds += 1
                    break
                blocked.append(r)
        for b in skipped:
            if b.sched_skips == 0:
                self.skipped_reqs += 1
            b.sched_skips += 1
            self.backfill_skips += 1
        return take


class FIFOAdmission(_OrderedBackfill):
    """Strict submission order, head-of-line blocking (the historical
    inline behavior): ``max_skips = 0`` makes the queue head an
    immediate barrier, so nothing ever skips ahead."""

    name = "fifo"

    def __init__(self):
        super().__init__(max_skips=0)

    def _key(self, req: "SolveRequest", now: float):
        return (req._seq,)


class PriorityAdmission(_OrderedBackfill):
    """Priority classes with bounded backfill.  Order: ``(priority,
    submission seq)`` — lower priority value is more urgent; within a
    class, FIFO.  Narrow requests may skip a blocked wide head at most
    ``max_skips`` rounds."""

    name = "priority"

    def _key(self, req: "SolveRequest", now: float):
        return (req.priority, req._seq)


class DeadlineAdmission(_OrderedBackfill):
    """Earliest-deadline-first with bounded backfill and hopeless-lane
    eviction.  Order: ``(deadline, priority, seq)``; requests without a
    deadline sort last within their priority class.  Sets
    ``evict_hopeless`` so the engine retires lanes that can no longer
    finish before their deadline (``status == "deadline_missed"``)
    instead of letting them hold fleet slots to maxiter."""

    name = "deadline"
    evict_hopeless = True

    def _key(self, req: "SolveRequest", now: float):
        dl = req._deadline_abs
        return (dl if dl is not None else float("inf"),
                req.priority, req._seq)


_POLICIES = {
    "fifo": FIFOAdmission,
    "priority": PriorityAdmission,
    "deadline": DeadlineAdmission,
}


def make_policy(name: str, *, max_skips: Optional[int] = None
                ) -> AdmissionPolicy:
    """Build a policy by CLI name (``fifo`` / ``priority`` /
    ``deadline``).  ``max_skips`` overrides the backfill allowance for
    the backfilling policies (FIFO is always 0 — that *is* FIFO)."""
    try:
        cls = _POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown admission policy {name!r}; "
                         f"choose from {sorted(_POLICIES)}") from None
    if cls is FIFOAdmission or max_skips is None:
        return cls()
    return cls(max_skips=max_skips)
