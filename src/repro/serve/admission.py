"""Pluggable admission scheduling for the solve service.

PR 3 made lane state device-resident; admission stayed an inline FIFO
inside ``SolveEngine._admit`` — fair, but a wide request at the head of
the queue idles every free lane behind it (head-of-line blocking).
This module factors the *decision* out of the engine into a policy
object the engine consults once per tick:

* :class:`FIFOAdmission` — strict submission order with head-of-line
  blocking; byte-for-byte the engine's historical behavior (it is the
  engine's default, so sync ``SolveEngine`` users see no change);
* :class:`PriorityAdmission` — priority classes (lower value = more
  urgent) with **backfill**: when the most-urgent waiting request does
  not fit the free lanes, later narrow requests may skip ahead into
  them;
* :class:`DeadlineAdmission` — earliest-deadline-first ordering (then
  priority, then arrival) with the same backfill machinery, plus
  ``evict_hopeless = True``: the engine retires lanes whose deadline can
  no longer be met with a ``deadline_missed`` status instead of letting
  them squat on fleet slots.

**Starvation bound.**  Backfill is capped: each *admission round* (one
``select`` call with a non-empty queue) in which at least one request is
admitted past a blocked, more-urgent request increments the blocked
request's ``sched_skips``.  Once ``sched_skips == max_skips`` the
request becomes a **barrier** — nothing behind it in the policy order
may be admitted until it fits.  Hence a skipped request waits at most
``max_skips`` backfill rounds once it is the most-urgent blocked
request, and ``backfill_skips <= max_skips * skipped_reqs`` is a hard
counter invariant (gated in CI by
``benchmarks.check_serve_regression``).

**Work-conserving backfill under seal.**  A sealed queue idles free
lanes even when the sealed request will be waiting on *busy* lanes for
many more ticks.  Backfilling policies therefore still admit, past a
seal, any request whose worst-case duration **provably** cannot extend
the wait bound of the sealer or of any blocked more-urgent request: the
engine passes per-occupied-lane worst-case remaining ticks
(``busy_bounds``, from ``maxiter`` budgets and admit ticks — a lane
retires by maxiter whatever happens), a candidate's worst case is
``ceil(maxiter / iters_per_tick)`` ticks, and a blocked request needing
``need`` more lanes admits — in the worst case — when the ``need``-th
soonest-bounded busy lane retires.  A candidate no longer-lived than
that bound occupies a lane that is provably free again by then, so the
seal's guarantee is unchanged.  (Ticks are the sound currency here: the
engine's running-min tick estimate converts the bound to seconds only
for reporting — a *minimum* per-tick duration cannot prove an earlier
finish.)  Sealed backfills never touch ``sched_skips`` — they are
counted separately as ``sealed_backfills`` — so the starvation-bound
invariant above is untouched (also CI-gated: FIFO, whose ``max_skips``
is 0 and which never seals, must report zero).

Policies only *order and bound* admission; the engine still performs
the jitted scatter per admitted request, so serving stays bit-exact
with direct ``FactorHandle.solve`` regardless of policy — scheduling
changes *when* a request's lanes start, never what they compute.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, TYPE_CHECKING

if TYPE_CHECKING:                                     # pragma: no cover
    from .engine import SolveRequest


class AdmissionPolicy:
    """Decides which waiting requests to admit into free lanes.

    ``select`` receives a snapshot of the waiting queue (submission
    order) and the number of free lanes, and returns the requests to
    admit *this round*, in admission order; the engine scatters each and
    removes it from the queue.  The policy must only return requests
    whose combined ``nrhs`` fits ``free``.

    ``evict_hopeless`` tells the engine to retire active lanes whose
    request can no longer meet its deadline (see
    :class:`DeadlineAdmission`).
    """

    name = "base"
    max_skips = 0
    evict_hopeless = False

    def __init__(self) -> None:
        self.rounds = 0            # select calls with a non-empty queue
        self.backfill_skips = 0    # total skip increments across requests
        self.skipped_reqs = 0      # requests that were ever skipped
        self.barrier_rounds = 0    # rounds cut short by a starvation barrier
        self.sealed_backfills = 0  # provably-short admissions past a seal

    def select(self, waiting: Sequence["SolveRequest"], free: int, *,
               now: float, busy_bounds: Sequence[int] = (),
               iters_per_tick: int = 1) -> List["SolveRequest"]:
        """``busy_bounds``: one worst-case-remaining-ticks entry per
        occupied lane (the engine derives them from maxiter budgets);
        only the work-conserving seal path reads them."""
        raise NotImplementedError

    def counters(self) -> Dict[str, int]:
        return dict(sched_rounds=self.rounds,
                    backfill_skips=self.backfill_skips,
                    skipped_reqs=self.skipped_reqs,
                    barrier_rounds=self.barrier_rounds,
                    sealed_backfills=self.sealed_backfills)


class _OrderedBackfill(AdmissionPolicy):
    """Shared machinery: admit greedily in policy order, let later
    requests backfill past blocked ones, stop at a starvation barrier.

    Subclasses define ``_key(req)`` — the policy order (ascending; ties
    broken by engine submission sequence, which ``_key`` must include
    last for stability).
    """

    def __init__(self, max_skips: int = 8, work_conserving: bool = True):
        super().__init__()
        if max_skips < 0:
            raise ValueError("max_skips must be >= 0")
        self.max_skips = max_skips
        self.work_conserving = work_conserving

    def _key(self, req: "SolveRequest", now: float):
        raise NotImplementedError

    @staticmethod
    def _worst_ticks(req: "SolveRequest", ipt: int) -> int:
        """Upper bound on a not-yet-admitted request's lane lifetime:
        it retires by ``maxiter`` iterations whatever happens."""
        return max(-(-req.maxiter // ipt), 1)

    def select(self, waiting: Sequence["SolveRequest"], free: int, *,
               now: float, busy_bounds: Sequence[int] = (),
               iters_per_tick: int = 1) -> List["SolveRequest"]:
        if not waiting:
            return []
        self.rounds += 1
        order = sorted(waiting, key=lambda r: self._key(r, now))
        take: List["SolveRequest"] = []
        blocked: List["SolveRequest"] = []   # more-urgent, didn't fit
        skipped: List["SolveRequest"] = []   # blocked AND passed over
        for r in order:
            if r.nrhs <= free:
                take.append(r)
                free -= r.nrhs
                for b in blocked:            # this admission skips past b
                    if b not in skipped:
                        skipped.append(b)
            else:
                if r.sched_skips >= self.max_skips:
                    # starvation barrier: r has been skipped its full
                    # allowance — nothing behind it may backfill until
                    # it admits (requests *before* it in policy order
                    # are more urgent, not backfill, so `take` stands).
                    # Only a real seal counts as a barrier round: under
                    # max_skips == 0 this branch is plain head-of-line
                    # blocking, not a seal.
                    if self.max_skips > 0:
                        self.barrier_rounds += 1
                        if self.work_conserving and free > 0:
                            take += self._seal_backfill(
                                order, r, blocked, take, free,
                                busy_bounds, iters_per_tick)
                    break
                blocked.append(r)
        for b in skipped:
            if b.sched_skips == 0:
                self.skipped_reqs += 1
            b.sched_skips += 1
            self.backfill_skips += 1
        return take

    def _seal_backfill(self, order: List["SolveRequest"],
                       sealer: "SolveRequest",
                       blocked: List["SolveRequest"],
                       take: List["SolveRequest"], free: int,
                       busy_bounds: Sequence[int],
                       ipt: int) -> List["SolveRequest"]:
        """Work-conserving admission past a starvation seal.

        A blocked request ``g`` needing ``need = g.nrhs - free`` more
        lanes admits, in the *worst* case, when the ``need``-th
        soonest-bounded occupied lane retires (every lane retires by its
        maxiter budget).  A candidate whose own worst-case tick count is
        ≤ every guarded request's bound occupies a free lane that is
        provably free again before any of them could have admitted
        anyway — so admitting it cannot extend the seal's wait bound.
        Sealed admissions never increment ``sched_skips`` (the
        starvation-bound counters are untouched); they count in
        ``sealed_backfills``."""
        wt = self._worst_ticks
        busy = list(busy_bounds)
        for t in take:                       # this round's admissions
            busy += [wt(t, ipt)] * t.nrhs    # occupy lanes too
        guarded = blocked + [sealer]
        out: List["SolveRequest"] = []
        for c in order[order.index(sealer) + 1:]:
            if c.nrhs > free:
                continue
            w = wt(c, ipt)
            b = sorted(busy)
            ok = True
            for g in guarded:
                need = g.nrhs - free         # busy lanes g waits for
                if need > len(b) or w > b[need - 1]:
                    ok = False               # no provable headroom
                    break
            if ok:
                out.append(c)
                free -= c.nrhs
                busy += [w] * c.nrhs
                self.sealed_backfills += 1
        return out


class FIFOAdmission(_OrderedBackfill):
    """Strict submission order, head-of-line blocking (the historical
    inline behavior): ``max_skips = 0`` makes the queue head an
    immediate barrier, so nothing ever skips ahead."""

    name = "fifo"

    def __init__(self):
        super().__init__(max_skips=0)

    def _key(self, req: "SolveRequest", now: float):
        return (req._seq,)


class PriorityAdmission(_OrderedBackfill):
    """Priority classes with bounded backfill.  Order: ``(priority,
    submission seq)`` — lower priority value is more urgent; within a
    class, FIFO.  Narrow requests may skip a blocked wide head at most
    ``max_skips`` rounds."""

    name = "priority"

    def _key(self, req: "SolveRequest", now: float):
        return (req.priority, req._seq)


class DeadlineAdmission(_OrderedBackfill):
    """Earliest-deadline-first with bounded backfill and hopeless-lane
    eviction.  Order: ``(deadline, priority, seq)``; requests without a
    deadline sort last within their priority class.  Sets
    ``evict_hopeless`` so the engine retires lanes that can no longer
    finish before their deadline (``status == "deadline_missed"``)
    instead of letting them hold fleet slots to maxiter."""

    name = "deadline"
    evict_hopeless = True

    def _key(self, req: "SolveRequest", now: float):
        dl = req._deadline_abs
        return (dl if dl is not None else float("inf"),
                req.priority, req._seq)


_POLICIES = {
    "fifo": FIFOAdmission,
    "priority": PriorityAdmission,
    "deadline": DeadlineAdmission,
}


def make_policy(name: str, *, max_skips: Optional[int] = None,
                work_conserving: bool = True) -> AdmissionPolicy:
    """Build a policy by CLI name (``fifo`` / ``priority`` /
    ``deadline``).  ``max_skips`` overrides the backfill allowance for
    the backfilling policies (FIFO is always 0 — that *is* FIFO);
    ``work_conserving=False`` disables provably-short admissions past a
    starvation seal (FIFO never seals, so it has neither)."""
    try:
        cls = _POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown admission policy {name!r}; "
                         f"choose from {sorted(_POLICIES)}") from None
    if cls is FIFOAdmission:
        return cls()
    if max_skips is None:
        return cls(work_conserving=work_conserving)
    return cls(max_skips=max_skips, work_conserving=work_conserving)
