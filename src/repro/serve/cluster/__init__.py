"""Solve cluster: factor-affinity routing over multi-replica engines,
hot-factor replication with TTL demotion, replica health ejection, and
cluster-wide telemetry.  See :mod:`repro.serve.cluster.router` for the
full design notes."""
from .replica import EngineReplica  # noqa: F401
from .selector import AdaptiveSelector  # noqa: F401
from .factor_tier import FactorTier, FactorReplica  # noqa: F401
from .router import (SolveCluster, Router, RoutingPolicy,  # noqa: F401
                     FactorAffinityRouting, LeastLoadedRouting,
                     RoundRobinRouting, make_routing,
                     resolve_devices, ClusterOverloadedError)
from .stats import ClusterStats, ReplicaStats  # noqa: F401
