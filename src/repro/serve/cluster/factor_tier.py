"""Dedicated factor tier: construction replicas that own no solve lanes.

Colocated clusters factor on the serving replica's driver thread
(``SolveFrontend.call``), freezing that replica's solve lanes for the
whole construction — seconds of ``control_s`` per cold graph.  This
module disaggregates the two phases the way LLM serving stacks split
prefill from decode (vLLM production-stack's disaggregated prefill
orchestration): a :class:`FactorTier` owns K :class:`FactorReplica`
worker threads, each pinned to its own device, draining one
cluster-level factor queue.  Solve replicas keep serving; the only
construction work that ever touches a serving driver thread is the
cheap ``FactorCache.adopt`` — device transfer + fleet-row scatter.

Three tier-level economies the colocated path cannot express:

* **Coalescing** — pending AC jobs (the batched-construction family)
  are drained together into one ``factorize_batched`` call, so a burst
  of N cold tenants pays one mega-batched wavefront program instead of
  N sequential ones (``parac`` buckets mixed shapes internally).
  Schedules derive in the same batch (``with_schedules=True``), so the
  serving replica never runs a schedule build either.
* **Dedup** — concurrent jobs for the same placement id ride one
  construction: later arrivals become *siblings* of the in-flight job
  and receive their own adoption of the shared payload (a hot graph
  being replicated to two solve replicas factors once, adopts twice).
* **Failover** — if the placement-target solve replica dies between
  enqueue and adoption, the finished payload is re-targeted through the
  cluster's ``on_retarget`` callback (which moves the router placement
  under the cluster lock) instead of dying with the driver it was
  aimed at.

The tier constructs with the same ``chunk``/``fill_slack``/``strict``
parameters as the serving caches, so an adopted factor is bit-identical
to what a colocated construction would have produced — the cluster's
bit-exactness invariant survives disaggregation (acceptance-tested).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Callable, Deque, Dict, List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.ref_ac import DeviceFactor
from repro.core.parac import factorize_batched
from repro.core.solver import get_family
from repro.core.trisolve import build_schedules_batched
from repro.obs.flight import NULL_FLIGHT
from repro.obs.registry import NULL as _NULL_METRICS

from .replica import EngineReplica


class FactorJob:
    """One queued construction: placement id, graph payload, and the
    solve replica the finished factor must be adopted onto.  ``future``
    resolves to the adopted handle (the same contract as
    ``EngineReplica.factor`` — the router stores it as the pending
    placement).  ``siblings`` are deduped later arrivals for the same
    placement id, each wanting its own adoption target."""

    __slots__ = ("gid", "g", "key", "family", "params", "ttl_s",
                 "target", "future", "siblings", "enqueue_t")

    def __init__(self, gid: str, g, key, *, family: str, params: Dict,
                 ttl_s: Optional[float], target: EngineReplica,
                 enqueue_t: float):
        self.gid = gid
        self.g = g
        self.key = key
        self.family = family
        self.params = dict(params or {})
        self.ttl_s = ttl_s
        self.target = target
        self.future: "Future" = Future()
        self.siblings: List["FactorJob"] = []
        self.enqueue_t = enqueue_t

    @property
    def coalescable(self) -> bool:
        # only the default-parameter AC construction goes through
        # factorize_batched; parameterized/deterministic families
        # construct singly (still off the serving driver)
        return self.family == "ac" and not self.params


class FactorReplica(threading.Thread):
    """One tier worker: drains the shared queue, constructs on its own
    pinned device, ships adoptions.  Crashing on one job fails that
    job's futures and keeps draining — a poisoned graph must not wedge
    the whole tier."""

    def __init__(self, index: int, tier: "FactorTier",
                 device: Optional[jax.Device]):
        super().__init__(name=f"factor-replica-{index}", daemon=True)
        self.index = index
        self.tier = tier
        self.device = device
        self.factored = 0        # constructions completed
        self.batches = 0         # construction calls issued
        self.coalesced = 0       # constructions that shared a batch
        self.adoptions = 0       # adoptions shipped (incl. siblings)
        self.failovers = 0       # adoptions re-targeted off a dead replica
        self.factor_s = 0.0      # construction wall-clock on this worker
        self.start()

    # -- construction -------------------------------------------------------
    def _construct(self, batch: List[FactorJob]) -> List[tuple]:
        """Build every job's payload (and schedules where derivable) on
        this worker's device.  Coalescable batches go through one
        ``factorize_batched``; singles through the family builder."""
        t = self.tier
        if len(batch) > 1 or (batch[0].coalescable and len(batch) == 1):
            fs, scheds = factorize_batched(
                [j.g for j in batch], jnp.stack([j.key for j in batch]),
                chunk=t.chunk, fill_slack=t.fill_slack, strict=t.strict,
                max_retries=t.max_retries, dtype=t.dtype,
                with_schedules=True, device=self.device)
            return list(zip(fs, scheds))
        job = batch[0]
        fam = get_family(job.family)
        kw = dict(job.params)
        if job.family == "ac":
            kw.setdefault("chunk", t.chunk)
            kw.setdefault("fill_slack", t.fill_slack)
            kw.setdefault("strict", t.strict)
            kw.setdefault("max_retries", t.max_retries)
        if self.device is not None:
            with jax.default_device(self.device):
                f = fam.build(job.g, job.key, dtype=t.dtype, **kw)
        else:
            f = fam.build(job.g, job.key, dtype=t.dtype, **kw)
        sch = None
        if fam.kind == "factor" and isinstance(f, DeviceFactor):
            sch = build_schedules_batched([f], device=self.device)[0]
        return [(f, sch)]

    # -- adoption (with dead-target failover) -------------------------------
    def _ship(self, job: FactorJob, f, sch, construct_s: float) -> None:
        target = job.target
        attempts = 0
        while True:
            t_a0 = time.perf_counter()
            try:
                handle = target.adopt(
                    job.g, f, graph_id=job.gid, family=job.family,
                    schedules=sch, construct_s=construct_s,
                    ttl_s=job.ttl_s).result()
            except Exception as exc:
                if target.alive:
                    # genuine adopt failure (budget, bad payload):
                    # surface it — the router drops the placement
                    if not job.future.done():
                        job.future.set_exception(exc)
                    return
                attempts += 1
                retarget = self.tier._on_retarget
                newt = (retarget(job.gid, target.index, job.future)
                        if retarget is not None
                        and attempts <= self.tier.max_failovers else None)
                if newt is None:
                    if not job.future.done():
                        job.future.set_exception(RuntimeError(
                            f"factor target replica {target.index} died "
                            f"and no healthy failover target remains "
                            f"for {job.gid!r}"))
                    return
                self.failovers += 1
                with self.tier._lock:
                    self.tier.failovers += 1
                self.tier._ev_failover(gid=job.gid, dead=target.index,
                                       new=newt.index)
                target = newt
                continue
            self.adoptions += 1
            self.tier._m_adopt_s.observe(time.perf_counter() - t_a0)
            self.tier._m_adoptions.inc()
            with self.tier._lock:
                self.tier.adoptions += 1
            if not job.future.done():
                job.future.set_result(handle)
            return

    # -- the drain loop -----------------------------------------------------
    def run(self) -> None:
        tier = self.tier
        while True:
            batch = tier._take_batch()
            if batch is None:
                return
            t0 = time.perf_counter()
            try:
                payloads = self._construct(batch)
            except Exception as exc:
                for job in batch:
                    victims = [job]
                    while True:
                        sibs = tier._finish(job)
                        if not sibs:
                            break
                        victims.extend(sibs)
                    for j in victims:
                        if not j.future.done():
                            j.future.set_exception(exc)
                continue
            dt = time.perf_counter() - t0
            self.factor_s += dt
            tier._m_construct_s.observe(dt)
            self.batches += 1
            self.factored += len(batch)
            if len(batch) > 1:
                self.coalesced += len(batch)
                with tier._lock:
                    tier.coalesced_factorizations += len(batch)
            per_job_s = dt / len(batch)
            for job, (f, sch) in zip(batch, payloads):
                self._ship(job, f, sch, per_job_s)
                # siblings deduped onto this job adopt the same payload
                # (possibly onto other replicas); drain until none race in
                while True:
                    sibs = tier._finish(job)
                    if not sibs:
                        break
                    for sib in sibs:
                        self._ship(sib, f, sch, 0.0)

    def stats(self) -> Dict:
        return dict(index=self.index, alive=self.is_alive(),
                    device=(str(self.device) if self.device is not None
                            else None),
                    factored=self.factored, batches=self.batches,
                    coalesced=self.coalesced, adoptions=self.adoptions,
                    failovers=self.failovers, factor_s=self.factor_s)


class FactorTier:
    """K construction workers over one shared factor queue.

    Args:
        replicas: worker-thread count.
        devices: per-worker device pinning (``None`` entries leave the
            worker on the process default device).
        chunk / fill_slack / strict / max_retries / dtype: construction
            parameters — must match the serving caches' so adopted
            factors are bit-identical to colocated ones.
        max_batch: coalescing cap per ``factorize_batched`` call.
        max_failovers: adoption re-target bound per job (a dead cluster
            must fail the future, not spin).
        on_retarget: ``(gid, dead_index, future) -> EngineReplica|None``
            — the cluster's placement-moving callback (runs under the
            cluster lock; returns the new target or ``None`` when no
            healthy replica remains).
    """

    def __init__(self, replicas: int = 1, *,
                 devices: Optional[Sequence[Optional[jax.Device]]] = None,
                 chunk: int = 64, fill_slack: int = 32,
                 strict: bool = True, max_retries: int = 3,
                 dtype=np.float32, max_batch: int = 16,
                 max_failovers: int = 8,
                 on_retarget: Optional[Callable] = None,
                 metrics=None, flight=None):
        if replicas < 1:
            raise ValueError("factor tier needs >= 1 replica")
        self.chunk = chunk
        self.fill_slack = fill_slack
        self.strict = strict
        self.max_retries = max_retries
        self.dtype = dtype
        self.max_batch = max_batch
        self.max_failovers = max_failovers
        self._on_retarget = on_retarget
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._queue: Deque[FactorJob] = deque()
        # gid -> in-flight job (queued or constructing): the dedup map.
        # Entries leave only via _finish, after adoption — a late twin
        # arriving mid-construction still rides the shared payload.
        self._pending: Dict[str, FactorJob] = {}
        self._inflight = 0
        self._closed = False
        self.enqueued = 0
        self.dedups = 0
        self.adoptions = 0
        self.failovers = 0
        self.coalesced_factorizations = 0
        # observability (repro.obs): tier-level instruments shared by
        # the workers — no-ops when metrics is None
        reg = metrics if metrics is not None else _NULL_METRICS
        self._m_enqueued = reg.counter(
            "repro_factor_tier_enqueued_total",
            "constructions queued on the factor tier")
        self._m_dedups = reg.counter(
            "repro_factor_tier_dedups_total",
            "placements that rode an in-flight construction")
        self._m_adoptions = reg.counter(
            "repro_factor_tier_adoptions_total",
            "factor payloads shipped to solve replicas")
        self._m_construct_s = reg.histogram(
            "repro_factor_tier_construct_seconds",
            "construction wall seconds per batch on a tier worker")
        self._m_adopt_s = reg.histogram(
            "repro_factor_tier_adopt_seconds",
            "adopt round-trip seconds per shipped payload")
        fl = flight if flight is not None else NULL_FLIGHT
        self._ev_failover = fl.bind("failover")
        self.workers = [
            FactorReplica(i, self,
                          devices[i] if devices is not None else None)
            for i in range(replicas)]

    # -- producer side (router / cluster threads) ---------------------------
    def submit(self, gid: str, g, key, *, family: str = "ac",
               precond_params: Optional[Dict] = None,
               ttl_s: Optional[float] = None,
               target: EngineReplica) -> "Future":
        """Queue a construction for ``gid`` destined for ``target``;
        returns the future the router stores as the pending placement
        (resolves to the adopted handle).  A job for the same ``gid``
        already in flight dedupes: this call rides its construction and
        only pays its own adoption."""
        with self._work:
            if self._closed:
                raise RuntimeError("submit on a closed FactorTier")
            job = FactorJob(gid, g, key, family=family,
                            params=precond_params, ttl_s=ttl_s,
                            target=target, enqueue_t=time.monotonic())
            prior = self._pending.get(gid)
            if prior is not None:
                prior.siblings.append(job)
                self.dedups += 1
                self._m_dedups.inc()
                return job.future
            self._pending[gid] = job
            self._queue.append(job)
            self.enqueued += 1
            self._m_enqueued.inc()
            self._work.notify()
        return job.future

    @property
    def queue_depth(self) -> int:
        """Constructions queued or in flight on a worker — the tier's
        backlog signal (advisory cross-thread read)."""
        return len(self._queue) + self._inflight

    # -- worker side --------------------------------------------------------
    def _take_batch(self) -> Optional[List[FactorJob]]:
        """Block for work; returns a head job plus any coalescable
        pending jobs (one ``factorize_batched`` worth), or ``None`` on
        close."""
        with self._work:
            while not self._queue and not self._closed:
                self._work.wait(timeout=0.05)
            if not self._queue:
                return None          # closed and drained
            head = self._queue.popleft()
            batch = [head]
            if head.coalescable:
                keep = deque()
                while self._queue and len(batch) < self.max_batch:
                    j = self._queue.popleft()
                    if j.coalescable:
                        batch.append(j)
                    else:
                        keep.append(j)
                while keep:
                    self._queue.appendleft(keep.pop())
            self._inflight += len(batch)
            return batch

    def _finish(self, job: FactorJob) -> List[FactorJob]:
        """Drain ``job``'s deduped siblings; once none remain, retire
        its dedup entry (and its in-flight count).  Called repeatedly
        until it returns empty — a twin racing in mid-adoption is still
        picked up."""
        with self._lock:
            sibs = job.siblings
            if sibs:
                job.siblings = []
                return sibs
            if self._pending.get(job.gid) is job:
                del self._pending[job.gid]
            self._inflight -= 1
            return []

    # -- telemetry / lifecycle ----------------------------------------------
    def stats(self) -> Dict:
        with self._lock:
            return dict(
                replicas=len(self.workers),
                factor_queue_depth=self.queue_depth,
                enqueued=self.enqueued, dedups=self.dedups,
                adoptions=self.adoptions, failovers=self.failovers,
                coalesced_factorizations=self.coalesced_factorizations,
                factor_s=sum(w.factor_s for w in self.workers),
                per_replica=[w.stats() for w in self.workers])

    def close(self, timeout: Optional[float] = 10.0) -> None:
        """Stop the workers once the queue drains; queued-but-unstarted
        jobs after the timeout fail their futures."""
        with self._work:
            self._closed = True
            self._work.notify_all()
        for w in self.workers:
            w.join(timeout=timeout)
        with self._lock:
            leftovers = list(self._queue)
            self._queue.clear()
        for job in leftovers:
            for j in [job] + job.siblings:
                if not j.future.done():
                    j.future.set_exception(
                        RuntimeError("FactorTier closed"))
