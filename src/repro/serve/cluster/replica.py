"""One solve-cluster replica: a **private** ``FactorCache`` (and with it
private ``FactorFleet`` stacks and jitted fleet programs) behind a
``SolveEngine`` + ``SolveFrontend`` driver thread.

The replica is the cluster's unit of isolation and of state: holding a
factor *is* holding device memory, so the router's whole job is to send
a ``graph_id`` where its factor already lives.  All engine/cache
**mutation** goes through the frontend's driver thread — ``factor()``
rides the frontend control channel (``SolveFrontend.call``), so a
router thread never races the driver inside the cache.  The read-only
probes the router needs (``fresh``/``load``/``capacity_probe``) are
plain GIL-atomic reads of host bookkeeping and are safe from any
thread.
"""
from __future__ import annotations

from concurrent.futures import Future
from typing import Callable, Dict, Optional

import jax

from repro.core.solver import FactorCache, FactorHandle
from repro.serve.admission import AdmissionPolicy
from repro.serve.engine import SolveEngine, SolveRequest
from repro.serve.frontend import SolveFrontend


class EngineReplica:
    """``SolveFrontend`` + private ``FactorCache`` as one unit of a
    :class:`~repro.serve.cluster.router.SolveCluster`.

    ``overload`` defaults to ``"reject"`` (unlike a standalone
    frontend's ``"block"``): the router wants the backpressure signal
    immediately so it can spill to another replica instead of stalling
    its submit path on one hot engine.

    ``device`` pins this replica's private cache — its fleet stacks,
    lane carries and (through committed-input placement) its jitted
    fleet programs — to one accelerator, so N replicas over N devices
    scale capacity with device count and the router is the only
    cross-device hop.
    """

    def __init__(self, index: int, *, slots: int = 8,
                 iters_per_tick: int = 8,
                 admission: Optional[AdmissionPolicy] = None,
                 max_queue: int = 256, overload: str = "reject",
                 clock: Optional[Callable[[], float]] = None,
                 device: Optional[jax.Device] = None,
                 cache_kw: Optional[Dict] = None,
                 metrics=None, tracer=None, flight=None, health=None):
        self.index = index
        self.device = device
        kw = dict(cache_kw or {})
        if clock is not None:
            kw.setdefault("clock", clock)
        if device is not None:
            kw.setdefault("device", device)
        if flight is not None:
            kw.setdefault("flight", flight)
        self.cache = FactorCache(**kw)
        self.engine = SolveEngine(self.cache, slots=slots,
                                  iters_per_tick=iters_per_tick,
                                  admission=admission, clock=clock,
                                  metrics=metrics, tracer=tracer,
                                  flight=flight, health=health,
                                  obs_replica=index,
                                  obs_device=str(device) if device is not None
                                  else "")
        self.frontend = SolveFrontend(self.engine, max_queue=max_queue,
                                      overload=overload, metrics=metrics,
                                      flight=flight, obs_replica=index)

    # -- read-only probes (any thread) --------------------------------------
    def fresh(self, graph_id: str) -> bool:
        """Resident and not TTL/tick-stale: routable without factoring."""
        return self.cache.fresh(graph_id)

    @property
    def load(self) -> int:
        """Requests waiting anywhere plus lanes in flight — the routing
        load signal.  ``queue_depth`` is the frontend's own backpressure
        read; the lane scan is the same advisory GIL-atomic contract."""
        return (self.frontend.queue_depth
                + sum(l is not None for l in self.engine.lanes))

    def capacity_probe(self) -> Dict[str, Optional[int]]:
        """Free-capacity snapshot of this replica's private cache
        (budget headroom, reusable fleet rows) — what miss placement
        ranks replicas by."""
        return self.cache.capacity_probe()

    @property
    def alive(self) -> bool:
        """Driver-thread liveness (see ``SolveFrontend.alive``) — the
        signal the cluster health loop keys ejection on."""
        return self.frontend.alive

    # -- mutation (driver thread via the control channel) -------------------
    def factor(self, g, key, *, graph_id: str, family: str = "ac",
               precond_params: Optional[Dict] = None,
               ttl_s: Optional[float] = None) -> "Future[FactorHandle]":
        """Factor ``g`` into this replica's private cache **on the
        driver thread**; resolves to the admitted handle.  ``family`` /
        ``precond_params`` select the preconditioner family constructed
        (the router passes the family its placement id encodes);
        ``ttl_s`` carries the hot-replica demotion TTL (``None`` =
        immortal primary placement)."""
        return self.frontend.call(self.cache.factor, g, key,
                                  graph_id=graph_id, family=family,
                                  precond_params=precond_params,
                                  ttl_s=ttl_s)

    def adopt(self, g, f, *, graph_id: str, family: str = "ac",
              schedules=None, construct_s: float = 0.0,
              ttl_s: Optional[float] = None) -> "Future[FactorHandle]":
        """Admit a payload constructed elsewhere (a factor-tier replica)
        into this replica's private cache **on the driver thread** —
        device transfer + fleet-row scatter only, never a factorization,
        so the driver stall is milliseconds where ``factor()`` is
        seconds (the whole point of the factor tier)."""
        return self.frontend.call(self.cache.adopt, g, f,
                                  graph_id=graph_id, family=family,
                                  schedules=schedules,
                                  construct_s=construct_s, ttl_s=ttl_s)

    def submit(self, req: SolveRequest) -> "Future[SolveRequest]":
        """Queue a routed request.  *This* replica's factor is pinned
        on the request first (a non-mutating ``peek``): a TTL expiry or
        LRU eviction while the request sits in the ingress queue must
        not fail it — the engine falls back to the strong ref, exactly
        like its own mid-flight pinning.  The pin is unconditional: an
        overload retry must not carry a previously-tried replica's
        handle here, or the fallback could serve the request out of
        another replica's private fleet."""
        req._handle = self.cache.peek(req.graph_id)
        return self.frontend.submit_request(req)

    # -- lifecycle ----------------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until this replica's submitted work resolves (False on
        timeout)."""
        return self.frontend.drain(timeout=timeout)

    def close(self, *, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Stop the replica's driver thread (draining first by
        default); pending futures fail once closed."""
        self.frontend.close(drain=drain, timeout=timeout)
