"""Factor-affinity router over multi-replica solve engines.

One ``SolveFrontend`` is a scale ceiling: one driver thread, one
``FactorCache``, one device's worth of fleet buffers.  ``SolveCluster``
owns N :class:`~repro.serve.cluster.replica.EngineReplica`\\ s and puts a
``Router`` in front, restating the cache-aware routing pattern of LLM
serving gateways (route to the replica that already holds the expensive
per-tenant state; replicate hot state; shed to the least-loaded replica
otherwise) for factor-once/serve-many PCG: the *factored graph* is the
warm state — cheap to reuse, costly to rebuild — so affinity routing is
what makes the cluster amortize like a single cache.

Routing policies (pluggable, ``make_routing``):

* ``factor_affinity`` — route a ``graph_id`` to the replica whose cache
  holds its fingerprint live (ties: least-loaded, so replicated hot
  factors split traffic); on miss, **place** it on the replica with the
  most free fleet capacity (budget headroom, reusable fleet rows) and
  record the placement;
* ``least_loaded`` (``p2c``) — power-of-two-choices on queue depth +
  in-flight lanes (seeded sampler, so traces replay deterministically);
* ``round_robin`` (``rr``) — the baseline that ignores all state.

Whatever the policy chooses, the cluster *ensures* the factor is
resident before submitting (factoring through the replica's driver-
thread control channel), so ``rr``/``p2c`` pay repeated placements
where affinity pays one — the difference the affinity-hit counters and
``benchmarks.bench_cluster`` measure.

**Hot-factor replication.**  The router tracks per-graph arrival rates
in a sliding window; when a graph crosses ``replicate_above`` req/s and
holds a single live placement, it is proactively factored onto a second
replica **with a TTL** (``replica_ttl_s``), and affinity routing then
splits its traffic across both copies.  Demotion reuses the cache's
existing staleness machinery: the copy expires out of the replica's
cache by TTL, the router observes the fingerprint is no longer fresh on
its next route and drops the placement (counted as a demotion); a graph
that is still hot simply re-promotes.

**Health.**  A replica is unroutable while its driver thread is dead
(``SolveFrontend.alive`` — a crashed driver fails its futures rather
than blackholing, and never comes back) or while it is *ejected*: too
many router-observed ``EngineOverloadedError`` rejections inside the
health window ejects the replica for ``readmit_cooldown_s``, after
which it is re-admitted with a cleared record.  Requests that no
healthy replica can take raise :class:`ClusterOverloadedError` and are
counted as ``shed``.

**Bit-exactness.**  Routing changes *where* a request runs, never what
it computes: each replica serves through the unchanged engine/fleet
programs, so any routed request is bit-exact with a direct
``FactorHandle.solve`` on the serving replica's own cache (the
cluster's signature invariant, acceptance-tested and CI-gated).
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict, deque
from concurrent.futures import Future
from typing import (Callable, Deque, Dict, List, Optional, Sequence, Set,
                    Tuple)

import numpy as np
import jax

from repro.core.solver import PRECOND_FAMILIES, graph_fingerprint
from repro.obs.flight import NULL_FLIGHT
from repro.obs.registry import NULL as _NULL_METRICS
from repro.serve.admission import make_policy
from repro.serve.engine import SolveRequest, make_request
from repro.serve.frontend import EngineOverloadedError

from .factor_tier import FactorTier
from .replica import EngineReplica
from .selector import AdaptiveSelector
from .stats import ClusterStats, ReplicaStats


def resolve_devices(spec, n: int) -> List[Optional[jax.Device]]:
    """Resolve a device assignment for ``n`` replica slots.

    ``spec`` may be ``None`` (round-robin over ``jax.devices()`` — on a
    one-device host this is the process default and pinning is a no-op),
    a comma-separated string (``"cpu:0,cpu:1"``, the ``--devices`` CLI
    form), or a sequence of devices / integer indices / ``platform:idx``
    strings.  Fewer entries than slots round-robin."""
    avail = jax.devices()
    if spec is None:
        pool = avail
    else:
        if isinstance(spec, str):
            spec = [s.strip() for s in spec.split(",") if s.strip()]
        pool = []
        for s in spec:
            if isinstance(s, int):
                pool.append(avail[s])
            elif isinstance(s, str):
                plat, sep, idx = s.partition(":")
                if sep:
                    pool.append(jax.devices(plat)[int(idx)])
                else:
                    pool.append(avail[int(s)] if s.isdigit()
                                else jax.devices(s)[0])
            else:
                pool.append(s)          # an actual jax.Device
        if not pool:
            raise ValueError("empty device spec")
    return [pool[i % len(pool)] for i in range(n)]


class ClusterOverloadedError(EngineOverloadedError):
    """No healthy replica could take the request (all ejected, dead, or
    rejecting under backpressure) — the cluster-level 429."""


def _capacity_score(rep: EngineReplica) -> Tuple:
    """Comparable free-capacity key (higher = roomier): budget headroom
    first, then admittable handles, then fleet rows reusable without
    growing a stack, then fewest resident handles."""
    p = rep.capacity_probe()
    return (p["free_bytes"] if p["free_bytes"] is not None else float("inf"),
            (p["free_handles"] if p["free_handles"] is not None
             else float("inf")),
            p["fleet_free_rows"], -p["handles"])


def _roomiest(reps: Sequence[EngineReplica]) -> EngineReplica:
    return max(reps, key=lambda r: (_capacity_score(r), -r.load, -r.index))


# ---------------------------------------------------------------------------
# Routing policies
# ---------------------------------------------------------------------------

class RoutingPolicy:
    """Chooses the serving replica for one request.  ``holders`` are the
    healthy replicas already holding the graph's factor live,
    ``pending`` those with a factor for it still in flight (both
    possibly empty); ``candidates`` are all healthy replicas (a
    superset).  The cluster ensures the factor is resident on whatever
    is returned, so a policy that ignores ``holders`` simply pays more
    placements."""

    name = "base"

    def choose(self, graph_id: str, holders: Sequence[EngineReplica],
               candidates: Sequence[EngineReplica],
               pending: Sequence[EngineReplica] = ()) -> EngineReplica:
        raise NotImplementedError


class RoundRobinRouting(RoutingPolicy):
    """Cycle over healthy replicas, blind to factor placement and load —
    the baseline affinity routing must beat on hit rate (CI-gated)."""

    name = "rr"

    def __init__(self):
        self._i = 0

    def choose(self, graph_id, holders, candidates, pending=()):
        rep = candidates[self._i % len(candidates)]
        self._i += 1
        return rep


class LeastLoadedRouting(RoutingPolicy):
    """Power-of-two-choices: sample two healthy replicas (seeded RNG —
    replays are deterministic) and take the less loaded; the classic
    balanced-allocations shed policy, still blind to placement."""

    name = "p2c"

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)

    def choose(self, graph_id, holders, candidates, pending=()):
        if len(candidates) > 2:
            ij = self._rng.choice(len(candidates), size=2, replace=False)
            candidates = [candidates[int(k)] for k in ij]
        return min(candidates, key=lambda r: (r.load, r.index))


class FactorAffinityRouting(RoutingPolicy):
    """Route to a replica already holding the factor (least-loaded among
    holders, so a replicated hot factor splits its traffic); a factor
    still *in flight* counts next — riding the pending placement
    instead of starting a second immortal copy of the same graph; only
    a true miss places, on the replica with the most free fleet
    capacity."""

    name = "affinity"

    def choose(self, graph_id, holders, candidates, pending=()):
        if holders:
            return min(holders, key=lambda r: (r.load, r.index))
        if pending:
            return min(pending, key=lambda r: (r.load, r.index))
        return _roomiest(candidates)


_ROUTINGS = {
    "rr": RoundRobinRouting, "round_robin": RoundRobinRouting,
    "p2c": LeastLoadedRouting, "least_loaded": LeastLoadedRouting,
    "affinity": FactorAffinityRouting,
    "factor_affinity": FactorAffinityRouting,
}


def make_routing(name: str, *, seed: int = 0) -> RoutingPolicy:
    """Build a routing policy by CLI name (``affinity`` / ``p2c`` /
    ``rr``, long aliases accepted)."""
    try:
        cls = _ROUTINGS[name]
    except KeyError:
        raise ValueError(f"unknown routing policy {name!r}; choose from "
                         f"{sorted(_ROUTINGS)}") from None
    return cls(seed=seed) if cls is LeastLoadedRouting else cls()


# ---------------------------------------------------------------------------
# Router: placements, rates, health, counters
# ---------------------------------------------------------------------------

_MISSING = object()


def _done_future() -> Future:
    fut: Future = Future()
    fut.set_result(None)
    return fut


class Router:
    """The cluster's stateful routing brain.  Owns the placement map
    (``graph_id -> {replica_index: None | pending factor Future}``),
    per-graph arrival-rate windows, per-replica health records and every
    routing counter.  All methods are called with the cluster lock held;
    replica probes they touch are read-only."""

    def __init__(self, policy: RoutingPolicy,
                 replicas: Sequence[EngineReplica], *,
                 clock: Callable[[], float],
                 factor_cb: Callable[[str, EngineReplica, Optional[float]],
                                     Future],
                 replicate_above: Optional[float] = None,
                 rate_window_s: float = 1.0,
                 replica_ttl_s: float = 30.0,
                 eject_rejections: int = 4,
                 health_window_s: float = 1.0,
                 readmit_cooldown_s: float = 2.0,
                 flight=None):
        self.policy = policy
        self.replicas = list(replicas)
        self._clock = clock
        self._factor_cb = factor_cb
        self.replicate_above = replicate_above
        self.rate_window_s = rate_window_s
        self.replica_ttl_s = replica_ttl_s
        self.eject_rejections = eject_rejections
        self.health_window_s = health_window_s
        self.readmit_cooldown_s = readmit_cooldown_s
        # graph_id -> {replica index: None (live) | Future (factoring)}
        self.placements: Dict[str, Dict[int, Optional[Future]]] = {}
        self._arrivals: Dict[str, Deque[float]] = defaultdict(deque)
        self._rejects: Dict[int, Deque[float]] = defaultdict(deque)
        self._ejected_until: Dict[int, float] = {}
        self.routed = 0
        self.affinity_hits = 0
        self.affinity_misses = 0
        self.factor_dedups = 0
        self.replications = 0
        self.demotions = 0
        self.ejections = 0
        self.readmissions = 0
        self.shed = 0
        self.routed_per: Dict[int, int] = defaultdict(int)
        self.rejections_per: Dict[int, int] = defaultdict(int)
        # flight-recorder hooks: pre-bound so the health loop pays one
        # call per *transition*, nothing per route.  incident() defers
        # its dump to a worker thread, so firing it here — under the
        # cluster lock — cannot deadlock against stats_fn.
        fl = flight if flight is not None else NULL_FLIGHT
        self._flight = fl
        self._ev_eject = fl.bind("eject")
        self._ev_readmit = fl.bind("readmit")

    # -- health -------------------------------------------------------------
    def healthy(self, *, advance: bool = True) -> List[EngineReplica]:
        """Routable replicas.  With ``advance`` (the routing path) this
        also runs the ejection/re-admission loop: a dead driver ejects
        permanently (its futures are already failed — work *drains*, it
        does not blackhole); an overload ejection expires after
        ``readmit_cooldown_s``.  ``advance=False`` (telemetry) is a pure
        read — polling stats must never change routing state or count
        cleanly-closed replicas as ejections."""
        now = self._clock()
        out = []
        for rep in self.replicas:
            i = rep.index
            until = self._ejected_until.get(i)
            if not rep.alive:
                if advance and until != float("inf"):
                    if until is None:
                        self.ejections += 1
                        self._ev_eject(replica=i, reason="dead_driver")
                        self._flight.incident("replica_ejected",
                                              replica=i,
                                              cause="dead_driver")
                    self._ejected_until[i] = float("inf")
                continue
            if until is not None:
                if now < until:
                    continue
                if advance:
                    del self._ejected_until[i]  # cooldown over: probation
                    self._rejects[i].clear()
                    self.readmissions += 1
                    self._ev_readmit(replica=i)
            out.append(rep)
        return out

    def record_overload(self, rep: EngineReplica) -> None:
        """A submit to ``rep`` raised ``EngineOverloadedError``; too many
        inside the health window ejects it for the cooldown."""
        i = rep.index
        self.rejections_per[i] += 1
        now = self._clock()
        dq = self._rejects[i]
        dq.append(now)
        while dq and dq[0] < now - self.health_window_s:
            dq.popleft()
        if len(dq) >= self.eject_rejections and \
                i not in self._ejected_until:
            self._ejected_until[i] = now + self.readmit_cooldown_s
            self.ejections += 1
            dq.clear()
            self._ev_eject(replica=i, reason="overload")
            self._flight.incident("replica_ejected", replica=i,
                                  cause="overload")

    def record_routed(self, rep: EngineReplica, *, hit: bool) -> None:
        """A submit to ``rep`` was accepted — only now does the route
        count (and classify as affinity hit or miss), so overload
        retries cannot double-count and ``affinity_hits +
        affinity_misses == routed`` is an exact invariant (CI-gated)."""
        self.routed += 1
        self.routed_per[rep.index] += 1
        if hit:
            self.affinity_hits += 1
        else:
            self.affinity_misses += 1

    # -- placements ---------------------------------------------------------
    def _refresh_placements(self, gid: str) -> Dict[int, Optional[Future]]:
        """Resolve pending factor futures, drop placements on dead
        replicas, demote TTL-expired (or externally evicted) copies."""
        pl = self.placements.get(gid)
        if not pl:
            return {}
        for i, fut in list(pl.items()):
            rep = self.replicas[i]
            if not rep.alive:
                del pl[i]                   # replica gone, placement too
                continue
            if fut is not None:
                if not fut.done():
                    continue                # still factoring
                if fut.exception() is not None:
                    del pl[i]               # factor failed
                    continue
                pl[i] = None                # landed: live placement
            if not rep.fresh(gid):
                del pl[i]                   # TTL demotion (staleness
                self.demotions += 1         # machinery did the aging)
        if not pl:
            self.placements.pop(gid, None)
            return {}
        return dict(pl)

    def place(self, gid: str, rep: EngineReplica, *,
              ttl_s: Optional[float] = None) -> Future:
        """Ensure ``gid``'s factor is (or is becoming) resident on
        ``rep``; returns a future resolving when it is.  The placement
        is recorded only once the factor call is actually in flight —
        a ``_factor_cb`` that raises (e.g. unregistered graph) must not
        leave a stray empty placement entry behind."""
        pl = self.placements.get(gid)
        if pl is not None:
            cur = pl.get(rep.index, _MISSING)
            if cur is None:
                return _done_future()       # already live
            if isinstance(cur, Future):
                self.factor_dedups += 1     # ride the in-flight factor
                return cur
        fut = self._factor_cb(gid, rep, ttl_s)
        self.placements.setdefault(gid, {})[rep.index] = fut
        return fut

    def drop_placement(self, gid: str, index: int) -> None:
        """Forget ``gid``'s placement on replica ``index`` (TTL expiry
        or eviction observed) — the next route re-places on a miss."""
        pl = self.placements.get(gid)
        if pl is not None:
            pl.pop(index, None)
            if not pl:
                self.placements.pop(gid, None)

    def note_arrival(self, gid: str) -> float:
        """Record one arrival; returns the windowed rate (req/s)."""
        now = self._clock()
        dq = self._arrivals[gid]
        dq.append(now)
        while dq and dq[0] < now - self.rate_window_s:
            dq.popleft()
        return len(dq) / self.rate_window_s

    # -- the routing decision ----------------------------------------------
    def route(self, gid: str, *, exclude: Set[int] = frozenset()
              ) -> Tuple[Optional[EngineReplica], Optional[Future], bool]:
        """Pick the serving replica for one request on ``gid``.  Returns
        ``(replica, wait, hit)`` — ``wait`` is a factor future the
        caller must resolve before submitting (``None`` when the factor
        is already live), ``hit`` whether the target already had a
        placement (counted via ``record_routed`` only once the submit
        lands) — or ``(None, None, False)`` when no healthy replica
        remains outside ``exclude``."""
        healthy = [r for r in self.healthy() if r.index not in exclude]
        if not healthy:
            return None, None, False
        # one arrival per *request*: overload retries (non-empty
        # exclude) must not inflate the rate — and must never trigger
        # replication, which would add factor work to a cluster at the
        # exact moment it is rejecting under load
        rate = self.note_arrival(gid) if not exclude else 0.0
        pl = self._refresh_placements(gid)
        hidx = {r.index for r in healthy}
        holders = [self.replicas[i] for i, f in pl.items()
                   if f is None and i in hidx]
        pending = [self.replicas[i] for i, f in pl.items()
                   if f is not None and i in hidx]
        target = self.policy.choose(gid, holders, healthy, pending)
        placed = target.index in pl
        # a hit is a route to a *live* factor (what hit_rate advertises);
        # riding a still-pending placement reuses the in-flight factor
        # but pays the cold latency, so it counts as a miss
        hit = placed and pl[target.index] is None
        if placed:
            wait = pl[target.index]         # None (live) or pending
            if wait is not None:
                self.factor_dedups += 1     # ride the in-flight factor
        else:
            wait = self.place(gid, target)  # immortal primary placement
        # hot-factor replication: a hot graph with exactly one *live*
        # copy gets a TTL'd twin on the roomiest other healthy replica.
        # The twin is opportunistic — a failure placing it (replica died
        # since the health snapshot, probe error) must never fail the
        # request that happened to trigger it.
        pls = self.placements.get(gid, {})
        if (self.replicate_above is not None
                and rate >= self.replicate_above
                and len(pls) == 1 and next(iter(pls.values())) is None):
            others = [r for r in healthy if r.index not in pls]
            if others:
                try:
                    self.place(gid, _roomiest(others),
                               ttl_s=self.replica_ttl_s)
                    self.replications += 1
                except Exception:
                    pass
        return target, wait, hit


# ---------------------------------------------------------------------------
# SolveCluster: the user-facing multi-replica service
# ---------------------------------------------------------------------------

class SolveCluster:
    """N engine replicas behind a routing policy.

    ::

        cluster = SolveCluster(replicas=2, routing="affinity",
                               replicate_above=100.0)
        gid = cluster.register(graph, jax.random.key(0))
        fut = cluster.submit(gid, b)          # Future[SolveRequest]
        res = fut.result()                    # res.replica = serving idx
        # or:  res = await cluster.solve(gid, b)

    ``register`` records ``(graph, key)`` so the router can factor the
    graph onto whichever replica it places it on (first routed request
    pays the cold factor; ``factor()`` pre-warms explicitly).  Every
    request is stamped with its serving replica (``req.replica``), and
    replaying it there directly reproduces the served result bit-exactly.

    **Preconditioner family** (``precond``): a fixed family name from
    :data:`repro.core.solver.PRECOND_FAMILIES` serves every request
    under that family, or ``"auto"`` puts an
    :class:`~repro.serve.cluster.selector.AdaptiveSelector` in front —
    an epsilon-greedy bandit choosing per request from per-graph
    convergence telemetry (cold graphs fall back to AC).  Placements of
    a non-AC family use **family-qualified graph ids**
    (``"<gid>::<family>"``), so one graph can hold several families'
    factors across the cluster; requests are rewritten to the chosen
    qualified id before routing, and ``res.graph_id`` reports the id
    that actually served.
    """

    def __init__(self, *, replicas: int = 2, routing: str = "affinity",
                 slots: int = 8, iters_per_tick: int = 8,
                 admission: str = "fifo", max_skips: Optional[int] = None,
                 max_queue: int = 256, overload: str = "reject",
                 precond: str = "ac",
                 precond_params: Optional[Dict] = None,
                 select_epsilon: float = 0.1,
                 replicate_above: Optional[float] = None,
                 rate_window_s: float = 1.0, replica_ttl_s: float = 30.0,
                 eject_rejections: int = 4, health_window_s: float = 1.0,
                 readmit_cooldown_s: float = 2.0,
                 clock: Optional[Callable[[], float]] = None,
                 seed: int = 0, cache_kw: Optional[Dict] = None,
                 devices=None, factor_replicas: int = 0,
                 factor_max_batch: int = 16,
                 metrics=None, tracer=None, detector=None,
                 flight=None, health=None):
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        if factor_replicas < 0:
            raise ValueError("factor_replicas must be >= 0")
        if precond != "auto" and precond not in PRECOND_FAMILIES:
            raise ValueError(
                f"unknown precond {precond!r}; choose a registered family "
                f"{sorted(PRECOND_FAMILIES)} or 'auto'")
        self.precond = precond
        self.precond_params = dict(precond_params or {})
        self.selector = (AdaptiveSelector(seed=seed, epsilon=select_epsilon)
                         if precond == "auto" else None)
        # perf_counter matches the engines' default clock, so the
        # cluster-stamped submit_time and the engine-stamped admit/finish
        # times live on one timeline (what makes the lifecycle span
        # partition sum to e2e latency)
        self._clock = clock if clock is not None else time.perf_counter
        # solve replicas take the first device slots, factor replicas
        # the next ones — on a host with >= replicas + factor_replicas
        # devices the tiers never share an accelerator
        devs = resolve_devices(devices, replicas + factor_replicas)
        self.devices = devs[:replicas]
        self.replicas = [
            EngineReplica(i, slots=slots, iters_per_tick=iters_per_tick,
                          admission=make_policy(admission,
                                                max_skips=max_skips),
                          max_queue=max_queue, overload=overload,
                          clock=clock, device=devs[i], cache_kw=cache_kw,
                          metrics=metrics, tracer=tracer,
                          flight=flight, health=health)
            for i in range(replicas)]
        ckw = dict(cache_kw or {})
        self.factor_tier = FactorTier(
            factor_replicas, devices=devs[replicas:],
            chunk=ckw.get("chunk", 64),
            fill_slack=ckw.get("fill_slack", 32),
            strict=ckw.get("strict", True),
            max_retries=ckw.get("max_retries", 3),
            dtype=ckw.get("dtype", np.float32),
            max_batch=factor_max_batch,
            on_retarget=self._retarget,
            metrics=metrics,
            flight=flight) if factor_replicas > 0 else None
        self.router = Router(
            make_routing(routing, seed=seed), self.replicas,
            clock=self._clock, factor_cb=self._factor_on,
            replicate_above=replicate_above, rate_window_s=rate_window_s,
            replica_ttl_s=replica_ttl_s, eject_rejections=eject_rejections,
            health_window_s=health_window_s,
            readmit_cooldown_s=readmit_cooldown_s,
            flight=flight)
        self.registry: Dict[str, Tuple] = {}
        self._lock = threading.Lock()
        self._seq = 0
        self.submitted = 0

        # -- observability (repro.obs): cluster-level instruments + the
        # pull-style mirror of router/cache counters.  The mirror runs
        # as a registry collect callback (sample/scrape time), so the
        # routing hot path is untouched by it.
        reg = metrics if metrics is not None else _NULL_METRICS
        self.metrics = metrics
        self.tracer = tracer
        self._m_arrivals = reg.counter(
            "repro_cluster_arrivals_total",
            "requests entering the cluster submit path")
        self._m_routed = reg.counter(
            "repro_cluster_routed_total",
            "requests successfully routed, by affinity outcome",
            labels=("hit",))
        self._m_shed = reg.counter(
            "repro_cluster_shed_total",
            "requests no healthy replica could take")
        self._m_queue = reg.gauge(
            "repro_cluster_queue_depth",
            "requests waiting before lane admission, summed over "
            "healthy replicas")
        self._m_latency = reg.histogram(
            "repro_cluster_latency_seconds",
            "client-observed end-to-end latency (cluster submit to "
            "finish)")
        self._m_factor_wait = reg.histogram(
            "repro_cluster_factor_wait_seconds",
            "cold-path construction/adopt wait per routed request")
        self._obs_lock = threading.Lock()
        self.detector = detector
        self._prev_det_state: Optional[str] = None
        # -- forensic half (repro.obs.flight / repro.obs.health): the
        # recorder gets the cluster's stats snapshot as post-mortem
        # context, the health monitor watches every replica's engine
        # retirements and feeds drift quarantines into the selector.
        self.flight = flight
        self.health = health
        fl = flight if flight is not None else NULL_FLIGHT
        self._ev_detector = fl.bind("detector_transition")
        if flight is not None:
            flight.attach(stats_fn=lambda: self.stats().as_dict(),
                          registry=metrics)
        if health is not None:
            for rep in self.replicas:
                health.watch_engine(rep.engine)
                health.watch_cache(rep.cache)
            if self.selector is not None:
                health.on_quarantine = self._quarantine
        if metrics is not None:
            self._g_healthy = reg.gauge(
                "repro_cluster_healthy_replicas", "routable replicas")
            self._g_placements = reg.gauge(
                "repro_cluster_live_placements", "live factor placements")
            self._g_factor_queue = reg.gauge(
                "repro_cluster_factor_tier_queue_depth",
                "constructions queued on the factor tier")
            self._g_overload = reg.gauge(
                "repro_cluster_overload_state",
                "overload detector state (0 = ok, 1 = overloaded)")
            self._g_cache_bytes = reg.gauge(
                "repro_cache_device_bytes",
                "device bytes held by a replica's factor cache",
                labels=("replica",))
            metrics.on_collect(self._collect)

    # -- graph registry -----------------------------------------------------
    def register(self, g, key, *, graph_id: Optional[str] = None) -> str:
        """Record ``(graph, key)`` under its fingerprint (or explicit
        id) so the router can place its factor on demand.  ``"::"`` is
        reserved in explicit ids (it separates the family qualifier in
        placement ids)."""
        gid = graph_id if graph_id is not None else graph_fingerprint(g, key)
        if "::" in gid:
            raise ValueError(f"graph_id {gid!r} contains the reserved "
                             f"family separator '::'")
        with self._lock:
            self.registry[gid] = (g, key)
        return gid

    @staticmethod
    def _qualify(gid: str, family: str) -> str:
        """Placement id for ``gid`` served under ``family`` — AC keeps
        the bare id (backward compatible with every recorded trace)."""
        return gid if family == "ac" else f"{gid}::{family}"

    @staticmethod
    def _split(placement_id: str) -> Tuple[str, str]:
        base, sep, fam = placement_id.partition("::")
        return base, (fam if sep else "ac")

    def _serving_family(self, gid: str,
                        deadline_s: Optional[float]) -> str:
        """Family this request serves under: the fixed configured
        family, or the selector's per-graph pick for ``auto``."""
        if self.selector is not None:
            return self.selector.pick(gid, deadline_s=deadline_s)
        return self.precond

    def _quarantine(self, gid: str, family: str) -> None:
        """Health-monitor drift callback: quarantine ``family`` for the
        drifting graph in the adaptive selector.  The engine reports the
        *placement* id (possibly family-qualified) — the selector keys
        on the base graph id."""
        base, _, _ = gid.partition("::")
        self.selector.quarantine(base, family)

    def _factor_on(self, gid: str, rep: EngineReplica,
                   ttl_s: Optional[float]) -> Future:
        base, fam = self._split(gid)
        try:
            g, key = self.registry[base]
        except KeyError:
            raise KeyError(
                f"graph_id {base!r} is not registered with the cluster "
                f"(call register(graph, key) first)") from None
        params = self.precond_params if fam == self.precond else None
        if self.factor_tier is not None:
            # disaggregated path: construction queues on the factor
            # tier; the serving driver only pays the adopt
            return self.factor_tier.submit(
                gid, g, key, family=fam, precond_params=params,
                ttl_s=ttl_s, target=rep)
        return rep.factor(g, key, graph_id=gid, family=fam,
                          precond_params=params, ttl_s=ttl_s)

    def _retarget(self, gid: str, dead_index: int,
                  fut: Future) -> Optional[EngineReplica]:
        """Factor-tier failover: the placement target died before its
        adoption landed.  Move the pending placement to the roomiest
        healthy replica (under the cluster lock — the tier worker calls
        in from its own thread) and return it, or ``None`` when the
        cluster has nowhere left to put the factor."""
        with self._lock:
            healthy = [r for r in self.router.healthy()
                       if r.index != dead_index]
            if not healthy:
                return None
            new = _roomiest(healthy)
            pl = self.router.placements.get(gid)
            if pl is not None and pl.get(dead_index) is fut:
                del pl[dead_index]
            self.router.placements.setdefault(gid, {})[new.index] = fut
            return new

    def factor(self, g, key, *, graph_id: Optional[str] = None,
               replica: Optional[int] = None) -> Tuple[str, int]:
        """Pre-warm: register and factor now (blocking) on ``replica``
        or on the roomiest healthy replica, under the cluster's
        configured family (``auto`` pre-warms the AC fallback — the
        family cold graphs serve under).  Returns ``(graph_id,
        replica_index)``."""
        gid = self.register(g, key, graph_id=graph_id)
        fam = "ac" if self.precond == "auto" else self.precond
        with self._lock:
            healthy = self.router.healthy()
            if not healthy:
                raise ClusterOverloadedError("no healthy replica to "
                                             "factor onto")
            rep = self.replicas[replica] if replica is not None \
                else _roomiest(healthy)
            fut = self.router.place(self._qualify(gid, fam), rep)
        fut.result()
        return gid, rep.index

    def _collect(self, reg) -> None:
        """Registry collect callback: mirror router/cache snapshot state
        into gauges at sample/scrape time (pull-style — the routing hot
        path never pays for these), then advance the overload detector
        on the freshly-aggregated queue depth."""
        alive = [rep for rep in self.replicas if rep.alive]
        self._g_healthy.set(len(alive))
        self._m_queue.set(sum(rep.frontend.queue_depth for rep in alive))
        self._g_placements.set(
            sum(1 for pl in list(self.router.placements.values())
                for v in list(pl.values()) if v is None))
        self._g_factor_queue.set(
            self.factor_tier.queue_depth if self.factor_tier is not None
            else 0)
        for rep in self.replicas:
            self._g_cache_bytes.labels(replica=str(rep.index)).set(
                rep.cache.device_bytes if rep.alive else 0)
        if self.detector is not None:
            with self._obs_lock:   # samples race in from replica drivers
                state = self.detector.update(self._clock())
            self._g_overload.set(1 if state == "overloaded" else 0)
            prev = self._prev_det_state
            if state != prev:
                self._prev_det_state = state
                self._ev_detector(state=state, prev=prev or "")
                # a flip *into* overloaded is the sustained-pressure
                # incident the post-mortem dump exists for; the flip
                # back to ok is just an event
                if prev is not None and state == "overloaded":
                    fl = self.flight
                    if fl is not None:
                        fl.incident("sustained_overload",
                                    detector=self.detector.name,
                                    state=state)

    def _obs_done(self, fut: Future) -> None:
        """Done-callback (attached only when metrics are on) observing
        the client-visible latency of one routed request."""
        try:
            res = fut.result()
        except Exception:
            return
        self._m_latency.observe(max(res.finish_time - res.submit_time, 0.0))

    def _observer(self, base_gid: str, fam: str) -> Callable:
        """Done-callback feeding one served request back into the
        selector: service seconds as the client saw them, block-max
        iterations, convergence and deadline outcome.  A failed future
        (replica died mid-flight) records a non-converged observation
        so the bandit deprioritizes whatever was being tried."""
        def _cb(fut: Future) -> None:
            sel = self.selector
            try:
                res = fut.result()
            except Exception:
                sel.observe(base_gid, fam, wall_s=float("inf"), ok=False,
                            deadline_ok=False)
                return
            wall = max(res.finish_time - res.submit_time, 0.0)
            iters = int(np.max(res.iters)) if res.iters is not None else None
            missed = res.status == "deadline_missed" or (
                res.deadline_s is not None and wall > res.deadline_s)
            # feed the bandit *deconflated* timings off the request's
            # lifecycle stamps: pure service time (admit -> finish) as
            # the serve signal, the cold-path construction wait as its
            # own component — not the wall-clock that mixed both with
            # queueing (the ROADMAP's conflated-EWMA defect)
            serve = max(res.finish_time - res.admit_time, 0.0) \
                if res.admit_time > 0.0 else wall
            construct = res.factor_wait_s if res.factor_mode else None
            sel.observe(base_gid, fam, wall_s=wall, serve_s=serve,
                        construct_s=construct, iters=iters,
                        ok=res.status == "converged",
                        deadline_ok=not missed)
        return _cb

    # -- request path -------------------------------------------------------
    def submit_request(self, req: SolveRequest) -> "Future[SolveRequest]":
        """Route and submit a pre-built request.  Overloaded replicas
        are retried on the next-best healthy replica (each rejection
        feeds the health/ejection record); when none remains — or the
        request cannot be served at all (unregistered graph, factor
        failure) — it is **shed**, so ``submitted == routed + shed``
        holds on every exit path (CI-gated)."""
        with self._lock:
            self.submitted += 1
        self._m_arrivals.inc()
        # stamp ingress on the cluster clock (shared with the engines):
        # route and factor waits below then land inside the request's
        # [submit, finish] window, so traces attribute them and cold
        # latency includes the construction the client actually waited on
        if req.submit_time == 0.0:
            req.submit_time = self._clock()
        # resolve the serving family once per request (overload retries
        # keep it — the retry is about *where*, not *what*) and rewrite
        # the graph id to the family-qualified placement id
        base_gid, req_fam = self._split(req.graph_id)
        if req_fam == "ac":               # not already qualified
            req_fam = self._serving_family(base_gid, req.deadline_s)
            req.graph_id = self._qualify(base_gid, req_fam)
        tried: Set[int] = set()
        route_errors = 0
        try:
            while True:
                with self._lock:
                    try:
                        rep, wait, hit = self.router.route(req.graph_id,
                                                           exclude=tried)
                    except RuntimeError:
                        # a replica closed between the health snapshot
                        # and the factor-call enqueue; its alive flag is
                        # already False so the next pass routes around
                        # it — bounded by the replica count so a
                        # persistent error still surfaces
                        route_errors += 1
                        if route_errors > len(self.replicas):
                            raise
                        continue
                if rep is None:
                    raise ClusterOverloadedError(
                        f"no healthy replica for graph_id="
                        f"{req.graph_id!r} ({len(tried)} overloaded "
                        f"this submit)")
                # time-to-final-routing-decision (overwritten on retry:
                # the span covers everything before this attempt's
                # factor wait, keeping the trace partition contiguous)
                req.route_s = max(self._clock() - req.submit_time, 0.0)
                if wait is not None:
                    t_w0 = self._clock()
                    try:
                        wait.result()  # cold path: factor landing first
                    except Exception:
                        with self._lock:
                            self.router.drop_placement(req.graph_id,
                                                       rep.index)
                        if not rep.alive:
                            # replica died mid-factor: fail over, same
                            # as the submit-path race below
                            tried.add(rep.index)
                            continue
                        raise          # genuine factor failure: surface
                    req.factor_wait_s = max(self._clock() - t_w0, 0.0)
                    req.factor_mode = ("adopt" if self.factor_tier
                                       is not None else "factor")
                    self._m_factor_wait.observe(req.factor_wait_s)
                try:
                    fut = rep.submit(req)
                except EngineOverloadedError:
                    with self._lock:
                        self.router.record_overload(rep)
                    tried.add(rep.index)
                    continue
                except RuntimeError:
                    # replica closed/crashed between the health snapshot
                    # and this submit: skip it for this request — the
                    # next route's health pass ejects it — and fail over
                    # to the remaining replicas instead of surfacing a
                    # raw frontend error to the caller
                    tried.add(rep.index)
                    continue
                req.replica = rep.index
                with self._lock:
                    self.router.record_routed(rep, hit=hit)
                self._m_routed.labels(hit="1" if hit else "0").inc()
                if self.metrics is not None:
                    fut.add_done_callback(self._obs_done)
                if self.selector is not None:
                    fut.add_done_callback(
                        self._observer(base_gid, req_fam))
                return fut
        except Exception:
            with self._lock:
                self.router.shed += 1
            self._m_shed.inc()
            raise

    def submit(self, graph_id: str, b, *, rid: Optional[int] = None,
               **kw) -> "Future[SolveRequest]":
        """Build, route and queue a solve request (same builder and
        kwargs as ``SolveFrontend.submit`` —
        :func:`repro.serve.engine.make_request`)."""
        with self._lock:
            self._seq += 1
            auto_rid = self._seq
        return self.submit_request(make_request(
            graph_id, b, rid=rid if rid is not None else auto_rid, **kw))

    async def solve(self, graph_id: str, b, **kw) -> SolveRequest:
        """Asyncio face (note: a cold-placement factor blocks the
        submitting coroutine — pre-warm with ``factor()`` where that
        matters)."""
        import asyncio
        return await asyncio.wrap_future(self.submit(graph_id, b, **kw))

    # -- telemetry ----------------------------------------------------------
    def stats(self) -> ClusterStats:
        """Point-in-time :class:`ClusterStats` snapshot: routing and
        health counters, per-replica breakdown (nesting each replica's
        ``FrontendStats``), the serving family, and the adaptive
        selector's estimate table under ``--precond auto`` (glossary in
        ``docs/serving.md``).  Pure read — never advances the ejection
        state machine."""
        with self._lock:
            r = self.router
            # telemetry must not advance the ejection state machine
            healthy_idx = {rep.index for rep in r.healthy(advance=False)}
            # placement counts filter on liveness here (pure read): the
            # routing path only prunes a dead replica's placements when
            # that gid is next routed, and idle graphs never are — a
            # dead replica must still report zero placements
            alive_idx = {rep.index for rep in self.replicas if rep.alive}
            def live_on(i):
                return sum(1 for pl in r.placements.values()
                           if i in pl and pl[i] is None) \
                    if i in alive_idx else 0
            per = [ReplicaStats(
                index=rep.index, healthy=rep.index in healthy_idx,
                ejected=rep.index in r._ejected_until,
                load=rep.load, placements=live_on(rep.index),
                routed=r.routed_per[rep.index],
                rejections=r.rejections_per[rep.index],
                frontend=rep.frontend.stats(),
                cache=rep.cache.stats(),
                device=(str(rep.device) if rep.device is not None
                        else None)) for rep in self.replicas]
            hot = sum(1 for pl in r.placements.values()
                      if sum(1 for i, v in pl.items()
                             if v is None and i in alive_idx) >= 2)
            return ClusterStats(
                policy=r.policy.name, replicas=len(self.replicas),
                healthy=len(healthy_idx), submitted=self.submitted,
                routed=r.routed, affinity_hits=r.affinity_hits,
                affinity_misses=r.affinity_misses,
                replications=r.replications, demotions=r.demotions,
                ejections=r.ejections, readmissions=r.readmissions,
                shed=r.shed, hot_graphs=hot, per_replica=per,
                precond=self.precond,
                selector=(self.selector.stats()
                          if self.selector is not None else None),
                factor_dedups=r.factor_dedups,
                adoptions=sum(rep.cache.adoptions
                              for rep in self.replicas),
                factor_tier=(self.factor_tier.stats()
                             if self.factor_tier is not None else None),
                overload=(self.detector.stats()
                          if self.detector is not None else None),
                health=(self.health.snapshot()
                        if self.health is not None else None))

    # -- lifecycle ----------------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every replica's submitted work has resolved (a
        dead replica's futures are already failed — skipped)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        ok = True
        for rep in self.replicas:
            if not rep.alive:
                continue
            t = None if deadline is None else \
                max(deadline - time.monotonic(), 0.0)
            ok = rep.drain(timeout=t) and ok
        return ok

    def close(self, *, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Close every replica (with ``drain``, in-flight work finishes
        first); the cluster is unusable afterwards.  The factor tier
        closes first so no construction lands on a closing driver."""
        if self.metrics is not None:
            # a scrape after close must not walk torn-down replicas
            self.metrics.remove_collect(self._collect)
        if self.factor_tier is not None:
            self.factor_tier.close()
        for rep in self.replicas:
            rep.close(drain=drain, timeout=timeout)
        if self.flight is not None:
            # post-mortem writers run on daemon threads; give in-flight
            # dumps a bounded window to land before the process moves on
            self.flight.flush(timeout=5.0)

    def __enter__(self) -> "SolveCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))
