"""Adaptive preconditioner-family selection for ``--precond auto``.

Every registered family (``repro.core.solver.PRECOND_FAMILIES``) can
serve any graph, but which one serves it *cheapest* depends on the
graph: a stiff mesh wants the AMG apply (one fused SpMV per iteration,
more iterations), a well-conditioned graph converges in a handful of
trisolve sweeps under AC, an SPD-borderline graph may only be safe
under AC's randomized construction.  The cluster cannot know this up
front, so it learns it per graph from its own serving telemetry —
the same contextual-bandit shape LLM gateways use to pick a serving
configuration per tenant.

``AdaptiveSelector`` is an **epsilon-greedy bandit** keyed by
``(graph_id, family)``:

* ``pick(gid, deadline_s=...)`` returns the family the next request on
  ``gid`` should serve under.  A *cold* graph (no observations at all)
  always gets the fallback family (AC — the paper's construction, and
  the only family with a construction-time guarantee), so exploration
  never makes the first request on a graph slower than the status quo.
* with probability ``epsilon`` the pick **explores**: families the
  graph has never tried are preferred (uniformly), then any family —
  this is what discovers that a cheaper family converges.
* otherwise it **exploits**: among observed families predicted to meet
  the request's deadline (EWMA service seconds ≤ ``deadline_margin`` ×
  ``deadline_s``), pick the cheapest by predicted wall clock; if none
  is predicted to meet it, pick the least-bad.  Families whose last
  observation *failed* (solver did not converge) are quarantined from
  exploitation — only an explicit explore retries them.
* ``observe(gid, family, wall_s=..., ...)`` folds a completed request
  back in (EWMA with factor ``alpha``); the router calls it from the
  result future's callback, so selection learns from exactly what was
  served, including deadline misses.

The RNG is seeded — a replayed trace picks identically, which is what
lets ``benchmarks.check_precond_regression`` gate ``auto`` against
always-AC on a recorded trace.  All methods are thread-safe (router
threads pick while driver-thread callbacks observe).
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class AdaptiveSelector:
    """Epsilon-greedy per-graph preconditioner-family chooser.

    Args:
        families: candidate family names, in preference order for
            tie-breaks (earlier wins).  Defaults to the four registered
            serving families.
        epsilon: exploration probability per pick (``0.0`` disables
            exploration — the selector then never leaves the fallback).
        alpha: EWMA factor for the per-``(gid, family)`` service-time
            and iteration estimates (higher = adapt faster).
        fallback: family served on cold graphs and preferred on ties.
        deadline_margin: safety factor applied to ``deadline_s`` when
            judging whether a family's predicted service time meets the
            deadline (``0.8`` → must be predicted 20% under budget).
        seed: RNG seed — picks are deterministic per (seed, call
            sequence), so replays reproduce.
    """

    def __init__(self, families: Sequence[str] = ("ac", "ichol", "amg",
                                                  "spai"),
                 *, epsilon: float = 0.1, alpha: float = 0.3,
                 fallback: str = "ac", deadline_margin: float = 0.8,
                 seed: int = 0):
        if fallback not in families:
            raise ValueError(f"fallback {fallback!r} not among candidate "
                             f"families {tuple(families)}")
        if not 0.0 <= epsilon <= 1.0:
            raise ValueError(f"epsilon must be in [0, 1], got {epsilon}")
        self.families = tuple(families)
        self.epsilon = float(epsilon)
        self.alpha = float(alpha)
        self.fallback = fallback
        self.deadline_margin = float(deadline_margin)
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        # (gid, family) -> mutable record
        self._est: Dict[Tuple[str, str], Dict] = {}
        # counters (exposed via stats())
        self.picks = 0
        self.cold_picks = 0
        self.explores = 0
        self.exploits = 0
        self.observed = 0
        self.deadline_misses = 0
        self.quarantined = 0      # external (drift-detector) quarantines
        self.picks_by_family: Dict[str, int] = {f: 0 for f in self.families}

    # -- internals ----------------------------------------------------------
    def _known(self, gid: str) -> List[str]:
        return [f for f in self.families if (gid, f) in self._est]

    def _predict(self, gid: str, family: str) -> float:
        # pure service time (admit -> finish), not the old conflated
        # wall clock: queueing and cold construction must not make a
        # fast family look slow (or a slow family look fast once warm)
        return self._est[(gid, family)]["serve_s"]

    def _count(self, family: str) -> None:
        self.picks += 1
        self.picks_by_family[family] += 1

    # -- the decision -------------------------------------------------------
    def pick(self, gid: str, *, deadline_s: Optional[float] = None) -> str:
        """Family the next request on ``gid`` should serve under.

        Args:
            gid: the request's (base, unqualified) graph id.
            deadline_s: the request's SLO budget in seconds, if any —
                exploitation filters candidates on predicted service
                time against it.

        Returns:
            A family name from ``families``.
        """
        with self._lock:
            known = self._known(gid)
            if not known:
                self.cold_picks += 1
                self._count(self.fallback)
                return self.fallback
            if self._rng.random() < self.epsilon:
                self.explores += 1
                untried = [f for f in self.families if f not in known]
                pool = untried if untried else list(self.families)
                fam = pool[int(self._rng.integers(len(pool)))]
                self._count(fam)
                return fam
            self.exploits += 1
            # quarantine families whose last serve failed outright
            ok = [f for f in known if self._est[(gid, f)]["ok"]]
            pool = ok if ok else known
            if deadline_s is not None:
                budget = self.deadline_margin * deadline_s
                meeting = [f for f in pool
                           if self._predict(gid, f) <= budget]
                if meeting:
                    pool = meeting
            fam = min(pool, key=lambda f: (self._predict(gid, f),
                                           self.families.index(f)))
            self._count(fam)
            return fam

    # -- the feedback path --------------------------------------------------
    def observe(self, gid: str, family: str, *, wall_s: float,
                serve_s: Optional[float] = None,
                construct_s: Optional[float] = None,
                iters: Optional[int] = None, ok: bool = True,
                deadline_ok: bool = True) -> None:
        """Fold one completed (or failed) request back into the model.

        Args:
            gid: base graph id the request served.
            family: family it served under.
            wall_s: submit→finish seconds as the client saw it (kept
                for telemetry back-compat; no longer the prediction
                signal).
            serve_s: pure service seconds (lane admission → finish),
                read off the request's lifecycle stamps — the signal
                predictions rank on.  Falls back to ``wall_s`` when the
                caller has no stamps (pre-tracing traces).
            construct_s: construction/adopt seconds this request paid
                on the cold path (``None`` = warm hit, leaves the
                estimate untouched) — the amortizable cost a predicted
                request stream divides down.
            iters: PCG iterations the solve took (block max), if known.
            ok: whether the solve converged — ``False`` quarantines the
                family for this graph until an explore retries it.
            deadline_ok: whether the request met its deadline (always
                ``True`` for deadline-less requests).
        """
        serve = float(serve_s) if serve_s is not None else float(wall_s)
        with self._lock:
            self.observed += 1
            if not deadline_ok:
                self.deadline_misses += 1
            rec = self._est.get((gid, family))
            if rec is None:
                self._est[(gid, family)] = {
                    "wall_s": float(wall_s),
                    "serve_s": serve,
                    "construct_s": (float(construct_s)
                                    if construct_s is not None else 0.0),
                    "iters": float(iters) if iters is not None else 0.0,
                    "n": 1, "ok": bool(ok)}
                return
            a = self.alpha
            rec["wall_s"] += a * (float(wall_s) - rec["wall_s"])
            rec["serve_s"] += a * (serve - rec["serve_s"])
            if construct_s is not None:
                # constructions are rare (factor-once/serve-many): a
                # plain EWMA against mostly-absent samples would decay
                # toward stale values, so only cold-path requests move it
                rec["construct_s"] += a * (float(construct_s)
                                           - rec["construct_s"])
            if iters is not None:
                rec["iters"] += a * (float(iters) - rec["iters"])
            rec["n"] += 1
            rec["ok"] = bool(ok)

    def quarantine(self, gid: str, family: str) -> None:
        """Externally quarantine ``family`` for ``gid`` — the health
        monitor's drift detector calls this when the family's iteration
        counts degrade against their own baseline.  Same mechanism as a
        failed serve: exploitation skips the pair until an explicit
        explore retries it (so a drifting family can rehabilitate if
        the drift was transient)."""
        with self._lock:
            rec = self._est.get((gid, family))
            if rec is None:
                # never served exploitatively yet: record the flag so a
                # first exploitation pass already avoids it
                self._est[(gid, family)] = {
                    "wall_s": 0.0, "serve_s": 0.0, "construct_s": 0.0,
                    "iters": 0.0, "n": 0, "ok": False}
            else:
                rec["ok"] = False
            self.quarantined += 1

    # -- telemetry ----------------------------------------------------------
    def stats(self) -> Dict:
        """Counters plus the per-graph estimate table (JSON-friendly)."""
        with self._lock:
            return {
                "families": list(self.families),
                "epsilon": self.epsilon,
                "picks": self.picks,
                "cold_picks": self.cold_picks,
                "explores": self.explores,
                "exploits": self.exploits,
                "observed": self.observed,
                "deadline_misses": self.deadline_misses,
                "quarantined": self.quarantined,
                "picks_by_family": dict(self.picks_by_family),
                "graphs": len({g for g, _ in self._est}),
                "estimates": {f"{g}::{f}": dict(rec)
                              for (g, f), rec in self._est.items()},
            }
