"""Cluster-wide telemetry: per-replica serving stats plus the router's
decision counters.

``ClusterStats`` is the one artifact a fleet operator (or the CI gate in
``benchmarks.check_cluster_regression``) needs: every replica's
:class:`~repro.serve.frontend.FrontendStats` (which nests its engine's
:class:`~repro.serve.engine.EngineStats`), and the routing counters that
summarize what the cluster-level scheduler did — affinity hits/misses,
hot-factor replications and TTL demotions, health ejections and
re-admissions, and requests shed because the cluster could not serve
them.  Request conservation across the cluster is
``routed == Σ replica completed+failed+pending`` and
``routed + shed == submitted`` — both CI-gated.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.serve.frontend import FrontendStats


@dataclasses.dataclass
class ReplicaStats:
    """One replica's view: router-side counters (``routed``,
    ``rejections`` — overload errors the *router* observed submitting
    here) next to the replica's own frontend/engine counters and its
    private :meth:`~repro.core.solver.FactorCache.stats` snapshot
    (``cache`` — hit/miss/eviction/compaction counters and the
    fleet-stack memory accounting, so a fleet operator sees
    ``fleet_device_bytes`` track live factors across compactions)."""

    index: int
    healthy: bool
    ejected: bool
    load: int            # ingress + engine queue + active lanes
    placements: int      # graphs the router holds live on this replica
    routed: int          # requests the router sent here
    rejections: int      # EngineOverloadedError seen routing here
    frontend: FrontendStats
    cache: Optional[Dict] = None
    device: Optional[str] = None  # pinned accelerator (None = default)

    def as_dict(self) -> Dict:
        # shallow: asdict() would deep-convert the nested frontend and
        # engine stats only for as_dict() below to rebuild them
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self) if f.name != "frontend"}
        d["frontend"] = self.frontend.as_dict()
        return d


@dataclasses.dataclass
class ClusterStats:
    """Routing counters + per-replica stats (``SolveCluster.stats()``).

    ``affinity_hits`` counts requests routed to a replica already
    holding (a live placement of) their factor; ``affinity_misses``
    counts routes that had to place the factor first — the
    factor-once/serve-many economics of the cluster live in this ratio
    (``hit_rate``).  ``replications`` / ``demotions`` count hot-factor
    copies promoted to a second replica and TTL-expired copies dropped;
    ``ejections`` / ``readmissions`` the health loop's decisions;
    ``shed`` the requests the cluster could not serve at all — no
    healthy replica, unregistered graph, or factor failure — so
    ``submitted == routed + shed`` holds on every exit path.

    ``precond`` is the cluster's configured preconditioner family
    (``"auto"`` = adaptive selection); ``selector`` carries the
    :class:`~repro.serve.cluster.selector.AdaptiveSelector` counters
    and per-graph estimates when adaptive, else ``None``.

    **Factor-tier telemetry** (disaggregated clusters): ``factor_dedups``
    counts routes/placements that rode an in-flight factor instead of
    enqueueing a second construction; ``adoptions`` the payloads solve
    replicas admitted without factoring (sum of their caches'
    ``adoptions``); ``factor_tier`` the tier's own counters —
    ``factor_queue_depth``, ``coalesced_factorizations``, ``failovers``,
    per-tier-replica ``factor_s`` — or ``None`` when the cluster
    factors colocated.

    ``overload`` carries the attached
    :class:`~repro.obs.overload.OverloadDetector` snapshot — state
    (``ok``/``overloaded``), windowed queue/arrival readings and the
    ``scale_up``/``scale_down``/``hold`` recommendation — or ``None``
    when the cluster runs without one.

    ``health`` carries the attached
    :class:`~repro.obs.health.HealthMonitor` snapshot — tracked
    ``(graph, family)`` pairs, drift quarantines, per-family worst
    maxiter/deadline-miss streaks — or ``None`` without one."""

    policy: str
    replicas: int
    healthy: int
    submitted: int
    routed: int
    affinity_hits: int
    affinity_misses: int
    replications: int
    demotions: int
    ejections: int
    readmissions: int
    shed: int
    hot_graphs: int      # graphs currently holding >= 2 live placements
    per_replica: List[ReplicaStats]
    precond: str = "ac"
    selector: Optional[Dict] = None
    factor_dedups: int = 0
    adoptions: int = 0
    factor_tier: Optional[Dict] = None
    overload: Optional[Dict] = None
    health: Optional[Dict] = None

    @property
    def hit_rate(self) -> float:
        """Fraction of routed requests that landed on a replica already
        holding the factor (0.0 before any routing)."""
        n = self.affinity_hits + self.affinity_misses
        return self.affinity_hits / n if n else 0.0

    def as_dict(self) -> Dict:
        d = {f.name: getattr(self, f.name)
             for f in dataclasses.fields(self) if f.name != "per_replica"}
        d["per_replica"] = [r.as_dict() for r in self.per_replica]
        d["hit_rate"] = self.hit_rate
        return d
