"""Device-resident continuous-batching engine for Laplacian solve
requests.

The serving workload of this repo *is* the paper's value proposition:
factor once (cheap randomized construction), then amortize the factor
over a stream of right-hand sides.  ``SolveEngine`` is the vLLM-style
continuous-batching loop restated for PCG instead of token decoding,
with the data-ownership model inverted relative to the PR-2 engine:
**lanes live on the device, not the host.**

* a fixed number of **lanes** (slots) share jitted programs with static
  shapes; every lane's PCG carry lives in a persistent ``(slots, n_pad)``
  :class:`pcg.FleetPCGState` owned by the lane's **shape bucket** for
  the lifetime of the engine — the carry never round-trips through the
  host;
* queued :class:`SolveRequest`\\ s ``(graph_id, rhs, tol)`` are admitted
  FIFO: admission is one jitted **scatter** of the request's initialized
  columns into free rows (host→device traffic = the new rhs columns,
  nothing else);
* each tick advances every bucket with active lanes through
  ``iters_per_tick`` iterations of ``pcg_fleet_step`` — one jitted call
  per bucket, with the bucket's stacked factor arrays
  (``FactorCache`` → :class:`FactorFleet` → ``pcg.FleetArrays``) passed
  as **traced arguments** and a per-lane factor index routing each lane
  to its own factor.  Grouping is by ``(family, shape bucket, K-tier)``,
  not factor identity: every preconditioner of one family whose graphs
  share a pow2 size bucket and panel-width tier shares one compiled
  step program (the family's apply ``kind`` and level bounds are the
  jit statics — sub-bucketing by K-tier keeps one hub-heavy factor from
  inflating every bucket-mate's trisolve panels);
* lanes whose column converged (or hit maxiter) retire at the end of a
  tick via one jitted **gather** of just the finished columns
  (device→host traffic = retired columns); freed lanes readmit from the
  queue on the next tick.

Admission *decisions* are delegated to a pluggable
:class:`admission.AdmissionPolicy` (default :class:`FIFOAdmission`,
which reproduces the historical inline FIFO with head-of-line blocking
exactly).  Backfilling policies let narrow requests skip a blocked wide
head into free lanes, bounded by ``max_skips`` per skipped request;
deadline-aware policies additionally have the engine retire lanes that
can no longer meet their deadline (``status == "deadline_missed"``)
via a jitted deactivate, freeing fleet slots early.  The asyncio-facing
frontend over this engine lives in :mod:`repro.serve.frontend`.

Because frozen-lane PCG rows are independent and the engine runs the
same fleet PCG body as ``FactorHandle.solve`` over the same stacked
arrays, a served request's trajectory is **bit-identical** to a direct
solve of its own rhs block — batch composition, padding lanes, bucket
mates and tick slicing change nothing.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.solver import FactorCache, FactorFleet, FactorHandle
from repro.core.parac import _next_pow2
from repro.core.pcg import (FleetArrays, FleetPCGState, pcg_fleet_init,
                            pcg_fleet_step)
from repro.obs.flight import NULL_FLIGHT
from repro.obs.registry import NULL as _NULL_METRICS
from repro.obs.tracing import trace_from_request
from repro.serve.admission import AdmissionPolicy, FIFOAdmission

# process-wide trace-id sequence: stamped once per request at
# construction (``__post_init__``) so flight-recorder events and
# Chrome trace rows join on the same id no matter which face —
# frontend, cluster, or a replay driver building SolveRequests
# directly — created the request
_TRACE_SEQ = itertools.count()


@dataclasses.dataclass(eq=False)          # identity equality: results are
class SolveRequest:                        # arrays, field-wise == is a trap
    """One solve job: ``L_graph x = b`` to relative tolerance ``tol``.

    ``b`` may be ``(n,)`` or ``(nrhs, n)`` — a block request occupies
    ``nrhs`` lanes and completes when every column has retired.  Result
    fields are populated on completion; ``x`` matches ``b``'s shape.
    ``arrival_s`` is an optional trace-relative arrival offset used by
    open-loop replay drivers (the engine itself only timestamps).

    Scheduling fields: ``priority`` (lower = more urgent; only ordering
    policies read it), ``deadline_s`` (SLO budget in seconds from
    submission; deadline-aware policies order by it and the engine
    evicts lanes that can no longer meet it).  ``status`` on completion
    is ``"converged"``, ``"maxiter"`` or ``"deadline_missed"``."""

    rid: int
    graph_id: str
    b: np.ndarray
    tol: float = 1e-6
    maxiter: int = 500
    arrival_s: float = 0.0
    priority: int = 0
    deadline_s: Optional[float] = None
    replica: int = -1         # filled by the cluster router (serving replica)
    trace_id: str = ""        # auto-stamped; joins flight events ↔ traces
    # -- filled by the engine -----------------------------------------------
    x: Optional[np.ndarray] = None
    iters: Optional[np.ndarray] = None
    relres: Optional[np.ndarray] = None
    converged: Optional[bool] = None
    status: str = ""
    sched_skips: int = 0      # admission rounds this request was skipped
    _seq: int = -1            # engine submission sequence (policy tiebreak)
    _deadline_abs: Optional[float] = None   # engine-clock absolute deadline
    _evicted: bool = False    # deadline eviction marked (once per request)
    submit_time: float = 0.0
    admit_time: float = 0.0
    finish_time: float = 0.0
    submit_tick: int = -1
    admit_tick: int = -1
    finish_tick: int = -1
    # -- lifecycle attribution (read by repro.obs.tracing) -------------------
    route_s: float = 0.0        # router decision + retry time (cluster)
    factor_wait_s: float = 0.0  # cold-path construction/adopt wait
    factor_mode: str = ""       # "" (warm hit) | "factor" | "adopt"
    first_tick_time: float = 0.0  # stamped by the engine when traced
    _partial: Dict[int, tuple] = dataclasses.field(
        default_factory=dict, repr=False)
    # handle resolved at submit time: the factor this request will solve
    # against, fixed for its lifetime even if the cache re-attaches the
    # graph_id to a different factor afterwards
    _handle: Optional[FactorHandle] = dataclasses.field(
        default=None, repr=False)

    def __post_init__(self):
        if not self.trace_id:
            self.trace_id = f"t{next(_TRACE_SEQ):06d}"

    @property
    def nrhs(self) -> int:
        """Lanes this request needs: 1 for a ``(n,)`` rhs, else the
        block width of its ``(nrhs, n)`` batch."""
        return 1 if np.ndim(self.b) == 1 else int(np.shape(self.b)[0])

    @property
    def latency_s(self) -> float:
        """End-to-end: submit → finish (includes queueing)."""
        return self.finish_time - self.submit_time

    @property
    def queue_wait_s(self) -> float:
        """Queueing delay: submit → lane admission."""
        return self.admit_time - self.submit_time

    @property
    def service_s(self) -> float:
        """Pure service time: lane admission → finish."""
        return self.finish_time - self.admit_time


def make_request(graph_id: str, b, *, rid: int, tol: float = 1e-6,
                 maxiter: int = 500, priority: int = 0,
                 deadline_s: Optional[float] = None) -> SolveRequest:
    """Canonical request builder shared by every submit face
    (``SolveFrontend.submit``, ``SolveCluster.submit``) so new
    per-request fields are threaded through one kwarg list, not N."""
    return SolveRequest(rid=rid, graph_id=graph_id, b=np.asarray(b),
                        tol=tol, maxiter=maxiter, priority=priority,
                        deadline_s=deadline_s)


@dataclasses.dataclass
class EngineStats:
    """Service-level counters (``SolveEngine.stats()``).  The compile
    counters expose the mega-batching contract: ``step_compiles`` grows
    per *(family, shape bucket, K-tier)*, never per factor (``families``
    counts the distinct preconditioner families that have served lanes);
    ``cols_in``/``cols_out`` count
    host↔device column transfers, which are O(admitted + retired), never
    O(slots × ticks).

    The scheduler block exposes every admission decision:
    ``admitted_reqs == completed + in_flight_reqs`` always (gated in
    CI), ``backfill_skips <= max_skips * skipped_reqs`` is the
    starvation bound, ``deadline_evictions`` counts requests retired
    early as hopeless, and ``queue_peak`` is the high-water queue
    depth."""

    ticks: int
    completed: int
    queued: int
    active_lanes: int
    slots: int
    factors: int
    buckets: int
    families: int
    step_compiles: int
    admit_compiles: int
    gather_compiles: int
    cols_in: int
    cols_out: int
    # -- padding-tax accounting ---------------------------------------------
    # sweeps_skipped: trisolve level sweeps the dynamic per-lane bounds
    # elided vs the static bucket ceilings (summed over stepped buckets);
    # sweep_elements: padded (lanes × n_pad × K × live sweeps) panel
    # elements swept per apply, the K-tiering figure of merit gated by
    # check_serve_regression; fleet_resyncs: bucket fidx re-scatters
    # after a fleet compaction moved row indices
    sweeps_skipped: int
    sweep_elements: int
    fleet_resyncs: int
    # -- scheduler decisions ------------------------------------------------
    policy: str
    max_skips: int
    admitted_reqs: int
    in_flight_reqs: int
    sched_rounds: int
    backfill_skips: int
    skipped_reqs: int
    barrier_rounds: int
    sealed_backfills: int
    deadline_evictions: int
    queue_peak: int

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class _LaneRef:
    """Host-side bookkeeping for one occupied lane: which request/column
    it serves and which bucket owns its device row.  No carry data —
    that stays resident in the bucket's ``FleetPCGState``."""

    __slots__ = ("req", "col", "bucket")

    def __init__(self, req: SolveRequest, col: int, bucket: "_BucketLanes"):
        self.req = req
        self.col = col
        self.bucket = bucket


class _BucketLanes:
    """Persistent device-resident lane state for one shape bucket.

    ``state`` is a ``(slots, n_pad)`` :class:`FleetPCGState` allocated
    once when the bucket first serves a request and updated only by the
    jitted admit/step programs.  ``n_active`` mirrors the device-side
    active count so idle buckets skip their step without a device sync.
    Lane row ``i`` of every bucket corresponds to global lane ``i``; a
    global lane is owned by exactly one bucket at a time, and a row's
    ``active`` flag is True iff this bucket owns the lane and its column
    is still iterating."""

    __slots__ = ("fleet", "state", "n_active", "generation")

    def __init__(self, fleet: FactorFleet, slots: int):
        n_pad = fleet.n_pad
        Z = jnp.zeros((slots, n_pad), jnp.float32)
        z = jnp.zeros((slots,), jnp.float32)
        self.fleet = fleet
        # fleet generation this bucket's resident fidx values refer to;
        # a compaction bumps the fleet's and the engine re-scatters
        self.generation = fleet.generation
        self.state = FleetPCGState(
            X=Z, R=Z, Z=Z, P=Z, rz=z,
            it=jnp.zeros((slots,), jnp.int32),
            active=jnp.zeros((slots,), bool),
            bnorm=jnp.ones((slots,), jnp.float32),
            fidx=jnp.zeros((slots,), jnp.int32),
            tol=jnp.ones((slots,), jnp.float32),
            maxiter=jnp.zeros((slots,), jnp.int32))
        if fleet.device is not None:
            # commit the carry alongside the pinned fleet stacks so the
            # first tick never pays a cross-device transfer and the
            # jitted step program compiles for the replica's device
            self.state = jax.device_put(self.state, fleet.device)
        self.n_active = 0


# -- jitted engine programs (module-level: shapes + statics key compiles) ---

def _admit_program(fa: FleetArrays, state: FleetPCGState, rows, B, fidx,
                   tol, maxiter, *, f_levels: int, b_levels: int,
                   kind: str = "factor"):
    """Initialize the admitted columns (same math as a direct solve's
    init) and scatter every carry field into the resident state at
    ``rows``.  Padding rows carry ``rows == slots`` and drop."""
    init = pcg_fleet_init(fa, fidx, B, tol, maxiter,
                          f_levels=f_levels, b_levels=b_levels, kind=kind)
    new = FleetPCGState(
        X=state.X.at[rows].set(init.X, mode="drop"),
        R=state.R.at[rows].set(init.R, mode="drop"),
        Z=state.Z.at[rows].set(init.Z, mode="drop"),
        P=state.P.at[rows].set(init.P, mode="drop"),
        rz=state.rz.at[rows].set(init.rz, mode="drop"),
        it=state.it.at[rows].set(init.it, mode="drop"),
        active=state.active.at[rows].set(init.active, mode="drop"),
        bnorm=state.bnorm.at[rows].set(init.bnorm, mode="drop"),
        fidx=state.fidx.at[rows].set(init.fidx, mode="drop"),
        tol=state.tol.at[rows].set(init.tol, mode="drop"),
        maxiter=state.maxiter.at[rows].set(init.maxiter, mode="drop"))
    return new, init.active


def _step_program(fa: FleetArrays, state: FleetPCGState, *, k: int,
                  f_levels: int, b_levels: int, kind: str = "factor"):
    return pcg_fleet_step(fa, state, k=k, f_levels=f_levels,
                          b_levels=b_levels, kind=kind)


def _gather_program(state: FleetPCGState, rows):
    """Pull only the finished columns back: iterate, iteration count and
    relative residual per retired row."""
    X = state.X[rows]
    relres = jnp.linalg.norm(state.R[rows], axis=1) / state.bnorm[rows]
    return X, state.it[rows], relres


def _evict_program(state: FleetPCGState, rows):
    """Force-freeze lanes at ``rows`` (deadline eviction): clearing the
    active flag makes the masked step a no-op for them, so the next
    retirement gather returns their current partial iterate.  Padding
    rows carry ``rows == slots`` and drop."""
    return state._replace(active=state.active.at[rows].set(False,
                                                           mode="drop"))


def _sync_program(state: FleetPCGState, rows, fidx):
    """Rewrite the resident factor indices at ``rows`` (one scatter) —
    a fleet compaction moved rows, the occupied lanes' handles already
    carry the new indices.  Padding rows carry ``rows == slots`` and
    drop.  Only ``fidx`` changes: the PCG carry itself never references
    fleet rows, so the lanes' trajectories are untouched."""
    return state._replace(fidx=state.fidx.at[rows].set(fidx, mode="drop"))


class SolveEngine:
    """Continuous-batching solve service over a :class:`FactorCache`.

    Graphs must be admitted to the cache (``cache.factor`` /
    ``factor_batched``) before requests referencing them are submitted.
    """

    def __init__(self, cache: FactorCache, *, slots: int = 8,
                 iters_per_tick: int = 8, completed_history: int = 4096,
                 admission: Optional[AdmissionPolicy] = None,
                 clock: Optional[Callable[[], float]] = None,
                 metrics=None, tracer=None, flight=None, health=None,
                 obs_replica: int = -1, obs_device: str = ""):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        self.cache = cache
        self.slots = slots
        self.iters_per_tick = iters_per_tick
        # pluggable admission scheduler; the default reproduces the
        # historical inline FIFO (head-of-line blocking) exactly
        self.admission = admission if admission is not None \
            else FIFOAdmission()
        # injectable clock (tests drive deadline eviction without wall
        # time); every engine timestamp and deadline uses this clock
        self._clock = clock if clock is not None else time.perf_counter
        self._est_tick_s = 0.0     # min observed tick duration (s)
        self._seq = 0              # submission sequence (policy tiebreak)
        self.admitted_reqs = 0
        self.deadline_evictions = 0
        self.queue_peak = 0
        # bounded: a long-running service must not accumulate every
        # finished request's arrays forever (drain return values are the
        # delivery path; this is just recent history)
        self.completed: Deque[SolveRequest] = deque(maxlen=completed_history)
        self.lanes: List[Optional[_LaneRef]] = [None] * slots
        self.queue: Deque[SolveRequest] = deque()
        self.ticks = 0
        # graph_id → most-recent handle with queued/active work.  Each
        # request holds a strong ref to its own resolved handle
        # (``req._handle`` — that ref is what keeps an in-flight
        # factor's fleet row claimed); this map only routes *new*
        # submits for a graph that was evicted mid-flight, and is
        # dropped when the graph goes idle.
        self._pinned: Dict[str, FactorHandle] = {}
        self._buckets: Dict[Tuple[str, int, int], _BucketLanes] = {}
        self.n_completed = 0       # lifetime count (completed is bounded)
        # compile + transfer accounting: the Python bodies below run
        # once per jit specialization (trace time), so the counters
        # count compiled programs; cols_in/cols_out count host↔device
        # column transfers (admitted / retired columns only).
        self.compile_counts = {"step": 0, "admit": 0, "gather": 0,
                               "evict": 0, "sync": 0}
        self.cols_in = 0
        self.cols_out = 0
        # padding-tax telemetry (see EngineStats)
        self.sweeps_skipped = 0
        self.sweep_elements = 0
        self.fleet_resyncs = 0

        # -- observability (repro.obs) — instruments pre-bound here so
        # the tick loop only ever calls inc/set/observe on a child
        # (no-op children when metrics is None); tracer gates the
        # first-tick stamping loop entirely
        reg = metrics if metrics is not None else _NULL_METRICS
        self.metrics = metrics
        self.tracer = tracer
        self._obs_replica = obs_replica
        self._obs_device = obs_device
        rep = str(obs_replica) if obs_replica >= 0 else "solo"
        self._m_ticks = reg.counter(
            "repro_engine_ticks_total", "engine ticks executed",
            labels=("replica",)).labels(replica=rep)
        self._m_tick_s = reg.histogram(
            "repro_engine_tick_seconds", "wall seconds per engine tick",
            labels=("replica",)).labels(replica=rep)
        self._m_queue = reg.gauge(
            "repro_engine_queue_depth", "requests waiting for lanes",
            labels=("replica",)).labels(replica=rep)
        self._m_lanes = reg.gauge(
            "repro_engine_active_lanes", "lanes currently occupied",
            labels=("replica",)).labels(replica=rep)
        self._m_admitted = reg.counter(
            "repro_engine_admitted_total", "requests granted lanes",
            labels=("replica",)).labels(replica=rep)
        self._m_done = reg.counter(
            "repro_engine_completed_total",
            "requests retired, by terminal status",
            labels=("replica", "status"))
        self._m_latency = reg.histogram(
            "repro_engine_latency_seconds",
            "end-to-end request latency (submit to finish)",
            labels=("replica",)).labels(replica=rep)
        self._m_qwait = reg.histogram(
            "repro_engine_queue_wait_seconds",
            "admission queue wait (submit to lane grant)",
            labels=("replica",)).labels(replica=rep)
        self._obs_rep_label = rep
        # flight recorder + health monitor ride the same pre-bound
        # pattern: no-op callables when absent, one dict build per event
        # when present — never a device sync either way
        fl = flight if flight is not None else NULL_FLIGHT
        self.flight = flight
        self.health = health
        self._ev_admit = fl.bind("admit", replica=rep)
        self._ev_retire = fl.bind("retire", replica=rep)
        self._ev_evict = fl.bind("evict", replica=rep)

        counts = self.compile_counts
        k = iters_per_tick

        def admit(fa, state, rows, B, fidx, tol, maxiter, *,
                  f_levels, b_levels, kind):
            counts["admit"] += 1
            return _admit_program(fa, state, rows, B, fidx, tol, maxiter,
                                  f_levels=f_levels, b_levels=b_levels,
                                  kind=kind)

        def step(fa, state, *, f_levels, b_levels, kind):
            counts["step"] += 1
            return _step_program(fa, state, k=k, f_levels=f_levels,
                                 b_levels=b_levels, kind=kind)

        def gather(state, rows):
            counts["gather"] += 1
            return _gather_program(state, rows)

        def evict(state, rows):
            counts["evict"] += 1
            return _evict_program(state, rows)

        def sync(state, rows, fidx):
            counts["sync"] += 1
            return _sync_program(state, rows, fidx)

        self._admit_fn = jax.jit(
            admit, static_argnames=("f_levels", "b_levels", "kind"))
        self._step_fn = jax.jit(
            step, static_argnames=("f_levels", "b_levels", "kind"))
        self._gather_fn = jax.jit(gather)
        self._evict_fn = jax.jit(evict)
        self._sync_fn = jax.jit(sync)

    # -- request lifecycle --------------------------------------------------
    def submit(self, req: SolveRequest) -> None:
        """Queue a request (validates routing and lane fit up front; the
        handle is pinned only once the request is actually accepted).
        The *cached* handle is preferred — a graph_id re-attached to a
        new factor routes new requests to the new factor immediately —
        with the pinned handle as fallback so an evicted-mid-flight
        graph keeps accepting work until it goes idle."""
        try:
            handle = self.cache.get(req.graph_id)  # raises on unknown graph
        except KeyError:
            # fallbacks, in order: a handle pinned by earlier traffic on
            # this graph, then a handle pre-pinned on the request itself
            # (a cluster router pins the routed factor so a TTL expiry /
            # LRU eviction between routing and this driver-side submit
            # cannot fail the request)
            handle = self._pinned.get(req.graph_id)
            if handle is None:
                handle = req._handle
            if handle is None:
                raise
        b = np.asarray(req.b)
        if b.ndim not in (1, 2) or b.shape[-1] != handle.n:
            raise ValueError(
                f"rhs must be (n,) or (nrhs, n) with n={handle.n}, "
                f"got {b.shape}")
        if not 1 <= req.nrhs <= self.slots:
            raise ValueError(
                f"request rid={req.rid} needs {req.nrhs} lanes but the "
                f"engine has {self.slots} slots")
        req._handle = handle
        self._pinned[req.graph_id] = handle
        if req.submit_time == 0.0:     # a frontend may pre-stamp at ingress
            req.submit_time = self._clock()
        req.submit_tick = self.ticks
        req._seq = self._seq
        self._seq += 1
        if req.deadline_s is not None:
            req._deadline_abs = req.submit_time + req.deadline_s
        self.queue.append(req)
        self.queue_peak = max(self.queue_peak, len(self.queue))

    def _bucket(self, fleet: FactorFleet) -> _BucketLanes:
        """Lane group for one ``(family, shape-bucket, K-tier)`` fleet.
        Keying by family keeps each family on its own compiled step
        program (the apply ``kind`` and level bounds are jit statics);
        keying by K-tier follows the cache's fleet sub-bucketing, so a
        hub-heavy factor's wide panels never ride in (and so never
        inflate) a narrow tier's step.  Every factor *within* a
        family-shape-tier still shares one compiled step."""
        key = (fleet.family, fleet.n_pad, fleet.k_tier)
        bl = self._buckets.get(key)
        if bl is None:
            bl = self._buckets[key] = _BucketLanes(fleet, self.slots)
        return bl

    def _resync_buckets(self) -> None:
        """Catch up buckets whose fleet compacted since their resident
        ``fidx`` values were written: one jitted scatter per affected
        bucket rewrites occupied lanes' factor indices from their
        handles (which compaction already updated).  Unoccupied lanes
        keep stale indices — their ``active`` flags are False, so the
        masked step discards whatever row they gather."""
        for bl in self._buckets.values():
            if bl.generation == bl.fleet.generation:
                continue
            occ = [i for i, lane in enumerate(self.lanes)
                   if lane is not None and lane.bucket is bl]
            if occ:
                j = len(occ)
                jp = _next_pow2(j)
                rows_a = np.full(jp, self.slots, np.int32)   # pads drop
                rows_a[:j] = occ
                fidx = np.zeros(jp, np.int32)
                fidx[:j] = [self.lanes[i].req._handle.fleet_row
                            for i in occ]
                bl.state = self._sync_fn(bl.state, jnp.asarray(rows_a),
                                         jnp.asarray(fidx))
            bl.generation = bl.fleet.generation
            self.fleet_resyncs += 1

    def _admit(self) -> None:
        """Scheduler-driven admission: the policy orders the waiting
        queue and decides which requests start this round (FIFO default:
        strict order with head-of-line blocking; backfill policies let
        narrow requests skip a blocked wide head, bounded by
        ``max_skips``).  One jitted scatter per admitted request;
        host→device traffic is the request's rhs columns."""
        free = [i for i, lane in enumerate(self.lanes) if lane is None]
        if not self.queue or not free:
            return
        # per-occupied-lane worst-case remaining ticks (a lane retires by
        # its maxiter budget; active lanes advance exactly iters_per_tick
        # iterations per tick) — the work-conserving seal path proves
        # candidates short against these bounds
        ipt = self.iters_per_tick
        busy = []
        for lane in self.lanes:
            if lane is not None:
                done = (self.ticks - lane.req.admit_tick) * ipt
                busy.append(-(-max(lane.req.maxiter - done, 1) // ipt))
        picked = self.admission.select(list(self.queue), len(free),
                                       now=self._clock(),
                                       busy_bounds=tuple(busy),
                                       iters_per_tick=ipt)
        for req in picked:
            if req.nrhs > len(free):   # defensive: policy overcommitted
                raise RuntimeError(
                    f"admission policy {self.admission.name!r} admitted "
                    f"rid={req.rid} ({req.nrhs} lanes) with only "
                    f"{len(free)} free")
            self.queue.remove(req)     # identity match (eq=False)
            self.admitted_reqs += 1
            self._m_admitted.inc()
            handle = req._handle       # fixed at submit: re-attaching the
            fleet = handle.fleet       # graph_id cannot hijack this request
            bl = self._bucket(fleet)
            j = req.nrhs
            jp = _next_pow2(j)
            rows = [free.pop(0) for _ in range(j)]
            n_pad = fleet.n_pad
            B = np.zeros((jp, n_pad), np.float32)
            B[:j, :handle.n] = np.atleast_2d(np.asarray(req.b, np.float32))
            rows_a = np.full(jp, self.slots, np.int32)   # pads drop
            rows_a[:j] = rows
            fidx = np.zeros(jp, np.int32)
            fidx[:j] = handle.fleet_row
            tol = np.full(jp, req.tol, np.float32)
            maxv = np.zeros(jp, np.int32)
            maxv[:j] = req.maxiter
            state, act0 = self._admit_fn(
                fleet.arrays, bl.state, jnp.asarray(rows_a),
                jnp.asarray(B), jnp.asarray(fidx), jnp.asarray(tol),
                jnp.asarray(maxv), f_levels=fleet.f_levels,
                b_levels=fleet.b_levels, kind=fleet.kind)
            bl.state = state
            act0 = np.asarray(act0)[:j]
            bl.n_active += int(act0.sum())
            self.cols_in += j
            req.admit_tick = self.ticks
            req.admit_time = self._clock()
            self._ev_admit(rid=req.rid, trace_id=req.trace_id,
                           gid=req.graph_id, nrhs=j, tick=self.ticks)
            for col, lane_i in enumerate(rows):
                self.lanes[lane_i] = _LaneRef(req, col, bl)

    # -- one engine tick ----------------------------------------------------
    def tick(self) -> List[SolveRequest]:
        """Admit, advance every bucket with active lanes by
        ``iters_per_tick`` PCG iterations (one jitted step per bucket —
        all factors in the bucket ride the same program), retire finished
        lanes.  Returns requests completed this tick."""
        t_tick0 = self._clock()
        self._resync_buckets()
        self._admit()
        if self.admission.evict_hopeless:
            self._evict_hopeless()
        done: List[SolveRequest] = []
        for bkey in sorted(self._buckets):
            bl = self._buckets[bkey]
            occ = [i for i, lane in enumerate(self.lanes)
                   if lane is not None and lane.bucket is bl]
            if not occ:
                continue
            if bl.n_active > 0:
                bl.state = self._step_fn(
                    bl.fleet.arrays, bl.state,
                    f_levels=bl.fleet.f_levels, b_levels=bl.fleet.b_levels,
                    kind=bl.fleet.kind)
                self._account_sweeps(bl, occ)
            active = np.asarray(bl.state.active)   # (slots,) flags only
            frozen = [i for i in occ if not active[i]]
            bl.n_active = int(active[occ].sum())
            if frozen:
                done.extend(self._retire(bl, frozen))
        self._unpin_idle()
        self.ticks += 1
        self.cache.advance_ticks(1)
        if self.tracer is not None:
            # first host-side timestamp after a lane's first step call —
            # only when tracing is on (the stamp loop is pure host work,
            # but a trace nobody asked for is still overhead)
            t_first = self._clock()
            for lane in self.lanes:
                if lane is not None and lane.req.first_tick_time == 0.0:
                    lane.req.first_tick_time = t_first
        # running *minimum* tick duration — the deadline-eviction lower
        # bound for "one more tick".  A minimum (not a mean) is the
        # safe estimator: compile-heavy first ticks must not inflate it
        # and spuriously evict meetable requests; underestimating only
        # delays eviction until the deadline has truly passed.  (An
        # injected constant clock keeps this at 0, so tests evict
        # exactly when the deadline passes.)
        dur = self._clock() - t_tick0
        self._est_tick_s = dur if self._est_tick_s == 0.0 else \
            min(self._est_tick_s, dur)
        self._m_ticks.inc()
        self._m_tick_s.observe(dur)
        self._m_queue.set(len(self.queue))
        self._m_lanes.set(sum(l is not None for l in self.lanes))
        if self.metrics is not None:
            self.metrics.maybe_sample(self._clock())
        return done

    def _account_sweeps(self, bl: _BucketLanes, occ: List[int]) -> None:
        """Host-side mirror of one stepped bucket's trisolve sweep work.

        ``sweep_elements`` counts the padded panel elements one
        preconditioner apply sweeps across the bucket's occupied lanes —
        ``lanes × n_pad × (Kf · fwd sweeps + Kb · bwd sweeps)`` for
        factor kinds (a level loop runs ``live_levels − 1`` sweeps over
        the full ``(n_pad, K)`` panel), ``lanes × n_pad × Kf`` for spmv
        kinds.  This is the padding tax K-tiering shrinks: untiered, a
        hub-heavy bucket-mate inflates ``Kf``/``Kb`` for every lane
        here.  ``sweeps_skipped`` counts the level sweeps the dynamic
        per-lane bounds elided vs the static bucket ceilings."""
        fl = bl.fleet
        if fl.kind == "factor":
            live_f = max(self.lanes[i].req._handle.n_levels_fwd
                         for i in occ)
            live_b = max(self.lanes[i].req._handle.n_levels_bwd
                         for i in occ)
            self.sweeps_skipped += (fl.f_levels - live_f) \
                + (fl.b_levels - live_b)
            per_lane = fl.n_pad * (fl.Kf * max(live_f - 1, 0)
                                   + fl.Kb * max(live_b - 1, 0))
        else:
            per_lane = fl.n_pad * fl.Kf
        self.sweep_elements += len(occ) * per_lane

    def _evict_hopeless(self) -> None:
        """Deadline eviction: a lane is *hopeless* once even an
        immediately-converging column could not retire before its
        deadline — it still needs at least one more tick, so
        ``now + est_tick_s`` (``est_tick_s`` = minimum observed tick
        duration, a lower bound) crossing the deadline proves the miss.
        Hopeless lanes are force-frozen on device (one jitted flag
        scatter per bucket) and retire through the normal gather this
        same tick with ``status == "deadline_missed"``, freeing their
        fleet slots instead of iterating on to maxiter."""
        now = self._clock()
        doomed: Dict[_BucketLanes, List[int]] = {}
        for i, lane in enumerate(self.lanes):
            if lane is None:
                continue
            dl = lane.req._deadline_abs
            if dl is None:
                continue
            if lane.req._evicted or now + self._est_tick_s > dl:
                if not lane.req._evicted:
                    lane.req._evicted = True
                    self.deadline_evictions += 1
                    self._ev_evict(rid=lane.req.rid,
                                   trace_id=lane.req.trace_id,
                                   gid=lane.req.graph_id,
                                   reason="deadline")
                doomed.setdefault(lane.bucket, []).append(i)
        for bl, rows in doomed.items():
            jp = _next_pow2(len(rows))
            rows_a = np.full(jp, self.slots, np.int32)   # pads drop
            rows_a[:len(rows)] = rows
            bl.state = self._evict_fn(bl.state, jnp.asarray(rows_a))

    def _retire(self, bl: _BucketLanes,
                rows: List[int]) -> List[SolveRequest]:
        """Gather the finished columns (one jitted gather; device→host
        traffic is exactly the retired columns), free their lanes, and
        complete requests whose last column retired."""
        j = len(rows)
        jp = _next_pow2(j)
        rows_a = np.zeros(jp, np.int32)
        rows_a[:j] = rows
        X, it, relres = self._gather_fn(bl.state, jnp.asarray(rows_a))
        X = np.asarray(X)[:j]
        it = np.asarray(it)[:j]
        relres = np.asarray(relres)[:j]
        self.cols_out += j
        done: List[SolveRequest] = []
        for k, lane_i in enumerate(rows):
            lane = self.lanes[lane_i]
            req = lane.req
            n = int(np.shape(req.b)[-1])
            req._partial[lane.col] = (X[k][:n], int(it[k]),
                                      float(relres[k]))
            self.lanes[lane_i] = None
            if len(req._partial) == req.nrhs:
                cols = [req._partial[c] for c in range(req.nrhs)]
                Xr = np.stack([c[0] for c in cols])
                req.iters = np.array([c[1] for c in cols])
                req.relres = np.array([c[2] for c in cols])
                req.converged = bool(np.all(req.relres <= req.tol))
                req.x = Xr[0] if np.ndim(req.b) == 1 else Xr
                req.finish_time = self._clock()
                req.finish_tick = self.ticks
                if req.converged:
                    req.status = "converged"
                elif req._evicted or (
                        req._deadline_abs is not None
                        and req.finish_time > req._deadline_abs):
                    # hopeless lane retired early, or a deadline request
                    # that ran its maxiter budget out past the deadline
                    req.status = "deadline_missed"
                else:
                    req.status = "maxiter"
                self._m_done.labels(replica=self._obs_rep_label,
                                    status=req.status).inc()
                self._m_latency.observe(req.latency_s)
                self._m_qwait.observe(req.queue_wait_s)
                it_max = int(req.iters.max())
                rr_max = float(req.relres.max())
                self._ev_retire(rid=req.rid, trace_id=req.trace_id,
                                gid=req.graph_id, status=req.status,
                                iters=it_max, relres=rr_max)
                if self.health is not None:
                    self.health.observe_retirement(
                        gid=req.graph_id, family=bl.fleet.family,
                        iters=it_max, relres=rr_max, status=req.status,
                        deadline_missed=req.status == "deadline_missed")
                if self.tracer is not None:
                    self.tracer.record(trace_from_request(
                        req, family=bl.fleet.family,
                        policy=self.admission.name,
                        replica=self._obs_replica,
                        device=self._obs_device))
                # release the factor ref: a completed request sitting in
                # the bounded history must not keep an evicted handle's
                # fleet row claimed (row recycling is weakref-driven)
                req._handle = None
                self.completed.append(req)
                self.n_completed += 1
                done.append(req)
        return done

    def _unpin_idle(self) -> None:
        """Release pins for graphs with no queued or active work.  The
        pinned handle is what keeps an evicted factor's fleet row (and
        with it the stacked device arrays) claimed, so dropping idle
        pins is also what lets the fleet recycle dead rows."""
        in_use = {r.graph_id for r in self.queue}
        in_use.update(lane.req.graph_id for lane in self.lanes
                      if lane is not None)
        for gid in [g for g in self._pinned if g not in in_use]:
            del self._pinned[gid]

    # -- driving loops ------------------------------------------------------
    @property
    def busy(self) -> bool:
        """True while any request is queued or holding lanes."""
        return bool(self.queue) or any(l is not None for l in self.lanes)

    def run_until_drained(self, max_ticks: int = 100_000
                          ) -> List[SolveRequest]:
        """Tick until queue and lanes are empty; returns every request
        completed during the drain, in completion order."""
        done: List[SolveRequest] = []
        for _ in range(max_ticks):
            if not self.busy:
                break
            done.extend(self.tick())
        return done

    def stats(self) -> EngineStats:
        """Point-in-time :class:`EngineStats` snapshot — scheduler
        counters, compile counts and host↔device column traffic (the
        counter glossary lives in ``docs/serving.md``)."""
        active = sum(l is not None for l in self.lanes)
        in_flight = len({id(l.req) for l in self.lanes if l is not None})
        sched = self.admission.counters()
        return EngineStats(
            ticks=self.ticks, completed=self.n_completed,
            queued=len(self.queue), active_lanes=active, slots=self.slots,
            factors=len(self.cache), buckets=len(self._buckets),
            families=len({fam for fam, _, _ in self._buckets}),
            step_compiles=self.compile_counts["step"],
            admit_compiles=self.compile_counts["admit"],
            gather_compiles=self.compile_counts["gather"],
            cols_in=self.cols_in, cols_out=self.cols_out,
            sweeps_skipped=self.sweeps_skipped,
            sweep_elements=self.sweep_elements,
            fleet_resyncs=self.fleet_resyncs,
            policy=self.admission.name,
            max_skips=self.admission.max_skips,
            admitted_reqs=self.admitted_reqs,
            in_flight_reqs=in_flight,
            sched_rounds=sched["sched_rounds"],
            backfill_skips=sched["backfill_skips"],
            skipped_reqs=sched["skipped_reqs"],
            barrier_rounds=sched["barrier_rounds"],
            sealed_backfills=sched.get("sealed_backfills", 0),
            deadline_evictions=self.deadline_evictions,
            queue_peak=self.queue_peak)
