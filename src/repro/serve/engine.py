"""Slot-based continuous-batching engine for Laplacian solve requests.

The serving workload of this repo *is* the paper's value proposition:
factor once (cheap randomized construction), then amortize the factor
over a stream of right-hand sides.  ``SolveEngine`` is the vLLM-style
continuous-batching loop restated for PCG instead of token decoding:

* a fixed number of **lanes** (slots) share jitted step programs with
  static shapes — the TPU-friendly formulation;
* queued :class:`SolveRequest`\\ s ``(graph_id, rhs, tol)`` are admitted
  FIFO into free lanes (a multi-RHS request takes one lane per column);
* active lanes are **grouped by factor** each tick and every group
  advances through ``iters_per_tick`` iterations of the batched
  frozen-column PCG (``pcg_batched_step`` over the group's
  ``FactorCache`` handle — matvec + fused multi-rhs trisolve);
* lanes whose column converged (or hit maxiter) retire at the end of a
  tick without stalling the rest of the batch; freed lanes readmit from
  the queue on the next tick.

Because frozen-column PCG lanes are independent, a request's trajectory
is identical to a direct ``FactorHandle.solve`` batched solve of its own
rhs block — batch composition, padding lanes, and tick slicing change
nothing.  Group batches are padded to power-of-two lane counts so each
graph compiles O(log slots) step programs, preserving the
jit-cached-per-shape discipline of the PR-1 engine.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.solver import FactorCache, FactorHandle
from repro.core.parac import _next_pow2
from repro.core.pcg import (PCGBatchState, pcg_batched_init,
                            pcg_batched_step)


@dataclasses.dataclass(eq=False)          # identity equality: results are
class SolveRequest:                        # arrays, field-wise == is a trap
    """One solve job: ``L_graph x = b`` to relative tolerance ``tol``.

    ``b`` may be ``(n,)`` or ``(nrhs, n)`` — a block request occupies
    ``nrhs`` lanes and completes when every column has retired.  Result
    fields are populated on completion; ``x`` matches ``b``'s shape.
    """

    rid: int
    graph_id: str
    b: np.ndarray
    tol: float = 1e-6
    maxiter: int = 500
    # -- filled by the engine -----------------------------------------------
    x: Optional[np.ndarray] = None
    iters: Optional[np.ndarray] = None
    relres: Optional[np.ndarray] = None
    converged: Optional[bool] = None
    submit_time: float = 0.0
    finish_time: float = 0.0
    submit_tick: int = -1
    admit_tick: int = -1
    finish_tick: int = -1
    _partial: Dict[int, tuple] = dataclasses.field(
        default_factory=dict, repr=False)

    @property
    def nrhs(self) -> int:
        return 1 if np.ndim(self.b) == 1 else int(np.shape(self.b)[0])

    @property
    def latency_s(self) -> float:
        return self.finish_time - self.submit_time


class _Lane:
    """Host-side record of one occupied lane: which request/column it
    serves plus the lane's slice of the PCG carry (device arrays)."""

    __slots__ = ("req", "col", "x", "r", "z", "p", "rz", "it", "active",
                 "bnorm")

    def __init__(self, req: SolveRequest, col: int, state: PCGBatchState,
                 row: int):
        self.req = req
        self.col = col
        self.read(state, row)

    def read(self, state: PCGBatchState, row: int) -> None:
        self.x = state.X[row]
        self.r = state.R[row]
        self.z = state.Z[row]
        self.p = state.P[row]
        self.rz = state.rz[row]
        self.it = state.it[row]
        self.active = bool(state.active[row])
        self.bnorm = state.bnorm[row]


class SolveEngine:
    """Continuous-batching solve service over a :class:`FactorCache`.

    Graphs must be admitted to the cache (``cache.factor`` /
    ``factor_batched``) before requests referencing them are submitted.
    """

    def __init__(self, cache: FactorCache, *, slots: int = 8,
                 iters_per_tick: int = 8, completed_history: int = 4096):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        self.cache = cache
        self.slots = slots
        self.iters_per_tick = iters_per_tick
        # bounded: a long-running service must not accumulate every
        # finished request's arrays forever (drain return values are the
        # delivery path; this is just recent history)
        self.completed: Deque[SolveRequest] = deque(maxlen=completed_history)
        self.lanes: List[Optional[_Lane]] = [None] * slots
        self.queue: Deque[SolveRequest] = deque()
        self.ticks = 0
        # handles pinned while they have queued/active work: in-flight
        # requests survive cache eviction, and a graph_id re-attached to
        # a *different* factor mid-flight cannot hijack them.  Jitted
        # init/step programs are keyed by handle identity for the same
        # reason; entries are pruned when an evicted handle goes idle.
        self._pinned: Dict[str, FactorHandle] = {}
        self._fns: Dict[int, tuple] = {}

    # -- request lifecycle --------------------------------------------------
    def submit(self, req: SolveRequest) -> None:
        """Queue a request (validates routing and lane fit up front; the
        handle is pinned only once the request is actually accepted)."""
        handle = self._pinned.get(req.graph_id)
        if handle is None:
            handle = self.cache.get(req.graph_id)  # raises on unknown graph
        b = np.asarray(req.b)
        if b.ndim not in (1, 2) or b.shape[-1] != handle.n:
            raise ValueError(
                f"rhs must be (n,) or (nrhs, n) with n={handle.n}, "
                f"got {b.shape}")
        if not 1 <= req.nrhs <= self.slots:
            raise ValueError(
                f"request rid={req.rid} needs {req.nrhs} lanes but the "
                f"engine has {self.slots} slots")
        self._pinned[req.graph_id] = handle
        req.submit_time = time.perf_counter()
        req.submit_tick = self.ticks
        self.queue.append(req)

    def _handle_fns(self, handle: FactorHandle):
        """Jitted init/step programs for one factor, keyed by handle
        identity (jax re-specializes per batch shape; power-of-two
        padding bounds the shape count)."""
        entry = self._fns.get(id(handle))
        if entry is None:
            bmv = jax.vmap(handle.matvec)

            def bpc(R):
                return handle.precondition(R.T).T

            k = self.iters_per_tick

            def init(B, tol):
                return pcg_batched_init(bmv, bpc, B, tol=tol)

            def step(state, tol, maxiter):
                return pcg_batched_step(bmv, bpc, state, k=k, tol=tol,
                                        maxiter=maxiter)

            entry = (handle, jax.jit(init), jax.jit(step))
            self._fns[id(handle)] = entry
        return entry[1], entry[2]

    def _admit(self) -> None:
        """FIFO admission: place queued requests into free lanes until
        the head request no longer fits (head-of-line blocking keeps
        completion order fair and shapes static)."""
        free = [i for i, lane in enumerate(self.lanes) if lane is None]
        while self.queue and self.queue[0].nrhs <= len(free):
            req = self.queue.popleft()
            handle = self._pinned[req.graph_id]
            init, _ = self._handle_fns(handle)
            B = np.atleast_2d(np.asarray(req.b, np.float32))
            state = init(jnp.asarray(B),
                         jnp.full((B.shape[0],), req.tol, jnp.float32))
            req.admit_tick = self.ticks
            for col in range(B.shape[0]):
                self.lanes[free.pop(0)] = _Lane(req, col, state, col)

    # -- one engine tick ----------------------------------------------------
    def tick(self) -> List[SolveRequest]:
        """Admit, advance every factor group ``iters_per_tick`` PCG
        iterations, retire finished lanes.  Returns requests completed
        this tick."""
        self._admit()
        groups: Dict[str, List[int]] = {}
        for i, lane in enumerate(self.lanes):
            if lane is not None and lane.active:
                groups.setdefault(lane.req.graph_id, []).append(i)

        for gid, idxs in groups.items():
            handle = self._pinned[gid]
            _, step = self._handle_fns(handle)
            n = handle.n
            L = _next_pow2(len(idxs))
            zeros = jnp.zeros(n, jnp.float32)
            pad = L - len(idxs)

            def stacked(attr, fill):
                rows = [getattr(self.lanes[i], attr) for i in idxs]
                return jnp.stack(rows + [fill] * pad)

            state = PCGBatchState(
                X=stacked("x", zeros), R=stacked("r", zeros),
                Z=stacked("z", zeros), P=stacked("p", zeros),
                rz=stacked("rz", jnp.float32(0)),
                it=stacked("it", jnp.int32(0)),
                active=stacked("active", jnp.bool_(False)),
                bnorm=stacked("bnorm", jnp.float32(1)))
            tolv = jnp.asarray(
                [self.lanes[i].req.tol for i in idxs] + [1.0] * pad,
                jnp.float32)
            maxv = jnp.asarray(
                [self.lanes[i].req.maxiter for i in idxs] + [0] * pad,
                jnp.int32)
            state = step(state, tolv, maxv)
            for row, i in enumerate(idxs):
                self.lanes[i].read(state, row)

        done = self._retire()
        self._unpin_idle()
        self.ticks += 1
        return done

    def _unpin_idle(self) -> None:
        """Release pins for graphs with no queued or active work, then
        sweep jitted programs whose handle is neither pinned nor still
        the cached one (evicted, or its graph_id re-attached to a new
        factor) — the closures capture the factor's device arrays, so
        keeping them would defeat the cache's memory budget."""
        in_use = {r.graph_id for r in self.queue}
        in_use.update(lane.req.graph_id for lane in self.lanes
                      if lane is not None)
        for gid in [g for g in self._pinned if g not in in_use]:
            del self._pinned[gid]
        pinned = {id(h) for h in self._pinned.values()}
        for hid in list(self._fns):
            handle = self._fns[hid][0]
            if hid not in pinned and \
                    self.cache.peek(handle.graph_id) is not handle:
                del self._fns[hid]

    def _retire(self) -> List[SolveRequest]:
        """Free every lane whose column froze (converged or hit maxiter)
        — immediately, so the slot readmits next tick even while sibling
        columns keep running.  A request completes when its last column
        retires; completed requests are handed back."""
        done: List[SolveRequest] = []
        for i, lane in enumerate(self.lanes):
            if lane is None or lane.active:
                continue
            req = lane.req
            relres = float(jnp.linalg.norm(lane.r) / lane.bnorm)
            req._partial[lane.col] = (np.asarray(lane.x), int(lane.it),
                                      relres)
            self.lanes[i] = None
            if len(req._partial) == req.nrhs:
                cols = [req._partial[c] for c in range(req.nrhs)]
                X = np.stack([c[0] for c in cols])
                req.iters = np.array([c[1] for c in cols])
                req.relres = np.array([c[2] for c in cols])
                req.converged = bool(np.all(req.relres <= req.tol))
                req.x = X[0] if np.ndim(req.b) == 1 else X
                req.finish_time = time.perf_counter()
                req.finish_tick = self.ticks
                self.completed.append(req)
                done.append(req)
        return done

    # -- driving loops ------------------------------------------------------
    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(l is not None for l in self.lanes)

    def run_until_drained(self, max_ticks: int = 100_000
                          ) -> List[SolveRequest]:
        """Tick until queue and lanes are empty; returns every request
        completed during the drain, in completion order."""
        done: List[SolveRequest] = []
        for _ in range(max_ticks):
            if not self.busy:
                break
            done.extend(self.tick())
        return done

    def stats(self) -> Dict[str, float]:
        active = sum(l is not None for l in self.lanes)
        return dict(ticks=self.ticks, completed=len(self.completed),
                    queued=len(self.queue), active_lanes=active,
                    slots=self.slots, factors=len(self.cache))
