"""Async serving frontend: a background-thread driver over
:class:`SolveEngine` with an asyncio-friendly submit/await API and a
bounded ingress queue with backpressure.

The engine itself is deliberately single-threaded (its lane maps, pin
table and jitted-program counters are plain Python state), so the
frontend owns **one driver thread** that is the only thread ever
touching the engine or its :class:`FactorCache`:

* ``submit()`` validates nothing itself — it enqueues ``(request,
  future)`` onto a bounded ingress deque and wakes the driver.  The
  driver forwards ingress to ``engine.submit`` (validation errors
  resolve the future exceptionally), ticks while the engine is busy,
  and resolves each request's future the moment it retires;
* **backpressure**: when ``ingress + engine queue`` reaches
  ``max_queue``, ``submit`` either blocks until the scheduler drains
  (``overload="block"``) or raises :class:`EngineOverloadedError`
  (``overload="reject"``) — rejected submissions are counted and never
  reach the engine;
* ``await frontend.solve(graph_id, b)`` is the asyncio face: it wraps
  the concurrent future for the running event loop, so a service can
  multiplex thousands of callers over one engine without threads of its
  own;
* ``call(fn, ...)`` runs a callable **on the driver thread** between
  engine rounds — the only safe way for another thread to mutate the
  engine or its cache (a cluster router uses it to factor graphs onto
  this replica);
* a driver-thread crash (engine exception outside per-request
  validation) fails every pending future with the crash recorded in
  ``driver_error`` instead of hanging them; ``alive`` exposes liveness
  to a cluster router's ejection loop.

Results are the engine's: the driver thread runs the same tick loop as
the synchronous ``run_until_drained``, so a request served through the
frontend is **bit-exact** with a direct ``FactorHandle.solve`` of the
same rhs block (tested), whatever the admission policy.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from repro.obs.flight import NULL_FLIGHT
from repro.obs.registry import NULL as _NULL_METRICS

from .engine import EngineStats, SolveEngine, SolveRequest, make_request


class EngineOverloadedError(RuntimeError):
    """Raised by ``submit`` under ``overload="reject"`` when the bounded
    request queue is full (the backpressure signal a load balancer turns
    into HTTP 429 / retry-after)."""


@dataclasses.dataclass
class FrontendStats:
    """Queue-depth and lifecycle counters for the async frontend.
    ``queue_depth``/``queue_peak`` count requests waiting *anywhere*
    before lane admission (frontend ingress + engine queue)."""

    submitted: int
    completed: int
    failed: int
    rejected: int
    queue_depth: int
    queue_peak: int
    max_queue: int
    alive: bool
    control_calls: int
    control_s: float
    factor_queue_depth: int
    engine: EngineStats

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["engine"] = self.engine.as_dict()
        return d


class SolveFrontend:
    """Asyncio-friendly service frontend over a :class:`SolveEngine`.

    ::

        eng = SolveEngine(cache, admission=make_policy("deadline"))
        with SolveFrontend(eng, max_queue=256) as fe:
            res = await fe.solve("grid2d_64", b, deadline_s=0.5)
            # res.x, res.status in {"converged", "deadline_missed", ...}

    ``submit`` / ``submit_request`` return a
    :class:`concurrent.futures.Future` resolving to the completed
    :class:`SolveRequest`; ``solve`` awaits it on the caller's event
    loop.  Thread-safe: any number of producer threads / event loops may
    submit concurrently.

    Args:
        engine: the engine this frontend drives — after construction,
            only the frontend's driver thread may touch it (use
            :meth:`call` for out-of-band work like factoring).
        max_queue: bound on requests waiting anywhere before lane
            admission (ingress + engine queue) — the backpressure
            threshold.
        overload: what a full queue does to ``submit`` — ``"block"``
            stalls the submitter until space frees, ``"reject"`` raises
            :class:`EngineOverloadedError`.
        idle_wait_s: driver-thread sleep between polls when the engine
            is idle (latency floor for a cold first request).
    """

    def __init__(self, engine: SolveEngine, *, max_queue: int = 256,
                 overload: str = "block", idle_wait_s: float = 0.05,
                 metrics=None, flight=None, obs_replica: int = -1):
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if overload not in ("block", "reject"):
            raise ValueError("overload must be 'block' or 'reject'")
        self.engine = engine
        self.max_queue = max_queue
        self.overload = overload
        self.idle_wait_s = idle_wait_s
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)    # driver wake-up
        self._space = threading.Condition(self._lock)   # submitter wake-up
        self._ingress: Deque[Tuple[SolveRequest, Future]] = deque()
        self._control: Deque[Tuple[Callable, tuple, dict, Future]] = deque()
        self._futures: Dict[SolveRequest, Future] = {}
        self._closed = False
        self.driver_error: Optional[BaseException] = None
        self._seq = 0
        self.submitted = 0
        self.completed = 0
        self.failed = 0          # futures resolved exceptionally
        self.rejected = 0
        self.queue_peak = 0
        # control-channel visibility: every second the driver spends in
        # `call()` work (factorizations, adopts, compactions) is a second
        # its solve lanes sit frozen — the colocated-vs-disaggregated
        # stall is read straight off these, not inferred from latency
        self.control_calls = 0
        self.control_s = 0.0
        self._control_inflight = 0
        # observability (repro.obs): pre-bound children; no-ops when
        # metrics is None, so the submit/driver paths never branch
        reg = metrics if metrics is not None else _NULL_METRICS
        rep = str(obs_replica) if obs_replica >= 0 else "solo"
        self._m_submitted = reg.counter(
            "repro_frontend_submitted_total", "requests accepted at ingress",
            labels=("replica",)).labels(replica=rep)
        self._m_rejected = reg.counter(
            "repro_frontend_rejected_total",
            "submissions refused by backpressure",
            labels=("replica",)).labels(replica=rep)
        self._m_completed = reg.counter(
            "repro_frontend_completed_total",
            "futures resolved with a finished request",
            labels=("replica",)).labels(replica=rep)
        self._m_failed = reg.counter(
            "repro_frontend_failed_total",
            "futures resolved exceptionally",
            labels=("replica",)).labels(replica=rep)
        self._m_queue = reg.gauge(
            "repro_frontend_queue_depth",
            "requests waiting before lane admission (ingress + engine)",
            labels=("replica",)).labels(replica=rep)
        self._m_control_s = reg.histogram(
            "repro_frontend_control_seconds",
            "driver-thread seconds per control-channel call",
            labels=("replica",)).labels(replica=rep)
        self._flight = flight if flight is not None else NULL_FLIGHT
        self._obs_rep_label = rep
        self._thread = threading.Thread(target=self._run,
                                        name="solve-frontend", daemon=True)
        self._thread.start()

    # -- submission (any thread) --------------------------------------------
    def _depth(self) -> int:
        # ingress + engine queue = requests waiting for a lane; reading
        # len() of the engine deque cross-thread is atomic under the GIL
        # and only feeds backpressure, never engine decisions
        return len(self._ingress) + len(self.engine.queue)

    @property
    def queue_depth(self) -> int:
        """Requests waiting anywhere before lane admission (ingress +
        engine queue) — the same advisory cross-thread read that drives
        backpressure; a cluster router's load signal."""
        return self._depth()

    def submit_request(self, req: SolveRequest) -> "Future[SolveRequest]":
        """Queue a pre-built :class:`SolveRequest`; returns a future that
        resolves to the same (completed) request object on retirement,
        or raises the engine's validation error."""
        fut: "Future[SolveRequest]" = Future()
        with self._work:
            if self._closed:
                raise RuntimeError("submit on a closed SolveFrontend")
            while self._depth() >= self.max_queue:
                if self.overload == "reject":
                    self.rejected += 1
                    self._m_rejected.inc()
                    raise EngineOverloadedError(
                        f"request queue full ({self.max_queue} waiting)")
                self._space.wait(timeout=self.idle_wait_s)
                if self._closed:
                    raise RuntimeError("SolveFrontend closed while "
                                       "blocked on backpressure")
            # pre-stamp submission so queueing delay includes ingress
            # time (the engine keeps a pre-stamped submit_time)
            if req.submit_time == 0.0:
                req.submit_time = self.engine._clock()
            self._ingress.append((req, fut))
            self.submitted += 1
            self._m_submitted.inc()
            depth = self._depth()
            self.queue_peak = max(self.queue_peak, depth)
            self._m_queue.set(depth)
            self._work.notify_all()
        return fut

    def submit(self, graph_id: str, b, *, rid: Optional[int] = None,
               **kw) -> "Future[SolveRequest]":
        """Build and queue a solve request (``b``: ``(n,)`` or
        ``(nrhs, n)``; ``kw`` = ``tol``/``maxiter``/``priority``/
        ``deadline_s``, see :func:`repro.serve.engine.make_request`)."""
        with self._lock:
            self._seq += 1
            auto_rid = self._seq
        return self.submit_request(make_request(
            graph_id, b, rid=rid if rid is not None else auto_rid, **kw))

    async def solve(self, graph_id: str, b, **kw) -> SolveRequest:
        """Asyncio face: ``res = await frontend.solve(gid, b)``."""
        import asyncio
        return await asyncio.wrap_future(self.submit(graph_id, b, **kw))

    # -- control channel (any thread) ---------------------------------------
    def call(self, fn: Callable, *args, **kw) -> "Future[Any]":
        """Run ``fn(*args, **kw)`` **on the driver thread**, between
        engine rounds, returning a future for its result.  This is the
        only safe way for another thread to touch the engine or its
        ``FactorCache`` (e.g. a cluster router factoring a graph onto
        this replica): the driver thread is their sole owner.  ``fn``
        exceptions resolve the future exceptionally; they never kill the
        driver."""
        fut: "Future[Any]" = Future()
        with self._work:
            if self._closed:
                raise RuntimeError("call on a closed SolveFrontend")
            self._control.append((fn, args, kw, fut))
            self._work.notify_all()
        return fut

    @property
    def factor_queue_depth(self) -> int:
        """Control-channel work waiting for (or holding) the driver —
        queued ``call()``s plus the one executing.  Under a colocated
        cluster this is the factorization backlog stalling this
        replica's lanes; with a factor tier it stays near zero (adopts
        are cheap).  Advisory cross-thread read, like ``queue_depth``."""
        return len(self._control) + self._control_inflight

    @property
    def alive(self) -> bool:
        """Driver-thread liveness — the health signal a cluster router
        keys ejection on.  False once the driver crashed (see
        ``driver_error``) or the frontend closed."""
        return (self._thread.is_alive() and self.driver_error is None
                and not self._closed)

    # -- driver thread (sole owner of the engine) ---------------------------
    def _run(self) -> None:
        # sole owner of the engine; `_futures` is touched only here
        # (dict get/set/pop are GIL-atomic, so stats/drain may peek)
        eng = self.engine
        while True:
            with self._work:
                while (not self._ingress and not self._control
                       and not eng.busy and not self._closed):
                    self._work.wait(timeout=self.idle_wait_s)
                if self._closed:
                    # close(drain=True) already waited for idle; a hard
                    # close abandons in-flight work deliberately
                    break
                batch = list(self._ingress)
                self._ingress.clear()
                control = list(self._control)
                self._control.clear()
                if batch:
                    self._space.notify_all()
            with self._lock:
                self._control_inflight = len(control)
            for fn, args, kw, cfut in control:
                t0 = time.monotonic()
                try:
                    res = fn(*args, **kw)
                except Exception as exc:
                    if not cfut.done():
                        cfut.set_exception(exc)
                else:
                    if not cfut.done():
                        cfut.set_result(res)
                finally:
                    dt = time.monotonic() - t0
                    # under the stats lock: these are read-modify-writes
                    # racing the `stats()` snapshots router/health threads
                    # take — unlocked, a snapshot could observe
                    # control_calls incremented but control_s stale
                    with self._lock:
                        self.control_calls += 1
                        self.control_s += dt
                        self._control_inflight -= 1
                    self._m_control_s.observe(dt)
            try:
                for req, fut in batch:
                    try:
                        eng.submit(req)
                    except Exception as exc:  # unknown graph / bad shape
                        self.failed += 1
                        self._m_failed.inc()
                        if not fut.done():    # caller may have cancelled
                            fut.set_exception(exc)
                    else:
                        self._futures[req] = fut
                if eng.busy:
                    for done in eng.tick():
                        fut = self._futures.pop(done, None)
                        if fut is None:
                            continue  # submitted directly to the engine,
                            # not through the frontend: not ours to count
                        self.completed += 1
                        self._m_completed.inc()
                        if not fut.done():
                            fut.set_result(done)
                    with self._space:
                        self._space.notify_all()  # lanes freed → drained
            except Exception as exc:
                # a wedged engine must fail fast, not hang every future:
                # record the crash (surfaced as `alive == False` — the
                # router's ejection signal), close, and fall through to
                # the cleanup below so pending futures resolve
                # exceptionally instead of blackholing
                self.driver_error = exc
                self._flight.incident(
                    "driver_crash", replica=self._obs_rep_label,
                    error=repr(exc))
                with self._work:
                    self._closed = True
                    self._work.notify_all()
                    self._space.notify_all()
                break
        # closed (or crashed): fail whatever never completed
        why = ("SolveFrontend closed" if self.driver_error is None
               else f"engine driver crashed: {self.driver_error!r}")
        for req, fut in list(self._futures.items()):
            self.failed += 1
            if not fut.done():
                fut.set_exception(RuntimeError(why))
        self._futures.clear()
        for req, fut in list(self._ingress):
            self.failed += 1
            if not fut.done():
                fut.set_exception(RuntimeError(why))
        self._ingress.clear()
        for fn, args, kw, cfut in list(self._control):
            if not cfut.done():
                cfut.set_exception(RuntimeError(why))
        self._control.clear()

    # -- lifecycle ----------------------------------------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted request has resolved (or timeout;
        returns False on timeout).  The driver keeps running.  Counts,
        not queue emptiness: work the driver holds between ingress and
        engine submission is still pending."""
        import time as _time
        deadline = None if timeout is None else _time.monotonic() + timeout
        while self.submitted > self.completed + self.failed:
            if deadline is not None and _time.monotonic() > deadline:
                return False
            _time.sleep(0.001)
        return True

    def close(self, *, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Stop the driver thread.  With ``drain`` (default) in-flight
        and queued work finishes first; otherwise pending futures fail
        with ``RuntimeError``."""
        if drain:
            self.drain(timeout=timeout)
        with self._work:
            self._closed = True
            self._work.notify_all()
            self._space.notify_all()
        self._thread.join(timeout=10.0)

    def __enter__(self) -> "SolveFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    def stats(self) -> FrontendStats:
        """Point-in-time :class:`FrontendStats` snapshot (nests the
        engine's :class:`EngineStats`); safe from any thread."""
        with self._lock:
            depth = self._depth()
            peak = max(self.queue_peak, depth)
            # read the control pair under the same lock the driver's
            # accumulation holds, so calls/seconds are mutually coherent
            control_calls = self.control_calls
            control_s = self.control_s
            factor_depth = len(self._control) + self._control_inflight
        return FrontendStats(
            submitted=self.submitted, completed=self.completed,
            failed=self.failed, rejected=self.rejected,
            queue_depth=depth, queue_peak=peak,
            max_queue=self.max_queue, alive=self.alive,
            control_calls=control_calls, control_s=control_s,
            factor_queue_depth=factor_depth,
            engine=self.engine.stats())
