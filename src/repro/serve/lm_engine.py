"""Slot-based continuous-batching *token* engine.  **Deprecated.**

.. deprecated::
    This is the seed's original LM serving workload, kept only as a
    substrate exercise (covered by one smoke test in
    ``test_substrates.py``; excluded from serve-layer coverage
    expectations).  It shares **no** code with the production solve
    service — that stack is ``serve.engine.SolveEngine`` (device-
    resident continuous batching), ``serve.admission`` (SLO-aware
    scheduling) and ``serve.frontend.SolveFrontend`` (async API) — so
    fixes there do not propagate here.  Do not extend this module; new
    serving features belong to the solve stack.

A fixed number of decode slots share one jitted decode step (static
shapes).  Requests are queued, prefilled into a free slot's cache
position-by-position (batched prefill fills the slot cache), and then
advance together one token per engine tick; finished slots are recycled
without stopping the batch — the standard continuous-batching pattern
(vLLM-style) restricted to a static slot count, which is the
TPU-friendly formulation.

Per-slot state lives in one pytree of stacked caches; slot i's sequence
position is tracked host-side.  Greedy or temperature sampling.
"""
from __future__ import annotations

import dataclasses
import queue
from typing import Callable, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # int32 [prompt_len]
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 512, dtype=jnp.float32, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.caches = tf.init_caches(cfg, slots, max_len, dtype)
        self.pos = np.zeros(slots, np.int64)          # next position per slot
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self._finished: List[Request] = []
        self.key = jax.random.key(seed)
        self._decode = jax.jit(
            lambda p, c, t, cp: tf.decode_step(p, cfg, c, t, cp))

    # -- request lifecycle --------------------------------------------------
    def submit(self, req: Request):
        req.out_tokens = []
        self.queue.put(req)

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and not self.queue.empty():
                req = self.queue.get()
                self._prefill_slot(s, req)
                self.active[s] = req

    def _prefill_slot(self, s: int, req: Request):
        """Feed the prompt through the decode path token by token (simple
        and always-correct; a batched prefill fast path is in tf.prefill —
        examples/serve.py uses it when all slots start together)."""
        self.pos[s] = 0
        for t in req.prompt[:-1]:
            tok = jnp.full((self.slots, 1), 0, jnp.int32).at[s, 0].set(int(t))
            _, self.caches = self._decode(self.params, self.caches, tok,
                                          jnp.int32(self.pos[s]))
            self.pos[s] += 1
        self._pending_first = int(req.prompt[-1])

    # -- one engine tick: advance every active slot one token ---------------
    def tick(self) -> Dict[int, int]:
        self._admit()
        if not any(a is not None for a in self.active):
            return {}
        tok = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if not req.out_tokens:
                tok[s, 0] = req.prompt[-1]
            else:
                tok[s, 0] = req.out_tokens[-1]
        # all slots share cache_pos per step; engine uses max position and
        # per-slot masking via positions (static-shape simplification:
        # slots admitted together decode in lockstep)
        cp = int(max(self.pos[s] for s, r in enumerate(self.active)
                     if r is not None))
        logits, self.caches = self._decode(self.params, self.caches,
                                           jnp.asarray(tok), jnp.int32(cp))
        emitted = {}
        logits = np.asarray(logits, np.float32)[:, : self.cfg.vocab]
        for s, req in enumerate(self.active):
            if req is None:
                continue
            if req.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                z = logits[s] / req.temperature
                nxt = int(jax.random.categorical(sub, jnp.asarray(z)))
            else:
                nxt = int(logits[s].argmax())
            req.out_tokens.append(nxt)
            emitted[req.rid] = nxt
            self.pos[s] = cp + 1
            if len(req.out_tokens) >= req.max_new_tokens:
                self.active[s] = None     # recycle slot
                self._finished.append(req)
        return emitted

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        """Tick until queue and slots are empty; returns the requests
        that finished during the drain, in completion order."""
        done: List[Request] = []
        start = len(self._finished)
        for _ in range(max_ticks):
            if self.queue.empty() and all(a is None for a in self.active):
                break
            self.tick()
        done.extend(self._finished[start:])
        if len(self._finished) > 4096:       # recent history only; the
            del self._finished[:-4096]       # drain return delivers results
        return done
