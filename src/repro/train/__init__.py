from .trainer import Trainer, TrainConfig  # noqa: F401
