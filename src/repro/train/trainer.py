"""Fault-tolerant training loop.

Responsibilities:
  * build the jitted train step (sharded per distributed.steps),
  * deterministic data (stateless per-step addressing -> elastic restart),
  * periodic preemption-safe checkpoints + automatic resume,
  * simple straggler/failure handling for the single-controller setting:
    every step is idempotent (step index -> batch), so a crashed run
    resumes from the last published checkpoint and replays identically
    (resume determinism is asserted in tests/test_substrates.py).

On a real multi-pod deployment the same loop runs under
``jax.distributed.initialize`` with one process per host; device failure
surfaces as a process exit -> the cluster manager restarts the job and
this loop resumes from ``latest_step``.  Elastic scaling = restart with
a different mesh: checkpoints are mesh-agnostic (full arrays resharded
on restore by ``jax.device_put`` against the new specs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.shapes import ShapeCell
from repro.data.tokens import SyntheticTokens
from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.distributed.steps import make_train_step, train_state_specs
from repro.models import transformer as tf
from repro.models.common import init_params
from repro.optim import adamw_init


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    lr: float = 3e-4
    grad_accum: int = 1
    seed: int = 0
    log_every: int = 10


class Trainer:
    def __init__(self, model_cfg, mesh, cell: ShapeCell, tcfg: TrainConfig,
                 param_dtype=jnp.float32):
        self.cfg = model_cfg
        self.mesh = mesh
        self.cell = cell
        self.tcfg = tcfg
        step_fn, in_sh, out_sh = make_train_step(
            model_cfg, mesh, cell, lr=tcfg.lr, grad_accum=tcfg.grad_accum)
        self.step_fn = jax.jit(step_fn, in_shardings=in_sh,
                               out_shardings=out_sh,
                               donate_argnums=(0, 1))
        pspecs, opt_specs = train_state_specs(model_cfg, mesh)
        self._pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
        self._oshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                    opt_specs)
        self.data = SyntheticTokens(model_cfg.vocab, cell.seq_len,
                                    cell.global_batch, seed=tcfg.seed)
        self.params = None
        self.opt = None
        self.step = 0

    def init_or_restore(self) -> bool:
        """Resume from the latest checkpoint if one exists (fault
        tolerance: a restarted job lands here and replays identically)."""
        if self.tcfg.ckpt_dir and latest_step(self.tcfg.ckpt_dir) is not None:
            like = jax.eval_shape(
                lambda: init_params(tf.pdefs(self.cfg), jax.random.key(0),
                                    jnp.float32))
            like_opt = jax.eval_shape(adamw_init, like)
            (params, opt, step), _ = restore_checkpoint(
                self.tcfg.ckpt_dir, (like, like_opt, 0))
            self.params = jax.device_put(params, self._pshard)
            self.opt = jax.device_put(opt, self._oshard)
            self.step = int(step)
            return True
        key = jax.random.key(self.tcfg.seed)
        params = init_params(tf.pdefs(self.cfg), key, jnp.float32)
        self.params = jax.device_put(params, self._pshard)
        self.opt = jax.device_put(adamw_init(self.params), self._oshard)
        self.step = 0
        return False

    def _host_batch(self, step: int):
        tokens, targets = self.data.batch_at(step)
        return (jnp.asarray(tokens), jnp.asarray(targets))

    def run(self, on_step: Optional[Callable[[int, Dict], None]] = None):
        metrics_hist = []
        t0 = time.time()
        while self.step < self.tcfg.steps:
            tokens, targets = self._host_batch(self.step)
            self.params, self.opt, m = self.step_fn(
                self.params, self.opt, tokens, targets)
            self.step += 1
            if self.step % self.tcfg.log_every == 0 or \
                    self.step == self.tcfg.steps:
                m = {k: float(v) for k, v in m.items()}
                m["step"] = self.step
                m["wall_s"] = round(time.time() - t0, 2)
                metrics_hist.append(m)
                if on_step:
                    on_step(self.step, m)
            if self.tcfg.ckpt_dir and (
                    self.step % self.tcfg.ckpt_every == 0
                    or self.step == self.tcfg.steps):
                save_checkpoint(self.tcfg.ckpt_dir, self.step,
                                (self.params, self.opt, self.step))
        return metrics_hist
