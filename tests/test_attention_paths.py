"""Attention path coverage: grouped (no padding) vs padded-head layouts,
rolling local windows, GQA mapping."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer as tf
from repro.models import attention as attn
from repro.models.common import init_params


def _cfg(**kw):
    base = get_smoke_config("qwen3-14b")
    return dataclasses.replace(base, **kw)


def test_q_to_kv_map_groups():
    cfg = _cfg(n_heads=6, n_kv_heads=2, pad_heads_multiple=4)  # pad to 8
    m = attn._q_to_kv_map(cfg)
    assert m.shape == (8,)
    assert list(m[:6]) == [0, 0, 0, 1, 1, 1]
    assert not attn._grouped_ok(cfg)
    cfg2 = _cfg(n_heads=6, n_kv_heads=2, pad_heads_multiple=1)
    assert attn._grouped_ok(cfg2)


def test_padded_path_forward_and_grad_finite():
    """The padded-head path (production layout) must run and train."""
    cfg = _cfg(n_layers=2, n_heads=6, n_kv_heads=2, head_dim=16,
               d_model=48, d_ff=96, vocab=128, pad_heads_multiple=4,
               remat=False)
    assert cfg.padded_heads == 8
    params = init_params(tf.pdefs(cfg), jax.random.key(0), jnp.float32)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    targets = jnp.roll(tokens, -1, 1)
    loss, _ = tf.loss_fn(params, cfg, tokens, targets)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: tf.loss_fn(p, cfg, tokens, targets)[0])(params)
    assert all(np.isfinite(np.asarray(x, np.float32)).all()
               for x in jax.tree.leaves(g))


def test_grouped_vs_padded_same_when_pad_is_noop():
    """pad multiple that divides n_heads exactly: both paths must agree
    (same weights, padded==n_heads so only the einsum layout differs)."""
    cfg_g = _cfg(n_layers=1, n_heads=4, n_kv_heads=2, head_dim=16,
                 d_model=32, d_ff=64, vocab=64, pad_heads_multiple=1,
                 remat=False)
    cfg_p = dataclasses.replace(cfg_g, pad_heads_multiple=2)  # 4 -> 4
    assert attn._grouped_ok(cfg_g) and attn._grouped_ok(cfg_p)
    params = init_params(tf.pdefs(cfg_g), jax.random.key(0), jnp.float32)
    tokens = jax.random.randint(jax.random.key(1), (1, 8), 0, 64)
    a, _ = tf.fwd_train(params, cfg_g, tokens)
    b, _ = tf.fwd_train(params, cfg_p, tokens)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_forced_expansion_path_matches_grouped():
    """Force the kmap-expansion path on a config where grouped is valid:
    results must match the grouped einsum (same math, different layout)."""
    cfg = _cfg(n_layers=1, n_heads=4, n_kv_heads=2, head_dim=16,
               d_model=32, d_ff=64, vocab=64, remat=False)
    params = init_params(tf.pdefs(cfg), jax.random.key(2), jnp.float32)
    tokens = jax.random.randint(jax.random.key(3), (2, 12), 0, 64)
    out_grouped, _ = tf.fwd_train(params, cfg, tokens)
    try:
        attn._grouped_ok_orig = attn._grouped_ok
        attn._grouped_ok = lambda c: False
        out_expand, _ = tf.fwd_train(params, cfg, tokens)
    finally:
        attn._grouped_ok = attn._grouped_ok_orig
    np.testing.assert_allclose(np.asarray(out_grouped),
                               np.asarray(out_expand),
                               rtol=2e-5, atol=2e-5)


def test_local_rolling_buffer_long_decode():
    """Decode far past the window: rolling buffer must keep exactly the
    last `window` positions (compare against full-context forward)."""
    cfg = _cfg(n_layers=2, n_heads=4, n_kv_heads=2, head_dim=16,
               d_model=32, d_ff=64, vocab=64, local_window=8,
               pattern=("local",), remat=False)
    params = init_params(tf.pdefs(cfg), jax.random.key(4), jnp.float32)
    S = 24
    tokens = jax.random.randint(jax.random.key(5), (1, S + 1), 0, 64)
    full, _ = tf.fwd_train(params, cfg, tokens)
    # drive the decode path across 3 window wraps
    caches = tf.init_caches(cfg, 1, 64, jnp.float32)
    for t in range(S):
        logits, caches = tf.decode_step(params, cfg, caches,
                                        tokens[:, t:t + 1], jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[:, S - 1]),
                               rtol=2e-2, atol=2e-2)


def test_banded_local_equals_full_masked():
    """Banded sliding-window path must equal the full-S² masked path."""
    cfg = _cfg(n_layers=1, n_heads=4, n_kv_heads=2, head_dim=16,
               d_model=32, d_ff=64, vocab=64, local_window=8,
               pattern=("local",), remat=False)
    params = init_params(tf.pdefs(cfg), jax.random.key(8), jnp.float32)
    x = jax.random.normal(jax.random.key(9), (2, 32, 32), jnp.float32)
    lp = params["scan"]["pos0"]
    mix = jax.tree.map(lambda a: a[0], lp["mixer"])
    banded = attn.attn_fwd(mix, cfg, x, local=True)
    # kv_mask disables the banded fast path -> full masked attention
    full = attn.attn_fwd(mix, cfg, x, local=True,
                         kv_mask=jnp.ones((2, 32), bool))
    np.testing.assert_allclose(np.asarray(banded), np.asarray(full),
                               rtol=2e-5, atol=2e-5)
