"""Baseline preconditioners (ichol/AMG), SDD reduction, and the
distributed solver paths."""
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data import graphs
from repro.core.laplacian import (Graph, laplacian_dense,
                                  sdd_to_grounded_laplacian,
                                  laplacian_matvec_np)
from repro.core.ichol import ichol, jacobi_preconditioner
from repro.core.amg import smoothed_aggregation_preconditioner
from repro.core.pcg import laplacian_pcg_np
from repro.core.parac import factorize_wavefront
from repro.core.trisolve import precond_apply_np


@pytest.fixture(scope="module")
def g():
    return graphs.grid2d(14, 14, seed=5)


def _rhs(n, seed=0):
    b = np.random.default_rng(seed).normal(size=n)
    return b - b.mean()


def test_ichol0_preconditions(g):
    ic = ichol(g, droptol=0.0)
    b = _rhs(g.n)
    res = laplacian_pcg_np(g, ic.apply, b, tol=1e-7, maxiter=600)
    plain = laplacian_pcg_np(g, lambda r: r, b, tol=1e-7, maxiter=2000)
    assert res.converged and res.iters < plain.iters


def test_icholt_quality_better_than_ic0(g):
    ic0 = ichol(g, droptol=0.0)
    ict = ichol(g, droptol=0.02)
    b = _rhs(g.n)
    r0 = laplacian_pcg_np(g, ic0.apply, b, tol=1e-7, maxiter=600)
    rt = laplacian_pcg_np(g, ict.apply, b, tol=1e-7, maxiter=600)
    assert rt.iters <= r0.iters
    assert ict.nnz >= ic0.nnz


def test_amg_vcycle_preconditions(g):
    amg = smoothed_aggregation_preconditioner(g)
    b = _rhs(g.n)
    res = laplacian_pcg_np(g, amg, b, tol=1e-7, maxiter=200)
    assert res.converged and res.iters < 40


def test_sdd_reduction_solves_sdd_system():
    """Solve A x = b with A = L + diag(surplus) via the grounded graph."""
    g0 = graphs.grid2d(8, 8, seed=2)
    rng = np.random.default_rng(0)
    surplus = rng.uniform(0.0, 0.5, g0.n)
    surplus[rng.random(g0.n) < 0.7] = 0.0
    surplus[0] = 1.0                      # ensure nonsingular
    A = laplacian_dense(g0) + np.diag(surplus)
    gg = sdd_to_grounded_laplacian(np.diag(A), g0)
    assert gg.n == g0.n + 1
    b = rng.normal(size=g0.n)
    bg = np.concatenate([b, [-b.sum()]])  # grounded rhs (mean-zero)
    f = factorize_wavefront(gg, jax.random.key(0), fill_slack=64)
    res = laplacian_pcg_np(gg, lambda r: precond_apply_np(f, r), bg,
                           tol=1e-9, maxiter=400)
    xg = np.asarray(res.x)
    x = xg[:-1] - xg[-1]                  # ground node potential = 0
    np.testing.assert_allclose(A @ x, b, rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_distributed_solver_subprocess():
    """shard_map sharded-SpMV PCG + batched factorization on a forced
    8-device host mesh; batched factors must equal the single-device
    engine bitwise."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.data import graphs
from repro.core.dist import sharded_pcg, batched_factorize, make_sharded_matvec
from repro.core.parac import factorize_wavefront, _run_engine, _build_pool
from repro.core.trisolve import make_preconditioner
from repro.core.laplacian import laplacian_matvec_np

from repro.launch.mesh import mesh_axis_types
mesh = jax.make_mesh((8,), ("data",), **mesh_axis_types(1))
g = graphs.grid2d(12, 12, seed=1)

# sharded SpMV == host matvec
mv = make_sharded_matvec(g, mesh)
x = np.random.default_rng(0).normal(size=g.n).astype(np.float32)
y = np.asarray(jax.jit(mv)(jnp.asarray(x)))
yref = laplacian_matvec_np(g, x.astype(np.float64))
assert np.allclose(y, yref, rtol=2e-4, atol=2e-4), "spmv mismatch"

# sharded PCG converges with the parac preconditioner
f = factorize_wavefront(g, jax.random.key(0), fill_slack=64)
b = np.random.default_rng(1).normal(size=g.n).astype(np.float32)
b -= b.mean()
res = jax.jit(lambda bb: sharded_pcg(
    g, mesh, make_preconditioner(f), bb, tol=1e-5, maxiter=300))(jnp.asarray(b))
assert bool(res.converged), float(res.relres)

# batched factorization across the mesh == single-device engine bitwise
keys = jax.random.split(jax.random.key(7), 8)
out = batched_factorize(g, keys, mesh)
single = factorize_wavefront(g, keys[3], chunk=256, fill_slack=32)
(pool_row, pool_val, fill, dep, col_base, cap, P, dmax) = _build_pool(g, 32, np.float32)
pv = np.asarray(out.pool_val[3])
# compare column 0..n against the single run's pool values
assert np.array_equal(np.asarray(out.col_fill[3]),
                      np.asarray(single.col_ptr[1:] - single.col_ptr[:-1])), "fill"
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                         capture_output=True, text=True, timeout=600)
    assert "OK" in out.stdout, (out.stdout[-500:], out.stderr[-2000:])
