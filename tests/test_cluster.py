"""Solve cluster: routing-policy units, cluster bit-exactness vs direct
per-replica solves, affinity-hit economics, hot-factor replication with
TTL demotion, replica health ejection/re-admission, and the core cache
probes the router rides on."""
import numpy as np
import jax
import pytest

from repro.core.solver import FactorCache
from repro.data import graphs
from repro.serve import ClusterOverloadedError, SolveCluster
from repro.serve.cluster import (FactorAffinityRouting, LeastLoadedRouting,
                                 RoundRobinRouting, make_routing)

CACHE_KW = dict(chunk=32, fill_slack=64, strict=False)


@pytest.fixture(scope="module")
def gset():
    return {"g2d": graphs.grid2d(6, 6, seed=3),      # n = 36
            "road": graphs.road_like(6, seed=4),     # n = 36
            "pl": graphs.powerlaw(80, 4, seed=3)}    # n = 80


def _rhs(rng, n, nrhs=1):
    b = rng.normal(size=(nrhs, n) if nrhs > 1 else n).astype(np.float32)
    return b - b.mean(axis=-1, keepdims=True)


def _cluster(gset, **kw):
    kw.setdefault("replicas", 2)
    kw.setdefault("slots", 4)
    kw.setdefault("iters_per_tick", 8)
    kw.setdefault("cache_kw", CACHE_KW)
    cl = SolveCluster(**kw)
    for i, (name, g) in enumerate(gset.items()):
        cl.register(g, jax.random.key(i), graph_id=name)
    return cl


# ---------------------------------------------------------------------------
# Core cache probes (the read-only surface the router rides on)
# ---------------------------------------------------------------------------

def test_cache_fresh_and_capacity_probe(gset):
    now = [0.0]
    c = FactorCache(clock=lambda: now[0], max_handles=4, **CACHE_KW)
    c.factor(gset["road"], jax.random.key(0), graph_id="road", ttl_s=5.0)
    assert c.fresh("road") and not c.fresh("nope")
    p = c.capacity_probe()
    assert p["handles"] == 1 and p["free_handles"] == 3
    assert p["free_bytes"] is None          # no byte budget set
    assert p["device_bytes"] > 0
    now[0] = 6.0                            # past the TTL
    assert not c.fresh("road")
    assert "road" in c                      # fresh() never sweeps
    c.sweep_stale()
    assert "road" not in c                  # the sweep does


# ---------------------------------------------------------------------------
# Routing policies: pure unit semantics over stub replicas
# ---------------------------------------------------------------------------

class _Stub:
    def __init__(self, index, load=0, handles=0, free_rows=0):
        self.index = index
        self.load = load
        self._p = dict(handles=handles, free_handles=None,
                       device_bytes=0, free_bytes=None,
                       fleet_free_rows=free_rows)

    def capacity_probe(self):
        return self._p


def test_round_robin_cycles_and_ignores_state():
    p = RoundRobinRouting()
    a, b = _Stub(0, load=100), _Stub(1, load=0)
    picks = [p.choose("g", [b], [a, b]).index for _ in range(4)]
    assert picks == [0, 1, 0, 1]            # blind to holders and load


def test_p2c_prefers_lower_load():
    p = LeastLoadedRouting(seed=0)
    a, b = _Stub(0, load=9), _Stub(1, load=1)
    assert p.choose("g", [], [a, b]) is b   # 2 candidates: plain min
    c = _Stub(2, load=5)
    picks = {p.choose("g", [], [a, b, c]).index for _ in range(20)}
    assert 0 not in picks                   # the loaded one never wins p2c


def test_affinity_prefers_holders_then_capacity():
    p = FactorAffinityRouting()
    a, b = _Stub(0, load=7), _Stub(1, load=2)
    assert p.choose("g", [a], [a, b]) is a  # holder beats lighter load
    assert p.choose("g", [a, b], [a, b]) is b   # holders tie-break: load
    roomy = _Stub(2, handles=0, free_rows=3)
    full = _Stub(3, handles=5)
    assert p.choose("g", [], [full, roomy]) is roomy   # miss: capacity
    assert make_routing("affinity").name == "affinity"
    with pytest.raises(ValueError):
        make_routing("random")


# ---------------------------------------------------------------------------
# Acceptance: cluster serving is bit-exact with direct per-replica solves
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("routing", ["affinity", "rr"])
def test_cluster_bit_exact_mixed_trace(gset, routing):
    """The mixed 3-graph trace routed through a 2-replica cluster (any
    policy) yields per-request x/iters/relres **identical** to a direct
    ``FactorHandle.solve`` on whichever replica served each request —
    the cluster's signature invariant."""
    rng = np.random.default_rng(11)
    spec = [("g2d", 1, 1e-6), ("pl", 2, 1e-5), ("road", 1, 1e-6),
            ("g2d", 3, 1e-6), ("pl", 1, 1e-6), ("road", 2, 1e-5),
            ("g2d", 1, 1e-4), ("pl", 2, 1e-6)]
    blocks = [(gid, _rhs(rng, gset[gid].n, nr), tol)
              for gid, nr, tol in spec]
    with _cluster(gset, routing=routing) as cl:
        futs = [cl.submit(gid, b, tol=tol, maxiter=400)
                for gid, b, tol in blocks]
        done = [f.result(timeout=300) for f in futs]
        assert cl.drain(timeout=120)
        served = {r.replica for r in done}
        assert served <= {0, 1} and len(served) == 2   # both replicas
        for (gid, b, tol), req in zip(blocks, done):
            assert req.status == "converged" and req.replica >= 0
            rep = cl.replicas[req.replica]
            ref = rep.cache.get(gid).solve(np.atleast_2d(b), tol=tol,
                                           maxiter=400)
            assert np.array_equal(np.atleast_2d(req.x), np.asarray(ref.x))
            assert np.array_equal(np.atleast_1d(req.iters),
                                  np.asarray(ref.iters))
            assert np.array_equal(np.atleast_1d(req.relres),
                                  np.atleast_1d(np.asarray(ref.relres)))
        st = cl.stats()
        assert st.submitted == st.routed == len(spec) and st.shed == 0
        assert st.affinity_hits + st.affinity_misses == st.routed


def test_affinity_hit_rate_beats_rr_on_skewed_traffic(gset):
    """Skewed traffic (one hot graph): affinity pays one placement per
    graph; rr keeps landing graphs on replicas that don't hold them."""
    hit_rates = {}
    for routing in ("affinity", "rr"):
        rng = np.random.default_rng(7)
        gids = ["g2d", "road", "pl"]
        picks = [gids[i] for i in rng.choice(3, size=18, p=[.7, .2, .1])]
        with _cluster(gset, routing=routing) as cl:
            futs = [cl.submit(g, _rhs(rng, gset[g].n), tol=1e-4,
                              maxiter=300) for g in picks]
            for f in futs:
                f.result(timeout=300)
            st = cl.stats()
            hit_rates[routing] = st.hit_rate
            assert st.routed == len(picks)
    assert hit_rates["affinity"] > hit_rates["rr"]


# ---------------------------------------------------------------------------
# Hot-factor replication and TTL demotion
# ---------------------------------------------------------------------------

def test_hot_factor_replication_splits_then_demotes(gset):
    """A graph crossing the replication threshold is factored onto a
    second replica (TTL'd), traffic splits across both copies while it
    is hot, and the TTL expiry demotes the copy via the cache's own
    staleness sweep."""
    now = [0.0]
    with _cluster(gset, routing="affinity", replicate_above=3.0,
                  rate_window_s=1.0, replica_ttl_s=5.0,
                  clock=lambda: now[0]) as cl:
        rng = np.random.default_rng(5)
        n = gset["road"].n
        futs = [cl.submit("road", _rhs(rng, n), tol=1e-30, maxiter=100)
                for _ in range(8)]
        for f in futs:
            f.result(timeout=300)
        st = cl.stats()
        assert st.replications >= 1            # promoted to a 2nd replica
        # wait for the async twin factor to land on the second replica
        import time
        for _ in range(600):
            if any(rep.fresh("road") for rep in cl.replicas[1:]):
                break
            time.sleep(0.05)
        assert any(rep.fresh("road") for rep in cl.replicas[1:])
        # twin is live: a hot burst splits across both copies
        futs = [cl.submit("road", _rhs(rng, n), tol=1e-30, maxiter=100)
                for _ in range(6)]
        served = {f.result(timeout=300).replica for f in futs}
        assert served == {0, 1}                # traffic actually split
        st = cl.stats()
        assert st.hot_graphs == 1
        assert sum(r.placements for r in st.per_replica) == 2
        # TTL expiry: next route observes the stale copy and demotes
        now[0] = 10.0
        cl.submit("road", _rhs(rng, n), tol=1e-4,
                  maxiter=300).result(timeout=300)
        st = cl.stats()
        assert st.demotions >= 1 and st.hot_graphs == 0


# ---------------------------------------------------------------------------
# Health: ejection, re-admission, shed
# ---------------------------------------------------------------------------

def test_dead_replica_ejected_and_rerouted(gset):
    """A replica whose driver thread is gone is ejected (permanently)
    and its graphs re-place on the survivors — requests keep completing
    instead of blackholing."""
    with _cluster(gset, routing="affinity") as cl:
        rng = np.random.default_rng(3)
        n = gset["road"].n
        first = cl.submit("road", _rhs(rng, n), tol=1e-4,
                          maxiter=300).result(timeout=300)
        cl.replicas[first.replica].frontend.close(drain=True)  # wedge it
        second = cl.submit("road", _rhs(rng, n), tol=1e-4,
                           maxiter=300).result(timeout=300)
        assert second.replica != first.replica
        assert second.status == "converged"
        st = cl.stats()
        assert st.ejections == 1 and st.healthy == 1
        assert st.readmissions == 0            # dead drivers stay out


def test_overload_ejection_and_readmission(gset):
    """Backpressure rejections inside the health window eject a replica
    for the cooldown; it re-admits after.  Driven by an injected clock
    so the window/cooldown arithmetic is deterministic."""
    now = [0.0]
    cl = _cluster(gset, routing="affinity", replicas=2, slots=1,
                  max_queue=1, overload="reject", eject_rejections=1,
                  health_window_s=1.0, readmit_cooldown_s=2.0,
                  clock=lambda: now[0])
    try:
        rng = np.random.default_rng(9)
        n = gset["road"].n
        # a blocker pins replica 0's only lane; the next submit fills
        # its 1-deep queue, the one after rejects -> instant ejection
        blocker = cl.submit("road", _rhs(rng, n), tol=1e-30, maxiter=4000)
        futs = [blocker]
        ejected = False
        for _ in range(6):
            futs.append(cl.submit("road", _rhs(rng, n), tol=1e-4,
                                  maxiter=300))
            st = cl.stats()
            if st.ejections >= 1:
                ejected = True
                break
        assert ejected
        st = cl.stats()
        assert st.healthy == 1                 # replica 0 in cooldown
        # let the rerouted request finish so the survivor's 1-deep
        # queue is empty before the spillover submit
        futs[-1].result(timeout=300)
        spill = cl.submit("road", _rhs(rng, n), tol=1e-4, maxiter=300)
        assert spill.result(timeout=300).status == "converged"
        now[0] = 5.0                           # past the cooldown
        st = cl.stats()
        assert st.healthy == 2                 # routable again (pure read)
        assert st.readmissions == 0            # ...but stats never advances
        cl.submit("road", _rhs(rng, n), tol=1e-4,
                  maxiter=300).result(timeout=300)
        st = cl.stats()                        # a route re-admitted it
        assert st.healthy == 2 and st.readmissions == 1
    finally:
        cl.close(drain=False)


def test_all_replicas_down_sheds_with_cluster_overload(gset):
    with _cluster(gset, replicas=2) as cl:
        for rep in cl.replicas:
            rep.frontend.close(drain=True)
        rng = np.random.default_rng(1)
        with pytest.raises(ClusterOverloadedError):
            cl.submit("road", _rhs(rng, gset["road"].n))
        st = cl.stats()
        assert st.shed == 1 and st.healthy == 0
        assert st.submitted == st.routed + st.shed


def test_unregistered_graph_raises_keyerror_and_counts_shed(gset):
    with _cluster(gset) as cl:
        with pytest.raises(KeyError):
            cl.submit("mystery", np.zeros(8, np.float32))
        st = cl.stats()
        assert st.submitted == st.routed + st.shed == 1  # conservation
        assert not cl.router.placements                  # no stray entry


def test_routed_request_survives_eviction_before_engine_submit(gset):
    """The expiry race: a factor evicted between the router's freshness
    snapshot and the driver-side engine submit must not fail the
    request — the replica pins the routed handle on the request and the
    engine falls back to it."""
    from repro.core.solver import FactorCache
    from repro.serve import SolveEngine, SolveRequest
    c = FactorCache(**CACHE_KW)
    g = gset["road"]
    c.factor(g, jax.random.key(0), graph_id="road")
    eng = SolveEngine(c, slots=2, iters_per_tick=8)
    rng = np.random.default_rng(17)
    req = SolveRequest(rid=0, graph_id="road", b=_rhs(rng, g.n),
                       tol=1e-4, maxiter=300)
    req._handle = c.peek("road")     # what EngineReplica.submit does
    c.evict("road")                  # TTL sweep / LRU between route+submit
    eng.submit(req)
    done = eng.run_until_drained()
    assert done == [req] and req.status == "converged"
