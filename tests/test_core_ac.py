"""Core correctness: AC factorization, ParAC engine, solver stack."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.laplacian import Graph, laplacian_dense, laplacian_matvec_np
from repro.core.ref_ac import factorize_sequential
from repro.core.parac import factorize_wavefront
from repro.core.trisolve import (build_schedules, solve_levels_np,
                                 make_jax_solver, make_preconditioner,
                                 precond_apply_np)
from repro.core.pcg import laplacian_pcg_np, laplacian_pcg_jax
from repro.core.ordering import ORDERINGS
from repro.core import etree
from repro.data import graphs


KEY = jax.random.key(7)


@pytest.fixture(scope="module")
def g_small():
    return graphs.grid2d(12, 12, seed=3)


@pytest.fixture(scope="module")
def suite_small():
    return {
        "grid2d": graphs.grid2d(10, 11, seed=1),
        "grid3d": graphs.grid3d(5, 5, 5, "contrast", seed=2),
        "powerlaw": graphs.powerlaw(300, 5, seed=3),
        "road": graphs.road_like(12, seed=4),
    }


# ---------------------------------------------------------------------------
# Laplacian basics
# ---------------------------------------------------------------------------

def test_laplacian_psd_and_nullspace(g_small):
    L = laplacian_dense(g_small)
    assert np.allclose(L, L.T)
    assert np.allclose(L @ np.ones(g_small.n), 0, atol=1e-10)
    ev = np.linalg.eigvalsh(L)
    assert ev[0] > -1e-8


def test_matvec_matches_dense(g_small):
    L = laplacian_dense(g_small)
    x = np.random.default_rng(0).normal(size=g_small.n)
    assert np.allclose(laplacian_matvec_np(g_small, x), L @ x, rtol=1e-6)


# ---------------------------------------------------------------------------
# Factorization: oracle == engine bit-exact (the wavefront-schedule claim)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["grid2d", "grid3d", "powerlaw", "road"])
@pytest.mark.parametrize("chunk", [4, 64])
def test_engine_matches_oracle_exactly(suite_small, name, chunk):
    g = suite_small[name]
    fs = factorize_sequential(g, KEY)
    fp = factorize_wavefront(g, KEY, chunk=chunk, fill_slack=64)
    assert fp.stats["overflow"] == 0
    assert np.array_equal(fs.col_ptr, fp.col_ptr)
    assert np.array_equal(fs.rows, fp.rows)
    assert np.array_equal(fs.vals, fp.vals)
    assert np.array_equal(fs.D, fp.D)


@pytest.mark.parametrize("ordering", ["random", "nnz-sort", "amd-like"])
def test_engine_matches_oracle_under_orderings(g_small, ordering):
    perm = ORDERINGS[ordering](g_small, seed=0)
    gp = g_small.permute(perm)
    fs = factorize_sequential(gp, KEY)
    fp = factorize_wavefront(gp, KEY, chunk=16, fill_slack=64)
    assert np.array_equal(fs.rows, fp.rows)
    assert np.array_equal(fs.vals, fp.vals)


def test_expectation_of_factor_is_laplacian():
    g = graphs.grid2d(4, 4, seed=9)
    L = laplacian_dense(g)
    acc = np.zeros_like(L)
    S = 300
    for s in range(S):
        acc += factorize_sequential(g, jax.random.key(s)).dense_M()
    rel = np.abs(acc / S - L).max() / np.abs(L).max()
    assert rel < 0.1, rel


def test_factor_structure(g_small):
    f = factorize_sequential(g_small, KEY)
    # strictly lower triangular columns, D >= 0
    for c in range(f.n):
        rows = f.rows[f.col_ptr[c]:f.col_ptr[c + 1]]
        assert np.all(rows > c)
        assert np.all(np.diff(rows) > 0)  # sorted, unique
    assert np.all(f.D >= 0)
    # column sums of G (with implicit unit diagonal) are ~0: each column of
    # G is  e_k - w/ℓkk  with Σw = ℓkk  ⇒  1 + Σ vals = 0 ... vals are -w/ℓkk
    for c in range(f.n):
        vals = f.vals[f.col_ptr[c]:f.col_ptr[c + 1]]
        if vals.size:
            assert abs(1.0 + vals.sum()) < 1e-4


# ---------------------------------------------------------------------------
# Triangular solves + preconditioner
# ---------------------------------------------------------------------------

def test_trisolve_matches_dense(g_small):
    f = factorize_sequential(g_small, KEY)
    G = f.dense_G()
    rng = np.random.default_rng(1)
    b = rng.normal(size=f.n)
    fwd, bwd = build_schedules(f)
    y = solve_levels_np(fwd, b)
    assert np.allclose(G @ y, b, atol=1e-8)
    x = solve_levels_np(bwd, b, flip=True)
    assert np.allclose(G.T @ x, b, atol=1e-8)


def test_jax_trisolve_matches_np(g_small):
    f = factorize_sequential(g_small, KEY)
    fwd, bwd = build_schedules(f)
    b = np.random.default_rng(2).normal(size=f.n).astype(np.float32)
    ynp = solve_levels_np(fwd, b)
    yj = jax.jit(make_jax_solver(fwd))(jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(yj), ynp, rtol=2e-4, atol=2e-4)
    xnp = solve_levels_np(bwd, b, flip=True)
    xj = jax.jit(make_jax_solver(bwd, flip=True))(jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(xj), xnp, rtol=2e-4, atol=2e-4)


def test_precond_apply_consistency(g_small):
    f = factorize_sequential(g_small, KEY)
    r = np.random.default_rng(3).normal(size=f.n).astype(np.float32)
    r = (r - r.mean()).astype(np.float32)   # project onto range(M) = 1⊥
    znp = precond_apply_np(f, r)
    zj = jax.jit(make_preconditioner(f))(jnp.asarray(r))
    np.testing.assert_allclose(np.asarray(zj), znp, rtol=5e-4,
                               atol=5e-4 * np.abs(znp).max())
    # defining property: M (M⁺ r) = r on 1⊥ (M = G D Gᵀ is singular with
    # nullspace ≈ span(1); Gᵀ1 = e_n exactly in exact arithmetic)
    M = f.dense_M()
    resid = M @ znp - r
    resid -= resid.mean()
    assert np.linalg.norm(resid) / np.linalg.norm(r) < 1e-3


# ---------------------------------------------------------------------------
# PCG end-to-end
# ---------------------------------------------------------------------------

def _rand_rhs(n, seed=0):
    b = np.random.default_rng(seed).normal(size=n)
    return b - b.mean()


def test_pcg_with_parac_converges_fast(g_small):
    f = factorize_wavefront(g_small, KEY, fill_slack=64)
    b = _rand_rhs(g_small.n)
    res = laplacian_pcg_np(g_small, lambda r: precond_apply_np(f, r), b,
                           tol=1e-8, maxiter=300)
    assert res.converged
    # sanity: solution solves the system
    x = np.asarray(res.x)
    r = b - laplacian_matvec_np(g_small, x)
    assert np.linalg.norm(r) / np.linalg.norm(b) < 1e-7
    # plain CG (identity preconditioner) should need more iterations
    res_plain = laplacian_pcg_np(g_small, lambda r: r, b,
                                 tol=1e-8, maxiter=2000)
    assert res.iters < res_plain.iters


def test_pcg_jax_matches_np(g_small):
    f = factorize_wavefront(g_small, KEY, fill_slack=64)
    b = _rand_rhs(g_small.n).astype(np.float32)
    apply_j = make_preconditioner(f)
    res = jax.jit(lambda bb: laplacian_pcg_jax(g_small, apply_j, bb,
                                               tol=1e-5, maxiter=300))(
        jnp.asarray(b))
    assert bool(res.converged)
    x = np.asarray(res.x, np.float64)
    r = b - laplacian_matvec_np(g_small, x)
    assert np.linalg.norm(r) / np.linalg.norm(b) < 5e-5


# ---------------------------------------------------------------------------
# E-tree analysis (paper Fig. 4)
# ---------------------------------------------------------------------------

def test_etree_heights_ordering(g_small):
    perm = ORDERINGS["natural"](g_small)
    f = factorize_sequential(g_small.permute(perm), KEY)
    h_classical = etree.classical_etree_height(g_small, perm)
    h_actual = etree.actual_etree_height(f)
    # randomized sampling cuts dependencies: actual ≤ classical (Fig. 4)
    assert h_actual <= h_classical
    prof = etree.wavefront_profile(f)
    assert prof.sum() == g_small.n


def test_wavefront_rounds_match_levels(g_small):
    # with chunk ≥ n the engine's round count equals the level count
    f = factorize_wavefront(g_small, KEY, chunk=g_small.n, fill_slack=64)
    assert f.stats["rounds"] == etree.actual_etree_height(f)


# ---------------------------------------------------------------------------
# Orderings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(ORDERINGS))
def test_orderings_are_permutations(g_small, name):
    perm = ORDERINGS[name](g_small, seed=1) if name in ("random", "nnz-sort") \
        else ORDERINGS[name](g_small)
    assert np.array_equal(np.sort(perm), np.arange(g_small.n))
