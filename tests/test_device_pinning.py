"""Multi-device placement acceptance: on a forced 8-device host, a
disaggregated cluster pins each solve replica's fleet to its assigned
device, constructs on the factor replica's own device, and serving
through the cross-device adopt path stays bit-exact with the engine's
``step_compiles == buckets`` mega-batching invariant intact.

Runs in a subprocess because ``XLA_FLAGS=--xla_force_host_platform_
device_count`` must be set before the first jax import (device count
locks at init)."""
import subprocess
import sys

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import numpy as np, jax
from repro.data import graphs
from repro.serve import SolveCluster

assert jax.device_count() == 8, jax.device_count()
gs = {"g2d": graphs.grid2d(6, 6, seed=3),
      "road": graphs.road_like(6, seed=4)}      # both n=36: one bucket
cl = SolveCluster(replicas=2, factor_replicas=1, routing="affinity",
                  slots=4, iters_per_tick=8,
                  devices="cpu:1,cpu:2,cpu:3",
                  cache_kw=dict(chunk=32, fill_slack=64, strict=False))
try:
    for i, (name, g) in enumerate(gs.items()):
        cl.register(g, jax.random.key(i), graph_id=name)
    rng = np.random.default_rng(0)
    for name, g in gs.items():
        b = rng.normal(size=g.n).astype(np.float32)
        b -= b.mean()
        r = cl.submit(name, b, tol=1e-6, maxiter=300).result(timeout=600)
        assert r.status == "converged", r.status
        rep = cl.replicas[r.replica]
        ref = rep.cache.get(name).solve(np.atleast_2d(b), tol=1e-6,
                                        maxiter=300)
        assert np.array_equal(np.atleast_2d(r.x), np.asarray(ref.x)), \
            f"{name}: cross-device adopt broke bit-exactness"
    assert cl.drain(timeout=120)
    st = cl.stats()
    # construction ran on the factor tier's own pinned device and
    # arrived on the solve replicas only by adoption
    tier = st.factor_tier
    assert tier["per_replica"][0]["device"] == "TFRT_CPU_3", tier
    assert sum(w["factored"] for w in tier["per_replica"]) == 2, tier
    assert st.adoptions == 2, st.adoptions
    # every solve replica's fleet bytes live on ITS assigned device
    assigned = ["TFRT_CPU_1", "TFRT_CPU_2"]
    placed = 0
    for rep, want in zip(cl.replicas, assigned):
        assert str(rep.device) == want, (str(rep.device), want)
        cs = rep.cache.stats()
        assert cs["device"] == want, cs["device"]
        bydev = cs["fleet_device_bytes_by_device"]
        if bydev:
            placed += 1
            assert set(bydev) == {want}, (bydev, want)
            assert all(v > 0 for v in bydev.values()), bydev
        # mega-batching survives pinning: one bucket, one step compile
        es = rep.frontend.stats().engine
        assert es.step_compiles == es.buckets, \
            (es.step_compiles, es.buckets)
    assert placed >= 1, "no fleet bytes resident anywhere"
finally:
    cl.close(drain=False)
print("OK")
"""


def test_cluster_device_pinning_subprocess():
    out = subprocess.run([sys.executable, "-c", _CHILD], cwd="/root/repo",
                         capture_output=True, text=True, timeout=900)
    assert "OK" in out.stdout, (out.stdout[-500:], out.stderr[-2000:])
