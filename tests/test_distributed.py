"""Structural tests for the sharding layer (no compilation needed):
spec trees must match value trees for every arch × cell, divisibility
rules must hold on the production mesh shapes."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import list_archs, get_config
from repro.configs.shapes import SHAPES, cell_applicable
from repro.models import transformer as tf
from repro.models.common import (abstract_params, param_pspecs,
                                 rules_for_mesh, DEFAULT_RULES)
from repro.distributed.steps import (cache_pspecs, batch_axes_for,
                                     kv_seq_axes)


class FakeMesh:
    """Mesh stand-in: shape dict + axis names (no devices needed)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESHES = {
    "16x16": FakeMesh({"data": 16, "model": 16}),
    "2x16x16": FakeMesh({"pod": 2, "data": 16, "model": 16}),
}


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh_name", list(MESHES))
def test_param_spec_tree_matches(arch, mesh_name):
    mesh = MESHES[mesh_name]
    cfg = get_config(arch, production=True)
    params = abstract_params(tf.pdefs(cfg))
    specs = param_pspecs(tf.pdefs(cfg), rules_for_mesh(mesh), mesh)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("cellname", ["decode_32k", "long_500k"])
def test_cache_spec_tree_matches(arch, cellname):
    mesh = MESHES["16x16"]
    cfg = get_config(arch, production=True)
    cell = SHAPES[cellname]
    ok, _ = cell_applicable(cfg, cell)
    if not ok:
        pytest.skip("cell not applicable")
    caches = jax.eval_shape(
        lambda: tf.init_caches(cfg, cell.global_batch, cell.seq_len,
                               jnp.bfloat16))
    specs = cache_pspecs(cfg, mesh, cell.global_batch, cell.seq_len)
    assert jax.tree.structure(caches) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P))


@pytest.mark.parametrize("arch", list_archs())
def test_probe_cfg_cache_spec_tree_matches(arch):
    """The dry-run probe configs (force_unroll) must also line up —
    regression test for the probe pytree bug."""
    mesh = MESHES["16x16"]
    cfg = get_config(arch, production=True)
    period = len(cfg.pattern)
    probe = dataclasses.replace(cfg, n_layers=period, force_unroll=True)
    cell = SHAPES["decode_32k"]
    caches = jax.eval_shape(
        lambda: tf.init_caches(probe, cell.global_batch, cell.seq_len,
                               jnp.bfloat16))
    specs = cache_pspecs(probe, mesh, cell.global_batch, cell.seq_len)
    assert jax.tree.structure(caches) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P))


def test_batch_axes_assignment():
    m1, m2 = MESHES["16x16"], MESHES["2x16x16"]
    assert batch_axes_for(m1, 256) == ("data",)
    assert batch_axes_for(m2, 256) == ("pod", "data")
    assert batch_axes_for(m1, 1) == ()
    assert batch_axes_for(m2, 32) == ("pod", "data")
    assert batch_axes_for(m2, 2) == ("pod",)


def test_kv_seq_axes_avoid_batch_axes():
    m = MESHES["2x16x16"]
    assert kv_seq_axes(m, 128) == ["model"]          # batch takes pod+data
    assert kv_seq_axes(m, 1) == ["model", "pod", "data"]


@pytest.mark.parametrize("arch", list_archs())
def test_production_divisibility(arch):
    """Every padded production config must shard cleanly on both meshes
    (hard axes raise; kv_heads is soft)."""
    cfg = get_config(arch, production=True)
    for mesh in MESHES.values():
        param_pspecs(tf.pdefs(cfg), rules_for_mesh(mesh), mesh)
    assert cfg.padded_vocab % 256 == 0
    if cfg.n_heads:
        assert cfg.padded_heads % 16 == 0
