"""Disaggregated factor tier: bit-exactness through the adopt path,
in-flight factor dedup (no double construction), burst coalescing into
one batched factorization, dead-target adoption failover, and the
control-channel visibility the colocated-vs-disaggregated comparison is
measured with."""
import concurrent.futures as cf
import threading
import time

import numpy as np
import jax
import pytest

from repro.data import graphs
from repro.serve import SolveCluster

CACHE_KW = dict(chunk=32, fill_slack=64, strict=False)


@pytest.fixture(scope="module")
def gset():
    return {"g2d": graphs.grid2d(6, 6, seed=3),      # n = 36
            "road": graphs.road_like(6, seed=4),     # n = 36
            "pl": graphs.powerlaw(80, 4, seed=3)}    # n = 80


def _rhs(rng, n, nrhs=1):
    b = rng.normal(size=(nrhs, n) if nrhs > 1 else n).astype(np.float32)
    return b - b.mean(axis=-1, keepdims=True)


def _cluster(gset, **kw):
    kw.setdefault("replicas", 2)
    kw.setdefault("factor_replicas", 1)
    kw.setdefault("slots", 4)
    kw.setdefault("iters_per_tick", 8)
    kw.setdefault("cache_kw", CACHE_KW)
    cl = SolveCluster(**kw)
    for i, (name, g) in enumerate(gset.items()):
        cl.register(g, jax.random.key(i), graph_id=name)
    return cl


# ---------------------------------------------------------------------------
# Acceptance: serving through the factor-tier adopt path stays bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("routing", ["affinity", "rr"])
def test_tier_bit_exact_mixed_trace(gset, routing):
    """The mixed trace served by a disaggregated cluster (every factor
    constructed on the tier, adopted cross-thread onto its serving
    replica) yields per-request x/iters/relres identical to a direct
    ``FactorHandle.solve`` on the serving replica's cache — the
    cluster's signature invariant survives disaggregation."""
    rng = np.random.default_rng(11)
    spec = [("g2d", 1, 1e-6), ("pl", 2, 1e-5), ("road", 1, 1e-6),
            ("g2d", 3, 1e-6), ("pl", 1, 1e-6), ("road", 2, 1e-5)]
    blocks = [(gid, _rhs(rng, gset[gid].n, nr), tol)
              for gid, nr, tol in spec]
    with _cluster(gset, routing=routing) as cl:
        futs = [cl.submit(gid, b, tol=tol, maxiter=400)
                for gid, b, tol in blocks]
        done = [f.result(timeout=600) for f in futs]
        assert cl.drain(timeout=120)
        for (gid, b, tol), req in zip(blocks, done):
            assert req.status == "converged" and req.replica >= 0
            rep = cl.replicas[req.replica]
            ref = rep.cache.get(gid).solve(np.atleast_2d(b), tol=tol,
                                           maxiter=400)
            assert np.array_equal(np.atleast_2d(req.x), np.asarray(ref.x))
            assert np.array_equal(np.atleast_1d(req.iters),
                                  np.asarray(ref.iters))
            assert np.array_equal(np.atleast_1d(req.relres),
                                  np.atleast_1d(np.asarray(ref.relres)))
        st = cl.stats()
        # every construction ran on the tier and arrived by adoption:
        # the serving drivers never factored
        tier = st.factor_tier
        factored = sum(w["factored"] for w in tier["per_replica"])
        assert factored == st.adoptions >= len(gset)
        assert all(r.cache["misses"] == 0 for r in st.per_replica)


# ---------------------------------------------------------------------------
# Satellite: concurrent routes dedupe onto one in-flight construction
# ---------------------------------------------------------------------------

def test_concurrent_cold_routes_ride_one_factorization(gset):
    """N concurrent cold submits for the same graph must produce exactly
    one tier construction — later routes ride the pending future
    (counted as ``factor_dedups``) and serve bit-identically."""
    N = 4
    rng = np.random.default_rng(3)
    b = _rhs(rng, gset["road"].n)
    with _cluster(gset, routing="affinity") as cl:
        with cf.ThreadPoolExecutor(max_workers=N) as pool:
            outer = [pool.submit(
                lambda: cl.submit("road", b, tol=1e-6,
                                  maxiter=300).result(timeout=600))
                for _ in range(N)]
            done = [f.result(timeout=600) for f in outer]
        st = cl.stats()
        tier = st.factor_tier
        assert tier["enqueued"] == 1                  # one construction
        assert sum(w["factored"] for w in tier["per_replica"]) == 1
        assert st.factor_dedups >= N - 1              # the rest rode it
        assert st.adoptions == 1
        xs = {np.asarray(r.x).tobytes() for r in done}
        assert len(xs) == 1                           # identical serving
        assert all(r.status == "converged" for r in done)


def test_tier_coalesces_burst_and_dedups_siblings(gset, monkeypatch):
    """A burst of distinct cold graphs drains as a single coalesced
    ``factorize_batched`` call; a duplicate placement id arriving while
    the job is queued becomes a sibling (construction shared, adoption
    separate).  The worker is gated until the whole burst is queued so
    the batch composition is deterministic."""
    import repro.serve.cluster.factor_tier as ft
    gate = threading.Event()
    orig_take = ft.FactorTier._take_batch
    monkeypatch.setattr(ft.FactorTier, "_take_batch",
                        lambda self: (gate.wait(60), orig_take(self))[1])
    rep = ft.EngineReplica(0, slots=4, cache_kw=CACHE_KW)
    tier = ft.FactorTier(1, chunk=CACHE_KW["chunk"],
                         fill_slack=CACHE_KW["fill_slack"], strict=False)
    try:
        names = ["g2d", "road", "pl"]
        futs = [tier.submit(n, gset[n], jax.random.key(i), target=rep)
                for i, n in enumerate(names)]
        # duplicate gid while its job is still queued: rides the
        # existing job instead of enqueueing a second build
        futs.append(tier.submit("pl", gset["pl"], jax.random.key(2),
                                target=rep))
        assert tier.queue_depth == 3      # dedup never lengthens queue
        gate.set()
        handles = [f.result(timeout=600) for f in futs]
        s = tier.stats()
        assert s["enqueued"] == 3 and s["dedups"] == 1
        assert s["adoptions"] == 4        # 3 jobs + 1 sibling adoption
        # the whole burst drained in ONE construction call
        w = s["per_replica"][0]
        assert w["factored"] == 3 and w["batches"] == 1
        assert s["coalesced_factorizations"] == 3
        assert s["factor_queue_depth"] == 0
        # deduped twin got the same resident handle
        assert handles[2] is handles[3]
        assert rep.cache.adoptions == 3   # sibling was a cache hit
    finally:
        tier.close()
        rep.close(drain=False)


# ---------------------------------------------------------------------------
# Satellite: pending factor futures fail over off a dead target
# ---------------------------------------------------------------------------

def test_adoption_fails_over_when_target_dies_mid_factorization(
        gset, monkeypatch):
    """Regression for the tier-less failure mode where a pending factor
    future died with its target's driver: crash the placement target
    while its construction is still on the tier — the finished payload
    must re-target to the healthy replica, the placement must move with
    it, and the request must serve there bit-exactly."""
    import repro.serve.cluster.factor_tier as ft
    killed = threading.Event()
    real = ft.factorize_batched
    # hold the tier's construction until the target replica is dead, so
    # the adoption deterministically lands on a crashed driver
    monkeypatch.setattr(
        ft, "factorize_batched",
        lambda *a, **kw: (killed.wait(60), real(*a, **kw))[1])
    rng = np.random.default_rng(5)
    b = _rhs(rng, gset["pl"].n)
    with _cluster(gset, routing="affinity") as cl:
        with cf.ThreadPoolExecutor(max_workers=1) as pool:
            outer = pool.submit(
                lambda: cl.submit("pl", b, tol=1e-6,
                                  maxiter=300).result(timeout=600))
            # wait for the router to record the pending placement, then
            # kill that exact replica while the tier is constructing
            target = None
            for _ in range(600):
                with cl._lock:
                    pl = cl.router.placements.get("pl")
                    if pl:
                        target = next(iter(pl))
                        break
                time.sleep(0.01)
            assert target is not None
            cl.replicas[target].frontend.close(drain=False)
            killed.set()
            res = outer.result(timeout=600)
        survivor = 1 - target
        assert res.status == "converged" and res.replica == survivor
        st = cl.stats()
        assert st.factor_tier["failovers"] == 1
        assert st.ejections == 1
        # the placement moved: live on the survivor, gone from the dead
        with cl._lock:
            pl = dict(cl.router.placements["pl"])
        assert pl == {survivor: None}
        ref = cl.replicas[survivor].cache.get("pl").solve(
            np.atleast_2d(b), tol=1e-6, maxiter=300)
        assert np.array_equal(np.atleast_2d(res.x), np.asarray(ref.x))


# ---------------------------------------------------------------------------
# Satellite: control-channel stats measure the driver stall directly
# ---------------------------------------------------------------------------

def test_frontend_control_channel_stats(gset):
    """``control_calls``/``control_s`` accumulate driver time spent in
    ``call()`` work and ``factor_queue_depth`` exposes the waiting
    control backlog — the counters the factor-storm gate compares."""
    from repro.core.solver import FactorCache
    from repro.serve import SolveEngine, SolveFrontend
    eng = SolveEngine(FactorCache(**CACHE_KW), slots=2)
    with SolveFrontend(eng, max_queue=8) as fe:
        st = fe.stats()
        assert st.control_calls == 0 and st.control_s == 0.0
        assert st.factor_queue_depth == 0
        gate = fe.call(time.sleep, 0.05)      # holds the driver
        queued = fe.call(lambda: 7)           # waits behind it
        assert queued.result(timeout=30) == 7 and gate.result(timeout=30) \
            is None
        st = fe.stats()
        assert st.control_calls == 2
        assert st.control_s >= 0.05
        assert st.factor_queue_depth == 0     # drained
        assert st.as_dict()["control_s"] == st.control_s
    # cluster surfacing: the per-replica FrontendStats nest the counters
    with _cluster(gset, factor_replicas=0) as cl:
        s = cl.stats().per_replica[0].frontend
        assert hasattr(s, "control_calls") and hasattr(s, "control_s")
