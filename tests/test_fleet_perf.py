"""Padding-tax machinery: the kernel runtime resolver (interpret vs
native, ``pad_k`` tiling), K-tiered fleet bucketing, the fleet row
free-list, and stack compaction — including bit-identity of solves
across a compaction and an in-flight engine lane surviving one."""
import gc

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.solver import FactorCache
from repro.kernels import runtime
from repro.serve import SolveEngine, SolveRequest
from repro.data import graphs


def _rhs(rng, n, nrhs=1):
    b = rng.normal(size=(nrhs, n) if nrhs > 1 else n).astype(np.float32)
    return b - b.mean(axis=-1, keepdims=True)


# ---------------------------------------------------------------------------
# Runtime resolver: env matrix + pad_k tiling policy
# ---------------------------------------------------------------------------

def test_resolver_env_matrix(monkeypatch):
    """REPRO_PALLAS_INTERPRET spellings, junk rejection, and the
    explicit-argument override; cache refreshed around each change."""
    for raw, want in (("1", True), ("true", True), ("YES", True),
                      (" on ", True), ("0", False), ("false", False),
                      ("No", False), ("off", False)):
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", raw)
        runtime.refresh()
        assert runtime.default_interpret() is want, raw
        # explicit argument always wins over the env
        assert runtime.resolve_interpret(not want) is (not want)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "maybe")
    runtime.refresh()
    with pytest.raises(ValueError, match="REPRO_PALLAS_INTERPRET"):
        runtime.default_interpret()
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    runtime.refresh()
    # unset: backend autodetect (this suite runs on CPU → interpret)
    assert runtime.default_interpret() is (
        jax.default_backend() not in ("gpu", "tpu", "cuda", "rocm"))
    runtime.refresh()


def test_pad_k_pow2_edges_interpret(monkeypatch):
    """Interpret-mode tiers are the historical pow2 rounding — exact at
    powers, bumping one past them."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    runtime.refresh()
    for k, want in ((1, 1), (2, 2), (3, 4), (4, 4), (5, 8), (8, 8),
                    (9, 16), (16, 16), (17, 32), (65, 128)):
        assert runtime.pad_k(k) == want, k
    assert runtime.pad_k(0) == 1          # degenerate width still pads
    runtime.refresh()


def test_pad_k_lane_multiple_native(monkeypatch):
    """Native lowering rounds panel widths up to the lane multiple so
    ``(rows, K)`` tiles stay lane-aligned; ``REPRO_PALLAS_LANE``
    overrides the quantum."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    runtime.refresh()
    assert runtime.pad_k(1) == 128
    assert runtime.pad_k(128) == 128
    assert runtime.pad_k(129) == 256
    monkeypatch.setenv("REPRO_PALLAS_LANE", "32")
    assert runtime.pad_k(1) == 32
    assert runtime.pad_k(33) == 64
    runtime.refresh()


# ---------------------------------------------------------------------------
# K-tiered bucketing: fleets split by panel width, engine follows
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mixed_k_suite():
    """One shape bucket, two panel-width populations: a hub-heavy
    powerlaw graph (fat ELL panels) and two low-degree graphs."""
    gs = {"hub": graphs.powerlaw(220, 12, seed=5),
          "mesh": graphs.grid2d(15, 15, seed=3),
          "road": graphs.road_like(15, seed=4)}
    keys = {name: jax.random.key(i) for i, name in enumerate(gs)}
    return gs, keys


def test_k_tier_splits_one_shape_bucket(mixed_k_suite):
    gs, keys = mixed_k_suite
    c = FactorCache(strict=False)
    c.factor_batched(list(gs.values()), [keys[k] for k in gs],
                     graph_ids=list(gs))
    fkeys = sorted(c.fleets)
    assert len({n_pad for _, n_pad, _ in fkeys}) == 1   # one shape bucket
    tiers = sorted({kt for _, _, kt in fkeys})
    assert len(tiers) == 2                 # hub split away from low-degree
    for kt in tiers:                       # pow2 tiers on interpret runs
        assert kt == runtime._next_pow2(kt)
    # every fleet's stacked panel width fits (and tightly: re-padding
    # the widest member reproduces the tier, so no fleet is oversized)
    for fleet in c.fleets.values():
        assert max(fleet.Kf, fleet.Kb) <= fleet.k_tier
        assert runtime.pad_k(max(fleet.Kf, fleet.Kb)) == fleet.k_tier
    # members of one fleet really share the tier key
    for gid in gs:
        h = c.get(gid)
        assert c.fleets[(h.family, h.n_pad, h.fleet.k_tier)] is h.fleet


def test_untiered_cache_merges_and_engine_buckets_follow(mixed_k_suite):
    """k_tiering=False restores the single merged fleet (tier 0), and
    the engine compiles one step program per (family, n_pad, K_tier)
    bucket in both modes — the ``step_compiles == buckets`` invariant
    under the new key."""
    gs, keys = mixed_k_suite
    rng = np.random.default_rng(11)
    B = {gid: _rhs(rng, g.n) for gid, g in gs.items()}   # shared rhs
    results = {}
    for tiering, want_buckets in ((True, 2), (False, 1)):
        c = FactorCache(strict=False, k_tiering=tiering)
        c.factor_batched(list(gs.values()), [keys[k] for k in gs],
                         graph_ids=list(gs))
        assert len(c.fleets) == want_buckets
        eng = SolveEngine(c, slots=4, iters_per_tick=8)
        for rid, gid in enumerate(gs):
            eng.submit(SolveRequest(rid=rid, graph_id=gid, b=B[gid],
                                    tol=1e-6, maxiter=300))
        done = eng.run_until_drained()
        assert len(done) == 3 and all(r.converged for r in done)
        st = eng.stats()
        assert st.buckets == want_buckets
        assert st.step_compiles == st.buckets
        assert set(eng._buckets) == set(c.fleets)
        results[tiering] = {r.rid: np.asarray(r.x) for r in done}
    # tiering only changes panel padding; the answers agree to solver
    # tolerance (bit-identity is not guaranteed ACROSS tiers — a wider
    # zero-padded panel reduces in a different tree shape — the
    # bit-exact contract is served == direct solve WITHIN a fleet)
    for rid in results[True]:
        assert np.allclose(results[True][rid], results[False][rid],
                           rtol=1e-3, atol=1e-4)


def test_tiered_engine_skips_padded_sweeps(mixed_k_suite):
    """The per-lane level bounds show up in the counters: serving the
    shallow low-degree graphs skips the sweeps their bucket ceiling
    would have launched, and the tiered engine does strictly less
    padded sweep work than the merged fleet on the same requests."""
    gs, keys = mixed_k_suite
    rng = np.random.default_rng(12)
    B = {gid: _rhs(rng, g.n) for gid, g in gs.items()}   # shared rhs
    elements = {}
    for tiering in (True, False):
        c = FactorCache(strict=False, k_tiering=tiering)
        c.factor_batched(list(gs.values()), [keys[k] for k in gs],
                         graph_ids=list(gs))
        eng = SolveEngine(c, slots=4, iters_per_tick=8)
        for rid, gid in enumerate(gs):
            eng.submit(SolveRequest(rid=rid, graph_id=gid, b=B[gid],
                                    tol=1e-6, maxiter=300))
        done = eng.run_until_drained()
        assert all(r.converged for r in done)
        st = eng.stats()
        assert st.sweep_elements > 0
        elements[tiering] = st.sweep_elements
    assert elements[True] < elements[False]


# ---------------------------------------------------------------------------
# Free-list row recycling
# ---------------------------------------------------------------------------

def test_free_list_recycles_lowest_rows_first():
    gs = [graphs.grid2d(12, 12, seed=i) for i in range(6)]
    keys = [jax.random.key(i) for i in range(6)]
    c = FactorCache(strict=False, compact_threshold=None)
    for i in range(4):
        c.factor(gs[i], keys[i], graph_id=f"g{i}")
    fleet = next(iter(c.fleets.values()))
    assert [c.get(f"g{i}").fleet_row for i in range(4)] == [0, 1, 2, 3]
    assert fleet.free_rows == 0
    c.evict("g2")
    c.evict("g1")
    gc.collect()                           # weakref callbacks free rows
    assert fleet.free_rows == 2
    assert fleet.live_rows == 2
    # recycled rows come back lowest-first, before any fresh row
    h4 = c.factor(gs[4], keys[4], graph_id="g4")
    h5 = c.factor(gs[5], keys[5], graph_id="g5")
    assert (h4.fleet_row, h5.fleet_row) == (1, 2)
    assert fleet.free_rows == 0


# ---------------------------------------------------------------------------
# Stack compaction
# ---------------------------------------------------------------------------

def test_compaction_bit_identical_solves():
    """Evict most of a fleet, compact, and every surviving handle's
    solve is bit-identical to its pre-compaction answer — row indices
    moved, values didn't."""
    gs = [graphs.grid2d(12, 12, seed=i) for i in range(6)]
    keys = [jax.random.key(i) for i in range(6)]
    c = FactorCache(strict=False, compact_threshold=None)
    c.factor_batched(gs, keys, graph_ids=[f"g{i}" for i in range(6)])
    fleet = next(iter(c.fleets.values()))
    rng = np.random.default_rng(7)
    B = {gid: jnp.asarray(_rhs(rng, 144, 2)) for gid in ("g0", "g5")}
    before = {gid: c.get(gid).solve(B[gid], tol=1e-8, maxiter=200)
              for gid in B}
    for gid in ("g1", "g2", "g3", "g4"):
        c.evict(gid)
    gc.collect()
    cap_before, gen_before = fleet.capacity, fleet.generation
    assert c.compact() >= 1                # at least one fleet shrank
    assert fleet.capacity < cap_before
    assert fleet.generation == gen_before + 1
    assert fleet.capacity >= fleet.live_rows == 2
    for gid, ref in before.items():
        got = c.get(gid).solve(B[gid], tol=1e-8, maxiter=200)
        assert np.array_equal(np.asarray(got.x), np.asarray(ref.x)), gid
        assert np.array_equal(np.asarray(got.iters),
                              np.asarray(ref.iters)), gid
    stats = c.stats()
    assert stats["compactions"] >= 1
    assert stats["fleet_device_bytes"] == stats["fleet_live_bytes"]


def test_compaction_threshold_triggers_on_evict():
    """The automatic path: crossing the free-fraction threshold during
    eviction compacts without an explicit call."""
    gs = [graphs.grid2d(12, 12, seed=i) for i in range(4)]
    keys = [jax.random.key(i) for i in range(4)]
    c = FactorCache(strict=False, compact_threshold=0.5)
    c.factor_batched(gs, keys, graph_ids=[f"g{i}" for i in range(4)])
    fleet = next(iter(c.fleets.values()))
    assert fleet.capacity == 4
    for gid in ("g1", "g2", "g3"):
        c.evict(gid)
    gc.collect()
    # the last evict saw free/capacity >= 0.5 and compacted in-line;
    # a final explicit pass must then be a no-op
    assert c.compactions >= 1
    assert fleet.capacity == 1 and fleet.live_rows == 1
    assert c.compact() == 0


def test_compaction_with_in_flight_lane():
    """A handle pinned by an occupied engine lane survives a compaction
    mid-solve: the engine re-syncs its resident row indices against the
    rebuilt stacks and the finished solve matches the direct
    ``PreconditionerHandle.solve`` answer bit for bit."""
    gs = [graphs.grid2d(12, 12, seed=i) for i in range(4)]
    keys = [jax.random.key(i) for i in range(4)]
    c = FactorCache(strict=False, compact_threshold=None)
    c.factor_batched(gs, keys, graph_ids=[f"g{i}" for i in range(4)])
    fleet = next(iter(c.fleets.values()))
    rng = np.random.default_rng(9)
    b = _rhs(rng, 144)
    # park g3 (a non-zero row, so compaction must move it) mid-solve
    eng = SolveEngine(c, slots=2, iters_per_tick=2)
    eng.submit(SolveRequest(rid=0, graph_id="g3", b=b, tol=1e-6,
                            maxiter=200))
    done = eng.tick()
    assert not done and eng.busy           # genuinely in flight
    row_before = c.get("g3").fleet_row
    assert row_before > 0
    for gid in ("g0", "g1", "g2"):
        c.evict(gid)
    gc.collect()
    assert c.compact() >= 1
    assert c.get("g3").fleet_row != row_before
    while eng.busy:
        done += eng.tick()
    assert len(done) == 1 and done[0].converged
    assert eng.stats().fleet_resyncs >= 1
    ref = c.get("g3").solve(jnp.asarray(np.atleast_2d(b)), tol=1e-6,
                            maxiter=200)
    assert np.array_equal(np.atleast_2d(done[0].x), np.asarray(ref.x))
    assert fleet.capacity == 1             # shrank under the live lane
