"""Forensic observability: the flight recorder's bounded ring and
post-mortem dumps (incident-triggered and explicit), numerical-health
drift detection wired through to selector quarantine, incident capture
on an injected driver crash and a sustained-overload flip, and the
fleet dashboard's scrape/summarize/render pipeline."""
import io
import json
import threading
import time
from types import SimpleNamespace

import numpy as np
import jax
import pytest

from repro.core.solver import FactorCache
from repro.data import graphs
from repro.launch import top
from repro.obs import (FlightRecorder, HealthMonitor, MetricsRegistry,
                       MetricsServer, NULL_FLIGHT, render)
from repro.serve import SolveCluster, SolveEngine, SolveFrontend
from repro.serve.cluster.selector import AdaptiveSelector

CACHE_KW = dict(chunk=32, fill_slack=64, strict=False)


def _read_dump(path):
    with open(path) as fh:
        return [json.loads(line) for line in fh if line.strip()]


# ---------------------------------------------------------------------------
# Ring buffer semantics
# ---------------------------------------------------------------------------

def test_ring_bounds_memory_and_counts_drops():
    fl = FlightRecorder(capacity=4)
    ev = fl.bind("admit", replica=0)
    for i in range(10):
        ev(rid=i)
    evs = fl.events()
    assert len(evs) == 4                      # bounded: oldest fell off
    assert [e["rid"] for e in evs] == [6, 7, 8, 9]
    st = fl.stats()
    assert st["recorded"] == 10 and st["dropped"] == 6
    assert fl.events(last=2)[0]["rid"] == 8


def test_bound_event_merges_static_and_call_fields():
    fl = FlightRecorder()
    fl.bind("retire", replica=3, component="engine")(
        rid=7, trace_id="t000001", status="converged")
    (e,) = fl.events()
    assert e["kind"] == "retire" and e["replica"] == 3
    assert e["component"] == "engine" and e["rid"] == 7
    assert e["trace_id"] == "t000001"
    assert e["seq"] == 1 and isinstance(e["t"], float)


def test_null_flight_is_inert():
    NULL_FLIGHT.bind("admit", replica=0)(rid=1)
    NULL_FLIGHT.record("retire", rid=1)
    NULL_FLIGHT.incident("whatever")
    assert NULL_FLIGHT.dump("whatever") is None
    assert NULL_FLIGHT.events() == []
    assert NULL_FLIGHT.stats()["recorded"] == 0
    assert NULL_FLIGHT.flush() is True


def test_concurrent_recording_loses_nothing_and_tears_nothing():
    """8 threads x 2000 bound-event records: every event lands exactly
    once (unique, gapless seqs) and every event carries both its static
    and per-call fields — no lost updates, no torn dicts."""
    n_threads, per_thread = 8, 2000
    fl = FlightRecorder(capacity=n_threads * per_thread)
    evs = [fl.bind("admit", thread=k) for k in range(n_threads)]

    def work(k):
        for i in range(per_thread):
            evs[k](i=i, trace_id=f"t{k}:{i}")

    threads = [threading.Thread(target=work, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    st = fl.stats()
    assert st["recorded"] == n_threads * per_thread
    assert st["dropped"] == 0
    out = fl.events()
    assert len(out) == n_threads * per_thread
    assert sorted(e["seq"] for e in out) == \
        list(range(1, n_threads * per_thread + 1))
    seen = set()
    for e in out:
        assert e["kind"] == "admit"
        assert e["trace_id"] == f"t{e['thread']}:{e['i']}"   # not torn
        seen.add((e["thread"], e["i"]))
    assert len(seen) == n_threads * per_thread               # not lost


# ---------------------------------------------------------------------------
# Dumps: format, caps, SLO-streak trigger
# ---------------------------------------------------------------------------

def test_sync_dump_writes_parseable_jsonl_with_context(tmp_path):
    reg = MetricsRegistry()
    reg.counter("repro_engine_ticks_total").inc(5)
    fl = FlightRecorder(postmortem_dir=str(tmp_path))
    fl.attach(stats_fn=lambda: {"routed": 12}, registry=reg)
    fl.bind("admit", replica=0)(rid=1, trace_id="t000001")
    fl.bind("retire", replica=0)(rid=1, trace_id="t000001",
                                 status="converged")
    path = fl.dump("bug report!", note="manual")
    assert path.endswith("postmortem-001-bug_report_.jsonl")
    lines = _read_dump(path)
    head = lines[0]
    assert head["type"] == "incident" and head["reason"] == "bug report!"
    assert head["context"] == {"note": "manual"}
    assert head["recorder"]["recorded"] == 2
    events = [ln for ln in lines if ln["type"] == "event"]
    assert [e["kind"] for e in events] == ["admit", "retire"]
    assert all(e["trace_id"] == "t000001" for e in events)
    (cs,) = [ln for ln in lines if ln["type"] == "cluster_stats"]
    assert cs["stats"] == {"routed": 12}
    (ms,) = [ln for ln in lines if ln["type"] == "metrics"]
    assert ms["series"]["repro_engine_ticks_total"][""] == 5.0
    assert path in fl.stats()["dump_paths"]


def test_incident_dumps_are_capped_but_explicit_dumps_are_not(tmp_path):
    fl = FlightRecorder(postmortem_dir=str(tmp_path), max_dumps=2)
    for i in range(4):
        fl.incident(f"crash_{i}")
    assert fl.flush(timeout=10)
    st = fl.stats()
    assert st["incidents"] == 4 and st["dumps"] == 2   # cap held
    assert len(st["dump_paths"]) == 2
    path = fl.dump("post_cap")                          # explicit: uncapped
    assert path is not None and _read_dump(path)[0]["reason"] == "post_cap"


def test_no_postmortem_dir_records_but_never_dumps():
    fl = FlightRecorder()
    fl.incident("driver_crash", replica=0)
    assert fl.flush(timeout=5)
    st = fl.stats()
    assert st["incidents"] == 1 and st["dumps"] == 0
    # the incident itself still landed in the ring
    assert fl.events()[-1]["kind"] == "incident"
    assert fl.dump("nope") is None


def test_slo_miss_streak_raises_incident_and_resets(tmp_path):
    fl = FlightRecorder(postmortem_dir=str(tmp_path), slo_miss_streak=3)
    retire = fl.bind("retire", replica=0)
    retire(rid=0, status="deadline_missed")
    retire(rid=1, status="deadline_missed")
    retire(rid=2, status="converged")          # streak resets
    assert fl.stats()["incidents"] == 0
    for rid in (3, 4, 5):
        retire(rid=rid, status="deadline_missed")
    assert fl.flush(timeout=10)
    st = fl.stats()
    assert st["incidents"] == 1 and st["dumps"] == 1
    lines = _read_dump(st["dump_paths"][0])
    assert lines[0]["reason"] == "slo_miss_streak"
    assert lines[0]["context"] == {"streak": 3}
    # the dump's trailing events reconstruct the losing streak
    misses = [ln for ln in lines if ln["type"] == "event"
              and ln.get("status") == "deadline_missed"]
    assert len(misses) == 5


def test_flight_gauges_exported_through_registry():
    reg = MetricsRegistry()
    fl = FlightRecorder()
    fl.attach(registry=reg)
    fl.attach(registry=reg)                    # idempotent re-attach
    fl.bind("admit")(rid=0)
    fl.incident("boom")
    text = render(reg)
    assert "repro_flight_events 2" in text     # admit + incident event
    assert "repro_flight_incidents 1" in text
    assert "repro_flight_dumps 0" in text


# ---------------------------------------------------------------------------
# Numerical health: drift detection, quarantine, fleet gauges
# ---------------------------------------------------------------------------

def test_drift_detector_latches_quarantines_and_records_flight_event():
    reg = MetricsRegistry()
    fl = FlightRecorder()
    fired = []
    hm = HealthMonitor(reg, min_samples=3, flight=fl,
                       on_quarantine=lambda g, f: fired.append((g, f)))
    for it in (10, 10, 30):                   # fast EWMA jumps past 1.5x
        hm.observe_retirement(gid="mesh", family="amg", iters=it,
                              relres=1e-7, status="converged")
    assert fired == [("mesh", "amg")]
    snap = hm.snapshot()
    assert snap["drifting"] == ["mesh::amg"] and snap["quarantines"] == 1
    assert snap["families"]["amg"]["drifting"] == 1
    (drift_ev,) = [e for e in fl.events() if e["kind"] == "health_drift"]
    assert drift_ev["gid"] == "mesh" and drift_ev["family"] == "amg"
    assert drift_ev["efficiency"] > 1.5
    # latched: further degradation does not re-fire the quarantine
    hm.observe_retirement(gid="mesh", family="amg", iters=50,
                          relres=1e-7, status="converged")
    assert fired == [("mesh", "amg")] and hm.snapshot()["quarantines"] == 1
    text = render(reg)
    assert 'repro_health_quarantines_total{family="amg"} 1' in text
    assert 'repro_health_drift{family="amg"} 1' in text


def test_health_streaks_track_worst_graph_and_reset():
    hm = HealthMonitor(MetricsRegistry(), min_samples=100)
    for _ in range(3):
        hm.observe_retirement(gid="g", family="ac", iters=None,
                              relres=None, status="maxiter")
    hm.observe_retirement(gid="h", family="ac", iters=5, relres=1e-6,
                          status="converged", deadline_missed=True)
    fam = hm.snapshot()["families"]["ac"]
    assert fam["max_maxiter_streak"] == 3
    assert fam["max_deadline_miss_streak"] == 1
    hm.observe_retirement(gid="g", family="ac", iters=4, relres=1e-6,
                          status="converged")
    assert hm.snapshot()["families"]["ac"]["max_maxiter_streak"] == 0


def test_quarantine_callback_exception_never_escapes():
    hm = HealthMonitor(min_samples=2,
                       on_quarantine=lambda g, f: 1 / 0)
    for it in (10, 40):
        hm.observe_retirement(gid="g", family="ac", iters=it,
                              relres=1e-6, status="converged")
    assert hm.snapshot()["quarantines"] == 1   # fired, exception swallowed


def test_fleet_gauges_collect_from_engine_and_cache_watermark():
    reg = MetricsRegistry()
    hm = HealthMonitor(reg)
    lane = SimpleNamespace(req=SimpleNamespace(
        _handle=SimpleNamespace(n=40, n_pad=64)))
    eng = SimpleNamespace(
        _buckets={("ac", 64, 4): SimpleNamespace(n_active=2)},
        lanes=[lane, None])
    bytes_now = [1000.0]
    cache = SimpleNamespace(stats=lambda: {
        "fleet_device_bytes_by_device": {"dev0": bytes_now[0]}})
    hm.watch_engine(eng)
    hm.watch_cache(cache)
    samples = top.parse_prom(render(reg))
    (labels, v) = samples["repro_fleet_lane_occupancy"][0]
    assert labels == {"family": "ac", "n_pad": "64", "k_tier": "4"}
    assert v == 2.0
    assert samples["repro_fleet_sweep_waste_ratio"][0][1] == \
        pytest.approx(1.0 - 40 / 64)
    assert samples["repro_fleet_bytes_watermark"][0][1] == 1000.0
    bytes_now[0] = 10.0                        # watermark never regresses
    samples = top.parse_prom(render(reg))
    assert samples["repro_fleet_bytes_watermark"][0][1] == 1000.0
    assert hm.snapshot()["fleet_bytes_watermark"] == {"dev0": 1000.0}


def test_selector_quarantine_skips_family_until_explore():
    sel = AdaptiveSelector(epsilon=0.0, seed=0)
    for _ in range(3):
        sel.observe("g", "ac", wall_s=0.1, serve_s=0.01)
        sel.observe("g", "ichol", wall_s=0.5, serve_s=0.4)
    assert sel.pick("g") == "ac"               # cheapest wins
    sel.quarantine("g", "ac")                  # the drift detector's call
    assert sel.pick("g") == "ichol"            # exploitation skips it
    st = sel.stats()
    assert st["quarantined"] == 1
    assert st["estimates"]["g::ac"]["ok"] is False
    # quarantining a never-served pair pre-flags it
    sel.quarantine("h", "amg")
    assert sel.stats()["estimates"]["h::amg"]["n"] == 0


# ---------------------------------------------------------------------------
# Incident capture: injected driver crash, sustained overload
# ---------------------------------------------------------------------------

def test_driver_crash_postmortem_reconstructs_inflight_lanes(
        tmp_path, monkeypatch):
    """Crash the frontend's driver thread mid-solve: the flight
    recorder must dump a post-mortem whose event log identifies the
    in-flight request (admitted, never retired) by trace id."""
    g = graphs.road_like(6, seed=4)
    cache = FactorCache(**CACHE_KW)
    cache.factor(g, jax.random.key(0), graph_id="road")
    reg = MetricsRegistry()
    fl = FlightRecorder(postmortem_dir=str(tmp_path))
    fl.attach(registry=reg)
    eng = SolveEngine(cache, slots=2, iters_per_tick=1, metrics=reg,
                      flight=fl, obs_replica=0)
    rng = np.random.default_rng(0)
    b = rng.normal(size=g.n).astype(np.float32)
    b -= b.mean()
    fe = SolveFrontend(eng, flight=fl, obs_replica=0)
    try:
        # unconvergeable: stays in flight until the injected crash
        fut = fe.submit("road", b, tol=1e-30, maxiter=10**6)
        deadline = time.monotonic() + 60
        while not any(e["kind"] == "admit" for e in fl.events()):
            assert time.monotonic() < deadline, "request never admitted"
            time.sleep(0.01)

        def boom():
            raise RuntimeError("injected tick fault")

        monkeypatch.setattr(eng, "tick", boom)
        with pytest.raises(RuntimeError, match="injected tick fault"):
            fut.result(timeout=60)
        deadline = time.monotonic() + 30
        while fe.alive:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        assert isinstance(fe.driver_error, RuntimeError)
        assert fl.flush(timeout=30)
    finally:
        fe.close(drain=False)
    st = fl.stats()
    assert st["incidents"] == 1 and st["dumps"] == 1
    lines = _read_dump(st["dump_paths"][0])
    assert lines[0]["reason"] == "driver_crash"
    assert "injected tick fault" in str(lines[0]["context"])
    events = [ln for ln in lines if ln["type"] == "event"]
    admitted = {e["trace_id"] for e in events if e["kind"] == "admit"}
    retired = {e["trace_id"] for e in events if e["kind"] == "retire"}
    in_flight = admitted - retired
    assert len(in_flight) == 1                 # the crashed lane, by id
    assert next(iter(in_flight)).startswith("t")
    # the registry sample rode along for cross-checking
    assert any(ln["type"] == "metrics" for ln in lines)


def test_replica_ejection_raises_incident_with_dump(tmp_path):
    """Kill one replica's driver in a 2-replica cluster: the router's
    ejection path must raise a ``replica_ejected`` incident naming the
    dead replica and the surviving replica must keep serving."""
    g = graphs.road_like(6, seed=4)
    fl = FlightRecorder(postmortem_dir=str(tmp_path))
    rng = np.random.default_rng(3)
    b = rng.normal(size=g.n).astype(np.float32)
    b -= b.mean()
    with SolveCluster(replicas=2, slots=2, cache_kw=CACHE_KW,
                      flight=fl) as cl:
        cl.register(g, jax.random.key(0), graph_id="road")
        first = cl.submit("road", b, tol=1e-4,
                          maxiter=300).result(timeout=300)
        cl.replicas[first.replica].frontend.close(drain=True)
        second = cl.submit("road", b, tol=1e-4,
                           maxiter=300).result(timeout=300)
        assert second.status == "converged"
        assert fl.flush(timeout=30)
    st = fl.stats()
    assert st["incidents"] == 1
    lines = _read_dump(st["dump_paths"][0])
    assert lines[0]["reason"] == "replica_ejected"
    assert lines[0]["context"] == {"replica": first.replica,
                                   "cause": "dead_driver"}
    events = [ln for ln in lines if ln["type"] == "event"]
    (eject,) = [e for e in events if e["kind"] == "eject"]
    assert eject["replica"] == first.replica
    # lifecycle events around the ejection kept their trace ids
    assert any(e["kind"] == "retire" and e.get("trace_id")
               for e in events)


class _FakeDetector:
    """Duck-typed overload detector the cluster's collect loop drives:
    ``update`` returns whatever state the test set."""

    name = "fake"
    recommendation = "scale_up"

    def __init__(self):
        self.state = "ok"
        self.updates = 0

    def update(self, now):
        self.updates += 1
        return self.state

    def stats(self):
        return {"detector": self.name, "state": self.state,
                "updates": self.updates}


def test_sustained_overload_flip_dumps_with_cluster_stats(tmp_path):
    """Flip the detector to ``overloaded`` between two collect passes:
    the transition is recorded as a flight event, the flip raises a
    ``sustained_overload`` incident, and the dump carries the cluster's
    own stats snapshot."""
    reg = MetricsRegistry()
    fl = FlightRecorder(postmortem_dir=str(tmp_path))
    det = _FakeDetector()
    with SolveCluster(replicas=1, slots=2, cache_kw=CACHE_KW,
                      metrics=reg, detector=det, flight=fl) as cl:
        cl._collect(reg)                       # ok: transition, no incident
        assert fl.stats()["incidents"] == 0
        det.state = "overloaded"
        cl._collect(reg)                       # the flip
        cl._collect(reg)                       # steady-state: no re-fire
        assert fl.flush(timeout=30)
        st = fl.stats()
        assert st["incidents"] == 1 and st["dumps"] == 1
        lines = _read_dump(st["dump_paths"][0])
        assert lines[0]["reason"] == "sustained_overload"
        assert lines[0]["context"]["detector"] == "fake"
        trans = [ln for ln in lines if ln["type"] == "event"
                 and ln["kind"] == "detector_transition"]
        assert [(t["prev"], t["state"]) for t in trans] == \
            [("", "ok"), ("ok", "overloaded")]
        (cs,) = [ln for ln in lines if ln["type"] == "cluster_stats"]
        assert cs["stats"]["overload"]["detector"] == "fake"
        samples = top.parse_prom(render(reg))
        assert samples["repro_cluster_overload_state"][0][1] == 1.0


# ---------------------------------------------------------------------------
# Fleet dashboard: parse -> summarize -> render, --once over file + HTTP
# ---------------------------------------------------------------------------

def test_parse_prom_and_quantile():
    text = "\n".join([
        "# HELP repro_engine_latency_seconds latency",
        "# TYPE repro_engine_latency_seconds histogram",
        'repro_engine_latency_seconds_bucket{replica="0",le="0.1"} 8',
        'repro_engine_latency_seconds_bucket{replica="0",le="1"} 10',
        'repro_engine_latency_seconds_bucket{replica="0",le="+Inf"} 10',
        'repro_engine_latency_seconds_bucket{replica="1",le="0.1"} 0',
        'repro_engine_latency_seconds_bucket{replica="1",le="1"} 10',
        'repro_engine_latency_seconds_bucket{replica="1",le="+Inf"} 12',
        "repro_engine_ticks_total 7", ""])
    samples = top.parse_prom(text)
    assert samples["repro_engine_ticks_total"] == [({}, 7.0)]
    assert len(samples["repro_engine_latency_seconds_bucket"]) == 6
    # cross-replica sum: 8/22 in [0,0.1], 12 more in (0.1,1], 2 at +Inf
    p50 = top._quantile(samples, "repro_engine_latency_seconds", 0.5)
    assert 0.1 < p50 < 1.0
    # a quantile landing past the last finite bound clamps to it
    p99 = top._quantile(samples, "repro_engine_latency_seconds", 0.99)
    assert p99 == 1.0
    assert top._quantile(samples, "no_such_series", 0.5) is None


def _populated_registry():
    reg = MetricsRegistry()
    reg.counter("repro_engine_ticks_total").inc(40)
    reg.counter("repro_engine_admitted_total").inc(9)
    c = reg.counter("repro_engine_completed_total", "",
                    ("replica", "status"))
    c.labels(replica=0, status="converged").inc(6)
    c.labels(replica=0, status="maxiter").inc(1)
    reg.gauge("repro_engine_queue_depth").set(2)
    reg.gauge("repro_engine_active_lanes").set(3)
    h = reg.histogram("repro_engine_latency_seconds")
    for v in (0.01, 0.02, 0.5):
        h.observe(v)
    r = reg.counter("repro_cluster_routed_total", "", ("hit",))
    r.labels(hit=1).inc(6)
    r.labels(hit=0).inc(2)
    reg.gauge("repro_cluster_overload_state").set(1)
    reg.gauge("repro_health_drift", "", ("family",)) \
        .labels(family="amg").set(1)
    reg.counter("repro_health_quarantines_total", "", ("family",)) \
        .labels(family="amg").inc()
    reg.gauge("repro_fleet_lane_occupancy", "",
              ("family", "n_pad", "k_tier")) \
        .labels(family="ac", n_pad=64, k_tier=4).set(3)
    reg.gauge("repro_fleet_sweep_waste_ratio").set(0.25)
    reg.gauge("repro_fleet_bytes_watermark", "", ("device",)) \
        .labels(device="dev0").set(2048)
    reg.gauge("repro_flight_incidents").set(1)
    return reg


def test_summarize_and_render_read_the_whole_display_model():
    samples = top.parse_prom(render(_populated_registry()))
    info = top.summarize_endpoint(samples)
    assert info["ticks"] == 40 and info["admitted"] == 9
    assert info["done"] == 7
    assert info["completed"] == {"converged": 6.0, "maxiter": 1.0}
    assert info["queue"] == 2 and info["lanes"] == 3
    assert info["hit_rate"] == pytest.approx(6 / 8)
    assert info["overload"] == 1 and info["incidents"] == 1
    assert info["drift"] == {"amg": 1.0} and info["quarantines"] == 1
    assert info["buckets"] == [("ac/64/K4", 3.0)]
    assert info["waste"] == 0.25 and info["watermark"] == 2048
    assert 0.0 < info["p50"] < info["p95"]
    text = "\n".join(top.render_lines("ep", info))
    assert "== ep ==" in text and "state OVERLOADED" in text
    assert "converged=6" in text and "maxiter=1" in text
    assert "affinity 75%" in text
    assert "drifting: amg(1)" in text and "incidents 1" in text
    assert "waste 25.0%" in text and "watermark 2KiB" in text
    assert "ac/64/K4" in text


def test_once_renders_prom_file_and_fails_only_when_all_do(tmp_path):
    path = tmp_path / "scrape.prom"
    path.write_text(render(_populated_registry()))
    buf = io.StringIO()
    assert top.once([str(path), str(tmp_path / "missing.prom")],
                    out=buf) == 0             # one endpoint is enough
    text = buf.getvalue()
    assert f"== {path} ==" in text and "ticks 40" in text
    assert "scrape failed" in text            # the missing one, flagged
    assert top.once([str(tmp_path / "missing.prom")],
                    out=io.StringIO()) == 1   # all failed -> nonzero


def test_once_scrapes_live_http_endpoint():
    reg = _populated_registry()
    with MetricsServer(reg, port=0, host="127.0.0.1") as srv:
        buf = io.StringIO()
        assert top.once([f"127.0.0.1:{srv.port}"], out=buf) == 0
        assert "ticks 40" in buf.getvalue()
        # full-URL endpoint form resolves to the same scrape
        info = top.summarize_endpoint(
            top.scrape(f"http://127.0.0.1:{srv.port}/metrics"))
        assert info["ticks"] == 40
