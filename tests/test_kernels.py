"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis
property tests.  sample_clique must match the reference *bit-exactly*
(same Hillis-Steele bracketing by construction)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # hypothesis is a dev-only extra; property tests skip without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(**kw):            # noqa: D103 — stand-in decorator: the
        def deco(fn):           # decorated test becomes a skip marker
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped():
                pass
            skipped.__name__ = fn.__name__
            return skipped
        return deco

    def settings(**kw):
        return lambda fn: fn

    class st:  # noqa: N801
        @staticmethod
        def integers(*a, **kw):
            return None

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.sample_clique import INVALID_ID
from repro.core.column_math import column_uniforms


def _random_rows(rng, R, W, dup_frac=0.3):
    ids = np.full((R, W), INVALID_ID, np.int32)
    ws = np.zeros((R, W), np.float32)
    fill = rng.integers(0, W + 1, R).astype(np.int32)
    for r in range(R):
        d = fill[r]
        pool = rng.choice(np.arange(1000, 1000 + 2 * W), size=max(d, 1),
                          replace=rng.random() < dup_frac)
        ids[r, :d] = pool[:d]
        ws[r, :d] = rng.uniform(0.01, 100.0, d)
    return ids, ws, fill


def _uniforms(key, R, W):
    return jax.vmap(lambda v: column_uniforms(key, v, W))(
        jnp.arange(R, dtype=jnp.int32))


@pytest.mark.parametrize("R,W", [(4, 8), (8, 16), (5, 31)])
def test_sample_clique_matches_ref_exactly(R, W):
    rng = np.random.default_rng(R * 100 + W)
    ids, ws, fill = _random_rows(rng, R, W)
    W2 = kops._next_pow2(W)
    idsp = np.pad(ids, ((0, 0), (0, W2 - W)), constant_values=INVALID_ID)
    wsp = np.pad(ws, ((0, 0), (0, W2 - W)))
    u = np.asarray(_uniforms(jax.random.key(0), R, W2))
    out_k = kops.sample_clique(jnp.asarray(ids), jnp.asarray(ws),
                               jnp.asarray(fill), jnp.asarray(u[:, :W]))
    out_r = kref.sample_clique_ref(jnp.asarray(idsp), jnp.asarray(wsp),
                                   jnp.asarray(fill), jnp.asarray(u))
    names = ["g_rows", "g_vals", "m", "ell", "e_lo", "e_hi", "e_w", "e_valid"]
    for name, a, b in zip(names, out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_sample_clique_tree_properties():
    """Sampled edges form a forest over merged neighbours with m-1 edges,
    and Σ sampled weights ≤ ℓkk (suffix-probability mass)."""
    rng = np.random.default_rng(7)
    ids, ws, fill = _random_rows(rng, 16, 32, dup_frac=0.0)
    u = np.asarray(_uniforms(jax.random.key(3), 16, 32))
    g_rows, g_vals, m, ell, e_lo, e_hi, e_w, e_valid = [
        np.asarray(x) for x in kops.sample_clique(
            jnp.asarray(ids), jnp.asarray(ws), jnp.asarray(fill),
            jnp.asarray(u))]
    for r in range(16):
        mv = int(m[r, 0])
        k = int(e_valid[r].sum())
        assert k == max(mv - 1, 0)
        if k:
            lo, hi = e_lo[r][e_valid[r]], e_hi[r][e_valid[r]]
            assert np.all(lo < hi)
            nbrs = set(g_rows[r, :mv].tolist())
            assert set(lo.tolist()) <= nbrs and set(hi.tolist()) <= nbrs
            assert np.all(e_w[r][e_valid[r]] > 0)


@settings(max_examples=10, deadline=None)
@given(d=st.integers(1, 24), seed=st.integers(0, 10_000))
def test_sample_clique_hypothesis_single_row(d, seed):
    rng = np.random.default_rng(seed)
    W = 32
    ids = np.full((1, W), INVALID_ID, np.int32)
    ws = np.zeros((1, W), np.float32)
    ids[0, :d] = rng.choice(np.arange(10, 500), d, replace=True)
    ws[0, :d] = rng.uniform(1e-3, 1e3, d)
    fill = np.array([d], np.int32)
    u = np.asarray(_uniforms(jax.random.key(seed), 1, W))
    out_k = kops.sample_clique(jnp.asarray(ids), jnp.asarray(ws),
                               jnp.asarray(fill), jnp.asarray(u))
    out_r = kref.sample_clique_ref(jnp.asarray(ids), jnp.asarray(ws),
                                   jnp.asarray(fill), jnp.asarray(u))
    for a, b in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # weight conservation: factor column sums to -1 (w/ℓkk sums to 1)
    g_vals, mv = np.asarray(out_k[1]), int(np.asarray(out_k[2])[0, 0])
    if mv:
        assert abs(1.0 + g_vals[0, :mv].sum()) < 1e-4


@pytest.mark.parametrize("R,K,n", [(16, 4, 64), (128, 9, 256), (33, 7, 100)])
def test_ell_spmv_matches_ref(R, K, n):
    rng = np.random.default_rng(R + K)
    cols = rng.integers(0, n, (R, K)).astype(np.int32)
    vals = rng.normal(size=(R, K)).astype(np.float32)
    x = rng.normal(size=n).astype(np.float32)
    yk = kops.ell_spmv(jnp.asarray(cols), jnp.asarray(vals), jnp.asarray(x))
    yr = kref.ell_spmv_ref(jnp.asarray(cols), jnp.asarray(vals),
                           jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=1e-4, atol=1e-5)


def test_spmv_laplacian_consistency():
    """ELL SpMV against the edge-list Laplacian matvec."""
    from repro.data import graphs
    from repro.core.laplacian import laplacian_matvec_np
    g = graphs.grid2d(8, 9, seed=2)
    cols, vals = kops.graph_to_ell(g.src, g.dst, g.w, g.n)
    x = np.random.default_rng(0).normal(size=g.n).astype(np.float32)
    yk = np.asarray(kops.ell_spmv(jnp.asarray(cols), jnp.asarray(vals),
                                  jnp.asarray(x)))
    yref = laplacian_matvec_np(g, x.astype(np.float64))
    np.testing.assert_allclose(yk, yref, rtol=2e-4, atol=2e-4)


def test_trisolve_levels_kernel():
    from repro.data import graphs
    from repro.core.ref_ac import factorize_sequential
    from repro.core.trisolve import build_schedules, solve_levels_np
    g = graphs.grid2d(9, 9, seed=4)
    f = factorize_sequential(g, jax.random.key(1))
    fwd, bwd = build_schedules(f)
    b = np.random.default_rng(1).normal(size=g.n).astype(np.float32)
    rows, cols, vals, _ = kops.schedule_to_ell(fwd)
    yk = np.asarray(kops.trisolve_levels(rows, cols, vals, b))
    yr = solve_levels_np(fwd, b)
    np.testing.assert_allclose(yk, yr, rtol=3e-4, atol=3e-4)


def test_sample_clique_engine_integration():
    """Kernel outputs drive a full factorization identical to the oracle:
    run the wavefront engine's per-round elimination through the kernel
    path on one synthetic wavefront and compare against eliminate_column.
    """
    rng = np.random.default_rng(11)
    ids, ws, fill = _random_rows(rng, 32, 16)
    u = np.asarray(_uniforms(jax.random.key(9), 32, 16))
    out_k = kops.sample_clique(jnp.asarray(ids), jnp.asarray(ws),
                               jnp.asarray(fill), jnp.asarray(u))
    out_r = kref.sample_clique_ref(jnp.asarray(ids), jnp.asarray(ws),
                                   jnp.asarray(fill), jnp.asarray(u))
    for a, b in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("B,H,S,d,causal", [
    (1, 2, 128, 32, True), (2, 1, 256, 64, True), (1, 1, 128, 32, False)])
def test_flash_attention_matches_ref(B, H, S, d, causal):
    from repro.kernels.flash_attention import flash_attention
    rng = np.random.default_rng(B * 10 + S)
    q = rng.normal(size=(B, H, S, d)).astype(np.float32)
    k = rng.normal(size=(B, H, S, d)).astype(np.float32)
    v = rng.normal(size=(B, H, S, d)).astype(np.float32)
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=causal, q_tile=64, block_k=64)
    ref = kref.flash_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
