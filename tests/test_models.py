"""Per-architecture smoke tests: reduced configs, fwd/train step on CPU,
shape + NaN checks, and prefill/decode cache consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs, get_smoke_config
from repro.configs.shapes import SHAPES, cell_applicable, input_specs
from repro.models import transformer as tf
from repro.models.common import init_params, abstract_params

ARCHS = list_archs()


def _toy_inputs(cfg, key, B=2, S=32):
    tk = jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)
    enc = None
    if cfg.is_encoder_decoder:
        enc = jax.random.normal(jax.random.fold_in(key, 1),
                                (B, cfg.encoder_len, cfg.d_model),
                                jnp.float32).astype(jnp.bfloat16) * 0.02
    return tk, enc


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.key(0)
    params = init_params(tf.pdefs(cfg), key, jnp.float32)
    tokens, enc = _toy_inputs(cfg, jax.random.fold_in(key, 7))
    targets = jnp.roll(tokens, -1, axis=1)

    logits, aux = jax.jit(
        lambda p, t: tf.fwd_train(p, cfg, t, enc))(params, tokens)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    loss, (ce, _) = jax.jit(
        lambda p: tf.loss_fn(p, cfg, tokens, targets, enc))(params)
    assert np.isfinite(float(loss))
    # a reasonable CE for random init: close to ln(vocab)
    assert float(ce) < np.log(cfg.vocab) + 2.0

    grads = jax.jit(jax.grad(
        lambda p: tf.loss_fn(p, cfg, tokens, targets, enc)[0]))(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    # gradient reaches the embedding
    assert float(jnp.abs(grads["embed"]).max()) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "whisper-tiny"])
def test_prefill_decode_matches_forward(arch):
    """logits(prefill S) + decode(t=S) must equal fwd_train at position S."""
    cfg = get_smoke_config(arch)
    key = jax.random.key(1)
    params = init_params(tf.pdefs(cfg), key, jnp.float32)
    B, S = 2, 16
    tokens, _ = _toy_inputs(cfg, jax.random.fold_in(key, 3), B, S + 1)
    max_len = 32

    full, _ = tf.fwd_train(params, cfg, tokens)
    pre_logits, caches = tf.prefill(params, cfg, tokens[:, :S], max_len,
                                    dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, -1]), np.asarray(full[:, S - 1]),
        rtol=2e-2, atol=2e-2)
    step_logits, _ = tf.decode_step(params, cfg, caches, tokens[:, S:S + 1],
                                    jnp.int32(S))
    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(full[:, S]),
        rtol=2e-2, atol=2e-2)


def test_whisper_decode_runs():
    cfg = get_smoke_config("whisper-tiny")
    key = jax.random.key(2)
    params = init_params(tf.pdefs(cfg), key, jnp.float32)
    tokens, enc = _toy_inputs(cfg, key, B=2, S=8)
    enc_out = tf.encode(params, cfg, enc)
    caches = tf.init_caches(cfg, 2, 16, jnp.float32)
    logits, caches = tf.decode_step(params, cfg, caches, tokens[:, :1],
                                    jnp.int32(0), enc_out=enc_out)
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_defined_for_applicable_cells(arch):
    cfg = get_smoke_config(arch)
    for cell in SHAPES.values():
        ok, why = cell_applicable(cfg, cell)
        if ok:
            specs = input_specs(cfg, cell)
            assert "tokens" in specs


def test_param_counts_sane():
    from repro.configs import get_config
    # spot-check against public parameter counts (±25%: padding, biases)
    expect = {"qwen3-14b": 14.8e9, "phi3-medium-14b": 14e9,
              "gemma3-27b": 27e9, "chameleon-34b": 34e9,
              "llama4-scout-17b-a16e": 109e9, "mamba2-1.3b": 1.3e9}
    for name, n_pub in expect.items():
        n = get_config(name).param_count()
        assert 0.7 < n / n_pub < 1.45, (name, n, n_pub)
