"""Observability control plane: the thread-safe metrics registry and
its time-series view, Prometheus text exposition + scrape endpoint,
per-request lifecycle tracing (Chrome trace_event export), and the
sustained-threshold overload detector."""
import json
import threading
import re
import urllib.request

import numpy as np
import jax
import pytest

from repro.obs import (
    CardinalityError, DEFAULT_LATENCY_BUCKETS_S, FlightRecorder,
    MetricsRegistry, MetricsServer, NULL, SustainedThresholdDetector,
    Tracer, percentile, quantile_from_counts, render,
    trace_from_request)
from repro.obs.prometheus import CONTENT_TYPE


# ---------------------------------------------------------------------------
# Registry: concurrency, cardinality, time-series reads
# ---------------------------------------------------------------------------

def test_concurrent_counter_and_histogram_updates():
    """N threads hammering one counter child and one histogram child
    must not lose updates: inc is a lock-guarded read-modify-write
    (bare += loses under GIL preemption)."""
    reg = MetricsRegistry()
    c = reg.counter("t_total", "test counter")
    h = reg.histogram("t_seconds", "test histogram")
    n_threads, per_thread = 8, 2000

    def work(k):
        for i in range(per_thread):
            c.inc()
            h.observe((k * per_thread + i) % 7 * 1e-4)

    threads = [threading.Thread(target=work, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n_threads * per_thread
    total, _, counts = h._default.snapshot()
    assert total == n_threads * per_thread
    assert sum(counts) == total


def test_labeled_children_are_cached_and_checked():
    reg = MetricsRegistry()
    c = reg.counter("by_replica_total", "per replica", ("replica",))
    assert c.labels(replica="0") is c.labels(replica=0)   # str-keyed
    c.labels(replica="0").inc(3)
    assert c.labels(replica="0").value == 3
    with pytest.raises(ValueError):
        c.labels(shard="0")                # wrong label name


def test_cardinality_cap_raises():
    """Past the cap, labels() raises instead of leaking series — an
    unbounded label value (request id) must fail at the call site."""
    reg = MetricsRegistry()
    c = reg.counter("capped_total", "capped", ("rid",), max_series=8)
    for i in range(8):
        c.labels(rid=i).inc()
    with pytest.raises(CardinalityError):
        c.labels(rid="one-too-many")


def test_registry_idempotent_and_kind_checked():
    reg = MetricsRegistry()
    a = reg.counter("dup_total")
    assert reg.counter("dup_total") is a
    with pytest.raises(ValueError):
        reg.gauge("dup_total")


def test_windowed_rate_gauge_stats_and_quantile():
    """The ring answers the three questions the detector and reports
    ask: counter rate, gauge stats, and histogram quantile — windowed
    via explicit, injected timestamps."""
    reg = MetricsRegistry()
    c = reg.counter("arrivals_total")
    g = reg.gauge("depth")
    h = reg.histogram("lat_seconds")
    for i in range(11):                       # t = 0..10, 2 arrivals/s
        c.inc(2)
        g.set(float(i))
        h.observe(0.01 if i < 8 else 1.0)
        reg.sample(now=float(i))
    assert reg.rate("arrivals_total", window_s=5.0, now=10.0) == \
        pytest.approx(2.0)
    st = reg.gauge_stats("depth", window_s=4.0, now=10.0)
    assert st["n"] == 5 and st["max"] == 10.0
    assert st["mean"] == pytest.approx(8.0)
    # windowed quantile sees only the last 3 (slow) observations
    q = reg.quantile("lat_seconds", 0.5, window_s=3.0, now=10.0)
    assert 0.5 < q <= 1.58                    # in the ~1 s bucket
    # lifetime quantile is dominated by the 8 fast observations
    assert reg.quantile("lat_seconds", 0.5) < 0.1


def test_null_registry_is_inert():
    c = NULL.counter("x_total")
    c.inc()
    c.labels(anything="goes").observe(1.0)    # no schema, no error
    assert c.value == 0.0
    assert NULL.rate("x_total", window_s=1.0) == 0.0


def test_quantile_from_counts_and_percentile_agree():
    rng = np.random.default_rng(0)
    xs = rng.exponential(0.02, size=2000)
    counts = [0] * (len(DEFAULT_LATENCY_BUCKETS_S) + 1)
    from repro.obs import bucket_index
    for x in xs:
        counts[bucket_index(DEFAULT_LATENCY_BUCKETS_S, x)] += 1
    exact = percentile(xs, 95)
    est = quantile_from_counts(DEFAULT_LATENCY_BUCKETS_S, counts, 0.95)
    # bucket resolution is ~1.58x: the estimate lands within one ratio
    assert exact / 1.6 <= est <= exact * 1.6


# ---------------------------------------------------------------------------
# Prometheus exposition + scrape endpoint
# ---------------------------------------------------------------------------

_LINE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? [0-9.eE+-]+(?:inf)?$")


def _parse_prom(text):
    """Minimal exposition-format check: every non-comment line is
    ``name{labels} value``; returns {sample_name: [(labels, value)]}."""
    out = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        assert _LINE.match(line), f"malformed exposition line: {line!r}"
        head, val = line.rsplit(" ", 1)
        name = head.split("{", 1)[0]
        out.setdefault(name, []).append((head, float(val)))
    return out


def test_render_round_trips_as_prometheus_text():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests", ("replica", "status")) \
        .labels(replica=0, status='conv"erged\\').inc(5)
    reg.gauge("depth", "queue depth").set(3)
    h = reg.histogram("lat_seconds", "latency")
    for v in (1e-4, 2e-3, 0.5):
        h.observe(v)
    text = render(reg)
    assert "# HELP req_total requests" in text
    assert "# TYPE lat_seconds histogram" in text
    samples = _parse_prom(text)
    assert samples["req_total"][0][1] == 5.0
    assert '\\"' in samples["req_total"][0][0]      # label escaping
    assert samples["depth"][0][1] == 3.0
    # cumulative buckets, monotone, +Inf == _count == 3
    buckets = [v for _, v in samples["lat_seconds_bucket"]]
    assert buckets == sorted(buckets) and buckets[-1] == 3.0
    assert any(head.endswith('le="+Inf"} 3') or 'le="+Inf"' in head
               for head, _ in samples["lat_seconds_bucket"])
    assert samples["lat_seconds_count"][0][1] == 3.0
    assert samples["lat_seconds_sum"][0][1] == pytest.approx(0.5021)


def test_metrics_server_scrape():
    reg = MetricsRegistry()
    reg.counter("scrape_total").inc(7)
    with MetricsServer(reg, port=0, host="127.0.0.1") as srv:
        url = f"http://127.0.0.1:{srv.port}/metrics"
        with urllib.request.urlopen(url, timeout=5) as resp:
            assert resp.status == 200
            assert resp.headers["Content-Type"] == CONTENT_TYPE
            body = resp.read().decode()
        assert _parse_prom(body)["scrape_total"][0][1] == 7.0
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/nope", timeout=5)


def test_metrics_server_fixed_port_replay_and_idempotent_close():
    """Back-to-back runs on a fixed ``--metrics-port`` (the replay
    workflow) must rebind immediately — SO_REUSEADDR, not a TIME_WAIT
    stall — and ``close`` must be callable from both a finally block
    and an exit handler without raising."""
    import socket
    reg = MetricsRegistry()
    reg.counter("replay_total").inc(3)
    with socket.socket() as s:                 # reserve a concrete port
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    for _ in range(2):                         # run, close, run again
        srv = MetricsServer(reg, port=port, host="127.0.0.1")
        assert srv.port == port
        url = f"http://127.0.0.1:{port}/metrics"
        with urllib.request.urlopen(url, timeout=5) as resp:
            body = resp.read().decode()
        assert _parse_prom(body)["replay_total"][0][1] == 3.0
        srv.close()
        srv.close()                            # idempotent second close
    # closed for real: the port no longer answers
    with pytest.raises(urllib.error.URLError):
        urllib.request.urlopen(url, timeout=1)


# ---------------------------------------------------------------------------
# Tracing: span partition + Chrome export on a real engine replay
# ---------------------------------------------------------------------------

def test_trace_partition_sums_to_e2e_synthetic():
    class R:
        rid = 1
        graph_id = "g"
        status = "converged"
        submit_time = 10.0
        admit_time = 10.5
        finish_time = 11.0
        first_tick_time = 10.6
        route_s = 0.1
        factor_wait_s = 0.2
        factor_mode = "adopt"
        iters = np.array([4, 9])
        nrhs = 2
        replica = 3

    tr = trace_from_request(R())
    names = [s.name for s in tr.spans]
    assert names == ["route", "adopt", "queue", "first_tick", "solve"]
    # contiguous partition: each span starts where the previous ended
    for a, b in zip(tr.spans, tr.spans[1:]):
        assert b.start == pytest.approx(a.end)
    assert tr.span_sum_s == pytest.approx(tr.e2e_s)
    assert tr.e2e_s == pytest.approx(1.0)
    assert tr.attrs["iters"] == 9 and tr.replica == 3


def test_trace_skips_unpaid_stages_and_unfinished_requests():
    class Warm:
        rid = 2
        graph_id = "g"
        status = "converged"
        submit_time = 5.0
        admit_time = 5.0
        finish_time = 5.4
        first_tick_time = 0.0
        route_s = 0.0
        factor_wait_s = 0.0
        factor_mode = ""
        iters = None
        nrhs = 1
        replica = -1

    tr = trace_from_request(Warm())
    assert [s.name for s in tr.spans] == ["solve"]
    assert tr.span_sum_s == pytest.approx(0.4)

    class Unfinished(Warm):
        finish_time = 0.0

    assert trace_from_request(Unfinished()) is None


@pytest.fixture(scope="module")
def traced_replay():
    """A mixed 3-graph replay through an instrumented engine: the
    fixture shared by the scrape, trace-export and overhead tests."""
    from repro.core.solver import FactorCache
    from repro.data import graphs
    from repro.launch.serve import make_trace, replay_trace
    from repro.serve import SolveEngine

    built = {"g2d": graphs.grid2d(10, 10, seed=1),
             "pl": graphs.powerlaw(200, 4, seed=2),
             "road": graphs.road_like(8, seed=3)}
    keys = {name: jax.random.key(i) for i, name in enumerate(built)}
    cache = FactorCache(strict=False)
    cache.factor_batched(list(built.values()),
                         [keys[name] for name in built],
                         graph_ids=list(built.keys()))
    reg = MetricsRegistry()
    tracer = Tracer()
    flight = FlightRecorder()
    eng = SolveEngine(cache, slots=4, iters_per_tick=8,
                      metrics=reg, tracer=tracer, flight=flight)
    sizes = {name: g.n for name, g in built.items()}
    trace = make_trace(list(built), sizes, 9, seed=0, max_nrhs=2)
    metrics, done = replay_trace(eng, trace)
    return reg, tracer, metrics, done, eng, flight


def test_engine_replay_records_traces_with_tight_span_sum(traced_replay):
    _, tracer, metrics, done, _, _ = traced_replay
    traces = tracer.traces()
    assert len(traces) == len(done) == metrics["completed"]
    by_rid = {tr.rid: tr for tr in traces}
    for r in done:
        tr = by_rid[r.rid]
        assert tr.graph_id == r.graph_id
        assert tr.status == r.status
        assert tr.family        # read off the fleet before handle drop
        assert tr.policy == "fifo"
        # the acceptance bound: span sum within 5% of e2e latency
        assert tr.span_sum_s == pytest.approx(r.latency_s, rel=0.05)
        # spans are ordered, contiguous, and inside [submit, finish]
        for a, b in zip(tr.spans, tr.spans[1:]):
            assert b.start >= a.end - 1e-9
        assert tr.start >= r.submit_time - 1e-9
        assert tr.end <= r.finish_time + 1e-9


def test_chrome_export_loads_and_nests(traced_replay, tmp_path):
    _, tracer, _, done, _, _ = traced_replay
    path = tmp_path / "trace.json"
    n = tracer.export_chrome(str(path))
    doc = json.loads(path.read_text())      # valid JSON, loads clean
    events = doc["traceEvents"]
    assert len(events) == n
    xs = [e for e in events if e["ph"] == "X"]
    assert {e["name"] for e in xs} <= {"route", "factor", "adopt",
                                       "queue", "first_tick", "solve"}
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in xs)
    # spans nest per request row: same (pid, tid) events don't overlap
    rows = {}
    for e in xs:
        rows.setdefault((e["pid"], e["tid"]), []).append(e)
    assert len(rows) == len(done)
    for evs in rows.values():
        evs.sort(key=lambda e: e["ts"])
        for a, b in zip(evs, evs[1:]):
            assert b["ts"] >= a["ts"] + a["dur"] - 1.0   # µs slack
    assert any(e["ph"] == "M" for e in events)           # track names


def test_engine_replay_is_scrapable(traced_replay):
    reg, _, metrics, _, eng, _ = traced_replay
    text = render(reg)
    samples = _parse_prom(text)
    assert samples["repro_engine_ticks_total"][0][1] == eng.ticks
    done = sum(v for _, v in samples["repro_engine_completed_total"])
    assert done == metrics["completed"]
    assert samples["repro_engine_latency_seconds_count"][0][1] == \
        metrics["completed"]
    # the ring sampled during the replay: windowed reads answer
    assert reg.series("repro_engine_ticks_total")


def test_flight_events_join_chrome_trace_rows_by_trace_id(
        traced_replay, tmp_path):
    """The forensic join the post-mortem workflow leans on: every
    request's auto-stamped ``trace_id`` appears identically in its
    flight-recorder lifecycle events and its Chrome trace row, so a
    dump cross-references ``--trace-json`` row for row."""
    _, tracer, metrics, done, _, flight = traced_replay
    evs = flight.events()
    admits = {e["trace_id"]: e for e in evs if e["kind"] == "admit"}
    retires = {e["trace_id"]: e for e in evs if e["kind"] == "retire"}
    assert len(retires) == metrics["completed"]
    for r in done:
        assert r.trace_id and r.trace_id in admits
        retire = retires[r.trace_id]
        assert retire["rid"] == r.rid and retire["status"] == r.status
    # a clean replay leaves no admitted-but-unretired lane behind
    assert set(admits) == set(retires)
    path = tmp_path / "trace.json"
    tracer.export_chrome(str(path))
    doc = json.loads(path.read_text())
    span_ids = {e["args"]["trace_id"] for e in doc["traceEvents"]
                if e["ph"] == "X"}
    assert span_ids == set(retires)            # the join, both ways


# ---------------------------------------------------------------------------
# Overload detection
# ---------------------------------------------------------------------------

def _feed(reg, det, depths, *, t0=0.0, dt=0.1):
    g = reg.gauge("repro_cluster_queue_depth")
    c = reg.counter("repro_cluster_arrivals_total")
    t = t0
    for d in depths:
        g.set(d)
        c.inc(max(d, 0))
        reg.sample(now=t)
        det.update(t)
        t += dt
    return t


def test_detector_flags_sustained_burst_and_cools():
    reg = MetricsRegistry()
    # sustain/cool sit strictly between sample-spacing multiples so
    # float accumulation of the 0.1 s feed steps can't straddle them
    det = SustainedThresholdDetector(
        reg, high_queue=8.0, low_queue=2.0, window_s=0.5,
        sustain_s=0.25, cool_s=0.25, idle_down_s=1.95)
    t = _feed(reg, det, [0, 1, 0, 1])                 # stationary: quiet
    assert det.state == "ok" and det.transitions == 0
    t = _feed(reg, det, [20, 25, 30, 25, 20, 25], t0=t)   # the storm
    assert det.state == "overloaded"
    assert det.recommendation == "scale_up"
    t = _feed(reg, det, [0] * 10, t0=t)               # drains + cools
    assert det.state == "ok" and det.transitions == 2
    # long idle flips the recommendation to scale_down
    _feed(reg, det, [0] * 25, t0=t)
    assert det.recommendation == "scale_down"
    st = det.stats()
    assert st["detector"] == "sustained_threshold"
    assert st["updates"] == det.updates


def test_detector_ignores_single_spike():
    """Hysteresis: one hot sample inside a quiet stream neither trips
    the detector nor leaves residue (the windowed mean absorbs it)."""
    reg = MetricsRegistry()
    det = SustainedThresholdDetector(
        reg, high_queue=8.0, low_queue=2.0, window_s=0.5,
        sustain_s=0.3, cool_s=0.3)
    _feed(reg, det, [0, 1, 30, 1, 0, 1, 0, 1, 0, 1])
    assert det.state == "ok" and det.transitions == 0


def test_detector_validates_hysteresis_band():
    with pytest.raises(ValueError):
        SustainedThresholdDetector(MetricsRegistry(), high_queue=2.0,
                                   low_queue=2.0)


# ---------------------------------------------------------------------------
# Selector reads deconflated timings
# ---------------------------------------------------------------------------

def test_selector_ranks_on_serve_time_not_wall_clock():
    """A family whose requests queued badly (big wall, small serve)
    must still outrank a slow family: predictions read the pure
    admit->finish serve time from the lifecycle stamps."""
    from repro.serve.cluster.selector import AdaptiveSelector
    sel = AdaptiveSelector(epsilon=0.0, seed=0)
    # ac: terrible wall (queueing), fast serve; ichol: the reverse
    for _ in range(3):
        sel.observe("g", "ac", wall_s=2.0, serve_s=0.01,
                    construct_s=None)
        sel.observe("g", "ichol", wall_s=0.5, serve_s=0.4)
    assert sel.pick("g") == "ac"
    est = sel.stats()["estimates"]
    assert est["g::ac"]["serve_s"] == pytest.approx(0.01)
    assert est["g::ac"]["wall_s"] == pytest.approx(2.0)
    # construct EWMA only moves on cold-path samples
    sel.observe("g", "ac", wall_s=1.0, serve_s=0.01, construct_s=0.8)
    c0 = sel.stats()["estimates"]["g::ac"]["construct_s"]
    sel.observe("g", "ac", wall_s=1.0, serve_s=0.01)      # warm: no decay
    assert sel.stats()["estimates"]["g::ac"]["construct_s"] == c0
