"""Preconditioner zoo: every registered family (ac / ichol / amg /
spai) must serve through the same cache + engine lifecycle — SPD-
consistent applies, eviction/re-attach round trips, and engine serving
bit-exact with the handle's own direct solve."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.solver import (FactorCache, PRECOND_FAMILIES,
                               get_family, graph_fingerprint)
from repro.serve import SolveEngine, SolveRequest
from repro.data import graphs

FAMILIES = sorted(PRECOND_FAMILIES)


@pytest.fixture(scope="module")
def g():
    return graphs.grid2d(8, 8, seed=5)          # n = 64


@pytest.fixture(scope="module")
def key():
    return jax.random.key(7)


@pytest.fixture(scope="module")
def zoo(g, key):
    """One cache holding the same graph under all four families."""
    c = FactorCache(chunk=32, fill_slack=64, strict=False)
    handles = {fam: c.factor(g, key, graph_id=f"g::{fam}", family=fam)
               for fam in FAMILIES}
    return c, handles


def _rhs(rng, n, nrhs=1):
    b = rng.normal(size=(nrhs, n) if nrhs > 1 else n).astype(np.float32)
    return b - b.mean(axis=-1, keepdims=True)


def test_zoo_covers_expected_families():
    assert set(FAMILIES) >= {"ac", "ichol", "amg", "spai"}
    for fam in FAMILIES:
        assert get_family(fam).kind in ("factor", "spmv")


@pytest.mark.parametrize("fam", ["ac", "ichol", "amg", "spai"])
def test_family_apply_spd_consistent(zoo, g, fam):
    """Each family's preconditioned CG run is SPD-consistent: the
    relative residual decreases monotonically-enough to converge, and
    the returned iterate actually solves the grounded system."""
    _, handles = zoo
    h = handles[fam]
    rng = np.random.default_rng(17)
    b = _rhs(rng, g.n)
    res = h.solve(jnp.asarray(b[None]), tol=1e-6, maxiter=500)
    relres = float(np.max(np.asarray(res.relres)))
    assert relres <= 1e-5, f"{fam}: relres={relres}"
    # verify against the operator directly: r = b - L x for the plain
    # Laplacian the fleet matvec applies (the 1e-12 grounding the host
    # baselines factor is far below this tolerance)
    x = np.asarray(res.x)[0]
    Lx = np.zeros(g.n, np.float64)
    w = np.asarray(g.w, np.float64)
    src, dst = np.asarray(g.src), np.asarray(g.dst)
    np.add.at(Lx, src, w * (x[src] - x[dst]))
    np.add.at(Lx, dst, w * (x[dst] - x[src]))
    resid = np.linalg.norm(b - Lx) / np.linalg.norm(b)
    assert resid < 1e-4, f"{fam}: true residual {resid}"


@pytest.mark.parametrize("fam", ["ac", "ichol", "amg", "spai"])
def test_family_cache_evict_reattach_roundtrip(g, key, fam):
    """Evicting a family handle frees its fleet row; re-attaching the
    same payload admits a fresh handle that solves identically."""
    c = FactorCache(chunk=32, fill_slack=64, strict=False)
    h1 = c.factor(g, key, graph_id="gg", family=fam)
    rng = np.random.default_rng(23)
    b = jnp.asarray(_rhs(rng, g.n)[None])
    r1 = h1.solve(b, tol=1e-6, maxiter=500)
    payload = h1.factor
    c.evict("gg")
    assert not c.fresh("gg")
    h2 = c.attach(g, payload, graph_id="gg", family=fam)
    assert c.fresh("gg") and h2.family == fam
    r2 = h2.solve(b, tol=1e-6, maxiter=500)
    assert np.array_equal(np.asarray(r1.x), np.asarray(r2.x))
    assert np.array_equal(np.asarray(r1.iters), np.asarray(r2.iters))


def test_family_fingerprints_distinct(g, key):
    """Same graph under different families (or params) must occupy
    distinct cache rows — family and params are part of the identity."""
    fps = {graph_fingerprint(g, key if f == "ac" else None, family=f)
           for f in FAMILIES}
    assert len(fps) == len(FAMILIES)
    assert graph_fingerprint(g, family="ichol") != \
        graph_fingerprint(g, family="ichol", params={"droptol": 0.02})


def test_cache_accounts_memory_per_family(zoo):
    c, handles = zoo
    st = c.stats()
    by_fam = st["device_bytes_by_family"]
    assert set(by_fam) == set(FAMILIES)
    assert all(v > 0 for v in by_fam.values())
    assert sum(by_fam.values()) == st["device_bytes"]
    assert st["handles_by_family"] == {f: 1 for f in FAMILIES}


def test_engine_serves_every_family_bit_exact(zoo, g):
    """Acceptance: one engine serving all four families concurrently —
    each request reproduces its handle's direct solve bit-exactly, and
    lanes group per (family, shape-bucket): 4 buckets for one graph."""
    c, handles = zoo
    eng = SolveEngine(c, slots=4, iters_per_tick=8)
    rng = np.random.default_rng(29)
    reqs = [SolveRequest(rid=i, graph_id=f"g::{fam}",
                         b=_rhs(rng, g.n, nrhs=2), tol=1e-6, maxiter=500)
            for i, fam in enumerate(FAMILIES)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    assert len(done) == len(reqs)
    for r, fam in zip(reqs, FAMILIES):
        ref = handles[fam].solve(jnp.asarray(np.atleast_2d(r.b)),
                                 tol=r.tol, maxiter=r.maxiter)
        assert np.array_equal(np.atleast_2d(r.x), np.asarray(ref.x)), fam
        assert np.array_equal(np.atleast_1d(r.iters),
                              np.asarray(ref.iters)), fam
    st = eng.stats()
    assert st.buckets == len(FAMILIES)        # (family, n_pad) grouping
    assert st.families == len(FAMILIES)
    assert st.step_compiles == st.buckets
