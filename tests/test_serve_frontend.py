"""Async serving frontend + SLO-aware admission scheduling: policy
unit semantics (backfill, starvation bound), engine-level backfill and
deadline eviction, and the :class:`SolveFrontend` submit/await surface
(bit-exact with direct solves, backpressure, error futures)."""
import asyncio

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.solver import FactorCache
from repro.serve import (DeadlineAdmission, EngineOverloadedError,
                         FIFOAdmission, PriorityAdmission, SolveEngine,
                         SolveFrontend, SolveRequest, make_policy)
from repro.data import graphs


@pytest.fixture(scope="module")
def fleet():
    gs = {"g2d": graphs.grid2d(12, 12, seed=3),       # n = 144
          "pl": graphs.powerlaw(300, 5, seed=3),      # n = 300
          "road": graphs.road_like(10, seed=4)}       # n = 100
    keys = {name: jax.random.key(i) for i, name in enumerate(gs)}
    return gs, keys


@pytest.fixture(scope="module")
def cache(fleet):
    gs, keys = fleet
    c = FactorCache(chunk=32, fill_slack=64)
    c.factor_batched(list(gs.values()), [keys[k] for k in gs],
                     graph_ids=list(gs))
    return c


def _rhs(rng, n, nrhs):
    b = rng.normal(size=(nrhs, n) if nrhs > 1 else n).astype(np.float32)
    return b - b.mean(axis=-1, keepdims=True)


def _fake(rid, nrhs, *, seq, priority=0, skips=0):
    """Policy-only request: admission reads nrhs/priority/_seq/skips."""
    r = SolveRequest(rid=rid, graph_id="x", b=np.zeros((nrhs, 4)),
                     priority=priority)
    r._seq = seq
    r.sched_skips = skips
    return r


# ---------------------------------------------------------------------------
# Admission policies: pure unit semantics (no engine, no device)
# ---------------------------------------------------------------------------

def test_fifo_is_head_of_line_blocking():
    p = FIFOAdmission()
    wide = _fake(0, 4, seq=0)
    narrow = _fake(1, 1, seq=1)
    assert p.select([wide, narrow], 2, now=0.0) == []   # head blocks all
    assert narrow.sched_skips == 0 and p.backfill_skips == 0
    assert p.select([wide, narrow], 5, now=0.0) == [wide, narrow]
    assert p.max_skips == 0                              # FIFO never skips


def test_priority_orders_classes_before_arrival():
    p = PriorityAdmission(max_skips=4)
    late_urgent = _fake(0, 1, seq=5, priority=0)
    early_lazy = _fake(1, 1, seq=1, priority=5)
    assert p.select([early_lazy, late_urgent], 2, now=0.0) == \
        [late_urgent, early_lazy]


def test_backfill_skips_blocked_head_and_counts():
    p = PriorityAdmission(max_skips=3)
    wide = _fake(0, 4, seq=0)
    n1, n2 = _fake(1, 1, seq=1), _fake(2, 1, seq=2)
    take = p.select([wide, n1, n2], 2, now=0.0)
    assert take == [n1, n2]                 # backfilled past the wide head
    assert wide.sched_skips == 1            # one skip *round*, not per req
    assert p.backfill_skips == 1 and p.skipped_reqs == 1


def test_starvation_bound_seals_queue_at_max_skips():
    p = PriorityAdmission(max_skips=2)
    wide = _fake(0, 4, seq=0)
    rounds_with_backfill = 0
    for i in range(6):                       # endless narrow stream
        narrow = _fake(10 + i, 1, seq=10 + i)
        if p.select([wide, narrow], 2, now=0.0):
            rounds_with_backfill += 1
    # once the bound is hit the wide head seals the queue: free lanes or
    # not, nothing behind it admits
    assert rounds_with_backfill == 2 == wide.sched_skips == p.max_skips
    assert p.backfill_skips <= p.max_skips * p.skipped_reqs
    assert p.barrier_rounds == 4
    # ...until it fits: the wide admits and the seal lifts
    assert p.select([wide, _fake(99, 1, seq=99)], 4, now=0.0)[0] is wide


def test_deadline_policy_orders_edf():
    p = DeadlineAdmission(max_skips=2)
    assert p.evict_hopeless
    no_dl = _fake(0, 1, seq=0)
    soon = _fake(1, 1, seq=1)
    soon._deadline_abs = 5.0
    later = _fake(2, 1, seq=2)
    later._deadline_abs = 50.0
    assert p.select([no_dl, later, soon], 3, now=0.0) == \
        [soon, later, no_dl]


def test_make_policy_names():
    assert isinstance(make_policy("fifo"), FIFOAdmission)
    assert make_policy("priority", max_skips=7).max_skips == 7
    assert make_policy("deadline").name == "deadline"
    with pytest.raises(ValueError):
        make_policy("lifo")


# ---------------------------------------------------------------------------
# Engine-level backfill: wide blocked head, bounded skip, throughput
# ---------------------------------------------------------------------------

def _wide_head_reqs(n, rng, *, slots, narrows, maxiter_blocker=64):
    blocker = SolveRequest(rid=0, graph_id="road", b=_rhs(rng, n, 1),
                           tol=1e-30, maxiter=maxiter_blocker)
    wide = SolveRequest(rid=1, graph_id="road", b=_rhs(rng, n, slots),
                        tol=1e-4, maxiter=300)
    ns = [SolveRequest(rid=2 + i, graph_id="road", b=_rhs(rng, n, 1),
                       tol=1e-3, maxiter=300) for i in range(narrows)]
    return blocker, wide, ns


def test_engine_backfill_beats_fifo_and_respects_bound(fleet, cache):
    """Acceptance: a wide blocked head + narrow stream shows backfill
    throughput (narrow requests retire while FIFO would park them), the
    wide request still completes within its bounded wait, and the
    scheduler counters satisfy the starvation-bound invariant."""
    gs, _ = fleet
    n = gs["road"].n
    ticks_narrow = {}
    for policy in ("fifo", "priority"):
        rng = np.random.default_rng(21)            # identical rhs content
        eng = SolveEngine(cache, slots=3, iters_per_tick=8,
                          admission=make_policy(policy, max_skips=8))
        blocker, wide, ns = _wide_head_reqs(n, rng, slots=3, narrows=4)
        for r in (blocker, wide, *ns):
            eng.submit(r)
        done = eng.run_until_drained()
        assert len(done) == 6
        st = eng.stats()
        assert st.admitted_reqs == st.completed == 6
        assert st.in_flight_reqs == 0 and st.queued == 0
        assert st.backfill_skips <= st.max_skips * max(st.skipped_reqs, 0)
        ticks_narrow[policy] = [r.finish_tick for r in ns]
        if policy == "fifo":
            assert st.backfill_skips == 0 and st.max_skips == 0
            # head-of-line: every narrow waits for the wide
            assert all(t > wide.admit_tick for t in ticks_narrow["fifo"])
        else:
            assert st.backfill_skips > 0
            assert wide.sched_skips <= st.max_skips
            # backfill throughput: narrows retire before the wide even
            # admits (they rode the free lanes behind the blocked head)
            assert all(t < wide.admit_tick
                       for t in ticks_narrow["priority"])
            assert wide.converged          # bounded wait: it still ran
    assert max(ticks_narrow["priority"]) < min(ticks_narrow["fifo"])


def test_engine_starvation_bound_admits_wide_after_max_skips(fleet, cache):
    """With ``max_skips=1`` exactly one backfill round passes the wide
    head; after that the queue is sealed — later narrows admit only
    once the wide request has its lanes."""
    gs, _ = fleet
    n = gs["road"].n
    rng = np.random.default_rng(33)
    eng = SolveEngine(cache, slots=3, iters_per_tick=8,
                      admission=make_policy("priority", max_skips=1))
    blocker, wide, ns = _wide_head_reqs(n, rng, slots=3, narrows=4)
    for r in (blocker, wide, *ns):
        eng.submit(r)
    done = eng.run_until_drained()
    assert len(done) == 6
    assert wide.sched_skips == 1                    # the bound, exactly
    early = [r for r in ns if r.admit_tick < wide.admit_tick]
    late = [r for r in ns if r.admit_tick >= wide.admit_tick]
    # one round of backfill fits two narrows (3 slots - blocker's lane)
    assert len(early) == 2 and len(late) == 2
    st = eng.stats()
    assert st.backfill_skips == 1 and st.skipped_reqs == 1
    assert st.barrier_rounds > 0                    # the seal was real


# ---------------------------------------------------------------------------
# Deadline eviction: hopeless lanes free their slots
# ---------------------------------------------------------------------------

def test_deadline_eviction_frees_slot_and_reports_missed(fleet, cache):
    """A lane that cannot meet its deadline retires early with
    ``deadline_missed`` (partial iterate returned, slot freed for the
    next request) — driven by an injected clock, no wall time."""
    gs, _ = fleet
    n = gs["road"].n
    now = [0.0]
    eng = SolveEngine(cache, slots=1, iters_per_tick=4,
                      admission=make_policy("deadline"),
                      clock=lambda: now[0])
    rng = np.random.default_rng(41)
    hopeless = SolveRequest(rid=0, graph_id="road", b=_rhs(rng, n, 1),
                            tol=1e-30, maxiter=10_000, deadline_s=5.0)
    follower = SolveRequest(rid=1, graph_id="road", b=_rhs(rng, n, 1),
                            tol=1e-3, maxiter=300)
    eng.submit(hopeless)
    eng.submit(follower)
    done = eng.tick()                   # admits + steps; deadline still ok
    assert done == [] and not hopeless._evicted
    now[0] = 6.0                        # past the 5s deadline
    done = eng.tick()                   # hopeless evicted, slot freed
    assert done == [hopeless]
    assert hopeless.status == "deadline_missed"
    assert not hopeless.converged and hopeless.x is not None
    assert int(hopeless.iters[0]) < 10_000      # retired early, not maxiter
    assert eng.deadline_evictions == 1
    done = eng.run_until_drained()
    assert done == [follower] and follower.status == "converged"
    st = eng.stats()
    assert st.deadline_evictions == 1
    assert st.admitted_reqs == st.completed == 2


def test_deadline_met_keeps_converged_status(fleet, cache):
    gs, _ = fleet
    n = gs["road"].n
    eng = SolveEngine(cache, slots=2, iters_per_tick=8,
                      admission=make_policy("deadline"))
    rng = np.random.default_rng(43)
    req = SolveRequest(rid=0, graph_id="road", b=_rhs(rng, n, 1),
                       tol=1e-4, maxiter=300, deadline_s=600.0)
    eng.submit(req)
    done = eng.run_until_drained()
    assert done == [req] and req.status == "converged" and req.converged
    assert eng.deadline_evictions == 0


def test_maxiter_without_deadline_reports_maxiter(fleet, cache):
    gs, _ = fleet
    n = gs["road"].n
    eng = SolveEngine(cache, slots=1, iters_per_tick=8)
    rng = np.random.default_rng(44)
    req = SolveRequest(rid=0, graph_id="road", b=_rhs(rng, n, 1),
                       tol=1e-30, maxiter=16)
    eng.submit(req)
    done = eng.run_until_drained()
    assert done == [req] and req.status == "maxiter"
    assert not req.converged and int(req.iters[0]) == 16


# ---------------------------------------------------------------------------
# SolveFrontend: async submit/await, bit-exactness, backpressure, errors
# ---------------------------------------------------------------------------

def test_frontend_async_bit_exact_vs_direct(fleet, cache):
    """Acceptance: the mixed 3-graph trace served through the async
    frontend (futures resolved by the background driver thread) is
    **bit-exact** with direct ``FactorHandle.solve`` — x, iters and
    relres — exactly like the synchronous engine path."""
    gs, _ = fleet
    rng = np.random.default_rng(11)
    spec = [("g2d", 1, 1e-6), ("pl", 2, 1e-5), ("road", 1, 1e-6),
            ("g2d", 3, 1e-6), ("pl", 1, 1e-6), ("road", 2, 1e-5),
            ("g2d", 1, 1e-4), ("pl", 2, 1e-6)]
    blocks = [(gid, _rhs(rng, gs[gid].n, nr), tol)
              for gid, nr, tol in spec]
    eng = SolveEngine(cache, slots=6, iters_per_tick=8)

    async def drive(fe):
        return await asyncio.gather(*[
            fe.solve(gid, b, tol=tol, maxiter=500)
            for gid, b, tol in blocks])

    with SolveFrontend(eng, max_queue=64) as fe:
        results = asyncio.run(drive(fe))
        fs = fe.stats()
    assert fs.submitted == fs.completed == len(spec)
    assert fs.failed == 0 and fs.rejected == 0
    for (gid, b, tol), req in zip(blocks, results):
        assert req.status == "converged"
        ref = cache.get(gid).solve(jnp.asarray(np.atleast_2d(b)),
                                   tol=tol, maxiter=500)
        assert np.array_equal(np.atleast_2d(req.x), np.asarray(ref.x))
        assert np.array_equal(np.atleast_1d(req.iters),
                              np.asarray(ref.iters))
        assert np.array_equal(np.atleast_1d(req.relres),
                              np.atleast_1d(np.asarray(ref.relres)))
    st = eng.stats()
    assert st.admitted_reqs == st.completed == len(spec)
    assert st.cols_in == st.cols_out == sum(nr for _, nr, _ in spec)


def test_frontend_error_futures(fleet, cache):
    gs, _ = fleet
    eng = SolveEngine(cache, slots=2)
    with SolveFrontend(eng) as fe:
        bad_graph = fe.submit("nope", np.zeros(4, np.float32))
        with pytest.raises(KeyError):
            bad_graph.result(timeout=30)
        bad_shape = fe.submit("road", np.zeros(7, np.float32))
        with pytest.raises(ValueError):
            bad_shape.result(timeout=30)
        fs = fe.stats()
        assert fs.failed == 2 and fs.completed == 0


def test_frontend_backpressure_rejects_when_full(fleet, cache):
    """Bounded queue + reject policy: once ingress + engine queue hold
    ``max_queue`` waiting requests, submit raises
    ``EngineOverloadedError`` instead of growing without bound."""
    gs, _ = fleet
    n = gs["road"].n
    rng = np.random.default_rng(51)
    eng = SolveEngine(cache, slots=1, iters_per_tick=4)
    fe = SolveFrontend(eng, max_queue=2, overload="reject")
    try:
        futs = [fe.submit("road", _rhs(rng, n, 1), tol=1e-30, maxiter=64)]
        rejected = 0
        for _ in range(8):
            try:
                futs.append(fe.submit("road", _rhs(rng, n, 1), tol=1e-3,
                                      maxiter=300))
            except EngineOverloadedError:
                rejected += 1
        assert rejected >= 1                 # the bound actually bites
        assert fe.stats().rejected == rejected
        for f in futs:
            assert f.result(timeout=120).x is not None
    finally:
        fe.close()
    assert fe.stats().queue_depth == 0


def test_frontend_close_rejects_new_submits(fleet, cache):
    eng = SolveEngine(cache, slots=2)
    fe = SolveFrontend(eng)
    fe.close()
    with pytest.raises(RuntimeError):
        fe.submit("road", np.zeros(4, np.float32))


def test_frontend_close_drain_resolves_in_flight(fleet, cache):
    """close(drain=True) with queued + in-flight work: every future
    resolves with its result before the driver stops."""
    gs, _ = fleet
    n = gs["road"].n
    rng = np.random.default_rng(61)
    eng = SolveEngine(cache, slots=2, iters_per_tick=4)
    fe = SolveFrontend(eng, max_queue=64)
    futs = [fe.submit("road", _rhs(rng, n, 1), tol=1e-3, maxiter=300)
            for _ in range(5)]
    fe.close(drain=True, timeout=300)
    for f in futs:
        assert f.done()
        assert f.result(timeout=0).status == "converged"
    fs = fe.stats()
    assert fs.completed == 5 and fs.failed == 0
    assert not fe.alive


def test_frontend_close_nodrain_fails_in_flight_deterministically(
        fleet, cache):
    """close(drain=False) with an admitted lane and queued work: every
    unresolved future fails with RuntimeError promptly — resolved or
    failed, never hanging."""
    gs, _ = fleet
    n = gs["road"].n
    rng = np.random.default_rng(62)
    eng = SolveEngine(cache, slots=1, iters_per_tick=4)
    fe = SolveFrontend(eng, max_queue=64)
    blocker = fe.submit("road", _rhs(rng, n, 1), tol=1e-30, maxiter=40_000)
    queued = [fe.submit("road", _rhs(rng, n, 1), tol=1e-3, maxiter=300)
              for _ in range(3)]
    # wait until the blocker actually holds the lane (it is in flight,
    # not just queued) so the abandon path is exercised for both states
    import time
    for _ in range(600):
        if eng.stats().in_flight_reqs >= 1:
            break
        time.sleep(0.01)
    fe.close(drain=False)
    for f in (blocker, *queued):
        with pytest.raises(RuntimeError):
            f.result(timeout=30)       # resolves exceptionally, no hang
    fs = fe.stats()
    assert fs.submitted == 4 and fs.completed + fs.failed == 4


def test_frontend_call_runs_on_driver_thread(fleet, cache):
    """The control channel runs callables on the driver thread (the
    engine/cache owner), resolves their results and exceptions, and
    refuses after close."""
    import threading
    eng = SolveEngine(cache, slots=2)
    with SolveFrontend(eng) as fe:
        ident = fe.call(lambda: threading.current_thread().name)
        assert ident.result(timeout=30) == "solve-frontend"

        def boom():
            raise ValueError("nope")
        bad = fe.call(boom)
        with pytest.raises(ValueError):
            bad.result(timeout=30)
        assert fe.alive                    # fn exceptions never kill it
        assert fe.call(lambda: 42).result(timeout=30) == 42
    with pytest.raises(RuntimeError):
        fe.call(lambda: 0)


def test_frontend_driver_crash_fails_futures_not_hangs(fleet, cache):
    """An engine exception outside per-request validation kills the
    driver loop: pending futures fail with the crash recorded, `alive`
    flips False (the cluster router's ejection signal), and new submits
    are refused — nothing blackholes."""
    gs, _ = fleet
    n = gs["road"].n
    rng = np.random.default_rng(63)
    eng = SolveEngine(cache, slots=1, iters_per_tick=4)
    fe = SolveFrontend(eng, max_queue=16)
    fut = fe.submit("road", _rhs(rng, n, 1), tol=1e-30, maxiter=40_000)
    eng._step_fn = None                    # wedge the engine mid-flight
    with pytest.raises(RuntimeError, match="driver crashed"):
        fut.result(timeout=60)
    assert not fe.alive and fe.driver_error is not None
    with pytest.raises(RuntimeError):
        fe.submit("road", _rhs(rng, n, 1))
    fe.close(drain=False)                  # idempotent on a dead driver


# ---------------------------------------------------------------------------
# Work-conserving backfill under seal
# ---------------------------------------------------------------------------

def test_seal_backfill_admits_only_provably_short(fleet, cache):
    """Policy unit: a sealed queue still admits candidates whose
    worst-case tick count fits under the sealer's wait bound, and only
    those; sealed admissions never touch the skip counters."""
    p = PriorityAdmission(max_skips=1)
    wide = _fake(0, 3, seq=0, skips=1)          # already at its bound
    short = _fake(1, 1, seq=1)
    short.maxiter = 16                          # 2 ticks at ipt=8
    long_ = _fake(2, 1, seq=2)
    long_.maxiter = 300                         # 38 ticks
    take = p.select([wide, short, long_], 2, now=0.0,
                    busy_bounds=(10,), iters_per_tick=8)
    # wide needs 3 lanes, 2 free -> waits on the 1 busy lane (<= 10
    # ticks); short (2) fits under that bound, long (38) does not
    assert take == [short]
    assert p.sealed_backfills == 1
    assert p.backfill_skips == 0 and wide.sched_skips == 1  # untouched
    assert p.barrier_rounds == 1


def test_seal_backfill_disabled_and_unprovable(fleet, cache):
    p = PriorityAdmission(max_skips=1, work_conserving=False)
    wide = _fake(0, 3, seq=0, skips=1)
    short = _fake(1, 1, seq=1)
    short.maxiter = 8
    assert p.select([wide, short], 2, now=0.0, busy_bounds=(10,),
                    iters_per_tick=8) == []
    assert p.sealed_backfills == 0
    # enabled but no busy-lane bounds -> nothing is provable -> seal holds
    p2 = PriorityAdmission(max_skips=1)
    assert p2.select([wide, short], 2, now=0.0) == []
    assert p2.sealed_backfills == 0


def test_engine_seal_backfill_work_conserving(fleet, cache):
    """Engine acceptance: under a sealed wide head, a provably-short
    narrow request rides a free lane and retires before the wide even
    admits; an unprovable one waits.  The starvation-bound invariant
    holds throughout and `sealed_backfills` surfaces in EngineStats."""
    gs, _ = fleet
    n = gs["road"].n
    rng = np.random.default_rng(64)
    eng = SolveEngine(cache, slots=3, iters_per_tick=8,
                      admission=make_policy("priority", max_skips=1))
    # blocker holds one lane for exactly 20 ticks (160/8); two easy
    # narrows ride the first backfill round, sealing the wide
    blocker = SolveRequest(rid=0, graph_id="road", b=_rhs(rng, n, 1),
                           tol=1e-30, maxiter=160)
    wide = SolveRequest(rid=1, graph_id="road", b=_rhs(rng, n, 3),
                        tol=1e-4, maxiter=300)
    n1 = SolveRequest(rid=2, graph_id="road", b=_rhs(rng, n, 1),
                      tol=1e-3, maxiter=300)
    n2 = SolveRequest(rid=3, graph_id="road", b=_rhs(rng, n, 1),
                      tol=1e-3, maxiter=300)
    for r in (blocker, wide, n1, n2):
        eng.submit(r)
    done = []
    done += eng.tick()
    done += eng.tick()                         # n1/n2 may retire here
    assert wide.sched_skips == 1               # sealed from here on
    # short candidate: 16 iters = 2 ticks << blocker's remaining bound;
    # long candidate: 38 ticks, not provable -> waits for the wide
    short = SolveRequest(rid=4, graph_id="road", b=_rhs(rng, n, 1),
                         tol=1e-30, maxiter=16)
    long_ = SolveRequest(rid=5, graph_id="road", b=_rhs(rng, n, 1),
                         tol=1e-3, maxiter=300)
    eng.submit(short)
    eng.submit(long_)
    done += eng.run_until_drained()
    assert len(done) == 6
    st = eng.stats()
    assert st.sealed_backfills >= 1
    assert short.finish_tick < wide.admit_tick  # rode a sealed-idle lane
    assert long_.admit_tick >= wide.admit_tick  # bound not provable
    assert wide.converged
    assert st.backfill_skips <= st.max_skips * max(st.skipped_reqs, 0)
    assert wide.sched_skips <= st.max_skips
